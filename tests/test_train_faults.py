"""Training chaos suite (DESIGN.md §4): seeded faults, bit-exact invariants.

The training counterpart of tests/test_serve_faults.py.  Everything here is
driven by :class:`repro.train.faults.TrainFaultPlan` — seeded, step-keyed,
zero wall clock — through the shared crash-safe loop
(:func:`repro.train.loop.run_loop`), and the two §4 training invariants are
asserted **bit-exactly** (``np.testing.assert_array_equal``, never allclose):

* resume-after-crash reproduces the uninterrupted run's loss trajectory and
  final params (step-addressed data + deterministic jitted step);
* a poisoned step (NaN loss / overflow spike) leaves params and opt_state
  bit-identical (the fused guard's ``where``-select skip path).

Runs on 1 device normally; ci.sh reruns the whole file on 8 fake devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) where the
mesh-gated tests additionally shard the conv stack over ``("data","model")``.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ft
from repro.ckpt import checkpoint as ckpt
from repro.configs.alexnet_conv import CNNConfig
from repro.core.conv import Conv2D
from repro.data.pipeline import DataConfig, retry_io, synthetic_image_batch
from repro.models import cnn
from repro.train import optimizer as opt
from repro.train import step as step_mod
from repro.train.faults import SimulatedCrash, TrainFaultPlan, TrainFaultSpec
from repro.train.loop import NonFiniteEscalation, run_loop

# ---------------------------------------------------------------------------
# tiny QAT stack: one conv layer, 8×8 images — real STE path, fast jit
# ---------------------------------------------------------------------------

TINY = CNNConfig(
    name="tiny-qat",
    in_chw=(1, 8, 8),
    layers=(Conv2D(k=3, c_in=1, c_out=4, stride=1, relu=True),),
    pools=(2,),
    classes=4,
    bins=4,
)
OCFG = opt.AdamWConfig(lr=1e-2, total_steps=64, warmup_steps=1)
DCFG = DataConfig(seed=0, vocab=2, seq_len=1, global_batch=4)


def batch_fn(step: int) -> dict:
    return synthetic_image_batch(DCFG, step, chw=TINY.in_chw, classes=TINY.classes)


def fresh_state():
    params = cnn.init_params(TINY, jax.random.PRNGKey(0))
    tree = {"params": params, "codebooks": cnn.qat_codebooks(params, TINY)}
    return tree, opt.init_opt_state(tree)


@pytest.fixture(scope="module")
def tiny_step():
    return jax.jit(step_mod.make_cnn_train_step(TINY, OCFG))


def assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# the fused guard: skip is bit-identical, escalation after K
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("poison", ["nan", "spike"])
def test_guard_skips_poisoned_step_bit_identical(tiny_step, poison):
    tree, opt_state = fresh_state()
    scale = float("nan") if poison == "nan" else TrainFaultSpec("grad_spike").scale
    batch = dict(batch_fn(0), loss_scale=jnp.float32(scale))
    new_tree, new_opt, metrics = tiny_step(tree, opt_state, batch)
    assert int(metrics["skipped"]) == 1
    assert not np.isfinite(float(metrics["loss"]))
    assert_trees_equal(new_tree, tree)
    assert_trees_equal(new_opt, opt_state)
    assert int(new_opt.step) == int(opt_state.step)  # counter did not advance


def test_clean_step_updates_and_reports_not_skipped(tiny_step):
    tree, opt_state = fresh_state()
    new_tree, new_opt, metrics = tiny_step(tree, opt_state, batch_fn(0))
    assert int(metrics["skipped"]) == 0
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt.step) == 1
    before = jax.tree.leaves(tree)
    after = jax.tree.leaves(new_tree)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(before, after))


def test_guard_off_applies_poisoned_update():
    step_fn = jax.jit(
        step_mod.make_cnn_train_step(TINY, OCFG, guard_nonfinite=False)
    )
    tree, opt_state = fresh_state()
    batch = dict(batch_fn(0), loss_scale=jnp.float32(float("nan")))
    new_tree, _, metrics = step_fn(tree, opt_state, batch)
    assert int(metrics["skipped"]) == 0
    # without the guard the NaN propagates into the masters
    leaves = jax.tree.leaves(new_tree["params"])
    assert any(np.isnan(np.asarray(x)).any() for x in leaves)


def test_lm_train_step_guard_skips_nan():
    from repro.configs import get_config
    from repro.models import api
    from repro.models.common import ShardCtx

    cfg = get_config("qwen3-32b", smoke=True)
    params = api.get_model(cfg).init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt.init_opt_state(params)
    step_fn = jax.jit(step_mod.make_train_step(cfg, OCFG, ShardCtx()))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "loss_scale": jnp.float32(float("nan"))}
    new_p, new_s, metrics = step_fn(params, opt_state, batch)
    assert int(metrics["skipped"]) == 1
    assert_trees_equal(new_p, params)
    assert_trees_equal(new_s, opt_state)


def test_escalates_after_k_consecutive_nonfinite(tiny_step):
    plan = TrainFaultPlan([TrainFaultSpec("nan_loss", step=s) for s in (2, 3, 4)])
    with pytest.raises(NonFiniteEscalation) as ei:
        run_loop(tiny_step, fresh_state(), batch_fn, steps=10, faults=plan,
                 max_consecutive_nonfinite=3)
    assert ei.value.step == 4
    assert ei.value.n_consecutive == 3
    assert isinstance(ei.value, ft.RestorableError)


def test_nonconsecutive_skips_do_not_escalate(tiny_step):
    plan = TrainFaultPlan([TrainFaultSpec("nan_loss", step=s) for s in (1, 3, 5)])
    res = run_loop(tiny_step, fresh_state(), batch_fn, steps=7, faults=plan,
                   max_consecutive_nonfinite=3)
    assert res.n_skipped == 3
    assert res.last_step == 7


def test_poisoned_step_loop_level_bit_identity(tiny_step):
    """N steps with the last poisoned ≡ N-1 clean steps, bit-for-bit."""
    n = 5
    clean = run_loop(tiny_step, fresh_state(), batch_fn, steps=n - 1)
    plan = TrainFaultPlan([TrainFaultSpec("nan_loss", step=n - 1)])
    poisoned = run_loop(tiny_step, fresh_state(), batch_fn, steps=n, faults=plan)
    assert poisoned.n_skipped == 1
    assert not np.isfinite(poisoned.losses[n - 1])
    assert_trees_equal(poisoned.state, clean.state)


# ---------------------------------------------------------------------------
# crash + restore: bit-exact resume under the supervisor
# ---------------------------------------------------------------------------


def _supervised_run(step_fn, plan, tmp, *, steps, ckpt_every, max_restarts=3):
    """launch/train.py's loop shape in miniature; returns merged history."""
    mgr = ckpt.CheckpointManager(tmp, keep=3)
    losses: dict = {}
    times: dict = {}
    box = {"state": fresh_state(), "resumed_at": []}
    sup = ft.Supervisor(ft.RestartPolicy(max_restarts=max_restarts, backoff_s=0.0),
                        sleep=lambda _d: None)

    def loop(resume_step):
        t, o = box["state"]
        start = 0
        if ckpt.latest_step(mgr.dir) is not None:
            (t, o), man = mgr.restore_latest((t, o))
            start = man["step"]
            box["resumed_at"].append(start)
        res = run_loop(step_fn, (t, o), batch_fn, steps=steps, start_step=start,
                       mgr=mgr, ckpt_every=ckpt_every, faults=plan,
                       losses=losses, step_times=times)
        box["state"] = res.state
        return res.last_step

    last = sup.run(loop)
    return last, box, losses, sup, mgr


@pytest.mark.parametrize("seed", [1, 7])
def test_resume_after_crash_bit_exact(tiny_step, tmp_path, seed):
    steps = 8
    ref = run_loop(tiny_step, fresh_state(), batch_fn, steps=steps)
    # crash-only sampled plan: trajectory-preserving by construction
    plan = TrainFaultPlan.sample(seed, n_steps=steps, n_nan=0, n_spike=0,
                                 n_ckpt_io=0, n_data_io=0, n_crash=1)
    assert plan.trajectory_preserving
    last, box, losses, sup, _ = _supervised_run(
        tiny_step, plan, tmp_path, steps=steps, ckpt_every=2
    )
    assert last == steps
    assert sup.restarts == 1
    assert [f[0] for f in plan.fired] == ["crash"]
    assert set(losses) == set(ref.losses)
    np.testing.assert_array_equal(
        np.asarray([losses[s] for s in range(steps)]),
        np.asarray([ref.losses[s] for s in range(steps)]),
    )
    assert_trees_equal(box["state"], ref.state)


def test_resume_restores_older_checkpoint_and_recomputes(tiny_step, tmp_path):
    # crash at 5: newest checkpoint is step 4 — steps 4 must be recomputed
    plan = TrainFaultPlan([TrainFaultSpec("crash", step=5)])
    last, box, losses, sup, _ = _supervised_run(
        tiny_step, plan, tmp_path, steps=8, ckpt_every=2
    )
    assert last == 8
    assert box["resumed_at"] == [4]


def test_sampled_chaos_plan_completes_under_supervisor(tiny_step, tmp_path):
    """The full fault menu at once: the run must still reach the last step."""
    plan = TrainFaultPlan.sample(3, n_steps=10, n_slow=1, slow_delay_s=0.5)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        last, box, losses, sup, _ = _supervised_run(
            tiny_step, plan, tmp_path, steps=10, ckpt_every=2
        )
    assert last == 10
    # crash/data_io/slow key on steps that are always visited; ckpt_io only
    # fires when its sampled step is a save boundary, nan+spike can merge
    assert {"crash", "data_io", "slow"} <= {f[0] for f in plan.fired}
    assert set(losses) == set(range(10))


# ---------------------------------------------------------------------------
# checkpoint integrity: CRC detection, fallback, gc-vs-inflight
# ---------------------------------------------------------------------------


def _flip_byte(path, offset_frac=0.5):
    raw = bytearray(path.read_bytes())
    raw[int(len(raw) * offset_frac)] ^= 0xFF
    path.write_bytes(bytes(raw))


def test_crc_verify_detects_byte_flip(tmp_path):
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(tmp_path, 1, tree)
    _flip_byte(tmp_path / "step_1" / "shard_0.npz")
    with pytest.raises(ckpt.CheckpointCorruptError):
        ckpt.restore(tmp_path, tree, step=1)


@pytest.mark.parametrize("corruption", ["byte_flip", "truncate"])
def test_fallback_to_newest_valid_checkpoint(tmp_path, corruption):
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(tmp_path, 1, jax.tree.map(lambda x: x + 1, tree))
    ckpt.save(tmp_path, 2, jax.tree.map(lambda x: x + 2, tree))
    shard = tmp_path / "step_2" / "shard_0.npz"
    if corruption == "byte_flip":
        _flip_byte(shard)
    else:
        shard.write_bytes(shard.read_bytes()[: len(shard.read_bytes()) // 2])
    with pytest.warns(RuntimeWarning, match="failed integrity"):
        restored, man = ckpt.restore(tmp_path, tree, fallback=True)
    assert man["step"] == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]) + 1)
    # without fallback the corruption surfaces
    with pytest.raises(ckpt.CheckpointCorruptError):
        ckpt.restore(tmp_path, tree)


def test_fallback_scans_past_multiple_corrupt_steps(tmp_path):
    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    for s in (1, 2, 3):
        ckpt.save(tmp_path, s, jax.tree.map(lambda x, s=s: x + s, tree))
    _flip_byte(tmp_path / "step_3" / "shard_0.npz")
    _flip_byte(tmp_path / "step_2" / "shard_0.npz")
    with pytest.warns(RuntimeWarning):
        restored, man = ckpt.restore(tmp_path, tree, fallback=True)
    assert man["step"] == 1


def test_all_corrupt_raises_corrupt_error(tmp_path):
    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    for s in (1, 2):
        ckpt.save(tmp_path, s, tree)
        _flip_byte(tmp_path / f"step_{s}" / "shard_0.npz")
    with pytest.warns(RuntimeWarning):
        with pytest.raises(ckpt.CheckpointCorruptError):
            ckpt.restore(tmp_path, tree, fallback=True)


def test_manager_restore_latest_falls_back(tmp_path):
    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    mgr = ckpt.CheckpointManager(tmp_path, keep=3)
    mgr.save(1, tree)
    mgr.save(2, jax.tree.map(lambda x: x * 2, tree))
    mgr.wait()
    _flip_byte(tmp_path / "step_2" / "shard_0.npz")
    with pytest.warns(RuntimeWarning):
        restored, man = mgr.restore_latest(tree)
    assert man["step"] == 1


def test_ckpt_io_fault_warns_counts_and_training_continues(tiny_step, tmp_path):
    plan = TrainFaultPlan([TrainFaultSpec("ckpt_io", step=2)])
    with pytest.warns(RuntimeWarning, match="checkpoint save"):
        res = run_loop(tiny_step, fresh_state(), batch_fn, steps=6,
                       faults=plan, mgr=ckpt.CheckpointManager(tmp_path, keep=3),
                       ckpt_every=2)
    assert res.last_step == 6
    assert res.n_ckpt_failures == 1
    # the failed interval's save is missing; later intervals landed
    assert ckpt.complete_steps(tmp_path) == [4, 6]


# ---------------------------------------------------------------------------
# data faults: retry absorption, exhaustion
# ---------------------------------------------------------------------------


def test_data_io_fault_absorbed_by_retry(tiny_step):
    plan = TrainFaultPlan([TrainFaultSpec("data_io", step=1)])
    with pytest.warns(RuntimeWarning, match="transient I/O"):
        res = run_loop(tiny_step, fresh_state(), batch_fn, steps=3,
                       faults=plan, io_sleep=lambda _d: None)
    assert res.last_step == 3
    assert plan.fired == [("data_io", 1, 1)]


def test_data_io_fault_exhausts_retries(tiny_step):
    # every attempt at step 1 fails (nth 1..5 > retries+1 attempts)
    plan = TrainFaultPlan(
        [TrainFaultSpec("data_io", step=1, nth=n) for n in range(1, 6)]
    )
    with pytest.warns(RuntimeWarning):
        with pytest.raises(OSError):
            run_loop(tiny_step, fresh_state(), batch_fn, steps=3,
                     faults=plan, data_retries=2, io_sleep=lambda _d: None)


def test_retry_io_backoff_schedule_capped():
    delays = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 5:
            raise OSError("flake")
        return "ok"

    with pytest.warns(RuntimeWarning):
        out = retry_io(flaky, retries=4, backoff_s=0.1, cap_s=0.25,
                       sleep=delays.append)
    assert out == "ok"
    assert delays == [0.1, 0.2, 0.25, 0.25]  # doubling, then capped


# ---------------------------------------------------------------------------
# slow faults + straggler detector; supervisor classification
# ---------------------------------------------------------------------------


def test_slow_fault_inflates_recorded_step_time_every_step(tiny_step):
    plan = TrainFaultPlan([TrainFaultSpec("slow", step=2, delay_s=100.0)])
    det = ft.StragglerDetector(n_hosts=1, window=8)
    res = run_loop(tiny_step, fresh_state(), batch_fn, steps=4,
                   faults=plan, detector=det)
    assert res.step_times[2] > 100.0  # virtual stall, zero wall clock
    assert len(det._times[0]) == 4  # recorded EVERY step, not just log steps


def test_supervisor_deterministic_same_step_fails_fast():
    calls = {"n": 0}

    def loop(resume_step):
        calls["n"] += 1
        raise SimulatedCrash(7)  # same step, same type, every attempt

    sup = ft.Supervisor(ft.RestartPolicy(max_restarts=5, backoff_s=0.0),
                        sleep=lambda _d: None)
    with pytest.raises(ft.DeterministicFailure):
        sup.run(loop)
    assert calls["n"] == 2  # one restart burned, then fail-fast
    assert sup.classified[-1] == (("SimulatedCrash", 7), "deterministic")


def test_supervisor_transient_different_steps_keep_restarting():
    calls = {"n": 0}

    def loop(resume_step):
        calls["n"] += 1
        if calls["n"] <= 3:
            raise SimulatedCrash(calls["n"])  # a different step each time
        return 42

    sup = ft.Supervisor(ft.RestartPolicy(max_restarts=5, backoff_s=0.0),
                        sleep=lambda _d: None)
    assert sup.run(loop) == 42
    assert sup.restarts == 3


def test_supervisor_threads_resume_step():
    seen = []

    def loop(resume_step):
        seen.append(resume_step)
        if len(seen) == 1:
            raise NonFiniteEscalation(9, 3, resume_step=6)
        return 10

    sup = ft.Supervisor(ft.RestartPolicy(max_restarts=2, backoff_s=0.0),
                        sleep=lambda _d: None)
    assert sup.run(loop) == 10
    assert seen == [None, 6]


def test_escalation_repeating_at_same_step_is_deterministic():
    def loop(resume_step):
        raise NonFiniteEscalation(9, 3, resume_step=6)

    sup = ft.Supervisor(ft.RestartPolicy(max_restarts=5, backoff_s=0.0),
                        sleep=lambda _d: None)
    with pytest.raises(ft.DeterministicFailure):
        sup.run(loop)
    assert sup.restarts == 1


# ---------------------------------------------------------------------------
# plan determinism
# ---------------------------------------------------------------------------


def test_fault_plan_sample_is_seed_deterministic():
    a = TrainFaultPlan.sample(11, n_steps=50, n_slow=2, slow_delay_s=1.0)
    b = TrainFaultPlan.sample(11, n_steps=50, n_slow=2, slow_delay_s=1.0)
    assert a.faults == b.faults
    c = TrainFaultPlan.sample(12, n_steps=50, n_slow=2, slow_delay_s=1.0)
    assert a.faults != c.faults
    assert all(1 <= f.step < 50 for f in a.faults)


def test_fault_plan_rejects_unknown_kind():
    with pytest.raises(ValueError, match="kind"):
        TrainFaultSpec("segfault", step=1)


# ---------------------------------------------------------------------------
# sharded: the same invariants on the ("data", "model") mesh
# ---------------------------------------------------------------------------

needs_8 = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 (fake) devices"
)


@needs_8
def test_sharded_guard_and_resume_bit_exact(tmp_path):
    from repro.launch.mesh import make_conv_mesh

    mesh = make_conv_mesh((4, 2))
    step_fn = jax.jit(step_mod.make_cnn_train_step(TINY, OCFG, mesh=mesh))
    steps = 6
    ref = run_loop(step_fn, fresh_state(), batch_fn, steps=steps)
    # poisoned step skips bit-identically under shard_map too
    tree, opt_state = fresh_state()
    batch = dict(batch_fn(0), loss_scale=jnp.float32(float("nan")))
    new_tree, new_opt, metrics = step_fn(tree, opt_state, batch)
    assert int(metrics["skipped"]) == 1
    assert_trees_equal(new_tree, tree)
    # crash + restore reproduces the sharded trajectory bit-exactly
    plan = TrainFaultPlan([TrainFaultSpec("crash", step=4)])
    last, box, losses, sup, _ = _supervised_run(
        step_fn, plan, tmp_path, steps=steps, ckpt_every=2
    )
    assert last == steps
    np.testing.assert_array_equal(
        np.asarray([losses[s] for s in range(steps)]),
        np.asarray([ref.losses[s] for s in range(steps)]),
    )
    assert_trees_equal(box["state"], ref.state)
