"""The hardware cost model vs every number quoted in the paper text."""
import math

import pytest

from repro.core import hwmodel as hw
from repro.core import pas


def test_table1_asymptotics():
    """Table 1: multiplier O(W²) dominates; PAS has no multiplier."""
    c = hw.GateConstants()
    m8, m32 = hw.mac_unit(8, c), hw.mac_unit(32, c)
    assert m32.mult / m8.mult == pytest.approx(16.0)  # O(W²)
    p = hw.pas_unit(32, 16, c)
    assert p.mult == 0.0
    # PAS registers grow with B (Table 1: B accumulation registers)
    assert hw.pas_unit(32, 64, c).seq > hw.pas_unit(32, 16, c).seq


def test_standalone_anchor_w32_b16():
    """§2.4: 16-PAS-4-MAC vs 16-MAC at W=32, B=16 — category savings."""
    r = hw.gate_ratio(32, 16)
    assert r["seq"] == pytest.approx(1 - 0.35, abs=0.02)    # 35 % fewer sequential
    assert r["logic"] == pytest.approx(1 - 0.68, abs=0.02)  # 68 % fewer logic
    assert r["inv"] == pytest.approx(1 - 0.78, abs=0.06)    # 78 % fewer inverters
    assert r["buf"] == pytest.approx(1 - 0.61, abs=0.06)    # 61 % fewer buffers
    assert r["total"] == pytest.approx(1 - 0.66, abs=0.04)  # 66 % overall


def test_standalone_power_anchor():
    """§2.4: −70 % dynamic, −60 % leakage, −70 % total power at W=32/B=16."""
    p = hw.power_model(32, 16)
    assert p["dynamic"] == pytest.approx(1 - 0.70, abs=0.04)
    assert p["leakage"] == pytest.approx(1 - 0.60, abs=0.04)
    assert p["total"] == pytest.approx(1 - 0.70, abs=0.05)


def test_savings_grow_with_bitwidth():
    """Figs 7/8: the PASM advantage grows with W (multiplier is O(W²))."""
    totals = [hw.gate_ratio(w, 16)["total"] for w in (4, 8, 16, 32)]
    assert totals == sorted(totals, reverse=True)  # ratio falls as W grows


def test_bin_crossover():
    """Fig 9: at B=256 the PASM register/buffer cost overtakes the MAC's."""
    r16 = hw.gate_ratio(32, 16)
    r256 = hw.gate_ratio(32, 256)
    assert r16["total"] < 1.0
    assert r256["seq"] > 1.0  # registers less efficient at 256 bins (paper)


def test_asic_accelerator_anchors():
    """§5.1: in-CNN accelerator, 32-bit kernels."""
    b4 = hw.accel_ratio_asic(4)
    assert b4["gates"] == pytest.approx(1 - 0.478, abs=1e-6)
    assert b4["power"] == pytest.approx(1 - 0.532, abs=1e-6)
    b8 = hw.accel_ratio_asic(8)
    assert b8["gates"] == pytest.approx(1 - 0.081, abs=1e-6)
    assert b8["power"] == pytest.approx(1 - 0.152, abs=1e-6)
    # the model PREDICTS the paper's qualitative B=16 crossover
    b16 = hw.accel_ratio_asic(16)
    assert b16["gates"] > 1.0 and b16["power"] > 1.0


def test_asic_int8_anchor():
    """§5.1: 8-bit kernels, 4 bins: −19.8 % gates, −31.3 % power."""
    r = hw.accel_ratio_asic(4, W=8)
    assert r["gates"] == pytest.approx(1 - 0.198, abs=1e-6)
    assert r["power"] == pytest.approx(1 - 0.313, abs=1e-6)


def test_fpga_anchors():
    """§5.2: 99 % fewer DSPs, 28 % fewer BRAMs; power −64/−41.6/−18 %."""
    assert hw.fpga_resources(4, pasm=True)["dsp"] == 3
    assert hw.fpga_resources(4, pasm=False)["dsp"] == 405
    assert hw.accel_ratio_fpga(4)["power"] == pytest.approx(0.36, abs=1e-6)
    assert hw.accel_ratio_fpga(8)["power"] == pytest.approx(0.584, abs=1e-6)
    assert hw.accel_ratio_fpga(16)["power"] == pytest.approx(1 - 0.18, abs=0.03)
    assert hw.accel_ratio_fpga(4)["dsp"] == pytest.approx(0.01, abs=1e-6)
    assert hw.accel_ratio_fpga(4)["bram"] == pytest.approx(0.72, abs=1e-6)


def test_shared_mac_cycles():
    """§2.2 worked example: 1024 + 4·16 = 1088 cycles."""
    assert pas.pasm_cycles(1024, 16, 4) == hw.PAPER_CLAIMS["cycles.example"]


def test_latency_fig14():
    """Fig 14: PASM latency +8.5 % (B=4) … +12.75 % (B=16) on the paper conv."""
    r4 = hw.conv_latency_ratio(4)
    r16 = hw.conv_latency_ratio(16)
    assert r4 == pytest.approx(1.085, abs=0.01)
    assert r16 == pytest.approx(1.1275, abs=0.01)
    assert hw.conv_latency_ratio(8) > r4 and hw.conv_latency_ratio(8) < r16


def test_latency_amortizes_with_channels():
    """§4/Table 2: more channels → post-pass amortized → overhead shrinks."""
    big_c = dict(hw.PAPER_CONV, C=512)
    assert hw.conv_latency_ratio(16, big_c) < hw.conv_latency_ratio(16)


def test_table2_macops():
    """Table 2: MAC ops per output = C·KX·KY."""
    for C in (32, 128, 512):
        for k in (1, 3, 5, 7):
            n = C * k * k
            assert hw.conv_latency_cycles(
                IH=k, IW=k, C=C, KY=k, KX=k, M=1, bins=0
            ) == n
