"""The AlexNet-style CNN stack: end-to-end forward on the Pallas conv path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CNN_IDS, get_cnn_config
from repro.models import api, cnn

KEY = jax.random.PRNGKey(0)


def _setup(impl="kernel"):
    cfg = dataclasses.replace(get_cnn_config("alexnet", smoke=True), impl=impl)
    params = cnn.init_params(cfg, KEY)
    qparams = cnn.quantize(params, cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, *cfg.in_chw))
    return cfg, params, qparams, imgs


def test_registry_and_dispatch():
    assert "alexnet" in CNN_IDS
    cfg = get_cnn_config("alexnet", smoke=True)
    assert api.get_model(cfg) is cnn
    full = get_cnn_config("alexnet")
    assert full.in_chw == (3, 224, 224) and full.classes == 1000
    assert cnn.feature_shape(full) == (256, 2, 2)


def test_forward_smoke_kernel_path():
    """Acceptance: the CNN forward runs end-to-end on the Pallas kernels."""
    cfg, params, qparams, imgs = _setup("kernel")
    logits = cnn.forward(qparams, imgs, cfg)
    assert logits.shape == (2, cfg.classes)
    assert bool(jnp.isfinite(logits).all())


def test_kernel_engines_agree_with_einsum():
    cfg, params, qparams, imgs = _setup("kernel")
    want = cnn.forward(qparams, imgs, dataclasses.replace(cfg, impl="einsum"))
    got_kernel = cnn.forward(qparams, imgs, cfg)
    got_pas = cnn.forward(qparams, imgs, dataclasses.replace(cfg, impl="pas_kernel"))
    np.testing.assert_allclose(np.asarray(got_kernel), np.asarray(want), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(got_pas), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_quantized_tracks_dense():
    """Per-layer 16-bin dictionaries keep logits correlated with dense."""
    cfg, params, qparams, imgs = _setup("kernel")
    dense = np.asarray(cnn.forward_dense(params, imgs, cfg)).ravel()
    quant = np.asarray(cnn.forward(qparams, imgs, cfg)).ravel()
    corr = np.corrcoef(dense, quant)[0, 1]
    assert corr > 0.9, corr


def test_per_layer_codebooks():
    cfg, params, qparams, imgs = _setup()
    assert len(qparams["conv"]) == len(cfg.layers)
    for p, layer in zip(qparams["conv"], cfg.layers):
        assert p.kind == "shared"
        assert p.codebook.shape == (cfg.bins,)
        assert p.idx.shape[0] == layer.c_out
        assert int(p.idx.max()) < cfg.bins


def test_packed_stack_matches_unpacked():
    """cfg.packed int4-packs every dictionary; logits must not move."""
    cfg, params, qparams, imgs = _setup("kernel")
    pcfg = dataclasses.replace(cfg, packed=True)
    pparams = cnn.quantize(params, pcfg)
    assert all(p.kind == "packed" for p in pparams["conv"])
    want = cnn.forward(qparams, imgs, cfg)
    got = cnn.forward(pparams, imgs, pcfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_same_padding_nhwc_stack():
    """The stack-wide padding/layout knobs: SAME+NHWC runs end to end and
    matches the dense reference geometry."""
    cfg = dataclasses.replace(
        get_cnn_config("alexnet", smoke=True), padding="same", layout="NHWC"
    )
    params = cnn.init_params(cfg, KEY)
    qparams = cnn.quantize(params, cfg)
    C, H, W = cfg.in_chw
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, H, W, C))
    logits = cnn.forward(qparams, imgs, cfg)
    assert logits.shape == (2, cfg.classes)
    assert cnn.feature_shape(cfg) == (32, 4, 4)  # 32→16→8→4 under SAME+pool
    want = cnn.forward(qparams, imgs, dataclasses.replace(cfg, impl="einsum"))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want), rtol=1e-3, atol=1e-3)
