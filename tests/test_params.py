"""PasmParams: one weight-shared container from dense matmuls to MoE and voice.

The multi-layer-refactor acceptance suite (ISSUE 6):

* ``nn.layers.linear`` dispatches dense | shared | int4-packed | grouped
  params through the Pallas kernels, matching the dequant-einsum oracle —
  including odd reduction lengths (the §3 reserved-zero-bin K-pad now
  covers dense layers, not just conv).
* ``mesh=`` shards the same call bit-exactly (8 fake host devices).
* MoE experts carry **per-expert grouped codebooks** through the kernels.
* Whisper-tiny (audio family) runs its quantized forward through the
  kernel path — the paper's technique on voice.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import params as P
from repro.nn import layers as L

KEY = jax.random.PRNGKey(0)


def _quantized(K, N, *, kind="shared", bins=16, groups=1, bias=False, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (K, N)) * K ** -0.5
    b = jnp.linspace(-0.2, 0.2, N) if bias else None
    p = P.PasmParams.quantize(w, bins, groups=groups, bias=b)
    if kind == "packed":
        p = p.pack()
    return p


CASES = [
    # (name, K, N, kind, bins, groups)
    ("shared", 48, 32, "shared", 16, 1),
    ("shared-odd-K", 47, 24, "shared", 16, 1),
    ("packed", 48, 32, "packed", 16, 1),
    ("packed-odd-K", 47, 24, "packed", 8, 1),  # §3 K-pad on a dense layer
    ("grouped", 48, 32, "shared", 8, 4),
    ("grouped-packed", 48, 32, "packed", 8, 4),
]


@pytest.mark.parametrize("name,K,N,kind,bins,groups", CASES)
@pytest.mark.parametrize("impl", ["kernel", "pas_kernel"])
def test_linear_kernel_matches_dequant_oracle(name, K, N, kind, bins, groups, impl):
    p = _quantized(K, N, kind=kind, bins=bins, groups=groups, bias=True)
    x = jax.random.normal(KEY, (3, 7, K))
    if impl == "pas_kernel" and groups > 1:
        with pytest.raises(ValueError, match="paper-faithful single-dictionary"):
            L.linear(x, p, impl)
        return
    want = L.linear(x, p, "dequant", relu=True)
    got = L.linear(x, p, impl, relu=True)
    assert got.shape == (3, 7, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_linear_dense_paths():
    """Plain arrays and dense-kind params always take the dense dot."""
    w = jax.random.normal(KEY, (32, 16))
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 32))
    want = x @ w
    for imp in ("dense", "kernel", "pas_kernel"):  # impl is moot for dense weights
        np.testing.assert_allclose(
            np.asarray(L.linear(x, w, imp)), np.asarray(want), rtol=1e-6, atol=1e-6
        )
    p = P.PasmParams.dense(w, bias=jnp.ones((16,)))
    np.testing.assert_allclose(
        np.asarray(L.linear(x, p, "kernel")), np.asarray(want + 1.0),
        rtol=1e-6, atol=1e-6,
    )


def test_container_accounting():
    """compression_ratio / nbytes on the shapes the bench rows stamp."""
    p = _quantized(256, 256, kind="packed", bins=16)
    assert p.bits == 4 and p.groups == 1
    # idx int4-packed: K·N/2 bytes + the (1, B) f32 codebook
    assert p.nbytes_weights == 256 * 256 // 2 + p.codebook.size * 4
    assert p.nbytes_dense_bf16 == 256 * 256 * 2
    assert p.compression_ratio > 3.9  # ~4× vs bf16 at 4 bits


def test_exactly_one_container_in_core():
    """Acceptance: repro.core exports one weight-shared container type."""
    import repro.core as core

    assert hasattr(core, "PasmParams")
    assert not hasattr(core, "PASMTensor")  # survives only on repro.core.pasm


# ---------------------------------------------------------------------------
# mesh: the same linear call, sharded (needs the 8 fake host devices)
# ---------------------------------------------------------------------------

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >=4 devices; run under "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8 (scripts/ci.sh)",
)


def _mesh(shape):
    from repro.launch.mesh import make_conv_mesh

    return make_conv_mesh(shape)


@needs_mesh
@pytest.mark.parametrize("name,K,N,kind,bins,groups", CASES)
@pytest.mark.parametrize("mesh_shape", [(4, 1), (2, 2)])
def test_linear_mesh_bit_exact(name, K, N, kind, bins, groups, mesh_shape):
    """Sharded linear ≡ single-device, every kind — the caveat is dead."""
    p = _quantized(K, N, kind=kind, bins=bins, groups=groups, bias=True)
    x = jax.random.normal(KEY, (8, K))
    want = L.linear(x, p, "kernel", relu=True)
    got = L.linear(x, p, "kernel", relu=True, mesh=_mesh(mesh_shape))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs_mesh
def test_linear_mesh_uneven_rows():
    """M % n_data != 0 pads rows in and slices them off."""
    p = _quantized(48, 32, kind="packed")
    x = jax.random.normal(KEY, (6, 48))  # 6 rows over 4-way data
    want = L.linear(x, p, "kernel")
    got = L.linear(x, p, "kernel", mesh=_mesh((4, 1)))
    assert got.shape == want.shape == (6, 32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE: per-expert grouped codebooks through the kernels
# ---------------------------------------------------------------------------


def test_moe_per_expert_codebooks():
    from repro.configs.base import MoEConfig
    from repro.nn import moe as M

    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=16, n_shared=0)
    D, E, Fe = 32, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    p = {
        "router": jax.random.normal(ks[0], (D, E)) * 0.2,
        "w1": jax.random.normal(ks[1], (E, D, Fe)) * 0.2,
        "w3": jax.random.normal(ks[2], (E, D, Fe)) * 0.2,
        "w2": jax.random.normal(ks[3], (E, Fe, D)) * 0.2,
    }
    pq = {**p}
    for name in ("w1", "w3", "w2"):
        pq[name] = P.PasmParams.quantize(p[name], bins=16, groups=2)
        # one (G, B) dictionary PER EXPERT — the private _dense_w unpack is gone
        assert pq[name].codebook.shape == (E, 2, 16)
    x = jax.random.normal(jax.random.PRNGKey(4), (32, D))
    y_k, _ = M.moe_ffn(x, pq, cfg, impl="kernel", dropless=True)
    y_d, _ = M.moe_ffn(x, pq, cfg, impl="dequant", dropless=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_d), rtol=1e-4, atol=1e-4)


def test_transformer_quantized_kernel_forward():
    """A dense transformer's FFN/attention matmuls through the kernel path."""
    from repro.configs import get_config
    from repro.models import api
    from repro.models.common import quantize_params

    cfg = get_config("qwen3-32b", smoke=True).with_quant(
        enabled=True, bins=16, impl="kernel", min_weight_elems=64
    )
    model = api.get_model(cfg)
    params = quantize_params(model.init_params(cfg, KEY), cfg)
    tokens = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    lg_k, _ = model.forward(params, tokens, cfg)
    lg_d, _ = model.forward(params, tokens, cfg.with_quant(impl="dequant"))
    assert bool(jnp.isfinite(lg_k.astype(jnp.float32)).all())
    # bf16 accumulation order differs between the kernel and XLA dots
    np.testing.assert_allclose(
        np.asarray(lg_k.astype(jnp.float32)), np.asarray(lg_d.astype(jnp.float32)),
        rtol=3e-2, atol=3e-2,
    )


# ---------------------------------------------------------------------------
# Whisper-tiny: the technique on voice, end to end through the kernels
# ---------------------------------------------------------------------------


def test_whisper_tiny_quantized_kernel_forward():
    from repro.configs import whisper_tiny
    from repro.models import encdec
    from repro.models.common import quantize_params

    cfg = whisper_tiny.smoke_config().with_quant(
        enabled=True, bins=16, impl="kernel", min_weight_elems=64
    )
    params = encdec.init_params(cfg, KEY)
    params = quantize_params(params, cfg)
    params = encdec.quantize_frontend(params, bins=16)
    B = 2
    mel = jax.random.normal(
        jax.random.PRNGKey(5), (B, cfg.n_mels, 2 * cfg.frontend_tokens)
    ).astype(jnp.bfloat16)
    tokens = jax.random.randint(KEY, (B, 8), 0, cfg.vocab)
    lg_k, _ = encdec.forward(params, tokens, cfg, frontend_embeds=mel)
    cfg_d = cfg.with_quant(impl="dequant")
    lg_d, _ = encdec.forward(params, tokens, cfg_d, frontend_embeds=mel)
    assert lg_k.shape == (B, 8, cfg.vocab)
    assert bool(jnp.isfinite(lg_k.astype(jnp.float32)).all())
    np.testing.assert_allclose(
        np.asarray(lg_k.astype(jnp.float32)), np.asarray(lg_d.astype(jnp.float32)),
        rtol=2e-2, atol=2e-2,
    )


def test_whisper_frontend_is_weight_shared():
    """quantize_frontend turns the conv stem into shared ConvParams."""
    from repro.configs import whisper_tiny
    from repro.core.conv import ConvParams
    from repro.models import encdec

    cfg = whisper_tiny.smoke_config()
    params = encdec.init_params(cfg, KEY)
    qp = encdec.quantize_frontend(params, bins=8)
    for name in ("conv1", "conv2"):
        cp = qp["frontend"][name]
        assert isinstance(cp, ConvParams) and cp.kind == "shared"
        assert cp.bins == 8 and cp.bias is not None
    # quantize_params leaves the stem alone (convs are an explicit opt-in)
    from repro.models.common import quantize_params

    qcfg = cfg.with_quant(enabled=True, bins=16, min_weight_elems=1)
    qp2 = quantize_params(params, qcfg)
    assert isinstance(qp2["frontend"]["conv1"]["kernel"], jax.Array)
