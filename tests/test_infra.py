"""Infrastructure: optimizer, checkpointing, fault tolerance, data pipeline."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ft
from repro.ckpt import checkpoint as ck
from repro.data.pipeline import DataConfig, TokenFileDataset, synthetic_batch, write_token_file
from repro.train import optimizer as opt


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init_opt_state(params)
    cfg = opt.AdamWConfig(lr=0.3, weight_decay=0.0, total_steps=100, warmup_steps=1)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_grad_clipping():
    params = {"w": jnp.zeros(4)}
    state = opt.init_opt_state(params)
    cfg = opt.AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=1)
    _, _, metrics = opt.adamw_update(params, {"w": jnp.full(4, 1e6)}, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_cosine_schedule():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(opt.cosine_lr(cfg, jnp.asarray(0))) == 0.0
    assert float(opt.cosine_lr(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(opt.cosine_lr(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


def test_integer_leaves_frozen():
    params = {"w": jnp.ones(4), "idx": jnp.arange(4, dtype=jnp.uint8)}
    state = opt.init_opt_state(params)
    cfg = opt.AdamWConfig(lr=0.1, warmup_steps=1)
    grads = {"w": jnp.ones(4), "idx": jnp.zeros(4)}
    p2, _, _ = opt.adamw_update(params, grads, state, cfg)
    np.testing.assert_array_equal(np.asarray(p2["idx"]), np.asarray(params["idx"]))
    assert float(jnp.abs(p2["w"] - params["w"]).max()) > 0


def test_compress_grads_error_bound():
    """PASM-style gradient dictionary: bounded quantization error."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
    gq = opt.compress_grads(g, bins=256)
    amax = float(jnp.abs(g["w"]).max())
    bin_width = amax / (256 / 2 - 1)
    assert float(jnp.abs(g["w"] - gq["w"]).max()) <= bin_width * 0.51


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------


def _tree():
    return {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16), "step": jnp.asarray(7)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ck.save(tmp_path, 10, t, extra={"note": "x"})
    restored, manifest = ck.restore(tmp_path, t)
    assert manifest["step"] == 10 and manifest["extra"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_latest_and_gc(tmp_path):
    mgr = ck.CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    mgr.wait()
    mgr._gc()
    assert ck.latest_step(tmp_path) == 4
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]  # keep-last-2


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ck.save(tmp_path, 1, _tree())
    bad = {"a": jnp.zeros((3, 3)), "nested": {"b": jnp.ones((4,)), "step": jnp.asarray(0)}}
    with pytest.raises(ValueError):
        ck.restore(tmp_path, bad)


def test_checkpoint_ignores_incomplete(tmp_path):
    ck.save(tmp_path, 5, _tree())
    # simulate a crash mid-write: dir without manifest
    (tmp_path / "step_9").mkdir()
    assert ck.latest_step(tmp_path) == 5


def test_background_save_failure_surfaces(tmp_path):
    """A failing background write must be reported, never a silently missing
    checkpoint: the captured exception re-raises from ``wait()`` — and from
    the NEXT ``save()``, which waits on the previous write first."""
    # an unwritable "directory": a path whose parent is an existing file
    # (robust under root, where permission bits don't block writes)
    blocker = tmp_path / "blocker"
    blocker.write_text("I am a file, not a directory")
    bad_dir = blocker / "ckpts"

    writer = ck.save(bad_dir, 1, _tree(), background=True)
    writer.join()
    with pytest.raises(RuntimeError, match="background checkpoint write failed"):
        writer.check()
    writer.check()  # idempotent: the failure is reported once, not re-raised

    mgr = ck.CheckpointManager(bad_dir)
    mgr.save(1, _tree())
    with pytest.raises(RuntimeError, match="background checkpoint write failed"):
        mgr.save(2, _tree())  # surfaces step 1's failure before starting
    mgr.wait()  # step-1 failure already consumed; wait() is now a no-op


def test_background_save_success_roundtrips(tmp_path):
    mgr = ck.CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(3, t)
    mgr.wait()
    restored, manifest = mgr.restore_latest(t)
    assert manifest["step"] == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# fault tolerance
# --------------------------------------------------------------------------


def test_straggler_detection():
    det = ft.StragglerDetector(n_hosts=4, window=10, threshold=1.5)
    for step in range(10):
        for h in range(4):
            det.record(h, 1.0 if h != 2 else 3.0)
    assert det.stragglers() == [2]


def test_supervisor_restarts_then_succeeds():
    calls = []

    def flaky(resume):
        calls.append(resume)
        if len(calls) < 3:
            raise RuntimeError("chip fell off")
        return 42

    sup = ft.Supervisor(ft.RestartPolicy(max_restarts=5, backoff_s=0.0), sleep=lambda s: None)
    assert sup.run(flaky) == 42
    assert sup.restarts == 2


def test_supervisor_gives_up():
    def always_fails(resume):
        raise RuntimeError("dead host")

    sup = ft.Supervisor(ft.RestartPolicy(max_restarts=2, backoff_s=0.0), sleep=lambda s: None)
    with pytest.raises(RuntimeError, match="exceeded max_restarts"):
        sup.run(always_fails)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------


def test_synthetic_deterministic():
    cfg = DataConfig(seed=1, vocab=1000, seq_len=32, global_batch=4)
    a = synthetic_batch(cfg, 7)
    b = synthetic_batch(cfg, 7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = synthetic_batch(cfg, 8)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_synthetic_shards_disjoint():
    base = dict(seed=1, vocab=1000, seq_len=16, global_batch=8, n_shards=2)
    s0 = synthetic_batch(DataConfig(**base, shard_index=0), 3)
    s1 = synthetic_batch(DataConfig(**base, shard_index=1), 3)
    assert s0["tokens"].shape == (4, 16)  # global 8 over 2 shards
    assert not np.array_equal(np.asarray(s0["tokens"]), np.asarray(s1["tokens"]))


def test_labels_are_shifted():
    cfg = DataConfig(seed=0, vocab=100, seq_len=16, global_batch=2)
    b = synthetic_batch(cfg, 0)
    np.testing.assert_array_equal(
        np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1])
    )


def test_file_dataset(tmp_path):
    toks = np.arange(17 * 10, dtype=np.uint32)
    path = tmp_path / "tokens.bin"
    write_token_file(str(path), toks)
    cfg = DataConfig(seed=0, vocab=200, seq_len=16, global_batch=2, path=str(path))
    ds = TokenFileDataset(cfg)
    assert ds.n_seqs == 10
    b = ds.batch(0)
    assert b["tokens"].shape == (2, 16)
    b2 = ds.batch(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"]), np.asarray(b2["tokens"]))


# --------------------------------------------------------------------------
# data validation + transient-I/O retry (DESIGN.md §4)
# --------------------------------------------------------------------------


def test_data_config_rejects_indivisible_shards():
    from repro.data.pipeline import DataValidationError

    with pytest.raises(DataValidationError, match="divide evenly"):
        DataConfig(global_batch=7, n_shards=2)
    with pytest.raises(DataValidationError, match="shard_index"):
        DataConfig(global_batch=8, n_shards=2, shard_index=2)
    with pytest.raises(DataValidationError):
        DataConfig(global_batch=0)


def test_empty_token_file_rejected(tmp_path):
    from repro.data.pipeline import DataValidationError

    path = tmp_path / "tiny.bin"
    write_token_file(str(path), np.arange(10, dtype=np.uint32))  # < seq_len+1
    cfg = DataConfig(seed=0, vocab=200, seq_len=16, global_batch=2, path=str(path))
    with pytest.raises(DataValidationError, match="empty/truncated"):
        TokenFileDataset(cfg)
    with pytest.raises(DataValidationError, match="cfg.path"):
        TokenFileDataset(DataConfig(seq_len=16, global_batch=2))


def test_token_file_batch_retries_transient_oserror(tmp_path):
    toks = np.arange(17 * 4, dtype=np.uint32)
    path = tmp_path / "tokens.bin"
    write_token_file(str(path), toks)
    cfg = DataConfig(seed=0, vocab=200, seq_len=16, global_batch=2, path=str(path))
    fails = {"n": 2}

    def hook(step):
        if fails["n"]:
            fails["n"] -= 1
            raise OSError("flaky mount")

    delays = []
    ds = TokenFileDataset(cfg, backoff_s=0.05, cap_s=0.08, sleep=delays.append,
                          fault_hook=hook)
    with pytest.warns(RuntimeWarning, match="transient I/O"):
        b = ds.batch(0)
    assert b["tokens"].shape == (2, 16)
    assert delays == [0.05, 0.08]  # doubled then capped, zero wall clock
    # reference content: identical to an unfaulted read of the same step
    clean = TokenFileDataset(cfg).batch(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"]), np.asarray(clean["tokens"]))


# --------------------------------------------------------------------------
# gc vs in-flight background write (regression)
# --------------------------------------------------------------------------


def test_gc_never_deletes_pending_inflight_write(tmp_path, monkeypatch):
    """After a fallback-restore the loop re-saves an OLDER step than stale
    on-disk checkpoints; keep-last-k would sort the pending step into the
    delete set.  Pin the worst interleaving — the background rename lands
    before ``_gc`` scans — and assert the pending target survives."""
    mgr = ck.CheckpointManager(tmp_path, keep=2)
    for s in (10, 20, 30):  # stale checkpoints newer than the resume point
        ck.save(tmp_path, s, _tree())

    orig_save = ck.save

    def landed_before_gc(directory, step, tree, *, extra=None, background=False):
        orig_save(directory, step, tree, extra=extra, background=False)
        done = ck.BackgroundWriter(lambda: None)
        done.start()
        return done

    monkeypatch.setattr(ck, "save", landed_before_gc)
    mgr.save(4, _tree())  # the post-fallback re-save: older than 10/20/30
    mgr.wait()
    assert (tmp_path / "step_4").exists(), "gc deleted the in-flight checkpoint"
    steps = ck.complete_steps(tmp_path)
    assert 4 in steps and 30 in steps
    # once the write is no longer pending, normal rotation applies again
    mgr.save(40, _tree())
    mgr.wait()
    mgr._gc()
    assert 4 not in ck.complete_steps(tmp_path)
