"""Per-kernel allclose vs the pure-jnp oracles (interpret=True on CPU).

Sweeps shapes/dtypes per the assignment: every Pallas kernel is validated
against its ref.py oracle across M/K/N, bins, groups, packing, and dtype.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import pasm
from repro.kernels import ops, ref


def _mk(M, K, N, bins, groups, dtype, seed=0):
    kk = jax.random.PRNGKey(seed)
    w = jax.random.normal(kk, (K, N))
    t = pasm.quantize(w, bins=bins, groups=groups)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, K)).astype(dtype)
    return x, t


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "M,K,N,bins,groups",
    [
        (8, 64, 32, 16, 1),      # packed int4, single dictionary
        (8, 64, 32, 64, 1),      # uint8
        (16, 128, 128, 16, 4),   # grouped + packed
        (5, 96, 17, 16, 2),      # non-tile-aligned M/N (padding path)
        (1, 256, 256, 256, 1),   # max bins, M=1 (decode-like)
        (33, 512, 64, 8, 8),     # many groups
    ],
)
def test_pasm_matmul_vs_oracle(M, K, N, bins, groups, dtype):
    x, t = _mk(M, K, N, bins, groups, dtype)
    got = ops.pasm_matmul(x, t, interpret=True)
    want = ref.pasm_matmul_ref(x, t.idx, t.codebook, packed=t.packed)
    # f32 tolerance covers k-tile reassociation (kernel accumulates per tile)
    tol = 5e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("gather", ["take", "onehot"])
def test_gather_strategies_agree(gather):
    x, t = _mk(8, 64, 32, 8, 1, jnp.float32)
    got = ops.pasm_matmul(x, t, gather=gather, interpret=True)
    want = ref.pasm_matmul_ref(x, t.idx, t.codebook, packed=t.packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "M,K,N,bins",
    [(8, 64, 32, 16), (16, 128, 64, 4), (4, 256, 128, 16)],
)
def test_pas_histogram_kernel_vs_oracle(M, K, N, bins):
    """The paper-faithful two-phase kernel: PAS bins in VMEM + post-pass."""
    x, t = _mk(M, K, N, bins, 1, jnp.float32)
    t = dataclasses.replace(t, idx=pasm.logical_idx(t), packed=False)
    got = ops.pas_matmul(x, t, interpret=True)
    want = ref.pas_matmul_ref(x, t.idx, t.codebook)
    ws = ref.pasm_matmul_ref(x, t.idx, t.codebook, packed=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
    # the PASM identity holds at the kernel level too
    np.testing.assert_allclose(np.asarray(got), np.asarray(ws), rtol=1e-3, atol=1e-3)


@settings(deadline=None, max_examples=10)
@given(
    m=st.integers(1, 40),
    n=st.integers(1, 40),
    kmul=st.integers(1, 4),
    bins=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 1000),
)
def test_pasm_matmul_property(m, n, kmul, bins, seed):
    """Property sweep: arbitrary shapes route through padding correctly."""
    K = 32 * kmul
    x, t = _mk(m, K, n, bins, 1, jnp.float32, seed)
    got = ops.pasm_matmul(x, t, interpret=True)
    want = ref.pasm_matmul_ref(x, t.idx, t.codebook, packed=t.packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "M,K,N,bins,groups",
    [
        (9, 363, 96, 16, 1),     # odd K (AlexNet conv1 im2col: 3·11·11)
        (16, 2400, 256, 16, 1),  # K = 96·5·5 (conv2), packed, padded to 2432
        (8, 1125, 32, 64, 1),    # odd K > 512: the seed raised ValueError here
        (8, 1200, 32, 16, 2),    # grouped + packed: per-group K padding
    ],
)
def test_pasm_matmul_kpad_vs_oracle(M, K, N, bins, groups):
    """Reduction dims with no clean tile divisor route through K-padding
    (reserved zero-codebook bin) instead of the seed's hard ``ValueError``."""
    pack = None if K % 2 == 0 else False
    kk = jax.random.PRNGKey(0)
    w = jax.random.normal(kk, (K, N))
    t = pasm.quantize(w, bins=bins, groups=groups, pack=pack)
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    got = ops.pasm_matmul(x, t, interpret=True)
    want = ref.pasm_matmul_ref(x, t.idx, t.codebook, packed=t.packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)


def test_pas_matmul_kpad_vs_oracle():
    """The paper-faithful kernel also accepts K-padded reductions."""
    K = 2400
    w = jax.random.normal(jax.random.PRNGKey(2), (K, 64))
    t = pasm.quantize(w, bins=16, groups=1, pack=False)
    x = jax.random.normal(jax.random.PRNGKey(3), (8, K))
    got = ops.pas_matmul(x, t, interpret=True)
    want = ref.pas_matmul_ref(x, t.idx, t.codebook)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize(
    "bins,groups,packed",
    [(16, 4, True), (16, 2, True), (64, 4, False), (16, 1, True)],
)
def test_pasm_bwd_gradcheck_vs_dequant_chain(bins, groups, packed):
    """The custom VJP (packed int4 + groups>1 included) ≡ grad through
    dequantize-then-dot: same codebook/activation gradients."""
    M, K, N = 6, 128, 48
    w = jax.random.normal(jax.random.PRNGKey(4), (K, N))
    t = pasm.quantize(w, bins=bins, groups=groups, pack=packed)
    assert t.packed == packed
    x = jax.random.normal(jax.random.PRNGKey(5), (M, K))

    def loss_kernel(x, cb):
        tt = dataclasses.replace(t, codebook=cb)
        return (ops.pasm_matmul(x, tt, interpret=True) ** 2).sum()

    def loss_chain(x, cb):
        tt = dataclasses.replace(t, codebook=cb)
        wd = pasm.dequantize(tt, dtype=x.dtype)
        return (jnp.dot(x, wd, preferred_element_type=jnp.float32) ** 2).sum()

    gx_k, gcb_k = jax.grad(loss_kernel, argnums=(0, 1))(x, t.codebook)
    gx_c, gcb_c = jax.grad(loss_chain, argnums=(0, 1))(x, t.codebook)
    assert gcb_k.shape == t.codebook.shape == (groups, bins)
    np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_c), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gcb_k), np.asarray(gcb_c), rtol=1e-4, atol=1e-4)


def test_gradients_match_numeric():
    x, t = _mk(6, 64, 24, 16, 2, jnp.float32)

    def loss(x, cb):
        tt = dataclasses.replace(t, codebook=cb)
        return (ops.pasm_matmul(x, tt, interpret=True) ** 2).sum()

    gx, gcb = jax.grad(loss, argnums=(0, 1))(x, t.codebook)
    eps = 5e-2  # central differences (f32 loss values ~1e3: large eps needed)
    num = (loss(x, t.codebook.at[1, 5].add(eps)) - loss(x, t.codebook.at[1, 5].add(-eps))) / (2 * eps)
    np.testing.assert_allclose(float(num), float(gcb[1, 5]), rtol=5e-2)
    num_x = (loss(x.at[2, 3].add(eps), t.codebook) - loss(x.at[2, 3].add(-eps), t.codebook)) / (2 * eps)
    np.testing.assert_allclose(float(num_x), float(gx[2, 3]), rtol=5e-2)


def test_batched_leading_dims():
    x, t = _mk(12, 64, 32, 16, 1, jnp.bfloat16)
    x3 = x.reshape(3, 4, 64)
    y3 = ops.pasm_matmul(x3, t, interpret=True)
    y2 = ops.pasm_matmul(x, t, interpret=True)
    assert y3.shape == (3, 4, 32)
    np.testing.assert_allclose(
        np.asarray(y3.reshape(12, 32)), np.asarray(y2), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# fused bias/ReLU epilogue (the last-k-step write-through)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize(
    "M,K,N,bins,groups,packed",
    [
        (8, 64, 32, 16, 1, True),    # packed, aligned
        (5, 96, 17, 16, 2, False),   # grouped + padding path (bias padded too)
        (16, 2400, 256, 16, 1, True),  # conv2-sized K-padded reduction
    ],
)
def test_pasm_matmul_fused_epilogue_vs_oracle(relu, M, K, N, bins, groups, packed):
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    t = pasm.quantize(w, bins=bins, groups=groups, pack=packed)
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    bias = jnp.linspace(-2.0, 2.0, N)
    got = ops.pasm_matmul(x, t, bias=bias, relu=relu, interpret=True)
    want = ref.apply_epilogue(
        ref.pasm_matmul_ref(x, t.idx, t.codebook, packed=t.packed), bias, relu
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)
    if relu:
        assert float(got.min()) >= 0.0


@pytest.mark.parametrize("relu", [False, True])
def test_pas_matmul_fused_epilogue_vs_oracle(relu):
    x, t = _mk(8, 128, 48, 16, 1, jnp.float32)
    t = dataclasses.replace(t, idx=pasm.logical_idx(t), packed=False)
    bias = jnp.linspace(-1.0, 1.0, 48)
    got = ops.pas_matmul(x, t, bias=bias, relu=relu, interpret=True)
    want = ref.apply_epilogue(ref.pas_matmul_ref(x, t.idx, t.codebook), bias, relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_fused_epilogue_gradcheck():
    """The fused-path VJP ≡ grad through dequantize→dot→bias→ReLU."""
    M, K, N = 6, 128, 48
    w = jax.random.normal(jax.random.PRNGKey(4), (K, N))
    t = pasm.quantize(w, bins=16, groups=2, pack=True)
    x = jax.random.normal(jax.random.PRNGKey(5), (M, K))
    bias = jnp.linspace(-0.5, 0.5, N)

    def loss_kernel(x, cb, b):
        tt = dataclasses.replace(t, codebook=cb)
        return (ops.pasm_matmul(x, tt, bias=b, relu=True, interpret=True) ** 2).sum()

    def loss_chain(x, cb, b):
        tt = dataclasses.replace(t, codebook=cb)
        wd = pasm.dequantize(tt, dtype=x.dtype)
        y = jnp.dot(x, wd, preferred_element_type=jnp.float32) + b
        return (jnp.maximum(y, 0.0) ** 2).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, t.codebook, bias)
    gc = jax.grad(loss_chain, argnums=(0, 1, 2))(x, t.codebook, bias)
    for a, b in zip(gk, gc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------


def _naive_attn(q, k, v, causal):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) * hd ** -0.5
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(k.shape[1])[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return o.reshape(B, Sq, H, hd)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "B,S,H,KV,hd,bq,bk",
    [
        (2, 64, 4, 2, 16, 16, 16),   # GQA
        (1, 56, 4, 4, 16, 16, 16),   # MHA, non-divisible S (pad path)
        (1, 128, 8, 1, 32, 32, 64),  # MQA, rectangular blocks
    ],
)
def test_flash_attention_vs_naive(causal, B, S, H, KV, hd, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    got = ops.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk, interpret=True)
    want = _naive_attn(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 16), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, bq=16, bk=16, interpret=True)
    want = _naive_attn(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=3e-2, atol=3e-2
    )
