"""Weight-sharing quantizer: k-means, packing, compression accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import pasm


@settings(deadline=None, max_examples=30)
@given(
    kdim=st.integers(1, 32).map(lambda v: v * 2),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_unpack_roundtrip(kdim, n, seed):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, 16, size=(kdim, n)), jnp.uint8)
    packed = pasm.pack_int4(idx)
    assert packed.shape == (kdim // 2, n)
    np.testing.assert_array_equal(np.asarray(pasm.unpack_int4(packed)), np.asarray(idx))


def test_quantize_error_decreases_with_bins():
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 64))
    errs = []
    for bins in (4, 16, 64, 256):
        t = pasm.quantize(w, bins=bins)
        errs.append(float(jnp.abs(w - pasm.dequantize(t)).mean()))
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < 0.02  # 256 bins ≈ near-lossless for gaussians


def test_grouped_codebooks_beat_single():
    """Beyond-paper: per-group dictionaries reduce quantization error."""
    k = jax.random.PRNGKey(3)
    # heterogeneous rows: scale varies by block — groups should win
    w = jax.random.normal(k, (128, 32)) * jnp.repeat(
        jnp.array([0.1, 1.0, 5.0, 20.0]), 32
    )[:, None]
    e1 = float(jnp.abs(w - pasm.dequantize(pasm.quantize(w, 16, groups=1))).mean())
    e4 = float(jnp.abs(w - pasm.dequantize(pasm.quantize(w, 16, groups=4))).mean())
    assert e4 < e1


def test_compression_ratio_accounting():
    w = jnp.zeros((256, 256))
    t16 = pasm.quantize(w, bins=16)  # packed int4
    t256 = pasm.quantize(w, bins=256)  # uint8
    assert t16.packed and t16.idx.shape == (128, 256)
    assert not t256.packed and t256.idx.shape == (256, 256)
    # bf16 dense = 131072 B; int4 = 32768 B + codebook
    assert 3.9 < t16.compression_ratio <= 4.0
    assert 1.9 < t256.compression_ratio <= 2.0


def test_bins_bits_mapping():
    assert pasm.bits_for_bins(16) == 4
    assert pasm.bits_for_bins(17) == 8
    assert pasm.bits_for_bins(256) == 8
    with pytest.raises(ValueError):
        pasm.bits_for_bins(257)
    with pytest.raises(ValueError):
        pasm.bits_for_bins(1)


def test_quantize_like_reassigns():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    t = pasm.quantize(w, bins=16)
    w2 = w + 0.01 * jax.random.normal(jax.random.PRNGKey(1), w.shape)
    t2 = pasm.quantize_like(t, w2)
    np.testing.assert_array_equal(np.asarray(t2.codebook), np.asarray(t.codebook))
    err = float(jnp.abs(pasm.dequantize(t2) - w2).mean())
    base = float(jnp.abs(pasm.dequantize(t) - w2).mean())
    assert err <= base + 1e-6


def test_kmeans_deterministic():
    w = jax.random.normal(jax.random.PRNGKey(7), (64, 64))
    a = pasm.quantize(w, bins=16)
    b = pasm.quantize(w, bins=16)
    np.testing.assert_array_equal(np.asarray(a.idx), np.asarray(b.idx))
    np.testing.assert_array_equal(np.asarray(a.codebook), np.asarray(b.codebook))
