"""MoE: routing invariants, dropless consistency, capacity drops, grouping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.nn import moe as M


def _params(D=16, E=8, Fe=8, shared=True, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    p = {
        "router": jax.random.normal(ks[0], (D, E)) * 0.2,
        "w1": jax.random.normal(ks[1], (E, D, Fe)) * 0.2,
        "w3": jax.random.normal(ks[2], (E, D, Fe)) * 0.2,
        "w2": jax.random.normal(ks[3], (E, Fe, D)) * 0.2,
    }
    if shared:
        p["shared_w1"] = jax.random.normal(ks[4], (D, Fe)) * 0.2
        p["shared_w3"] = jax.random.normal(ks[5], (D, Fe)) * 0.2
        p["shared_w2"] = jax.random.normal(ks[6], (Fe, D)) * 0.2
    return p


def dense_reference(x, p, cfg):
    """Oracle: run every expert on every token, combine with top-k weights."""
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_w, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    y = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(x @ p["w1"][e]) * (x @ p["w3"][e])
        ye = h @ p["w2"][e]
        wgt = jnp.where(top_i == e, top_w, 0.0).sum(-1)
        y = y + ye * wgt[:, None]
    if "shared_w1" in p:
        y = y + (jax.nn.silu(x @ p["shared_w1"]) * (x @ p["shared_w3"])) @ p["shared_w2"]
    return y


def test_dropless_matches_dense_reference():
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=8, n_shared=1, d_shared=8)
    p = _params()
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    y, aux = M.moe_ffn(x, p, cfg, dropless=True)
    want = dense_reference(x, p, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=5e-3, atol=5e-3)
    assert aux == {}  # serving skips the aux reductions (§Perf kimi-prefill/4)


def test_grouped_dispatch_matches_ungrouped_dropless():
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=8, n_shared=0)
    p = _params(shared=False)
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    y1, _ = M.moe_ffn(x, p, cfg, dropless=True, n_groups=1)
    y4, _ = M.moe_ffn(x, p, cfg, dropless=True, n_groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), rtol=5e-3, atol=5e-3)


def test_capacity_drops_tokens():
    cfg = MoEConfig(n_experts=4, top_k=1, d_expert=8, n_shared=0, capacity_factor=0.25)
    p = _params(E=4, shared=False)
    # all tokens identical → all route to one expert → drops guaranteed
    x = jnp.ones((16, 16))
    y, aux = M.moe_ffn(x, p, cfg, dropless=False)
    assert float(aux["moe_drop_frac"]) > 0.4
    # dropped tokens produce zero routed output (shared experts absent)
    assert float(jnp.abs(y).sum()) > 0  # capacity keeps some


def test_load_balance_loss_range():
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=8, n_shared=0, capacity_factor=4.0)
    p = _params(shared=False)
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 16))
    _, aux = M.moe_ffn(x, p, cfg, dropless=False)  # train path computes aux
    lb = float(aux["moe_load_balance"])
    assert 0.5 < lb < 8.0  # ≈1 when balanced; E when collapsed


def test_moe_differentiable():
    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, n_shared=1, d_shared=8)
    p = _params(E=4)
    x = jax.random.normal(jax.random.PRNGKey(4), (16, 16))

    def loss(p):
        y, _ = M.moe_ffn(x, p, cfg, dropless=True)
        return (y ** 2).sum()

    g = jax.grad(loss)(p)
    flat = jax.tree_util.tree_leaves(g)
    assert all(bool(jnp.isfinite(l).all()) for l in flat)
    assert any(float(jnp.abs(l).max()) > 0 for l in flat)
