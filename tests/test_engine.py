"""Serving-engine admission: mid-decode submits must not disturb live slots.

Regression for the cache-clobbering bug: ``Engine._admit`` used to re-run
``prefill`` over the WHOLE batch whenever a free slot existed — zero tokens
in live slots — overwriting live slots' KV caches and the shared position
counter.  Admission is now CONTINUOUS (per-slot ``KVCache.pos``): a free
slot prefills batch-of-one against a fresh cache and grafts in at its slot
index, so live slots' positions and KV are untouched by construction.  The
full exactness/scheduling suite is tests/test_serve.py; these two tests
remain as the original regression surface.
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import Engine

KEY = jax.random.PRNGKey(0)


def _setup():
    cfg = get_config("stablelm-3b", smoke=True)
    model = api.get_model(cfg)
    return cfg, model.init_params(cfg, KEY)


def test_staggered_submit_preserves_live_outputs():
    """A request admitted mid-decode must not change earlier requests' output."""
    cfg, params = _setup()
    rng = np.random.default_rng(7)
    p1 = rng.integers(0, cfg.vocab, size=6)
    p2 = rng.integers(0, cfg.vocab, size=4)

    # baseline: the first request decoded with nothing else in flight
    solo = Engine(cfg, params, batch_slots=2, max_seq=64)
    r_solo = solo.submit(p1, max_new=8)
    solo.run_until_drained()

    # staggered: identical first request; second submitted mid-decode
    eng = Engine(cfg, params, batch_slots=2, max_seq=64)
    r1 = eng.submit(p1, max_new=8)
    for _ in range(3):  # r1 is now live and mid-decode
        eng.step()
    assert not r1.done
    r2 = eng.submit(p2, max_new=4)
    eng.run_until_drained()

    assert r1.done and r2.done
    assert r1.out == r_solo.out  # live slot unaffected by the later admit
    assert len(r2.out) == 4


def test_slot_reuse_does_not_leak_kv_prefix():
    """A request served in a reused slot matches the same request served
    first — the grafted fresh-cache prefill leaves no stale prefix."""
    cfg, params = _setup()
    rng = np.random.default_rng(11)
    p1 = rng.integers(0, cfg.vocab, size=5)
    p2 = rng.integers(0, cfg.vocab, size=5)

    solo = Engine(cfg, params, batch_slots=1, max_seq=64)
    want = solo.submit(p2, max_new=6)
    solo.run_until_drained()

    eng = Engine(cfg, params, batch_slots=1, max_seq=64)
    first = eng.submit(p1, max_new=6)
    second = eng.submit(p2, max_new=6)  # queued: admitted on slot release
    eng.run_until_drained()

    assert first.done and second.done
    assert second.out == want.out  # fresh grafted cache: no stale prefix
