import os
import sys
from pathlib import Path

# tests run on ONE device (the dry-run alone forces 512 placeholders)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))
