"""Continuous-batching serve subsystem: exactness, scheduling, metrics.

The load-bearing guarantees:

- CONTINUOUS ADMISSION IS EXACT: a request admitted while other slots are
  mid-decode produces a token stream bit-identical to running its prompt
  alone (transformer AND encdec — the two padded-prefill families).  This
  holds because prefill is batch-of-one against a fresh cache in both runs,
  per-slot ``KVCache.pos`` masks every slot's reads/writes at its own
  position, and decode is row-parallel at a fixed batch width.
- The left-pad bug is gone: prompts are right-padded to a length bucket and
  prefill consumes ``lengths=`` — a short prompt in a mixed-length batch
  matches its solo run (pads are structurally unattendable, never real keys).
- Slot reuse never leaks the previous occupant's KV; admission under full
  slots is FCFS; per-request ``max_new`` is honored under concurrent load;
  ``run_until_drained`` raises (and marks requests stuck) instead of
  silently returning at ``max_ticks``.
"""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_cnn_config, get_config
from repro.models import api, cnn
from repro.serve.batcher import CnnBatcher, MixedBatcher
from repro.serve.engine import Engine
from repro.serve.metrics import Metrics, percentile
from repro.serve.scheduler import Scheduler, exact_bucket, pow2_bucket

KEY = jax.random.PRNGKey(0)


@functools.lru_cache(maxsize=None)
def _setup(arch: str):
    cfg = get_config(arch, smoke=True)
    model = api.get_model(cfg)
    return cfg, model.init_params(cfg, KEY)


@functools.lru_cache(maxsize=None)
def _setup_cnn():
    ccfg = get_cnn_config("alexnet", smoke=True)
    params = cnn.quantize(cnn.init_params(ccfg, KEY), ccfg)
    return ccfg, params


def _solo_out(cfg, params, prompt, max_new, *, slots=3, max_seq=48):
    eng = Engine(cfg, params, batch_slots=slots, max_seq=max_seq)
    r = eng.submit(prompt, max_new=max_new)
    eng.run_until_drained()
    return r.out


# ---------------------------------------------------------------------------
# tentpole acceptance: continuous admission is bit-exact (transformer, encdec)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["stablelm-3b", "whisper-tiny"])
def test_continuous_admission_bit_identical(arch):
    """With slots mid-decode, a newly admitted request's full output equals
    its solo (batch-of-one prefill) run, token for token."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(3)
    probe = rng.integers(0, cfg.vocab, size=5)
    want = _solo_out(cfg, params, probe, 8)

    eng = Engine(cfg, params, batch_slots=3, max_seq=48)
    others = [
        eng.submit(rng.integers(0, cfg.vocab, size=int(n)), max_new=12)
        for n in (4, 9)
    ]
    for _ in range(3):
        eng.step()
    assert all(not o.done for o in others)  # traffic genuinely concurrent
    r = eng.submit(probe, max_new=8)
    assert eng.live  # admitted while other slots are live: no wave gate
    eng.run_until_drained()
    assert r.out == want
    assert all(o.done for o in others)


def test_mid_decode_admission_does_not_disturb_live_slots():
    cfg, params = _setup("stablelm-3b")
    rng = np.random.default_rng(5)
    p1 = rng.integers(0, cfg.vocab, size=7)
    want = _solo_out(cfg, params, p1, 10)

    eng = Engine(cfg, params, batch_slots=2, max_seq=48)
    r1 = eng.submit(p1, max_new=10)
    for _ in range(4):
        eng.step()
    eng.submit(rng.integers(0, cfg.vocab, size=3), max_new=5)
    eng.run_until_drained()
    assert r1.out == want  # the live slot never saw the admission


def test_left_pad_regression_short_prompt_in_mixed_batch():
    """Satellite: a SHORT prompt admitted alongside longer ones (same tick,
    same pow2 bucket machinery) matches its solo run — pad positions are
    never attended (right-pad + lengths; pads ≥ lengths are invalid keys)."""
    cfg, params = _setup("stablelm-3b")
    rng = np.random.default_rng(9)
    short = rng.integers(0, cfg.vocab, size=3)
    want = _solo_out(cfg, params, short, 8)

    eng = Engine(cfg, params, batch_slots=3, max_seq=48)
    eng.submit(rng.integers(0, cfg.vocab, size=11), max_new=8)
    r = eng.submit(short, max_new=8)  # same admission tick as the long one
    eng.submit(rng.integers(0, cfg.vocab, size=8), max_new=8)
    eng.run_until_drained()
    assert r.out == want


# ---------------------------------------------------------------------------
# satellite: scheduler invariants under continuous batching
# ---------------------------------------------------------------------------


def test_slot_reuse_never_leaks_prior_kv():
    """More requests than slots: a request served in a REUSED slot matches
    its solo run (prefill grafts a fresh cache; no stale attention prefix)."""
    cfg, params = _setup("stablelm-3b")
    rng = np.random.default_rng(11)
    probe = rng.integers(0, cfg.vocab, size=6)
    want = _solo_out(cfg, params, probe, 6, slots=1)

    eng = Engine(cfg, params, batch_slots=1, max_seq=48)
    first = eng.submit(rng.integers(0, cfg.vocab, size=6), max_new=6)
    second = eng.submit(probe, max_new=6)  # queued; reuses slot 0 afterwards
    eng.run_until_drained()
    assert first.done and second.done
    assert second.slot == first.slot
    assert second.out == want


def test_admission_under_full_slots_is_fcfs():
    cfg, params = _setup("stablelm-3b")
    rng = np.random.default_rng(13)
    eng = Engine(cfg, params, batch_slots=2, max_seq=48)
    reqs = [eng.submit(rng.integers(0, cfg.vocab, size=4), max_new=3 + i)
            for i in range(5)]
    eng.run_until_drained()
    admits = [eng.metrics.timelines[r.uid].t_admit for r in reqs]
    assert admits == sorted(admits)  # FCFS: admitted in submit order
    assert all(r.done for r in reqs)


def test_per_request_max_new_honored():
    cfg, params = _setup("stablelm-3b")
    rng = np.random.default_rng(17)
    eng = Engine(cfg, params, batch_slots=3, max_seq=48)
    reqs = [eng.submit(rng.integers(0, cfg.vocab, size=5), max_new=n)
            for n in (2, 7, 4, 9)]
    eng.run_until_drained()
    assert [len(r.out) for r in reqs] == [2, 7, 4, 9]


def test_scheduler_unit_fcfs_buckets_release():
    class R:
        def __init__(self, uid, n):
            self.uid, self.prompt = uid, list(range(n))

    s = Scheduler(2, bucket_fn=pow2_bucket, max_seq=64)
    for uid, n in ((1, 3), (2, 9), (3, 5)):
        s.submit(R(uid, n))
    plans = s.admit()
    assert [(p.req.uid, p.slot, p.bucket) for p in plans] == [(1, 0, 8), (2, 1, 16)]
    assert s.queue_depth == 1 and not s.free_slots
    assert s.admit() == []  # full: uid 3 stays queued
    s.release(0)
    (p,) = s.admit()
    assert (p.req.uid, p.slot, p.bucket) == (3, 0, 8)
    assert exact_bucket(5) == 5 and pow2_bucket(17, hi=16) == 16
    with pytest.raises(ValueError):
        s.submit(R(9, 99))  # prompt longer than max_seq


# ---------------------------------------------------------------------------
# satellite: run_until_drained must not silently return undrained
# ---------------------------------------------------------------------------


def test_run_until_drained_raises_and_marks_stuck():
    cfg, params = _setup("stablelm-3b")
    rng = np.random.default_rng(19)
    eng = Engine(cfg, params, batch_slots=1, max_seq=48)
    r1 = eng.submit(rng.integers(0, cfg.vocab, size=4), max_new=40)
    r2 = eng.submit(rng.integers(0, cfg.vocab, size=4), max_new=40)  # queued
    with pytest.raises(RuntimeError, match="undrained"):
        eng.run_until_drained(max_ticks=3)
    assert r1.stuck and r2.stuck
    assert r1.status == "stuck"
    assert eng.metrics.rollup()["n_stuck"] == 2

    # non-strict: a REAL warning (assertable, filterable — not a bare print),
    # and the engine can still be driven to drain
    eng2 = Engine(cfg, params, batch_slots=1, max_seq=48)
    r = eng2.submit(rng.integers(0, cfg.vocab, size=4), max_new=30)
    with pytest.warns(RuntimeWarning, match="undrained"):
        t = eng2.run_until_drained(max_ticks=2, strict=False)
    assert t == 2 and r.stuck and not r.done
    eng2.run_until_drained()
    assert r.done


def test_submit_validates_total_kv_footprint():
    """Regression: prompt + max_new - 1 must fit max_seq — a long prompt
    with the default max_new used to decode past the KV cache end and
    silently wrap/clobber.  The boundary case (exact fit) must pass."""
    cfg, params = _setup("stablelm-3b")
    rng = np.random.default_rng(29)
    eng = Engine(cfg, params, batch_slots=1, max_seq=32)
    with pytest.raises(ValueError, match="wrap"):
        eng.submit(rng.integers(0, cfg.vocab, size=30), max_new=16)
    with pytest.raises(ValueError, match="wrap"):
        eng.submit(rng.integers(0, cfg.vocab, size=20), max_new=14)
    r = eng.submit(rng.integers(0, cfg.vocab, size=20), max_new=13)  # 20+13-1=32
    eng.run_until_drained()
    assert r.done and len(r.out) == 13


# ---------------------------------------------------------------------------
# metrics + mixed LM/CNN dataflow
# ---------------------------------------------------------------------------


def test_metrics_rollup_deterministic_clock():
    t = [0.0]
    m = Metrics(clock=lambda: t[0])
    for uid, (dt_admit, dt_done, slo) in enumerate(
        [(1.0, 5.0, 10.0), (2.0, 8.0, 4.0)], start=1
    ):
        t[0] = 0.0
        m.submit(uid, "lm", slo_s=slo)
        t[0] = dt_admit
        m.mark_admit(uid)
        m.mark_first(uid)
        t[0] = dt_done
        m.mark_done(uid, n_out=4)
    roll = m.rollup()
    assert roll["lm_p50_latency_s"] == 5.0 and roll["lm_p99_latency_s"] == 8.0
    assert roll["slo_met"] == 1 and roll["slo_missed"] == 1
    assert percentile([], 50) != percentile([], 50)  # nan on empty
    assert percentile([3.0, 1.0, 2.0], 50) == 2.0


def test_cnn_batcher_buckets_and_pad_equivalence():
    ccfg, cparams = _setup_cnn()
    b = CnnBatcher(ccfg, cparams, max_batch=3)
    rng = np.random.default_rng(2)
    img = rng.standard_normal((3, 14, 18)).astype(np.float32)
    native = np.zeros((3,) + ccfg.in_chw[1:], np.float32)
    native[:, :14, :18] = img
    r_small = b.submit(img)
    r_full = b.submit(native)
    assert r_small.bucket == (16, 32) or r_small.bucket[0] >= 14
    b.flush()
    assert r_small.done and r_full.done
    # bucket→native zero-pad inside the jit is the same image the native
    # request classifies: identical logits ⇒ identical class
    assert r_small.cls == r_full.cls
    with pytest.raises(ValueError):
        b.submit(rng.standard_normal((3, 64, 64)).astype(np.float32))


def test_mixed_lm_cnn_traffic_drains_both():
    cfg, params = _setup("stablelm-3b")
    ccfg, cparams = _setup_cnn()
    metrics = Metrics()
    eng = Engine(cfg, params, batch_slots=2, max_seq=48, metrics=metrics)
    b = CnnBatcher(ccfg, cparams, max_batch=2, metrics=metrics)
    mix = MixedBatcher(eng, b)
    rng = np.random.default_rng(23)
    lm = [eng.submit(rng.integers(0, cfg.vocab, size=5), max_new=4) for _ in range(3)]
    im = [b.submit(rng.standard_normal((3, 16, 16)).astype(np.float32))
          for _ in range(3)]
    mix.run_until_drained(max_ticks=100)
    assert all(r.done for r in lm) and all(r.done for r in im)
    roll = metrics.rollup()
    assert roll["lm_n"] == 3 and roll["cnn_n"] == 3
    assert roll["tok_s"] > 0 and roll["img_s"] > 0
    assert 0 < roll["mean_occupancy"] <= 1
