"""Mamba-2 SSD: chunked scan vs naive recurrence; decode step consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import ssm as S


def naive_ssd(x, dt, A, Bm, Cm, D):
    """Token-by-token recurrence oracle: h = e^{dtA} h + dt·B⊗x; y = C·h + Dx."""
    Bsz, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    h = np.zeros((Bsz, H, P, N), np.float64)
    ys = np.zeros((Bsz, T, H, P), np.float64)
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    Af = np.asarray(A, np.float64)
    Bf = np.repeat(np.asarray(Bm, np.float64), rep, axis=2)
    Cf = np.repeat(np.asarray(Cm, np.float64), rep, axis=2)
    Df = np.asarray(D, np.float64)
    for t in range(T):
        decay = np.exp(dtf[:, t] * Af[None])  # (B, H)
        h = h * decay[..., None, None] + np.einsum(
            "bh,bhn,bhp->bhpn", dtf[:, t], Bf[:, t], xf[:, t]
        )
        ys[:, t] = np.einsum("bhpn,bhn->bhp", h, Cf[:, t]) + xf[:, t] * Df[None, :, None]
    return ys, h


def _inputs(Bsz=2, T=32, H=4, P=8, G=1, N=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (Bsz, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, T, H)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (Bsz, T, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (Bsz, T, G, N)) * 0.5
    D = jnp.ones((H,))
    return x, dt, A, Bm, Cm, D


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_chunked_ssd_matches_recurrence(chunk):
    x, dt, A, Bm, Cm, D = _inputs()
    y, h = S.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk)
    y_ref, h_ref = naive_ssd(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-3, atol=2e-3)


def test_non_divisible_seq_padding():
    x, dt, A, Bm, Cm, D = _inputs(T=27)
    y, h = S.ssd_scan(x, dt, A, Bm, Cm, D, chunk=8)
    y_ref, h_ref = naive_ssd(x, dt, A, Bm, Cm, D)
    assert y.shape[1] == 27
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-3, atol=2e-3)


def test_multi_group():
    x, dt, A, Bm, Cm, D = _inputs(H=8, G=2)
    y, h = S.ssd_scan(x, dt, A, Bm, Cm, D, chunk=8)
    y_ref, _ = naive_ssd(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)


def test_decode_steps_match_scan():
    x, dt, A, Bm, Cm, D = _inputs(T=8)
    y_scan, h_scan = S.ssd_scan(x, dt, A, Bm, Cm, D, chunk=4)
    h = jnp.zeros_like(h_scan)
    ys = []
    for t in range(8):
        y, h = S.ssd_decode_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D, h)
        ys.append(y)
    y_steps = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_steps), np.asarray(y_scan), rtol=2e-3, atol=2e-3
    )
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_scan), rtol=2e-3, atol=2e-3)


def test_init_state_continuation():
    """scan(first half) + scan(second half, init_state) == scan(full)."""
    x, dt, A, Bm, Cm, D = _inputs(T=32)
    y_full, h_full = S.ssd_scan(x, dt, A, Bm, Cm, D, chunk=8)
    y1, h1 = S.ssd_scan(x[:, :16], dt[:, :16], A, Bm[:, :16], Cm[:, :16], D, chunk=8)
    y2, h2 = S.ssd_scan(
        x[:, 16:], dt[:, 16:], A, Bm[:, 16:], Cm[:, 16:], D, chunk=8, init_state=h1
    )
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 16:]), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), rtol=2e-3, atol=2e-3)
