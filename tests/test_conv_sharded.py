"""Mesh-aware conv dispatch: sharded outputs are bit-exact vs single-device.

Runs on ≥4 host-platform fake devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — scripts/ci.sh);
skips itself on the tier-1 single-device run.

Covers the multi_layer_refactor acceptance criteria:

* ``conv2d(mesh=)`` is **bit-exact** (``assert_array_equal``) against the
  single-device path for all four param kinds — dense / shared / packed /
  grouped — on both Pallas engines (explicit and implicit) plus the PAS
  pair and the sharded einsum, on a 4-way data mesh and a (2, 2)
  data×model mesh.
* an uneven batch remainder (B % n_data != 0) pads zero images in and
  slices them off; the bitwise comparison point is the single-device run of
  the same padded batch (that IS the sharded semantic — on fake-device CPU,
  XLA's threaded dot may pick a different K-reduction strategy when the
  *global* M changes, so the unpadded run is compared with allclose).
* a ``model``-axis size that doesn't divide ``c_out`` falls back to
  N-replicated weights while ``data`` still shards — and stays bit-exact.
* the AlexNet-style stack forward runs end-to-end under shard_map with the
  models/sharding.py pspecs (idx/bias really sharded — no replicated
  fallback), bit-exact vs the single-device stack.
* the fused conv/ReLU/max-pool stage under a mesh: every Pallas engine
  fuses now — implicit pool windows live inside ``data``-sharded images,
  and explicit window-major patch rows split per image in whole pool
  windows (the PR-5 explicit carve-out is closed).
* slab streaming under a mesh: a per-shard image past ``vmem_budget``
  streams as row-band slabs inside the shard_map body, bit-exact.
* the epilogue-fused collective: with ``gather_output=True`` (the
  default) the inter-layer all-gather rides inside the sharded kernel
  body, so consecutive model-sharded conv layers show NO XLA
  all-gather/resharding between their pallas_calls in the jaxpr.
* ``models/sharding.py`` CNN pspec rules and ``ops.conv_hbm_bytes(shards=)``
  per-device traffic accounting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import conv as cv
from repro.kernels import ops
from repro.models import sharding as sh

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >=4 devices; run under "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8 (scripts/ci.sh)",
)


def _mesh(shape):
    from repro.launch.mesh import make_conv_mesh

    return make_conv_mesh(shape)


def _mk(conv: cv.Conv2D, seed=0, batch=8, hw=(13, 11)):
    ih, iw = hw
    shape = (batch, ih, iw, conv.c_in) if conv.layout == "NHWC" \
        else (batch, conv.c_in, ih, iw)
    imgs = jax.random.normal(jax.random.PRNGKey(seed), shape)
    kern = jax.random.normal(
        jax.random.PRNGKey(seed + 1), (conv.c_out, conv.c_in, conv.ky, conv.kx)
    ) * conv.K ** -0.5
    bias = jnp.linspace(-0.5, 0.5, conv.c_out)
    return imgs, kern, bias


def _params(kind: str, kern, bias):
    if kind == "dense":
        return cv.ConvParams.dense(kern, bias=bias)
    if kind == "shared":
        return cv.ConvParams.quantize(kern, 16, bias=bias)
    if kind == "packed":
        return cv.ConvParams.quantize(kern, 16, bias=bias).pack()
    return cv.ConvParams.quantize(kern, 8, bias=bias, groups=3)  # grouped


_ENGINES = {
    "dense": ("einsum",),
    "shared": ("kernel", "kernel_implicit", "pas_kernel", "pas_kernel_implicit"),
    "packed": ("kernel", "kernel_implicit"),
    "grouped": ("kernel", "kernel_implicit"),
}


# ---------------------------------------------------------------------------
# bit-exactness: every param kind, every engine, data and data×model meshes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mesh_shape", [(4, 1), (2, 2)])
@pytest.mark.parametrize("kind", ["dense", "shared", "packed", "grouped"])
def test_sharded_bitexact_all_kinds(kind, mesh_shape):
    mesh = _mesh(mesh_shape)
    conv = cv.Conv2D(k=3, c_in=5, c_out=8, stride=1, padding="same", relu=True)
    imgs, kern, bias = _mk(conv)
    p = _params(kind, kern, bias)
    for engine in _ENGINES[kind]:
        want = cv.conv2d(imgs, p, conv, engine=engine, interpret=True)
        got = cv.conv2d(imgs, p, conv, engine=engine, interpret=True, mesh=mesh)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want), err_msg=f"{kind}/{engine}"
        )


def test_sharded_bitexact_nhwc_stride():
    """Layout/stride coverage on the (2, 2) mesh, both Pallas engines."""
    mesh = _mesh((2, 2))
    conv = cv.Conv2D(k=3, c_in=6, c_out=16, stride=2, padding="same",
                     layout="NHWC", relu=True)
    imgs, kern, bias = _mk(conv)
    p = cv.ConvParams.quantize(kern, 16, bias=bias)
    for engine in ("kernel", "kernel_implicit"):
        want = cv.conv2d(imgs, p, conv, engine=engine, interpret=True)
        got = cv.conv2d(imgs, p, conv, engine=engine, interpret=True, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=engine)


def test_sharded_fused_pool_bitexact():
    """The fused conv/ReLU/max-pool stage under a mesh: EVERY Pallas engine
    fuses now — implicit pool windows live inside ``data``-sharded images,
    and the explicit engines' window-major patch rows split per image
    (``(B/n_data)·P_rows``, always whole ``pool²`` windows — the PR-5
    carve-out is closed) — all bit-exact vs the single-device fused call on
    (4, 1) and (2, 2) meshes, uneven batch included."""
    conv = cv.Conv2D(k=3, c_in=5, c_out=8, stride=1, padding="same", relu=True)
    imgs, kern, bias = _mk(conv)
    p = cv.ConvParams.quantize(kern, 16, bias=bias)
    for engine in ("kernel_implicit", "kernel", "pas_kernel",
                   "pas_kernel_implicit"):
        want = cv.conv2d(imgs, p, conv, engine=engine, interpret=True,
                         pool=2, pool_impl="fused")
        for mesh_shape in ((4, 1), (2, 2)):
            mesh = _mesh(mesh_shape)
            got = cv.conv2d(imgs, p, conv, engine=engine, interpret=True,
                            pool=2, pool_impl="fused", mesh=mesh)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want),
                err_msg=f"{engine}/{mesh_shape}",
            )
    mesh = _mesh((4, 1))
    # uneven batch: compare against the padded single-device fused run (the
    # sharded semantic, as in test_uneven_batch_remainder) — the padded
    # batch divides data, so even the explicit engine's shard rows stay on
    # whole pool windows
    imgs6 = imgs[:6]
    padded = jnp.pad(imgs6, ((0, 2),) + ((0, 0),) * 3)
    for engine in ("kernel_implicit", "kernel"):
        got6 = cv.conv2d(imgs6, p, conv, engine=engine, interpret=True,
                         pool=2, mesh=mesh)
        want6 = cv.conv2d(padded, p, conv, engine=engine,
                          interpret=True, pool=2)[:6]
        np.testing.assert_array_equal(np.asarray(got6), np.asarray(want6),
                                      err_msg=engine)


# ---------------------------------------------------------------------------
# slab streaming + the epilogue-fused collective under a mesh
# ---------------------------------------------------------------------------


def test_sharded_slab_bitexact():
    """A tight ``vmem_budget`` splits each shard's image into row-band
    slabs INSIDE the shard_map body — bit-exact vs the un-slabbed
    single-device call on both implicit engines and both mesh shapes
    (slab planning sees per-shard operands, so sharding must not move
    the k-tile sequence either)."""
    conv = cv.Conv2D(k=3, c_in=4, c_out=8, stride=1, padding="same",
                     relu=True)
    imgs, kern, bias = _mk(conv, hw=(24, 16))
    p = cv.ConvParams.quantize(kern, 16, bias=bias)
    budget = 60_000  # n_slabs=3 at 24×16 (test_slab_bitexact_all_engines)
    assert not cv._implicit_fits(conv, 24, 16, budget, params=p)
    for engine in ("kernel_implicit", "pas_kernel_implicit"):
        want = cv.conv2d(imgs, p, conv, engine=engine, interpret=True)
        for mesh_shape in ((4, 1), (2, 2)):
            got = cv.conv2d(imgs, p, conv, engine=engine, interpret=True,
                            mesh=_mesh(mesh_shape), vmem_budget=budget)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want),
                err_msg=f"{engine}/{mesh_shape}",
            )


def _deep_names(jaxpr):
    out = []
    for e in jaxpr.eqns:
        out.append(e.primitive.name)
        for v in e.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else [v]):
                if hasattr(sub, "eqns"):
                    out += _deep_names(sub)
                elif hasattr(sub, "jaxpr"):
                    out += _deep_names(sub.jaxpr)
    return out


def test_fused_collective_no_resharding_between_layers():
    """Acceptance: with model-sharded c_out, the inter-layer all-gather
    rides INSIDE each conv's shard_map body (the kernel epilogue), so the
    stack jaxpr shows zero collectives between consecutive conv
    pallas_calls — activations leave every layer model-replicated and XLA
    has nothing to reshard."""
    import dataclasses as dc

    from repro.configs import get_cnn_config
    from repro.models import cnn

    mesh = _mesh((4, 2))
    cfg = dc.replace(get_cnn_config("alexnet", smoke=True),
                     mesh_shape=(4, 2), impl="kernel_implicit")
    params = cnn.init_params(cfg, jax.random.PRNGKey(0))
    qpm = cnn.quantize(params, cfg, mesh=mesh)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (8, *cfg.in_chw))
    jx = jax.make_jaxpr(
        lambda x: cnn.forward(qpm, x, cfg, interpret=True, mesh=mesh))(imgs)

    top, bodies = [], []

    def walk(jaxpr):
        for e in jaxpr.eqns:
            if e.primitive.name == "shard_map":
                bodies.append(_deep_names(e.params["jaxpr"]))
                continue
            top.append(e.primitive.name)
            for v in e.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else [v]):
                    if hasattr(sub, "eqns"):
                        walk(sub)
                    elif hasattr(sub, "jaxpr"):
                        walk(sub.jaxpr)

    walk(jx.jaxpr)
    conv_bodies = [b for b in bodies if "pallas_call" in b]
    assert len(conv_bodies) == len(cfg.layers)
    for b in conv_bodies:  # ONE kernel + ONE epilogue gather per layer
        assert b.count("pallas_call") == 1 and b.count("all_gather") == 1
    collectives = {"all_gather", "psum", "all_to_all", "ppermute",
                   "reduce_scatter"}
    assert not [n for n in top if n in collectives]  # nothing between layers


# ---------------------------------------------------------------------------
# uneven batch remainder + indivisible c_out
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["kernel", "kernel_implicit"])
def test_uneven_batch_remainder(engine):
    """B=6 on a 4-way data mesh: two zero images pad in, slice back off.

    The sharded run computes the padded batch, so the bitwise comparison
    point is the single-device padded-batch run; the unpadded single-device
    run agrees to float tolerance (XLA's CPU dot may re-tile its reduction
    when the global M changes — on TPU the Pallas tile plan pins the order).
    """
    mesh = _mesh((4, 1))
    conv = cv.Conv2D(k=3, c_in=5, c_out=8, stride=1, padding="same", relu=True)
    imgs, kern, bias = _mk(conv, batch=6)
    p = cv.ConvParams.quantize(kern, 16, bias=bias)
    got = cv.conv2d(imgs, p, conv, engine=engine, interpret=True, mesh=mesh)
    assert got.shape[0] == 6
    padded = jnp.pad(imgs, ((0, 2),) + ((0, 0),) * 3)
    want_pad = cv.conv2d(padded, p, conv, engine=engine, interpret=True)[:6]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want_pad))
    want = cv.conv2d(imgs, p, conv, engine=engine, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert sh.conv_batch_pad(6, 4) == 2 and sh.conv_batch_pad(8, 4) == 0


def test_model_axis_does_not_divide_c_out():
    """c_out=7 on a model=2 axis: weights N-replicate, data still shards,
    outputs stay bit-exact (the per-engine replicated-or-N-sharded rule)."""
    mesh = _mesh((2, 2))
    conv = cv.Conv2D(k=3, c_in=5, c_out=7, stride=1, padding="same", relu=True)
    imgs, kern, bias = _mk(conv)
    p = cv.ConvParams.quantize(kern, 16, bias=bias)
    for engine in ("kernel", "kernel_implicit", "pas_kernel"):
        want = cv.conv2d(imgs, p, conv, engine=engine, interpret=True)
        got = cv.conv2d(imgs, p, conv, engine=engine, interpret=True, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=engine)


def test_mesh_rejects_single_image_and_pas_einsum():
    mesh = _mesh((4, 1))
    conv = cv.Conv2D(k=3, c_in=5, c_out=8)
    imgs, kern, bias = _mk(conv, hw=(9, 9))
    p = cv.ConvParams.quantize(kern, 16, bias=bias)
    with pytest.raises(ValueError, match="batched"):
        cv.conv2d(imgs[0], p, conv, engine="kernel", interpret=True, mesh=mesh)
    with pytest.raises(ValueError, match="pas_einsum"):
        cv.conv2d(imgs, p, conv, engine="pas_einsum", interpret=True, mesh=mesh)


# ---------------------------------------------------------------------------
# the AlexNet-style stack under shard_map with models/sharding.py pspecs
# ---------------------------------------------------------------------------


def test_cnn_stack_sharded_bitexact():
    """Acceptance: the stack forward runs under shard_map with pspec-placed
    params (no replicated fallback on idx/bias/head) and matches the
    single-device forward bitwise."""
    import dataclasses as dc

    from repro.configs import get_cnn_config
    from repro.models import cnn

    mesh = _mesh((4, 2))
    cfg = dc.replace(get_cnn_config("alexnet", smoke=True), mesh_shape=(4, 2))
    params = cnn.init_params(cfg, jax.random.PRNGKey(0))
    qp = cnn.quantize(params, cfg)
    qpm = cnn.quantize(params, cfg, mesh=mesh)

    # placement really shards: every conv idx/bias leaf carries 'model'
    specs = sh.conv_param_pspecs(qpm, {"data": 4, "model": 2})
    for i, spec in enumerate(specs["conv"]):
        assert spec.idx == P("model", None, None, None), (i, spec.idx)
        assert spec.bias == P("model"), (i, spec.bias)
        assert spec.codebook == P(None), (i, spec.codebook)
    assert specs["head"]["w"] == P(None, "model")
    for leaf in jax.tree.leaves(qpm):
        assert len(leaf.sharding.device_set) == 8, leaf.shape

    imgs = jax.random.normal(jax.random.PRNGKey(1), (8, *cfg.in_chw))
    want = cnn.forward(qp, imgs, cfg, interpret=True)
    got = cnn.forward(qpm, imgs, cfg, interpret=True, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cnn_stack_sharded_uneven_batch():
    """Stack-level remainder handling: B=6 over a 4-way data axis."""
    import dataclasses as dc

    from repro.configs import get_cnn_config
    from repro.models import cnn

    mesh = _mesh((4, 1))
    cfg = dc.replace(get_cnn_config("alexnet", smoke=True), impl="kernel_implicit")
    params = cnn.init_params(cfg, jax.random.PRNGKey(0))
    qp = cnn.quantize(params, cfg, mesh=mesh)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (6, *cfg.in_chw))
    got = cnn.forward(qp, imgs, cfg, interpret=True, mesh=mesh)
    want = cnn.forward(cnn.quantize(params, cfg), imgs, cfg, interpret=True)
    assert got.shape == (6, cfg.classes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# pspec rules + per-device traffic model
# ---------------------------------------------------------------------------


def test_conv_param_pspec_rules():
    conv = cv.Conv2D(k=3, c_in=4, c_out=8)
    kern = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 3, 3))
    bias = jnp.zeros((8,))
    params = {
        "conv": [
            cv.ConvParams.dense(kern, bias=bias),
            cv.ConvParams.quantize(kern, 16, bias=bias),
            cv.ConvParams.quantize(kern, 16, bias=bias).pack(),
        ],
        "head": {"w": jnp.zeros((32, 10)), "b": jnp.zeros((10,))},
    }
    ax = {"data": 4, "model": 2}
    specs = sh.conv_param_pspecs(params, ax)
    assert specs["conv"][0].kernel == P("model", None, None, None)
    assert specs["conv"][1].idx == P("model", None, None, None)
    assert specs["conv"][1].codebook == P(None)
    assert specs["conv"][2].idx == P(None, "model")  # packed: (Kp//2, c_out)
    assert specs["conv"][2].bias == P("model")
    assert specs["head"]["w"] == P(None, "model")
    assert specs["head"]["b"] == P("model")
    # indivisible c_out (7 % 2) falls back to replication — matching dispatch
    k7 = kern[:7]
    p7 = {"conv": [cv.ConvParams.quantize(k7, 16, bias=bias[:7])], "head": {}}
    s7 = sh.conv_param_pspecs(p7, ax)
    assert s7["conv"][0].idx == P(None, None, None, None)
    assert s7["conv"][0].bias == P(None)
    # inputs: batch over data
    assert sh.conv_input_pspecs() == P("data", None, None, None)


def test_per_device_hbm_bytes_strictly_below_single():
    """The --devices N accounting: sharding AlexNet conv1's batch over 8
    devices models strictly fewer per-device bytes than one device moving
    the whole batch — weights replicate, activations/outputs split."""
    conv = cv.Conv2D(k=11, c_in=3, c_out=96, stride=4, relu=True)
    kern = jax.random.normal(jax.random.PRNGKey(0), (96, 3, 11, 11))
    t = cv.ConvParams.quantize(kern, 16).gemm_tensor("NCHW")
    geom = cv.conv_geom(conv, 224, 224)
    for implicit in (True, False):
        single = ops.conv_hbm_bytes(t, geom, 8, 224, 224, implicit=implicit)
        per_dev = ops.conv_hbm_bytes(t, geom, 8, 224, 224, implicit=implicit,
                                     shards=(8, 1))
        assert per_dev < single, (implicit, per_dev, single)
        # activations split 8x; the replicated idx/codebook bound the gap
        assert single / per_dev > 4, (implicit, per_dev, single)
    # model-axis sharding additionally splits the idx stream — visible once
    # the local N still spans whole bn tiles (conv2: 256 → 128 per device;
    # conv1's 96 pads to one 128 tile sharded or not)
    conv2 = cv.Conv2D(k=5, c_in=96, c_out=256, stride=1, relu=True)
    k2 = jax.random.normal(jax.random.PRNGKey(1), (256, 96, 5, 5))
    t2 = cv.ConvParams.quantize(k2, 16).gemm_tensor("NCHW")
    g2 = cv.conv_geom(conv2, 27, 27)
    dm = ops.conv_hbm_bytes(t2, g2, 8, 27, 27, implicit=True, shards=(4, 2))
    d = ops.conv_hbm_bytes(t2, g2, 8, 27, 27, implicit=True, shards=(4, 1))
    assert dm < d
    # uneven batch: the remainder rounds up (pad images are real traffic)
    assert ops.conv_hbm_bytes(
        t, geom, 9, 224, 224, implicit=True, shards=(8, 1)
    ) == ops.conv_hbm_bytes(t, geom, 16, 224, 224, implicit=True, shards=(8, 1))
