"""Sharding rules: specs by path, divisibility fallback, ZeRO/FSDP derivation."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import api, sharding
from repro.models.common import quantize_params

AX = {"data": 16, "model": 16}


def test_param_spec_rules():
    params = {
        "embed": jnp.zeros((1600, 64)),
        "layers": {
            "attn": {"wq": jnp.zeros((2, 64, 256)), "wo": jnp.zeros((2, 256, 64))},
            "mlp": {"w1": jnp.zeros((2, 64, 256)), "w2": jnp.zeros((2, 256, 64))},
            "attn_norm": jnp.zeros((2, 64)),
            "moe": {"w1": jnp.zeros((2, 32, 64, 256)), "router": jnp.zeros((2, 64, 32))},
        },
        "lm_head": jnp.zeros((64, 1600)),
    }
    sp = sharding.param_pspecs(params, AX)
    assert sp["embed"] == P("model", None)
    assert sp["layers"]["attn"]["wq"] == P(None, None, "model")
    assert sp["layers"]["attn"]["wo"] == P(None, "model", None)
    assert sp["layers"]["mlp"]["w2"] == P(None, "model", None)
    assert sp["layers"]["attn_norm"] == P(None, None)
    assert sp["layers"]["moe"]["w1"] == P(None, "model", None, "data")
    assert sp["layers"]["moe"]["router"] == P(None, None, None)
    assert sp["lm_head"] == P(None, "model")


def test_indivisible_falls_back_to_replicated():
    params = {"attn": {"wq": jnp.zeros((10, 24))}}  # 24 % 16 != 0
    sp = sharding.param_pspecs(params, AX)
    assert sp["attn"]["wq"] == P(None, None)


def test_pasm_leaves_get_specs():
    cfg = get_config("qwen3-32b", smoke=True).with_quant(
        enabled=True, bins=64, min_weight_elems=64
    )
    model = api.get_model(cfg)
    params = quantize_params(model.init_params(cfg, jax.random.PRNGKey(0)), cfg)
    sp = sharding.param_pspecs(params, {"data": 2, "model": 2})
    # idx inherits the parent weight layout; codebook replicated
    wq = sp["layers"]["attn"]["wq"]
    assert wq.idx == P(None, None, "model")
    assert wq.codebook == P(None, None, None)


def test_zero1_opt_specs_add_data():
    params = {"w1": jnp.zeros((64, 256))}
    base = sharding.param_pspecs(params, AX)
    z = sharding.opt_state_pspecs(params, base, AX)
    # w1 is (None, model): ZeRO shards dim0 over data
    assert z["w1"] == P("data", "model")


def test_zero1_skips_already_data_sharded():
    params = {"moe": {"w1": jnp.zeros((32, 64, 256))}}
    base = sharding.param_pspecs(params, AX)
    z = sharding.opt_state_pspecs(params, base, AX)
    assert z["moe"]["w1"] == base["moe"]["w1"]  # 2-D expert sharding untouched


def test_cache_specs_kv_heads_vs_seq():
    from repro.nn.attention import KVCache

    # kv=32 divisible by 16 → heads sharded
    c1 = {"scan": KVCache(k=jnp.zeros((2, 8, 64, 32, 16)), v=jnp.zeros((2, 8, 64, 32, 16)), pos=jnp.zeros((2,), jnp.int32))}
    cfg = get_config("stablelm-3b")
    sp = sharding.cache_pspecs(cfg, c1, AX, ("data",))
    assert sp["scan"].k == P(None, ("data",), None, "model", None)
    # kv=8 not divisible by 16 → sequence sharded
    cfg2 = get_config("qwen3-32b")
    c2 = {"scan": KVCache(k=jnp.zeros((2, 8, 64, 8, 16)), v=jnp.zeros((2, 8, 64, 8, 16)), pos=jnp.zeros((2,), jnp.int32))}
    sp2 = sharding.cache_pspecs(cfg2, c2, AX, ("data",))
    assert sp2["scan"].k == P(None, ("data",), "model", None, None)


def test_batch_axes_adaptive():
    assert sharding.batch_axes(False, 256) == ("data",)
    assert sharding.batch_axes(True, 256) == ("pod", "data")
    assert sharding.batch_axes(False, 1) == ()  # long_500k: batch unshardable
