"""Fused conv/ReLU/max-pool epilogue: one pallas_call per CNN stage (PR 5).

Covers the perf_opt acceptance criteria:

* ``conv2d(pool=)`` with the fused epilogue is **bit-exact** against
  ``conv2d`` + ``reduce_window`` (``max_pool2d``) for shared / packed /
  grouped params on the explicit and implicit engines, both layouts, odd
  spatial sizes (floor/VALID windowing) and pool ∈ {2, 3}.
* ``pool=1`` is an exact passthrough of the unpooled call.
* dispatch rules: ``auto`` fuses only where a pool-aligned tile plan exists
  (Pallas engines, whole windows, ``lcm(pool², 8) ≤ 256``, implicit-only
  under a mesh); everything else takes the bit-exact ``reduce_window``
  fallback; ``pool_impl="fused"`` raises where fusion is impossible.
* the pooled custom VJP routes gradients through the argmax mask (shared and
  packed params, explicit and implicit engines) and matches the einsum +
  ``reduce_window`` reference.
* ``max_pool2d`` pools integer/quantized dtypes exactly (``jnp.iinfo`` init —
  the former unconditional ``-jnp.inf`` init fails the integer
  ``reduce_window`` dtype check) and keeps the float max identity (``-inf``)
  so the fallback stays differentiable.
* jaxpr inspection: a fused conv/ReLU/pool stage is exactly ONE
  ``pallas_call`` with no ``reduce_window`` — and the unfused stage HAS one,
  so the assertion is meaningful.
* the traffic models: the fused stage's modeled bytes sit strictly below
  implicit-unfused + the separate pool pass on the AlexNet conv1 geometry
  (the ci.sh gate's numbers).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import conv as cv
from repro.core import hwmodel as hw
from repro.kernels import ops


def _mk(conv: cv.Conv2D, bins=16, seed=0, batch=2, hw=(13, 11)):
    ih, iw = hw
    shape = (batch, ih, iw, conv.c_in) if conv.layout == "NHWC" \
        else (batch, conv.c_in, ih, iw)
    imgs = jax.random.normal(jax.random.PRNGKey(seed), shape)
    kern = jax.random.normal(
        jax.random.PRNGKey(seed + 1), (conv.c_out, conv.c_in, conv.ky, conv.kx)
    ) * conv.K ** -0.5
    bias = jnp.linspace(-0.5, 0.5, conv.c_out)
    return imgs, kern, bias


def _oracle(imgs, params, conv, engine, pool):
    """conv2d + the separate reduce_window — the unfused ground truth."""
    y = cv.conv2d(imgs, params, conv, engine=engine, interpret=True)
    return cv.max_pool2d(y, pool, conv.layout)


# ---------------------------------------------------------------------------
# bit-exactness: fused epilogue vs conv + reduce_window
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["kernel", "kernel_implicit"])
@pytest.mark.parametrize("layout", ["NCHW", "NHWC"])
def test_fused_pool_bitexact_odd_spatial(engine, layout):
    """13×11 SAME output pools 2 with floor (6×5) — remainder row/col dropped
    identically on both paths, NCHW and NHWC."""
    conv = cv.Conv2D(k=3, c_in=5, c_out=8, padding="same", layout=layout,
                     relu=True)
    imgs, kern, bias = _mk(conv)
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)
    want = _oracle(imgs, shared, conv, engine, 2)
    got = cv.conv2d(imgs, shared, conv, engine=engine, interpret=True, pool=2,
                    pool_impl="fused")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("engine", ["pas_kernel", "pas_kernel_implicit"])
def test_fused_pool_pas_engines(engine):
    """The paper-faithful two-phase formulation pools in its post-pass."""
    conv = cv.Conv2D(k=3, c_in=6, c_out=8, stride=2, padding="same", relu=True)
    imgs, kern, bias = _mk(conv)
    shared = cv.ConvParams.quantize(kern, 8, bias=bias)
    want = _oracle(imgs, shared, conv, engine, 2)
    got = cv.conv2d(imgs, shared, conv, engine=engine, interpret=True, pool=2,
                    pool_impl="fused")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_pool_window3_odd_alignment():
    """pool=3 forces the lcm(9, 8) = 72-row block plan (bm is no longer a
    power of two) — the k-tile sequence is untouched, so still bit-exact."""
    conv = cv.Conv2D(k=3, c_in=4, c_out=8, padding="valid", relu=True)
    imgs, kern, bias = _mk(conv, hw=(12, 12))  # 10×10 conv out → 3×3 pooled
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)
    for engine in ("kernel", "kernel_implicit"):
        want = _oracle(imgs, shared, conv, engine, 3)
        got = cv.conv2d(imgs, shared, conv, engine=engine, interpret=True,
                        pool=3, pool_impl="fused")
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want), err_msg=engine
        )


def test_fused_pool_packed_and_grouped():
    """int4-packed (§3 K-pad, odd K=45) and grouped dictionaries ride the
    fused pool unchanged."""
    conv = cv.Conv2D(k=3, c_in=5, c_out=8, padding="same", relu=True)
    imgs, kern, bias = _mk(conv)
    packed = cv.ConvParams.quantize(kern, 16, bias=bias).pack()
    assert packed.pad_k == 1
    grouped = cv.ConvParams.quantize(kern, 8, bias=bias, groups=3,
                                     layout="NCHW")
    for params in (packed, grouped):
        for engine in ("kernel", "kernel_implicit"):
            want = _oracle(imgs, params, conv, engine, 2)
            got = cv.conv2d(imgs, params, conv, engine=engine, interpret=True,
                            pool=2, pool_impl="fused")
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want),
                err_msg=f"{params.kind} {engine}",
            )


def test_fused_pool_no_epilogue_and_single_image():
    """pool without bias/ReLU (routes through the epilogue variant with a
    zero bias) and the squeezed 3-D input path."""
    conv = cv.Conv2D(k=3, c_in=5, c_out=8, padding="same", bias=False)
    imgs, kern, _ = _mk(conv)
    shared = cv.ConvParams.quantize(kern, 16)
    for engine in ("kernel", "kernel_implicit", "pas_kernel_implicit"):
        want = _oracle(imgs, shared, conv, engine, 2)
        got = cv.conv2d(imgs, shared, conv, engine=engine, interpret=True,
                        pool=2, pool_impl="fused")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=engine)
    got1 = cv.conv2d(imgs[0], shared, conv, engine="kernel_implicit",
                     interpret=True, pool=2, pool_impl="fused")
    want1 = _oracle(imgs[0], shared, conv, "kernel_implicit", 2)
    assert got1.ndim == 3
    np.testing.assert_array_equal(np.asarray(got1), np.asarray(want1))


def test_pool1_passthrough():
    """pool=1 must be the identity dispatch: same array as the plain call on
    fused-capable engines, and max_pool2d(x, 1) is x itself."""
    conv = cv.Conv2D(k=3, c_in=4, c_out=8, padding="same", relu=True)
    imgs, kern, bias = _mk(conv, hw=(9, 9))
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)
    for engine in ("kernel", "kernel_implicit"):
        plain = cv.conv2d(imgs, shared, conv, engine=engine, interpret=True)
        pooled = cv.conv2d(imgs, shared, conv, engine=engine, interpret=True,
                           pool=1)
        np.testing.assert_array_equal(np.asarray(pooled), np.asarray(plain))
    x = jnp.ones((2, 4, 9, 9))
    assert cv.max_pool2d(x, 1, "NCHW") is x


# ---------------------------------------------------------------------------
# dispatch rules and the reduce_window fallback
# ---------------------------------------------------------------------------


def test_pool_dispatch_rules():
    conv = cv.Conv2D(k=3, c_in=4, c_out=8, padding="same", relu=True)
    # Pallas engines fuse; einsum ports never do
    assert cv._pool_fusible("kernel_implicit", conv, 9, 9, 2, None)
    assert cv._pool_fusible("kernel", conv, 9, 9, 2, None)
    assert not cv._pool_fusible("einsum", conv, 9, 9, 2, None)
    assert not cv._pool_fusible("pas_einsum", conv, 9, 9, 2, None)
    # sub-window outputs (floor would be empty) fall back
    assert not cv._pool_fusible("kernel_implicit", conv, 9, 9, 16, None)
    # no pool-aligned block plan (lcm(49, 8) = 392 > 256) falls back
    assert not cv._pool_fusible("kernel_implicit", conv, 60, 60, 7, None)
    # a mesh blocks no engine any more: conv2d pads the batch to divide
    # ``data`` and each image contributes P_rows (a pool² multiple) of
    # window-major rows, so explicit patch-row shards land on whole windows
    # too (PR-5 carve-out closed).  The predicate must not dereference the
    # mesh — dispatch rules are shape-only.
    mesh = object()
    assert cv._pool_fusible("kernel_implicit", conv, 9, 9, 2, mesh)
    assert cv._pool_fusible("kernel", conv, 9, 9, 2, mesh)
    assert cv._pool_fusible("pas_kernel", conv, 9, 9, 2, mesh)
    assert not cv._pool_fusible("einsum", conv, 9, 9, 2, mesh)
    # pool_impl validation + demanding the impossible raises
    imgs, kern, _ = _mk(conv, hw=(9, 9))
    shared = cv.ConvParams.quantize(kern, 16)
    with pytest.raises(ValueError, match="pool_impl"):
        cv.conv2d(imgs, shared, conv, pool=2, pool_impl="nope")
    with pytest.raises(ValueError, match="positive integer"):
        cv.conv2d(imgs, shared, conv, pool=0)
    with pytest.raises(ValueError, match="fused"):
        cv.conv2d(imgs, shared, conv, engine="einsum", pool=2,
                  pool_impl="fused")


def test_pool_fallback_matches_fused_and_dense_einsum():
    """pool_impl='unfused' (and the dense/einsum path, which always falls
    back) give the identical pooled output."""
    conv = cv.Conv2D(k=3, c_in=4, c_out=8, padding="same", relu=True)
    imgs, kern, bias = _mk(conv, hw=(9, 9))
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)
    fused = cv.conv2d(imgs, shared, conv, engine="kernel_implicit",
                      interpret=True, pool=2, pool_impl="fused")
    unfused = cv.conv2d(imgs, shared, conv, engine="kernel_implicit",
                        interpret=True, pool=2, pool_impl="unfused")
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))
    dense = cv.ConvParams.dense(kern, bias=bias)
    got = cv.conv2d(imgs, dense, conv, pool=2)  # einsum → fallback
    want = cv.max_pool2d(cv.conv2d(imgs, dense, conv), 2, conv.layout)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_max_pool2d_integer_dtype():
    """The bugfix: integer/quantized activations pool with the dtype's own
    ``jnp.iinfo`` minimum as the window init (the former unconditional
    ``-jnp.inf`` relied on a silent float→int cast), exactly and in-dtype —
    signed, all-negative, and uint8 maps included."""
    x = -(jnp.arange(2 * 3 * 8 * 8, dtype=jnp.int32).reshape(2, 3, 8, 8) + 1)
    got = cv.max_pool2d(x, 2, "NCHW")
    assert got.dtype == jnp.int32
    ref = np.asarray(x).reshape(2, 3, 4, 2, 4, 2).max(axis=(3, 5))
    np.testing.assert_array_equal(np.asarray(got), ref)
    u = jax.random.randint(jax.random.PRNGKey(0), (2, 8, 8, 3), 0, 255,
                           dtype=jnp.int32).astype(jnp.uint8)
    gu = cv.max_pool2d(u, 2, "NHWC")
    assert gu.dtype == jnp.uint8
    ru = np.asarray(u).reshape(2, 4, 2, 4, 2, 3).max(axis=(2, 4))
    np.testing.assert_array_equal(np.asarray(gu), ru)
    # the float init stays -inf (the max identity): the fallback keeps the
    # reduce_window_max primitive and with it differentiability
    xf = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 8, 8))
    jax.grad(lambda v: cv.max_pool2d(v, 2, "NCHW").sum())(xf)


# ---------------------------------------------------------------------------
# the pooled custom VJP (argmax routing)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["kernel", "kernel_implicit"])
def test_fused_pool_grad_matches_reference_shared(engine):
    conv = cv.Conv2D(k=3, c_in=5, c_out=8, padding="same", relu=True)
    imgs, kern, bias = _mk(conv)
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)

    def loss(x, cb, b, eng, impl):
        p = cv.ConvParams.shared(shared.idx, cb, bias=b)
        return (cv.conv2d(x, p, conv, engine=eng, interpret=True, pool=2,
                          pool_impl=impl) ** 2).sum()

    gi = jax.grad(loss, argnums=(0, 1, 2))(imgs, shared.codebook, bias,
                                           engine, "fused")
    ge = jax.grad(loss, argnums=(0, 1, 2))(imgs, shared.codebook, bias,
                                           "einsum", "unfused")
    for a, b, name in zip(gi, ge, ("x", "codebook", "bias")):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4, err_msg=name
        )


def test_fused_pool_grad_packed():
    """Packed params, no-bias pooled VJP (K-pad rows get no gradient)."""
    conv = cv.Conv2D(k=3, c_in=3, c_out=8, bias=False)  # K=27 odd → pad_k=1
    imgs, kern, _ = _mk(conv, hw=(9, 9))
    packed = cv.ConvParams.quantize(kern, 8).pack()

    def loss(x, cb, eng, impl):
        p = dataclasses.replace(packed, codebook=cb)
        return (cv.conv2d(x, p, conv, engine=eng, interpret=True, pool=2,
                          pool_impl=impl) ** 2).sum()

    gi = jax.grad(loss, argnums=(0, 1))(imgs, packed.codebook,
                                        "kernel_implicit", "fused")
    ge = jax.grad(loss, argnums=(0, 1))(imgs, packed.codebook, "einsum",
                                        "unfused")
    for a, b in zip(gi, ge):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


# ---------------------------------------------------------------------------
# jaxpr: the fused stage is ONE pallas_call, no reduce_window
# ---------------------------------------------------------------------------


def _iter_eqns(jaxpr):
    """All eqns, recursing into sub-jaxprs EXCEPT the pallas kernel body
    (the in-kernel pooled write-through is the point; don't count it)."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            yield from _iter_sub(v)


def _iter_sub(v):
    if hasattr(v, "jaxpr"):
        yield from _iter_eqns(v.jaxpr)
    elif hasattr(v, "eqns"):
        yield from _iter_eqns(v)
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _iter_sub(x)


def _prim_names(fn, *args):
    return [e.primitive.name
            for e in _iter_eqns(jax.make_jaxpr(fn)(*args).jaxpr)]


def test_fused_stage_is_one_pallas_call():
    conv = cv.Conv2D(k=3, c_in=4, c_out=8, padding="same", relu=True)
    imgs, kern, bias = _mk(conv, hw=(9, 9))
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)
    names = _prim_names(
        lambda x: cv.conv2d(x, shared, conv, engine="kernel_implicit",
                            interpret=True, pool=2, pool_impl="fused"), imgs
    )
    assert names.count("pallas_call") == 1, names
    assert not any("reduce_window" in n or "select_and" in n for n in names)
    # ...and the unfused stage DOES lower a reduce_window — the assertion
    # above is meaningful
    names_u = _prim_names(
        lambda x: cv.conv2d(x, shared, conv, engine="kernel_implicit",
                            interpret=True, pool=2, pool_impl="unfused"), imgs
    )
    assert any("reduce_window" in n for n in names_u), names_u


# ---------------------------------------------------------------------------
# traffic models: the fused stage beats unfused + separate pool pass
# ---------------------------------------------------------------------------


def test_fused_pool_hbm_bytes_below_unfused_plus_pool_pass():
    """AlexNet conv1 geometry (the ci.sh gate's numbers): the fused stage
    stores the pooled map only, so its modeled bytes sit strictly below the
    unfused conv plus the separate reduce_window read+write."""
    conv = cv.Conv2D(k=11, c_in=3, c_out=96, stride=4, relu=True)
    kern = jax.random.normal(jax.random.PRNGKey(0), (96, 3, 11, 11))
    t = cv.ConvParams.quantize(kern, 16).gemm_tensor("NCHW")
    geom_p = cv.conv_geom(conv, 224, 224, pool=2)
    geom_u = cv.conv_geom(conv, 224, 224)
    assert (geom_p.ohp, geom_p.owp) == (27, 27) and geom_p.P_rows == 2916
    fused = ops.conv_hbm_bytes(t, geom_p, 1, 224, 224, implicit=True)
    unfused = ops.conv_hbm_bytes(t, geom_u, 1, 224, 224, implicit=True)
    pool_pass = 54 * 54 * 96 * 4 + 27 * 27 * 96 * 4  # read pre-pool + store
    assert fused < unfused  # the pooled store alone already wins
    assert fused < unfused + pool_pass
    # the analytic (plan-free) model agrees on the direction and on the
    # exact store shrink: pooled store is P/4 of the pre-pool one
    geo = dict(IH=224, IW=224, C=3, KY=11, KX=11, M=96, stride=4)
    a_f = hw.conv_hbm_traffic(**geo, pool=2)
    a_u = hw.conv_hbm_traffic(**geo)
    assert a_u - a_f == (54 * 54 - 27 * 27) * 96 * 4
    # dense=True models the einsum f32 weight stream: K·M·4 vs packed K·M/2
    K = 3 * 11 * 11
    d = hw.conv_hbm_traffic(**geo, implicit=False, dense=True)
    p = hw.conv_hbm_traffic(**geo, implicit=False, packed=True)
    assert d - p == (K * 96 * 4 - K * 96 // 2) - 16 * 4


def test_cnn_stack_fused_matches_unfused():
    """The smoke CNN stack end to end: fused pools (cfg default) vs
    pool_impl='unfused' — identical logits, layer 2's odd 13×13 map floors
    to 6×6 on both paths."""
    import dataclasses as dc

    from repro.configs import get_cnn_config
    from repro.models import cnn

    cfg = dc.replace(get_cnn_config("alexnet", smoke=True),
                     impl="kernel_implicit")
    params = cnn.quantize(cnn.init_params(cfg, jax.random.PRNGKey(0)), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, *cfg.in_chw))
    fused = cnn.forward(params, imgs, cfg, interpret=True)
    unfused = cnn.forward(params, imgs, dc.replace(cfg, pool_impl="unfused"),
                          interpret=True)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))
