"""Per-arch smoke: reduced configs — forward/train shapes, no NaNs, decode.

Assignment requirement (f): one smoke test per assigned architecture that
instantiates a reduced config of the same family and runs one forward and
one train step on CPU asserting output shapes + finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import api
from repro.models.common import ShardCtx
from repro.train import optimizer as opt
from repro.train import step as step_mod

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_config(arch, smoke=True)
    model = api.get_model(cfg)
    params = model.init_params(cfg, KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    fe = api.frontend_spec(cfg, B)
    kw = {"frontend_embeds": jnp.zeros(fe.shape, fe.dtype)} if fe is not None else {}
    logits, aux = model.forward(params, tokens, cfg, **kw)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    model = api.get_model(cfg)
    params = model.init_params(cfg, KEY)
    state = opt.init_opt_state(params)
    B, S = 2, 16
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab),
    }
    fe = api.frontend_spec(cfg, B)
    if fe is not None:
        batch["frontend_embeds"] = jnp.zeros(fe.shape, fe.dtype)
    ts = step_mod.make_train_step(cfg, opt.AdamWConfig(lr=1e-3, total_steps=10), ShardCtx())
    params2, state2, metrics = ts(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
        if jnp.issubdtype(a.dtype, jnp.floating)
        else 0.0,
        params,
        params2,
    )
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode(prefill(S)) ≡ prefill(S+1) up to bf16 noise (all families)."""
    cfg = get_config(arch, smoke=True)
    model = api.get_model(cfg)
    params = model.init_params(cfg, KEY)
    B, S = 2, 8
    tokens = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    fe = api.frontend_spec(cfg, B)
    kw = {"frontend_embeds": jnp.zeros(fe.shape, fe.dtype)} if fe is not None else {}
    caches = model.init_caches(cfg, B, 32)
    lg_pre, caches = model.prefill(params, tokens[:, :S], caches, cfg, **kw)
    lg_dec, _ = model.decode_step(params, tokens[:, S : S + 1], caches, cfg)
    caches2 = model.init_caches(cfg, B, 32)
    lg_pre2, _ = model.prefill(params, tokens, caches2, cfg, **kw)
    err = float(jnp.abs(lg_dec[:, 0] - lg_pre2[:, 0]).max())
    assert err < 0.15, f"{arch}: decode/prefill mismatch {err}"
    assert lg_dec.shape == (B, 1, cfg.vocab)


@pytest.mark.parametrize("arch", ["qwen3-32b", "deepseek-moe-16b", "mamba2-130m"])
def test_pasm_quantized_forward(arch):
    """The paper's technique as a config knob: quantized forward stays close."""
    from repro.models.common import quantize_params

    cfg = get_config(arch, smoke=True)
    # smoke weights are small — drop the min-size guard so something quantizes
    cfg = cfg.with_quant(enabled=True, bins=64, impl="dequant", min_weight_elems=64)
    model = api.get_model(cfg)
    params = model.init_params(cfg, KEY)
    qparams = quantize_params(params, cfg)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    lg_dense, _ = model.forward(params, tokens, cfg.with_quant(enabled=False))
    lg_q, _ = model.forward(qparams, tokens, cfg)
    assert bool(jnp.isfinite(lg_q.astype(jnp.float32)).all())
    # 64-bin quantization: logits correlated with dense output
    a = np.asarray(lg_dense.astype(jnp.float32)).ravel()
    b = np.asarray(lg_q.astype(jnp.float32)).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.9, f"{arch}: corr {corr}"
