"""Implicit-GEMM convolution: no materialized patch matrix, bit-exact.

Covers the perf_opt acceptance criteria:

* ``engine="kernel_implicit"`` / ``"pas_kernel_implicit"`` are **bit-exact**
  against the explicit-im2col kernel paths for shared / packed / grouped
  params — same tile plan, same accumulation order — across paddings,
  layouts and strides.
* jaxpr inspection: between the input and the single ``pallas_call`` there is
  no XLA ``gather``, no ``conv_general_dilated``, and no reshape producing
  the ``(B·P, K)`` patch matrix (the explicit path HAS one — the assertion
  is meaningful).
* exact oracle vs ``jax.lax.conv_general_dilated`` on the
  dictionary-dereferenced kernel, VALID and SAME, NCHW and NHWC, stride > 1.
* ``auto`` prefers the implicit engine when the image tiles into VMEM and
  falls back to explicit above the budget.
* the custom VJP (explicit col2im backward) matches grads through the einsum
  reference.
* grouped codebooks ride every non-PAS engine (`ConvParams.quantize(groups=)`,
  the ROADMAP plumbing) and refuse the PAS ones.
* the new traffic models: implicit strictly below explicit on the AlexNet
  conv1 geometry, both tile-plan-aware (`ops.conv_hbm_bytes`) and analytic
  (`hwmodel.conv_hbm_traffic`).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import conv as cv
from repro.core import hwmodel as hw
from repro.kernels import ops


def _mk(conv: cv.Conv2D, bins=16, seed=0, batch=2, hw=(13, 11)):
    ih, iw = hw
    shape = (batch, ih, iw, conv.c_in) if conv.layout == "NHWC" \
        else (batch, conv.c_in, ih, iw)
    imgs = jax.random.normal(jax.random.PRNGKey(seed), shape)
    kern = jax.random.normal(
        jax.random.PRNGKey(seed + 1), (conv.c_out, conv.c_in, conv.ky, conv.kx)
    ) * conv.K ** -0.5
    bias = jnp.linspace(-0.5, 0.5, conv.c_out)
    return imgs, kern, bias


def _lax_conv(imgs, kern, conv: cv.Conv2D):
    if conv.layout == "NHWC":
        dn, k = ("NHWC", "HWIO", "NHWC"), kern.transpose(2, 3, 1, 0)
    else:
        dn, k = ("NCHW", "OIHW", "NCHW"), kern
    return jax.lax.conv_general_dilated(
        imgs, k, (conv.stride, conv.stride), conv.padding.upper(),
        dimension_numbers=dn,
    )


# ---------------------------------------------------------------------------
# bit-exactness vs the explicit-im2col kernel paths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("layout", ["NCHW", "NHWC"])
@pytest.mark.parametrize("padding", ["same", "valid"])
def test_implicit_bitexact_vs_explicit(padding, layout, stride):
    conv = cv.Conv2D(k=3, c_in=5, c_out=8, stride=stride, padding=padding,
                     layout=layout, relu=True)
    imgs, kern, bias = _mk(conv)
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)
    want = cv.conv2d(imgs, shared, conv, engine="kernel", interpret=True)
    got = cv.conv2d(imgs, shared, conv, engine="kernel_implicit", interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bins", [8, 16])
def test_implicit_bitexact_packed_odd_k(bins):
    """int4-packed dictionaries with the §3 K-pad (odd K=45): the in-kernel
    zero mask pairs with the reserved zero bin exactly like the explicit
    path's zero patch columns (bins=16 exercises the bin-0 fallback)."""
    conv = cv.Conv2D(k=3, c_in=5, c_out=8, stride=1, padding="same", relu=True)
    imgs, kern, bias = _mk(conv, hw=(10, 10))
    packed = cv.ConvParams.quantize(kern, bins, bias=bias).pack()
    assert packed.pad_k == 1
    want = cv.conv2d(imgs, packed, conv, engine="kernel", interpret=True)
    got = cv.conv2d(imgs, packed, conv, engine="kernel_implicit", interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pas_implicit_bitexact_vs_explicit():
    conv = cv.Conv2D(k=3, c_in=6, c_out=8, stride=2, padding="same", relu=True)
    imgs, kern, bias = _mk(conv)
    shared = cv.ConvParams.quantize(kern, 8, bias=bias)
    want = cv.conv2d(imgs, shared, conv, engine="pas_kernel", interpret=True)
    got = cv.conv2d(imgs, shared, conv, engine="pas_kernel_implicit",
                    interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_implicit_vs_lax_oracle_alexnet_conv1_geometry():
    """Exact oracle: AlexNet conv1 geometry (k=11, s=4, SAME, NHWC) against
    lax.conv_general_dilated on the dictionary-dereferenced kernel."""
    conv = cv.Conv2D(k=11, c_in=3, c_out=16, stride=4, padding="same",
                     layout="NHWC", relu=True)
    imgs, kern, bias = _mk(conv, batch=1, hw=(56, 56))
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)
    kern_q = shared.codebook[shared.idx.astype(jnp.int32)]
    want = jnp.maximum(_lax_conv(imgs, kern_q, conv) + bias, 0)
    for engine in ("kernel_implicit", "pas_kernel_implicit"):
        got = cv.conv2d(imgs, shared, conv, engine=engine, interpret=True)
        assert got.shape == want.shape == (1, 14, 14, 16)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4,
            err_msg=engine,
        )


def test_implicit_single_image_and_valid_centred():
    """3-D inputs and the paper's kernel-centred windowing route too."""
    conv = cv.Conv2D(k=(3, 2), c_in=4, c_out=8, stride=2)
    imgs, kern, bias = _mk(conv, hw=(9, 8))
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)
    want = cv.conv2d(imgs[0], shared, conv, engine="kernel", interpret=True)
    got = cv.conv2d(imgs[0], shared, conv, engine="kernel_implicit",
                    interpret=True)
    assert got.ndim == 3
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# jaxpr inspection: the patch matrix must not exist
# ---------------------------------------------------------------------------


def _iter_eqns(jaxpr):
    """All eqns, recursing into sub-jaxprs EXCEPT the pallas kernel body."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue  # in-kernel tile assembly is the point; don't count it
        for v in eqn.params.values():
            yield from _iter_sub(v)


def _iter_sub(v):
    if hasattr(v, "jaxpr"):
        yield from _iter_eqns(v.jaxpr)
    elif hasattr(v, "eqns"):
        yield from _iter_eqns(v)
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _iter_sub(x)


def _profile(fn, *args):
    eqns = list(_iter_eqns(jax.make_jaxpr(fn)(*args).jaxpr))
    names = [e.primitive.name for e in eqns]
    cut = names.index("pallas_call")
    return names, eqns[:cut]


def _patch_reshapes(eqns, P, K):
    """Reshape eqns whose output is the (B·P, K(+pad)) patch matrix."""
    return [
        e for e in eqns
        if e.primitive.name == "reshape"
        and len(e.outvars[0].aval.shape) == 2
        and e.outvars[0].aval.shape[0] == P
        and e.outvars[0].aval.shape[1] >= K
    ]


@pytest.mark.parametrize("engine", ["kernel_implicit", "pas_kernel_implicit"])
def test_implicit_jaxpr_has_no_patch_matrix(engine):
    """Acceptance: between input and pallas_call the implicit path has no
    XLA gather, no conv_general_dilated, and no (B·P, K) reshape."""
    conv = cv.Conv2D(k=3, c_in=4, c_out=8, stride=1, padding="same", relu=True)
    imgs, kern, bias = _mk(conv, hw=(9, 9))
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)
    P, K = 2 * 9 * 9, conv.K

    names, pre = _profile(
        lambda x: cv.conv2d(x, shared, conv, engine=engine, interpret=True), imgs
    )
    assert names.count("pallas_call") == 1, names
    pre_names = [e.primitive.name for e in pre]
    assert "gather" not in pre_names, pre_names
    assert "conv_general_dilated" not in pre_names, pre_names
    assert not _patch_reshapes(pre, P, K), "patch matrix materialized in HBM"

    # the explicit path DOES gather a (B·P, K) patch matrix first — the
    # assertions above are meaningful
    names_e, pre_e = _profile(
        lambda x: cv.conv2d(x, shared, conv, engine="kernel", interpret=True),
        imgs,
    )
    pre_e_names = [e.primitive.name for e in pre_e]
    assert "gather" in pre_e_names
    assert _patch_reshapes(pre_e, P, K)


def test_fused_pool_cnn_forward_one_pallas_call_per_stage():
    """PR 5 regression: with the fused pool config, every conv/ReLU/pool
    stage of ``cnn.forward`` lowers to exactly ONE pallas_call and no
    ``reduce_window`` appears between conv stages (the smoke stack pools
    every stage, including the odd 13×13 → 6×6 floor of layer 2); forcing
    ``pool_impl='unfused'`` restores one reduce_window per stage, so the
    assertion is meaningful."""
    import dataclasses as dc

    from repro.configs import get_cnn_config
    from repro.models import cnn

    cfg = dc.replace(get_cnn_config("alexnet", smoke=True),
                     impl="kernel_implicit")
    params = cnn.quantize(cnn.init_params(cfg, jax.random.PRNGKey(0)), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, *cfg.in_chw))

    def names_of(c):
        return [e.primitive.name for e in _iter_eqns(jax.make_jaxpr(
            lambda x: cnn.forward(params, x, c, interpret=True))(imgs).jaxpr)]

    names = names_of(cfg)
    assert names.count("pallas_call") == len(cfg.layers), names
    assert not any("reduce_window" in n or "select_and" in n for n in names)
    names_u = names_of(dc.replace(cfg, pool_impl="unfused"))
    assert names_u.count("pallas_call") == len(cfg.layers)
    assert sum("reduce_window" in n for n in names_u) == len(cfg.layers)


def test_auto_always_implicit_no_explicit_fallback(monkeypatch):
    conv = cv.Conv2D(k=3, c_in=4, c_out=8, stride=1, padding="same")
    imgs, kern, _ = _mk(conv, hw=(9, 9))
    shared = cv.ConvParams.quantize(kern, 16)
    assert cv._resolve_engine("auto", shared, False, conv, 9, 9) == "kernel_implicit"
    # single images keep the einsum reference port
    assert cv._resolve_engine("auto", shared, True, conv, 9, 9) == "einsum"
    # above the VMEM budget auto STAYS implicit — the image streams as
    # row-band slabs instead of falling back to explicit im2col
    monkeypatch.setattr(cv, "_IMPLICIT_VMEM_BUDGET", 4 * 9 * 9 * 4 - 1)
    assert cv._resolve_engine(
        "auto", shared, False, conv, 9, 9
    ) == "kernel_implicit"
    monkeypatch.undo()
    # degenerate geometry (no output pixels) keeps the explicit path, whose
    # empty patch matrix handles it
    big = cv.Conv2D(k=12, c_in=4, c_out=8, stride=1, padding="valid")
    assert cv._resolve_engine("auto", shared, False, big, 9, 9) == "kernel"
    # and auto-batched output equals the explicit engine regardless
    got = cv.conv2d(imgs, shared, conv, engine="auto", interpret=True)
    want = cv.conv2d(imgs, shared, conv, engine="kernel", interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_vmem_budget_knob_tunes_slabs():
    """conv2d(vmem_budget=)/CNNConfig.vmem_budget replace the hard-coded
    6 MiB budget: a tight budget now splits the image into row-band slabs
    (it no longer flips auto to the explicit engine) — outputs bit-exact
    either way."""
    import dataclasses as dc

    conv = cv.Conv2D(k=3, c_in=4, c_out=8, stride=1, padding="same")
    imgs, kern, _ = _mk(conv, hw=(9, 9))
    shared = cv.ConvParams.quantize(kern, 16)
    img_bytes = 4 * 11 * 11 * 4  # c_in · (9+SAME pad)² · f32
    tight, roomy = img_bytes - 1, None
    # the tight budget fails the whole-image residency check but auto stays
    # on the implicit engine
    assert not cv._implicit_fits(conv, 9, 9, tight, params=shared)
    assert cv._resolve_engine(
        "auto", shared, False, conv, 9, 9, tight
    ) == "kernel_implicit"
    got_t = cv.conv2d(imgs, shared, conv, engine="auto", interpret=True,
                      vmem_budget=tight)
    got_r = cv.conv2d(imgs, shared, conv, engine="auto", interpret=True,
                      vmem_budget=roomy)
    np.testing.assert_array_equal(np.asarray(got_t), np.asarray(got_r))
    # the CNNConfig knob threads through models/cnn.py forward (impl="auto")
    from repro.configs import get_cnn_config
    from repro.models import cnn

    cfg = dc.replace(get_cnn_config("alexnet", smoke=True), impl="auto")
    params = cnn.quantize(cnn.init_params(cfg, jax.random.PRNGKey(0)), cfg)
    xs = jax.random.normal(jax.random.PRNGKey(1), (2, *cfg.in_chw))
    want = cnn.forward(params, xs, cfg, interpret=True)
    got = cnn.forward(
        params, xs, dc.replace(cfg, vmem_budget=70_000), interpret=True
    )  # slab-streams every layer that can split — same logits
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# slab-pipelined streaming (DESIGN.md §3.3)
# ---------------------------------------------------------------------------


def _slab_plan(conv: cv.Conv2D, params: cv.ConvParams, ih, iw, pool, budget):
    """The plan conv2d's implicit path would build (mirrors _conv_fwd_impl)."""
    geom = cv.conv_geom(conv, ih, iw, pool)
    (pt, pb), (pl, pr) = geom.pad
    hp, wp = ih + pt + pb, iw + pl + pr
    t = params.gemm_tensor(conv.layout)
    bm, bn, bk, _ = ops._pick_blocks(
        geom.P_rows, t.shape[0], conv.c_out,
        t.shape[0] // t.codebook.shape[0], t.packed)
    bm = ops._pool_bm(bm, pool)
    return ops.conv_slab_plan(
        geom, hp, wp, bm=bm, bn=bn, bk=bk, bins=t.codebook.shape[1],
        packed=t.packed, pas=False, has_bias=True, vmem_budget=budget)


@pytest.mark.parametrize("layout", ["NCHW", "NHWC"])
@pytest.mark.parametrize("pas", [False, True])
def test_slab_bitexact_all_engines(layout, pas):
    """The ISSUE's seam matrix: a 3-slab budget (n_slabs=3, band 8, halo 2 —
    bands cross both pool-window and halo boundaries) is bit-exact vs the
    explicit engines for shared / packed / grouped params, with and without
    the fused pool.  assert_array_equal: the k-tile sequence is untouched,
    so slabbing must not change a single bit."""
    conv = cv.Conv2D(k=3, c_in=4, c_out=8, stride=1, padding="same",
                     layout=layout)
    imgs, kern, _ = _mk(conv, hw=(24, 16))
    shared = cv.ConvParams.quantize(kern, 16)
    kinds = [shared, shared.pack(layout=layout),
             cv.ConvParams.quantize(kern, 16, groups=2, layout=layout)]
    if pas:
        kinds = kinds[:2]  # PAS engines refuse grouped codebooks
        imp_eng, exp_eng = "pas_kernel_implicit", "pas_kernel"
    else:
        imp_eng, exp_eng = "kernel_implicit", "kernel"
    budget = 60_000
    plan = _slab_plan(conv, shared, 24, 16, 2, budget)
    assert plan.n_slabs == 3 and plan.halo_rows > 0  # seams ARE exercised
    for params in kinds:
        for pool in (1, 2):
            got = cv.conv2d(imgs, params, conv, engine=imp_eng,
                            interpret=True, vmem_budget=budget,
                            pool=pool, pool_impl="fused")
            want = cv.conv2d(imgs, params, conv, engine=exp_eng,
                             interpret=True, pool=pool, pool_impl="fused")
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_implicit_fits_counts_all_blocks():
    """Pinned accounting for the _implicit_fits fix: the budget must cover
    EVERY per-grid-step block (idx/codebook/bias/output, each double-
    buffered, plus scratch) on top of the double-buffered image — the old
    image-only model under-counted by exactly the fixed-block term."""
    # the fixed-block model itself, pinned by hand:
    #   idx 2·64·128 + codebook 2·17·4 + bias 2·128·4 + out 2·64·128·4
    base = dict(bm=64, bn=128, bk=64, bins=16)
    assert ops._conv_block_vmem_bytes(**base) == 16384 + 136 + 1024 + 65536
    # packed halves the idx tile
    assert ops._conv_block_vmem_bytes(**base, packed=True) == \
        8192 + 136 + 1024 + 65536
    # fused pool: pooled output block + un-double-buffered accumulator
    assert ops._conv_block_vmem_bytes(**base, pool=2) == \
        16384 + 136 + 1024 + 16384 + 64 * 128 * 4
    # PAS: the (bm, bn, bins) histogram scratch dominates
    assert ops._conv_block_vmem_bytes(**base, pas=True) == \
        16384 + 136 + 1024 + 65536 + 64 * 128 * 16 * 4
    # ...and _implicit_fits sits exactly at image + fixed blocks:
    conv = cv.Conv2D(k=3, c_in=4, c_out=8, stride=1, padding="same")
    _, kern, bias = _mk(conv, hw=(9, 9))
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)
    t = shared.gemm_tensor("NCHW")
    geom = cv.conv_geom(conv, 9, 9)
    bm, bn, bk, _ = ops._pick_blocks(
        geom.P_rows, t.shape[0], conv.c_out,
        t.shape[0] // t.codebook.shape[0], t.packed)
    img = 2 * 4 * 11 * 11 * 4  # double-buffered SAME-padded image
    fixed = ops._conv_block_vmem_bytes(bm=bm, bn=bn, bk=bk, bins=16)
    assert cv._implicit_fits(conv, 9, 9, fixed + img, params=shared)
    assert not cv._implicit_fits(conv, 9, 9, fixed + img - 1, params=shared)
    # regression: a budget covering only the image is NOT enough
    assert not cv._implicit_fits(conv, 9, 9, img, params=shared)


def test_slab_streams_image_failing_default_fits():
    """THE acceptance shape: an image whose double-buffered residency blows
    the default 6 MiB budget (16·256·256·f32 ≈ 8.4 MiB doubled) — auto
    stays on the implicit engine, the planner splits it into two slabs,
    the output is bit-exact vs the explicit oracle, and the modeled HBM
    bytes land strictly below explicit."""
    conv = cv.Conv2D(k=11, c_in=16, c_out=32, stride=8, padding="same")
    imgs, kern, _ = _mk(conv, batch=1, hw=(256, 256))
    shared = cv.ConvParams.quantize(kern, 16)
    # fails whole-image residency at the DEFAULT budget...
    assert not cv._implicit_fits(conv, 256, 256, params=shared)
    # ...yet auto does NOT fall back to explicit
    assert cv._resolve_engine(
        "auto", shared, False, conv, 256, 256) == "kernel_implicit"
    plan = _slab_plan(conv, shared, 256, 256, 1, None)
    assert plan.n_slabs == 2
    assert plan.band_rows == 160 and plan.halo_rows == 4
    assert plan.rows_total == 324  # 2·160 + 4: kernel operand rows
    got = cv.conv2d(imgs, shared, conv, engine="auto", interpret=True)
    want = cv.conv2d(imgs, shared, conv, engine="kernel", interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    t = shared.gemm_tensor("NCHW")
    geom = cv.conv_geom(conv, 256, 256)
    imp = ops.conv_hbm_bytes(t, geom, 1, 256, 256, implicit=True)
    exp = ops.conv_hbm_bytes(t, geom, 1, 256, 256, implicit=False)
    assert imp < exp


def test_slab_cnn_forward_fused_one_pallas_call_per_stage():
    """Slab-pipelined fused conv/ReLU/pool stays ONE pallas_call per stage
    with zero reduce_window through cnn.forward — slabbing reshapes the
    grid and operands, never the stage count."""
    import dataclasses as dc

    from repro.configs import get_cnn_config
    from repro.models import cnn

    budget = 60_000
    cfg = dc.replace(get_cnn_config("alexnet", smoke=True),
                     impl="kernel_implicit", vmem_budget=budget)
    # every stage fails whole-image residency at this budget → all slab
    assert not cv._implicit_fits(cfg.layers[0], 32, 32, budget,
                                 pool=cfg.pools[0])
    params = cnn.quantize(cnn.init_params(cfg, jax.random.PRNGKey(0)), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, *cfg.in_chw))
    names = [e.primitive.name for e in _iter_eqns(jax.make_jaxpr(
        lambda x: cnn.forward(params, x, cfg, interpret=True))(imgs).jaxpr)]
    assert names.count("pallas_call") == len(cfg.layers), names
    assert not any("reduce_window" in n or "select_and" in n for n in names)


def test_conv_hbm_bytes_slab_bigimg():
    """The CI gate's bigimg numbers (512×512 conv1-style, k=11/s=4): the
    slab-aware implicit model charges n_slabs·(band+halo) fetched rows —
    pinned: 2 slabs × (256+8) = 528 of 512 rows (3.1% seam re-fetch) —
    and stays far below the explicit patch-matrix stream."""
    conv = cv.Conv2D(k=11, c_in=3, c_out=96, stride=4, relu=True)
    kern = jax.random.normal(jax.random.PRNGKey(0), (96, 3, 11, 11))
    shared = cv.ConvParams.quantize(kern, 16)
    plan = _slab_plan(conv, shared, 512, 512, 1, None)
    assert plan.n_slabs == 2
    assert (plan.band_rows, plan.halo_rows) == (256, 8)
    assert plan.fetched_rows == 2 * (256 + 8) == 528
    t = shared.gemm_tensor("NCHW")
    geom = cv.conv_geom(conv, 512, 512)
    imp = ops.conv_hbm_bytes(t, geom, 1, 512, 512, implicit=True)
    exp = ops.conv_hbm_bytes(t, geom, 1, 512, 512, implicit=False)
    assert imp < exp and exp / imp > 4
    # the image term charges exactly the fetched rows
    roomy = ops.conv_hbm_bytes(t, geom, 1, 512, 512, implicit=True,
                               vmem_budget=1 << 30)  # whole image resident
    assert imp - roomy == 3 * (528 - 512) * 512 * 4
    # the analytic model charges seam halos too: 512×512×3 doubled is
    # exactly 6 MiB, so shrink the budget to force the split — 2 slabs
    # re-fetch (n_slabs−1)·max(ky−stride, 0) = 7 rows
    ana_slab = hw.conv_hbm_traffic(IH=512, IW=512, C=3, KY=11, KX=11, M=96,
                                   stride=4, implicit=True,
                                   vmem_budget=4 << 20)
    ana_whole = hw.conv_hbm_traffic(IH=512, IW=512, C=3, KY=11, KX=11, M=96,
                                    stride=4, implicit=True,
                                    vmem_budget=1 << 30)
    assert ana_slab - ana_whole == 3 * 7 * 512 * 4
    assert ana_slab < hw.conv_hbm_traffic(IH=512, IW=512, C=3, KY=11, KX=11,
                                          M=96, stride=4, implicit=False)


# ---------------------------------------------------------------------------
# custom VJP (explicit col2im backward)
# ---------------------------------------------------------------------------


def test_implicit_grad_matches_einsum_reference():
    conv = cv.Conv2D(k=3, c_in=5, c_out=8, stride=2, padding="same", relu=True)
    imgs, kern, bias = _mk(conv)
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)

    def loss(x, cb, b, engine):
        p = cv.ConvParams.shared(shared.idx, cb, bias=b)
        return (cv.conv2d(x, p, conv, engine=engine, interpret=True) ** 2).sum()

    gi = jax.grad(loss, argnums=(0, 1, 2))(imgs, shared.codebook, bias,
                                           "kernel_implicit")
    ge = jax.grad(loss, argnums=(0, 1, 2))(imgs, shared.codebook, bias, "einsum")
    for a, b, name in zip(gi, ge, ("x", "codebook", "bias")):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4, err_msg=name
        )


def test_implicit_grad_packed_no_epilogue():
    """The no-epilogue VJP variant, packed params (K-pad rows get no grad)."""
    conv = cv.Conv2D(k=3, c_in=3, c_out=8, stride=1)  # K=27 odd → pad_k=1
    imgs, kern, _ = _mk(conv, hw=(8, 8))
    packed = cv.ConvParams.quantize(kern, 8).pack()

    def loss(x, cb, engine):
        p = dataclasses.replace(packed, codebook=cb)
        return (cv.conv2d(x, p, conv, engine=engine, interpret=True) ** 2).sum()

    gi = jax.grad(loss, argnums=(0, 1))(imgs, packed.codebook, "kernel_implicit")
    ge = jax.grad(loss, argnums=(0, 1))(imgs, packed.codebook, "einsum")
    for a, b in zip(gi, ge):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


# ---------------------------------------------------------------------------
# grouped codebooks through ConvParams.quantize (ROADMAP plumbing)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["NCHW", "NHWC"])
def test_grouped_codebooks_all_engines(layout):
    conv = cv.Conv2D(k=3, c_in=4, c_out=8, stride=1, padding="same",
                     layout=layout, relu=True)
    imgs, kern, bias = _mk(conv, hw=(9, 9))
    g = cv.ConvParams.quantize(kern, 8, bias=bias, groups=3, layout=layout)
    assert g.groups == 3 and g.codebook.shape == (3, 8)
    want = cv.conv2d(imgs, g, conv, engine="einsum")
    for engine in ("kernel", "kernel_implicit"):
        got = cv.conv2d(imgs, g, conv, engine=engine, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4,
            err_msg=engine,
        )
    # grouped quantization with more dictionaries reconstructs no worse
    g1 = cv.ConvParams.quantize(kern, 8, bias=bias)
    e1 = float(jnp.abs(g1.dense_operand(layout) - cv.ConvParams.dense(kern)
                       .dense_operand(layout)).mean())
    eg = float(jnp.abs(g.dense_operand(layout) - cv.ConvParams.dense(kern)
                       .dense_operand(layout)).mean())
    assert eg <= e1 * 1.05


def test_shared_normalizes_single_group_2d_codebook():
    """pasm.kmeans_codebook(groups=1) hands back a (1, B) codebook; shared()
    must treat it as the single-dictionary rule on every engine."""
    conv = cv.Conv2D(k=3, c_in=4, c_out=8, relu=True)
    imgs, kern, bias = _mk(conv, hw=(8, 8))
    flat = cv._flatten_kernel(kern, "ckk")
    from repro.core import pasm
    cb2, idxf = pasm.kmeans_codebook(flat, 8, groups=1)
    assert cb2.shape == (1, 8)
    p = cv.ConvParams.shared(
        cv._unflatten_kernel(idxf, "ckk", kern.shape), cb2, bias=bias
    )
    assert p.groups == 1 and p.codebook.shape == (8,)
    want = cv.conv2d(imgs, p, conv, engine="einsum")
    assert want.shape == (2, 8, 6, 6)
    got = cv.conv2d(imgs, p, conv, engine="kernel_implicit", interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


def test_grouped_codebooks_validation():
    conv = cv.Conv2D(k=3, c_in=4, c_out=8)
    imgs, kern, _ = _mk(conv, hw=(6, 6))
    g = cv.ConvParams.quantize(kern, 8, groups=2, layout="NCHW")
    with pytest.raises(ValueError, match="re-quantize"):
        g.gemm_tensor("NHWC")  # group membership is order-dependent
    with pytest.raises(ValueError, match="single-dictionary"):
        cv.conv2d(imgs, g, conv, engine="pas_kernel", interpret=True)
    with pytest.raises(ValueError, match="divisible"):
        cv.ConvParams.quantize(kern, 8, groups=5)
    with pytest.raises(ValueError, match="order="):
        cv.ConvParams.shared(g.idx, g.codebook)  # grouped needs an order
    with pytest.raises(ValueError, match="divisible"):  # K=36, 5 ∤ 36
        cv.ConvParams.shared(g.idx, jnp.zeros((5, 8)), order="ckk")
    # grouped + packed: even per-group reduction packs and agrees
    p = cv.ConvParams.quantize(kern, 16, groups=2, layout="NCHW").pack()
    assert p.kind == "packed" and p.groups == 2
    want = cv.conv2d(imgs, p, conv, engine="kernel", interpret=True)
    got = cv.conv2d(imgs, p, conv, engine="kernel_implicit", interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # odd per-group reduction cannot pack (nibbles would straddle groups)
    odd = cv.ConvParams.quantize(kern, 16, groups=4, layout="NCHW")  # gs=9
    with pytest.raises(ValueError, match="even per-group"):
        odd.pack()


# ---------------------------------------------------------------------------
# the traffic models: implicit strictly below explicit
# ---------------------------------------------------------------------------


def test_conv_hbm_bytes_implicit_below_explicit():
    """Tile-plan-aware model, AlexNet conv1 geometry (the CI gate's numbers):
    the explicit path pays ≈2× the padded patch matrix, the implicit path
    one image stream — >4× total-traffic reduction at stride 4."""
    conv = cv.Conv2D(k=11, c_in=3, c_out=96, stride=4, relu=True)
    kern = jax.random.normal(jax.random.PRNGKey(0), (96, 3, 11, 11))
    t = cv.ConvParams.quantize(kern, 16).gemm_tensor("NCHW")
    geom = cv.conv_geom(conv, 224, 224)
    assert (geom.oh, geom.ow) == (54, 54)
    imp = ops.conv_hbm_bytes(t, geom, 1, 224, 224, implicit=True)
    exp = ops.conv_hbm_bytes(t, geom, 1, 224, 224, implicit=False)
    # pinned: explicit streams 2·Mp·Kp·4 = 2·2944·363·4 patch bytes; implicit
    # streams the raw image once (no SAME pad here): 3·224·224·4
    assert exp - imp == 2 * 2944 * 363 * 4 - 3 * 224 * 224 * 4
    assert imp < exp and exp / imp > 4


def test_hwmodel_conv_traffic_analytic():
    """Plan-free analytic model: the activation terms differ by exactly the
    im2col inflation factor (≈7.6× for conv1), implicit < explicit."""
    geo = dict(IH=224, IW=224, C=3, KY=11, KX=11, M=96, stride=4)
    imp = hw.conv_hbm_traffic(**geo, implicit=True)
    exp = hw.conv_hbm_traffic(**geo, implicit=False)
    assert imp < exp
    assert hw.im2col_inflation(11, 11, 4) == pytest.approx(7.5625)
    # activation terms only: explicit = 2·P·K·4, implicit = image·4
    P, K = 54 * 54, 3 * 11 * 11
    assert exp - imp == 2 * P * K * 4 - 3 * 224 * 224 * 4
