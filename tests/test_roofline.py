"""Roofline machinery: collective parsing, term math, HLO attribution."""
import numpy as np

from repro import roofline as RL

HLO = """
  %all-reduce.1 = f32[1024,512]{1,0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256]
  %all-gather.2 = bf16[64,128]{1,0} all-gather(%y), replica_groups={{0,1,2,3}}, dimensions={0}
  %rs = f32[32]{0} reduce-scatter(%z), replica_groups=[2,8]<=[16]
  %all-reduce-start.3 = (f32[8]{0}, f32[8]{0}) all-reduce-start(%w), replica_groups=[1,4]<=[4]
  %ard = f32[8]{0} all-reduce-done(%all-reduce-start.3)
  %notacoll = f32[10]{0} add(%a, %b)
"""


def test_parse_collective_bytes():
    st = RL.parse_collective_bytes(HLO)
    # all-reduce: 1024·512·4 bytes × 2·15/16
    ar = 1024 * 512 * 4 * 2 * 15 / 16
    # start op: two f32[8] in the tuple = 64 B × 2·3/4
    ar += 64 * 2 * 3 / 4
    assert np.isclose(st.bytes_by_kind["all-reduce"], ar)
    ag = 64 * 128 * 2 * 3 / 4  # explicit groups of 4
    assert np.isclose(st.bytes_by_kind["all-gather"], ag)
    assert st.count_by_kind["all-reduce"] == 2  # start counted, done skipped
    assert "add" not in st.bytes_by_kind


def test_roofline_terms_and_bottleneck():
    r = RL.roofline_terms(
        arch="a", shape="s", mesh_name="16x16", n_devices=256,
        cost={"flops": 197e12, "bytes accessed": 819e9 / 2},
        hlo_text="", model_flops=197e12 * 256 * 0.5,
    )
    assert np.isclose(r.compute_s, 1.0)
    assert np.isclose(r.memory_s, 0.5)
    assert r.bottleneck == "compute"
    assert np.isclose(r.roofline_fraction, 0.5)
    assert np.isclose(r.useful_flops_frac, 0.5)


def test_hlo_bytes_by_op():
    txt = "  %d = f32[128,128]{1,0} dot(%a, %b)\n  %c = bf16[64]{0} copy(%d)\n"
    agg = dict(RL.hlo_bytes_by_op(txt))
    assert agg["dot"] == 128 * 128 * 4
    assert agg["copy"] == 128
