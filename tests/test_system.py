"""End-to-end behaviour: train→checkpoint→restart equivalence, serving, QAT."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck
from repro.configs import get_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models import api
from repro.models.common import ShardCtx, quantize_params, weight_bytes
from repro.serve.engine import Engine
from repro.train import optimizer as opt
from repro.train import step as step_mod

KEY = jax.random.PRNGKey(0)


def _train_n(cfg, params, state, train_step, dcfg, start, n):
    losses = []
    for s in range(start, start + n):
        params, state, m = train_step(params, state, synthetic_batch(dcfg, s))
        losses.append(float(m["loss"]))
    return params, state, m, losses


def test_training_reduces_loss():
    cfg = get_config("stablelm-3b", smoke=True)
    model = api.get_model(cfg)
    params = model.init_params(cfg, KEY)
    state = opt.init_opt_state(params)
    dcfg = DataConfig(seed=0, vocab=cfg.vocab, seq_len=64, global_batch=4)
    ts = jax.jit(
        step_mod.make_train_step(cfg, opt.AdamWConfig(lr=1e-3, total_steps=30), ShardCtx()),
        donate_argnums=(0, 1),
    )
    params, state, m, losses = _train_n(cfg, params, state, ts, dcfg, 0, 30)
    assert losses[-1] < losses[0] - 0.5


def test_checkpoint_restart_bitwise(tmp_path):
    """train(4) == train(2) → checkpoint → restore → train(2): same params.

    The fault-tolerance contract: a crash+restore never changes the math
    (data pipeline is step-addressed; optimizer state is saved whole).
    """
    cfg = get_config("qwen3-32b", smoke=True)
    model = api.get_model(cfg)
    dcfg = DataConfig(seed=3, vocab=cfg.vocab, seq_len=32, global_batch=2)
    ts = jax.jit(step_mod.make_train_step(cfg, opt.AdamWConfig(lr=1e-3), ShardCtx()))

    p0 = model.init_params(cfg, KEY)
    s0 = opt.init_opt_state(p0)
    pa, sa, _, _ = _train_n(cfg, p0, s0, ts, dcfg, 0, 4)

    pb, sb, _, _ = _train_n(cfg, p0, s0, ts, dcfg, 0, 2)
    ck.save(tmp_path, 2, (pb, sb))
    (pr, sr), man = ck.restore(tmp_path, (pb, sb))
    pc, sc, _, _ = _train_n(cfg, pr, sr, ts, dcfg, man["step"], 2)

    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serving_engine_drains_and_is_deterministic():
    cfg = get_config("stablelm-3b", smoke=True)
    model = api.get_model(cfg)
    params = model.init_params(cfg, KEY)
    outs = []
    for _ in range(2):
        eng = Engine(cfg, params, batch_slots=2, max_seq=64)
        rng = np.random.default_rng(0)
        reqs = [eng.submit(rng.integers(0, cfg.vocab, size=6), max_new=5) for _ in range(4)]
        eng.run_until_drained()
        assert all(r.done for r in reqs)
        assert all(len(r.out) == 5 for r in reqs)
        outs.append([tuple(r.out) for r in reqs])
    assert outs[0] == outs[1]  # greedy decode is deterministic


def test_pasm_end_to_end_compression_and_serving():
    """The paper's pipeline: train dense → k-means weight-share → serve."""
    cfg = get_config("stablelm-3b", smoke=True)
    model = api.get_model(cfg)
    params = model.init_params(cfg, KEY)
    qcfg = cfg.with_quant(enabled=True, bins=16, impl="dequant", min_weight_elems=1024)
    qparams = quantize_params(params, qcfg)
    wb = weight_bytes(qparams)
    assert wb["ratio"] > 1.5  # int4 storage on the large mats
    eng = Engine(qcfg, qparams, batch_slots=2, max_seq=64)
    r = eng.submit(np.arange(5) % cfg.vocab, max_new=4)
    eng.run_until_drained()
    assert r.done and len(r.out) == 4


def test_microbatched_grad_accum_matches_full_batch():
    cfg = get_config("qwen3-32b", smoke=True)
    model = api.get_model(cfg)
    params = model.init_params(cfg, KEY)
    state = opt.init_opt_state(params)
    dcfg = DataConfig(seed=5, vocab=cfg.vocab, seq_len=32, global_batch=4)
    batch = synthetic_batch(dcfg, 0)
    ocfg = opt.AdamWConfig(lr=1e-3)
    full = step_mod.make_train_step(cfg, ocfg, ShardCtx(), microbatches=1)
    micro = step_mod.make_train_step(cfg, ocfg, ShardCtx(), microbatches=2)
    p1, _, m1 = full(params, state, batch)
    p2, _, m2 = micro(params, state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-3, atol=2e-3
        )


def test_grad_compression_trains():
    """PASM-style gradient dictionary compression still converges."""
    cfg = get_config("stablelm-3b", smoke=True)
    model = api.get_model(cfg)
    params = model.init_params(cfg, KEY)
    state = opt.init_opt_state(params)
    dcfg = DataConfig(seed=0, vocab=cfg.vocab, seq_len=64, global_batch=4)
    ts = jax.jit(
        step_mod.make_train_step(
            cfg, opt.AdamWConfig(lr=1e-3, total_steps=20), ShardCtx(), compress_grads_bins=256
        ),
        donate_argnums=(0, 1),
    )
    _, _, m, losses = _train_n(cfg, params, state, ts, dcfg, 0, 20)
    assert losses[-1] < losses[0] - 0.3
