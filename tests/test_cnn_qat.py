"""CNN QAT: core/qat.py's STE wired to the conv stack's per-layer
dictionaries (the ROADMAP "CNN QAT" item).

The training loop keeps dense master ConvParams; each step STE-snaps every
kernel onto its layer dictionary (``cnn.qat_apply``) so the forward serves
codebook values while gradients flow to the masters unchanged and codebook
entries accumulate bin-summed grads.  ``cnn.qat_requantize`` is the
``quantize_like``-style re-assignment that freezes the masters back into
``shared`` ConvParams for the PASM engines.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_cnn_config
from repro.core import conv as cv
from repro.core import pasm, qat
from repro.models import cnn

KEY = jax.random.PRNGKey(0)


def _setup():
    cfg = get_cnn_config("alexnet", smoke=True)
    params = cnn.init_params(cfg, KEY)
    cbs = cnn.qat_codebooks(params, cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, *cfg.in_chw))
    return cfg, params, cbs, imgs


def test_qat_codebooks_per_layer():
    cfg, params, cbs, _ = _setup()
    assert len(cbs) == len(cfg.layers)
    for cb in cbs:
        assert cb.shape == (cfg.bins,)


def test_qat_refuses_grouped_codebooks():
    """QAT is single-dictionary (paper rule); a grouped config must not
    silently train a different scheme than quantize() serves."""
    cfg, params, cbs, _ = _setup()
    gcfg = dataclasses.replace(cfg, groups=2)
    import pytest

    with pytest.raises(ValueError, match="single-dictionary"):
        cnn.qat_codebooks(params, gcfg)
    with pytest.raises(ValueError, match="single-dictionary"):
        cnn.qat_requantize(params, cbs, gcfg)


def test_qat_forward_serves_snapped_weights():
    """qat_forward == forward_dense at the snapped params, and equals the
    requantized (shared-dictionary) stack — the inference path it trains."""
    cfg, params, cbs, imgs = _setup()
    logits = cnn.qat_forward(params, cbs, imgs, cfg)
    snapped = cnn.qat_apply(params, cbs)
    np.testing.assert_array_equal(
        np.asarray(logits), np.asarray(cnn.forward_dense(snapped, imgs, cfg))
    )
    qp = cnn.qat_requantize(params, cbs, cfg)
    assert all(p.kind == "shared" for p in qp["conv"])
    served = cnn.forward(qp, imgs, dataclasses.replace(cfg, impl="einsum"))
    np.testing.assert_allclose(np.asarray(served), np.asarray(logits),
                               rtol=1e-5, atol=1e-5)


def test_qat_requantize_matches_quantize_like():
    """The re-assignment rule IS pasm.quantize_like's nearest-entry argmin."""
    cfg, params, cbs, _ = _setup()
    qp = cnn.qat_requantize(params, cbs, cfg)
    for p, q, cb in zip(params["conv"], qp["conv"], cbs):
        t = pasm.quantize_like(
            pasm.PASMTensor(
                idx=jnp.zeros((p.kernel[0].size, p.kernel.shape[0]), jnp.uint8),
                codebook=cb.reshape(1, -1),
                shape=(p.kernel[0].size, p.kernel.shape[0]),
                bins=cfg.bins,
                bits=pasm.bits_for_bins(cfg.bins),
                packed=False,
            ),
            p.kernel.reshape(p.kernel.shape[0], -1).T,
        )
        np.testing.assert_array_equal(
            np.asarray(q.idx.reshape(q.idx.shape[0], -1).T),
            np.asarray(t.idx),
        )


def test_qat_gradcheck_ste_identity_and_codebook_bins():
    """Gradcheck (the ROADMAP acceptance): masters get the straight-through
    gradient — identical to differentiating the dense forward at the snapped
    weights — and each codebook entry the bin-sum of its weights' grads."""
    cfg, params, cbs, imgs = _setup()
    kernels = [p.kernel for p in params["conv"]]

    def with_kernels(ks):
        convs = [cv.ConvParams.dense(k, bias=p.bias)
                 for k, p in zip(ks, params["conv"])]
        return {"conv": convs, "head": params["head"]}

    def loss_qat(ks, cbs_):
        return (cnn.qat_forward(with_kernels(ks), cbs_, imgs, cfg) ** 2).mean()

    def loss_dense_at(ws):
        return (cnn.forward_dense(with_kernels(ws), imgs, cfg) ** 2).mean()

    g_k, g_cb = jax.grad(loss_qat, argnums=(0, 1))(kernels, cbs)
    snapped = [qat.ste_quantize(k, cb) for k, cb in zip(kernels, cbs)]
    g_dense = jax.grad(loss_dense_at)(snapped)
    for a, b in zip(g_k, g_dense):  # STE: dL/dmaster == dL/dw at snap point
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    for k, cb, gk, gcb in zip(kernels, cbs, g_dense, g_cb):
        want = qat.codebook_grads(k, cb, gk)  # PAS bin-accumulate identity
        np.testing.assert_allclose(np.asarray(gcb), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def test_qat_step_reduces_loss():
    """One SGD burst through the STE stack moves masters AND codebooks."""
    cfg, params, cbs, imgs = _setup()
    tgt = jax.nn.one_hot(jnp.arange(2) % cfg.classes, cfg.classes)
    kernels = [p.kernel for p in params["conv"]]

    def loss(ks, cbs_):
        convs = [cv.ConvParams.dense(k, bias=p.bias)
                 for k, p in zip(ks, params["conv"])]
        logits = cnn.qat_forward(
            {"conv": convs, "head": params["head"]}, cbs_, imgs, cfg
        )
        return jnp.mean((jax.nn.softmax(logits) - tgt) ** 2)

    l0 = float(loss(kernels, cbs))
    g = jax.jit(jax.grad(loss, argnums=(0, 1)))
    for _ in range(5):
        g_k, g_cb = g(kernels, cbs)
        kernels = [k - 0.5 * gk for k, gk in zip(kernels, g_k)]
        cbs = [cb - 0.5 * gc for cb, gc in zip(cbs, g_cb)]
    assert float(loss(kernels, cbs)) < l0
