"""The unified ConvParams/conv2d surface: geometry, fused epilogue, packing.

Covers the api_redesign acceptance criteria:

* SAME/VALID × NCHW/NHWC × stride sweep oracled against
  ``jax.lax.conv_general_dilated`` on dense and weight-shared params.
* torchvision AlexNet layer-1 geometry (3×224×224, k=11, s=4) under
  ``padding="same"`` + NHWC for dense / weight-shared / PASM / packed params.
* The fused epilogue: a batched weight-shared conv with bias+ReLU lowers to
  exactly ONE pallas_call with no XLA add/max epilogue (jaxpr inspection).
* int4-packed conv dictionaries (§3 K-pad before packing) agree with
  unpacked ones, including the reserved-zero-bin append for bins < 16.
* ``pasm_hbm_bytes`` audited against ``PASMTensor.nbytes_weights`` with the
  roofline numbers pinned for packed/unpacked, aligned/K-padded shapes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import conv as cv
from repro.core import pasm
from repro.kernels import ops

KEY = jax.random.PRNGKey(0)


def _mk(conv: cv.Conv2D, bins=16, seed=0, batch=2, hw=(13, 11)):
    """Random (images, dense kernel, bias) for a spec at image dims ``hw``."""
    ih, iw = hw
    shape = (batch, ih, iw, conv.c_in) if conv.layout == "NHWC" \
        else (batch, conv.c_in, ih, iw)
    imgs = jax.random.normal(jax.random.PRNGKey(seed), shape)
    kern = jax.random.normal(
        jax.random.PRNGKey(seed + 1), (conv.c_out, conv.c_in, conv.ky, conv.kx)
    ) * conv.K ** -0.5
    bias = jnp.linspace(-0.5, 0.5, conv.c_out)
    return imgs, kern, bias


def _lax_conv(imgs, kern, conv: cv.Conv2D):
    """jax.lax oracle in the spec's layout (kern is (c_out, c_in, ky, kx))."""
    if conv.layout == "NHWC":
        dn, k = ("NHWC", "HWIO", "NHWC"), kern.transpose(2, 3, 1, 0)
    else:
        dn, k = ("NCHW", "OIHW", "NCHW"), kern
    return jax.lax.conv_general_dilated(
        imgs, k, (conv.stride, conv.stride), conv.padding.upper(),
        dimension_numbers=dn,
    )


# ---------------------------------------------------------------------------
# geometry: SAME/VALID × layouts × strides vs the lax oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2, 4])
@pytest.mark.parametrize("layout", ["NCHW", "NHWC"])
@pytest.mark.parametrize("padding", ["same", "valid"])
def test_conv2d_geometry_vs_lax(padding, layout, stride):
    conv = cv.Conv2D(k=3, c_in=5, c_out=8, stride=stride, padding=padding,
                     layout=layout)
    imgs, kern, bias = _mk(conv)
    want = _lax_conv(imgs, kern, conv) + (
        bias if layout == "NHWC" else bias[:, None, None]
    )
    got = cv.conv2d(imgs, cv.ConvParams.dense(kern, bias=bias), conv)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    # weight-shared params on the Pallas kernel path: same geometry, the
    # oracle runs on the dictionary-dereferenced kernel
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)
    kern_q = shared.codebook[shared.idx.astype(jnp.int32)]
    want_q = _lax_conv(imgs, kern_q, conv) + (
        bias if layout == "NHWC" else bias[:, None, None]
    )
    got_q = cv.conv2d(imgs, shared, conv, engine="kernel", interpret=True)
    np.testing.assert_allclose(np.asarray(got_q), np.asarray(want_q), rtol=1e-4, atol=1e-4)


def test_valid_centred_matches_paper_bounds():
    """valid_centred keeps the seed's kernel-centred loop-bound geometry."""
    # the paper's Fig-1 loop bounds on a 9×8 image, 3×2 kernel, stride 2:
    # kernel-centred windows run over each axis's interior, one output short
    # of VALID on the even (KX=2) axis when it tiles the width exactly
    conv = cv.Conv2D(k=(3, 2), c_in=3, c_out=4, stride=2)
    assert cv.conv_out_hw(9, 8, conv) == (4, 3)
    # odd kernels: valid_centred ≡ valid
    c3 = cv.Conv2D(k=3, c_in=1, c_out=1, stride=2, padding="valid_centred")
    v3 = dataclasses.replace(c3, padding="valid")
    for ih in range(5, 12):
        assert cv.conv_out_hw(ih, ih, c3) == cv.conv_out_hw(ih, ih, v3)


def test_alexnet_conv1_same_nhwc_exact():
    """Acceptance: torchvision AlexNet layer 1 (3×224×224, k=11, s=4) under
    SAME+NHWC reproduces lax for dense, weight-shared, PASM and packed."""
    conv = cv.Conv2D(k=11, c_in=3, c_out=96, stride=4, padding="same",
                     layout="NHWC", relu=True)
    imgs, kern, bias = _mk(conv, batch=1, hw=(224, 224))
    dense = cv.ConvParams.dense(kern, bias=bias)
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)
    kern_q = shared.codebook[shared.idx.astype(jnp.int32)]

    want = jnp.maximum(_lax_conv(imgs, kern, conv) + bias, 0)
    want_q = jnp.maximum(_lax_conv(imgs, kern_q, conv) + bias, 0)
    assert want.shape == (1, 56, 56, 96)  # torchvision geometry

    got = cv.conv2d(imgs, dense, conv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    for params, engine in [
        (shared, "kernel"),        # fused-dequant Pallas GEMM
        (shared, "pas_kernel"),    # paper-faithful two-phase formulation
        (shared.pack(layout="NHWC"), "kernel"),  # int4, K=363 → §3 K-pad
    ]:
        got = cv.conv2d(imgs, params, conv, engine=engine, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want_q), rtol=1e-4, atol=1e-4,
            err_msg=f"{params.kind}/{engine}",
        )


# ---------------------------------------------------------------------------
# fused epilogue: one pallas_call, no XLA add/max
# ---------------------------------------------------------------------------


def _iter_eqns(jaxpr):
    """All eqns, recursing into sub-jaxprs EXCEPT the pallas kernel body."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue  # the fused epilogue lives INSIDE; don't count it as XLA
        for v in eqn.params.values():
            yield from _iter_sub(v)


def _iter_sub(v):
    if hasattr(v, "jaxpr"):  # ClosedJaxpr
        yield from _iter_eqns(v.jaxpr)
    elif hasattr(v, "eqns"):  # Jaxpr
        yield from _iter_eqns(v)
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _iter_sub(x)


def _prim_profile(fn, *args):
    eqns = list(_iter_eqns(jax.make_jaxpr(fn)(*args).jaxpr))
    names = [e.primitive.name for e in eqns]
    f32_adds = [
        e for e in eqns
        if e.primitive.name == "add"
        and jnp.issubdtype(e.outvars[0].aval.dtype, jnp.floating)
    ]
    return names, f32_adds


@pytest.mark.parametrize("engine", ["kernel", "pas_kernel"])
def test_fused_epilogue_single_pallas_call(engine):
    """Acceptance: batched weight-shared conv + bias + ReLU is exactly one
    pallas_call — bias-add and ReLU do NOT appear as XLA add/max eqns."""
    conv = cv.Conv2D(k=3, c_in=4, c_out=8, stride=1, padding="same", relu=True)
    imgs, kern, bias = _mk(conv, hw=(9, 9))
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)

    names, f32_adds = _prim_profile(
        lambda x: cv.conv2d(x, shared, conv, engine=engine, interpret=True), imgs
    )
    assert names.count("pallas_call") == 1, names
    assert "max" not in names, "ReLU leaked out of the kernel into XLA"
    assert not f32_adds, "bias-add leaked out of the kernel into XLA"

    # sanity: the einsum reference DOES epilogue in XLA — the assertion above
    # is meaningful
    names_ref, f32_adds_ref = _prim_profile(
        lambda x: cv.conv2d(x, shared, conv, engine="einsum"), imgs
    )
    assert "max" in names_ref and f32_adds_ref


def test_fused_epilogue_matches_reference():
    """Kernel outputs with fused bias/ReLU still match the einsum reference
    on the paper spec and a realistic AlexNet-ish layer."""
    cases = [
        (cv.Conv2D(k=3, c_in=15, c_out=2, stride=1, relu=True), (5, 5)),
        (cv.Conv2D(k=3, c_in=64, c_out=128, stride=1, relu=True), (16, 16)),
    ]
    for conv, hw in cases:
        imgs, kern, bias = _mk(conv, hw=hw)
        shared = cv.ConvParams.quantize(kern, 16, bias=bias)
        want = cv.conv2d(imgs, shared, conv, engine="einsum")
        for engine in ("kernel", "pas_kernel"):
            got = cv.conv2d(imgs, shared, conv, engine=engine, interpret=True)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4,
                err_msg=f"{conv.c_in}ch/{engine}",
            )
        assert float(want.min()) == 0.0  # ReLU actually clamped something


# ---------------------------------------------------------------------------
# int4-packed conv dictionaries
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bins", [8, 16])
def test_packed_agrees_with_unpacked_odd_k(bins):
    """§3 K-pad before packing: odd C·KY·KX (K=27) packs and agrees.

    bins < 16 exercises the reserved-zero-bin append (bins+1); bins == 16
    the bin-0 fallback (inert via the zero patch column).
    """
    conv = cv.Conv2D(k=3, c_in=3, c_out=8, stride=1, padding="same", relu=True)
    imgs, kern, bias = _mk(conv, hw=(10, 10))
    shared = cv.ConvParams.quantize(kern, bins, bias=bias)
    packed = shared.pack()
    assert packed.kind == "packed" and packed.pad_k == 1
    assert packed.bins == (bins + 1 if bins < 16 else bins)
    assert packed.idx.shape == ((conv.K + 1) // 2, conv.c_out)
    if bins < 16:
        assert float(packed.codebook[-1]) == 0.0  # the reserved pad bin

    want = cv.conv2d(imgs, shared, conv, engine="einsum")
    for engine in ("einsum", "kernel", "pas_kernel"):
        got = cv.conv2d(imgs, packed, conv, engine=engine, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4, err_msg=engine
        )


def test_packed_halves_weight_bytes_and_checks_layout():
    conv = cv.Conv2D(k=5, c_in=8, c_out=16, stride=1)
    _, kern, _ = _mk(conv, hw=(8, 8))
    shared = cv.ConvParams.quantize(kern, 16)
    packed = shared.pack(layout="NCHW")
    assert packed.idx.nbytes * 2 == shared.idx.size  # two indices per byte
    with pytest.raises(ValueError, match="re-pack"):
        packed.gemm_tensor("NHWC")
    with pytest.raises(ValueError, match="shared"):
        cv.ConvParams.dense(kern).pack()


def test_engine_validation():
    conv = cv.Conv2D(k=3, c_in=2, c_out=4)
    imgs, kern, _ = _mk(conv, hw=(6, 6))
    dense = cv.ConvParams.dense(kern)
    with pytest.raises(ValueError, match="dense"):
        cv.conv2d(imgs, dense, conv, engine="kernel")
    with pytest.raises(ValueError, match="engine"):
        cv.conv2d(imgs, dense, conv, engine="nope")
    with pytest.raises(ValueError, match="channels"):
        cv.conv2d(imgs[:, :1], dense, conv)
    with pytest.raises(ValueError, match="padding"):
        cv.Conv2D(k=3, c_in=2, c_out=4, padding="full")


# ---------------------------------------------------------------------------
# pasm_hbm_bytes audit (roofline numbers pinned)
# ---------------------------------------------------------------------------


def _t(K, N, bins, pack):
    w = jax.random.normal(KEY, (K, N))
    return pasm.quantize(w, bins=bins, pack=pack)


def test_pasm_hbm_bytes_aligned_matches_nbytes_weights():
    """On tile-aligned shapes the weight term is exactly nbytes_weights."""
    t = _t(512, 256, 16, True)  # packed int4
    assert t.nbytes_weights == 512 * 256 // 2 + 16 * 4
    # x: 8·512·2, weights: nbytes, out: 8·256·4 (f32 store, not act_bytes)
    assert ops.pasm_hbm_bytes(t, 8) == 8 * 512 * 2 + t.nbytes_weights + 8 * 256 * 4

    tu = _t(512, 256, 64, False)  # uint8
    assert tu.nbytes_weights == 512 * 256 + 64 * 4
    assert ops.pasm_hbm_bytes(tu, 8) == 8 * 512 * 2 + tu.nbytes_weights + 8 * 256 * 4


def test_pasm_hbm_bytes_padded_counts_streamed_bytes():
    """K-padded shapes stream the padded operands: the seed's logical-shape
    accounting under-reported index (and activation) bytes."""
    t = _t(2400, 256, 16, True)  # AlexNet conv2 im2col K, packed → Kp=2432
    naive = 16 * 2400 * 2 + t.nbytes_weights + 16 * 256 * 2  # the seed's formula
    got = ops.pasm_hbm_bytes(t, 16)
    # pinned: x 16·2432·2 + idx 1216·256 + cb 16·4 + out 16·256·4
    assert got == 16 * 2432 * 2 + 1216 * 256 + 64 + 16 * 256 * 4 == 405568
    assert got > naive

    tu = _t(2400, 256, 16, False)  # unpacked: K-pad appends a reserved bin
    got_u = ops.pasm_hbm_bytes(tu, 16)
    assert got_u == 16 * 2432 * 2 + 2432 * 256 + 17 * 4 + 16 * 256 * 4 == 716868


def test_pasm_hbm_bytes_rounds_m_n_to_blocks():
    """M/N round up to the tile plan (bm multiple of 8, bn of 128)."""
    t = _t(128, 100, 16, True)
    # M=5 → Mp=8 (bm=8); N=100 → Np=128 (bn=128)
    assert ops.pasm_hbm_bytes(t, 5) == 8 * 128 * 2 + (64 * 128 + 64) + 8 * 128 * 4
