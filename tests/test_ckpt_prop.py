"""Property test: checkpoint save/restore is a bit-exact roundtrip for every
``PasmParams`` kind (dense / shared / grouped / int4-packed) and for the
dtype edge cases the manifest must survive — bf16 masters (npz can't store
ml_dtypes, so save upcasts to f32 and restore re-casts: lossless because
f32 ⊃ bf16) and uint8 index payloads (including packed int4 pairs).

Runs through tests/_prop.py: real Hypothesis when installed, else the
deterministic seeded shim (same decorator surface, CRC-seeded examples).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _prop import given, settings, st
from repro.ckpt import checkpoint as ckpt
from repro.core.params import PasmParams


def _make_params(kind: str, seed: int, *, K: int, N: int, bins: int, groups: int,
                 dtype) -> PasmParams:
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (K, N), jnp.float32).astype(dtype)
    bias = jax.random.normal(jax.random.fold_in(key, 1), (N,), jnp.float32)
    if kind == "dense":
        return PasmParams.dense(w, bias=bias)
    q = PasmParams.quantize(w.astype(jnp.float32), bins, groups=groups, bias=bias)
    if kind == "packed":
        q = q.pack()
    return q


def _roundtrip(tmp_path, tree):
    ckpt.save(tmp_path, 1, tree)
    restored, manifest = ckpt.restore(tmp_path, tree, step=1)
    flat_in = jax.tree.leaves(tree)
    flat_out = jax.tree.leaves(restored)
    assert len(flat_in) == len(flat_out)
    for a, b in zip(flat_in, flat_out):
        assert a.dtype == b.dtype, (a.dtype, b.dtype)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    return manifest


@settings(deadline=None, max_examples=12)
@given(
    kind=st.sampled_from(["dense", "shared", "grouped", "packed"]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    K=st.integers(min_value=2, max_value=12).map(lambda k: 2 * k),  # even K
    N=st.integers(min_value=1, max_value=16),
    bins=st.sampled_from([4, 8, 16]),
    bf16=st.booleans(),
)
def test_pasm_params_checkpoint_roundtrip(kind, seed, K, N, bins, bf16):
    # no pytest fixtures here: the _prop shim hides the signature, so the
    # scratch dir is a plain tempdir per example
    import tempfile
    from pathlib import Path

    groups = 2 if kind == "grouped" else 1
    dtype = jnp.bfloat16 if (bf16 and kind == "dense") else jnp.float32
    p = _make_params(
        "shared" if kind == "grouped" else kind,
        seed, K=K, N=N, bins=bins, groups=groups, dtype=dtype,
    )
    if kind == "packed":
        assert p.idx.dtype == jnp.uint8 and p.packed  # int4 pairs in uint8
    if kind in ("shared", "grouped"):
        assert p.idx.dtype == jnp.uint8
    with tempfile.TemporaryDirectory() as d:
        manifest = _roundtrip(
            Path(d) / "ck", {"layer": p, "step_scalar": jnp.int32(7)}
        )
    assert "crc32" in manifest and len(manifest["crc32"]) == len(manifest["keys"])


def test_bf16_upcast_roundtrip_is_lossless(tmp_path):
    # every representable bf16 payload survives the f32 detour bit-exactly
    w = (jnp.arange(-128, 128, dtype=jnp.float32) / 16.0).astype(jnp.bfloat16)
    tree = {"w": w.reshape(16, 16)}
    ckpt.save(tmp_path, 1, tree)
    restored, _ = ckpt.restore(tmp_path, tree, step=1)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(restored["w"].astype(jnp.float32)),
        np.asarray(tree["w"].astype(jnp.float32)),
    )


def test_mixed_train_state_roundtrip(tmp_path):
    """The real training tree shape: masters + codebooks + OptState."""
    from repro.train import optimizer as opt

    params = {
        "dense": PasmParams.dense(jax.random.normal(jax.random.PRNGKey(0), (8, 4))),
        "packed": PasmParams.quantize(
            jax.random.normal(jax.random.PRNGKey(1), (8, 4)), 4
        ).pack(),
    }
    state = opt.init_opt_state(params)
    _roundtrip(tmp_path, (params, state))
