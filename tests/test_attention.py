"""Chunked/online-softmax attention vs a naive reference; decode; windows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import attention as A


def naive_attention(q, k, v, causal=True, window=None):
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) * hd ** -0.5
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return o.reshape(B, Sq, H, hd)


def _qkv(B=2, S=64, H=4, KV=2, hd=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    return q, k, v


@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_naive(chunk, causal):
    q, k, v = _qkv()
    got = A.gqa_attention(q, k, v, causal=causal, chunk=chunk)
    want = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [8, 16])
def test_local_window(window):
    q, k, v = _qkv(S=48)
    got = A.gqa_attention(q, k, v, causal=True, window=window, chunk=16)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_non_divisible_kv_padding():
    q, k, v = _qkv(S=56)  # 56 % 16 != 0 → internal pad path
    got = A.gqa_attention(q, k, v, causal=True, chunk=16)
    want = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_decode_matches_full():
    B, S, H, KV, hd = 2, 32, 4, 2, 16
    q, k, v = _qkv(B, S, H, KV, hd)
    cache = A.init_kv_cache(B, 48, KV, hd, jnp.float32)
    cache = A.update_cache(cache, k, v)
    # decode for the last position
    got = A.decode_attention(q[:, -1:], cache)
    want = naive_attention(q, k, v, causal=True)[:, -1:]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_cache_update_positions():
    cache = A.init_kv_cache(1, 16, 1, 4, jnp.float32)
    k1 = jnp.ones((1, 3, 1, 4))
    cache = A.update_cache(cache, k1, k1)
    assert int(cache.pos[0]) == 3
    cache = A.update_cache(cache, 2 * k1[:, :1], 2 * k1[:, :1])
    assert int(cache.pos[0]) == 4
    np.testing.assert_allclose(np.asarray(cache.k[0, 3, 0]), 2.0)
    np.testing.assert_allclose(np.asarray(cache.k[0, 4, 0]), 0.0)  # untouched


# ---------------------------------------------------------------------------
# int8 PASM KV cache (beyond paper — §Perf qwen-decode/1)
# ---------------------------------------------------------------------------


def test_quant_cache_decode_close_to_fp():
    B, S, H, KV, hd = 2, 32, 4, 2, 16
    q, k, v = _qkv(B, S, H, KV, hd)
    fp = A.init_kv_cache(B, 48, KV, hd, jnp.float32)
    fp = A.update_cache(fp, k, v)
    qc = A.init_quant_kv_cache(B, 48, KV, hd)
    qc = A.update_quant_cache(qc, k, v)
    want = A.decode_attention(q[:, -1:], fp)
    got = A.decode_attention_quant(q[:, -1:], qc)
    # int8 with per-token·head scales: ~1% relative error budget
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-2, atol=5e-2)


def test_quant_cache_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 2, 16)) * 3.0
    qv, scale = A._quantize_kv(x)
    deq = qv.astype(jnp.float32) * scale[..., None]
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    assert float(jnp.max(jnp.abs(deq - x) / jnp.maximum(amax, 1e-6))) <= 1 / 127 + 1e-6


def test_quant_cache_incremental_updates():
    qc = A.init_quant_kv_cache(1, 16, 1, 4)
    k1 = jnp.ones((1, 3, 1, 4))
    qc = A.update_quant_cache(qc, k1, k1)
    assert int(qc.pos[0]) == 3
    qc = A.update_quant_cache(qc, 2 * k1[:, :1], 2 * k1[:, :1])
    assert int(qc.pos[0]) == 4
    deq = qc.k_q[0, 3, 0].astype(jnp.float32) * qc.k_scale[0, 3, 0]
    np.testing.assert_allclose(np.asarray(deq), 2.0, rtol=1e-2)
