"""QAT (straight-through estimator) — beyond-paper training path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qat


def test_ste_forward_snaps():
    cb = jnp.array([-1.0, 0.0, 1.0])
    w = jnp.array([-0.9, 0.1, 0.45, 2.0])
    y = qat.ste_quantize(w, cb)
    np.testing.assert_allclose(np.asarray(y), [-1.0, 0.0, 0.0, 1.0])  # 0.45 → 0


def test_ste_gradient_passthrough():
    cb = jnp.array([-1.0, 0.0, 1.0])
    w = jnp.array([0.3, -0.6])

    def loss(w):
        return (qat.ste_quantize(w, cb) * jnp.array([2.0, 3.0])).sum()

    g = jax.grad(loss)(w)
    np.testing.assert_allclose(np.asarray(g), [2.0, 3.0])  # identity STE


def test_codebook_grads_are_pas_binned():
    """dL/dcb[b] = Σ of upstream grads whose weight lands in bin b — the PAS
    identity on the backward pass (DESIGN.md §2)."""
    cb = jnp.array([-1.0, 1.0])
    w = jnp.array([-0.9, 0.8, 0.7, -0.2])

    def loss(cb):
        return (qat.ste_quantize(w, cb) * jnp.array([1.0, 2.0, 3.0, 4.0])).sum()

    g = jax.grad(loss)(cb)
    # bins: w<0 → bin0 (grads 1+4), w>0 → bin1 (grads 2+3)
    np.testing.assert_allclose(np.asarray(g), [5.0, 5.0])
    explicit = qat.codebook_grads(w, cb, jnp.array([1.0, 2.0, 3.0, 4.0]))
    np.testing.assert_allclose(np.asarray(explicit), [5.0, 5.0])


def test_qat_training_reduces_loss():
    """Train dense master weights through the STE against a fixed codebook."""
    key = jax.random.PRNGKey(0)
    cb = jnp.linspace(-2, 2, 16)
    Wt = jax.random.normal(key, (8, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    y = x @ Wt
    w = jnp.zeros((8, 8))

    def loss(w):
        return jnp.mean((x @ qat.ste_quantize(w, cb) - y) ** 2)

    l0 = float(loss(w))
    for _ in range(200):
        w = w - 0.05 * jax.grad(loss)(w)
    assert float(loss(w)) < 0.25 * l0
