"""Hybrid (RecurrentGemma) specifics: ring-buffer local attention wrap-around.

The long_500k cell depends on the ring buffer holding exactly the last
``local_window`` positions once decode passes the window size — this test
decodes past the wrap point and checks every step against the full forward
(which computes local attention by masking).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import api


def test_ring_buffer_decode_past_window():
    cfg = get_config("recurrentgemma-2b", smoke=True)  # local_window = 16
    model = api.get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    B, total = 1, 24  # prefill 4 + decode 20 → wraps the 16-slot buffer
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, total), 0, cfg.vocab)

    logits_full, _ = model.forward(params, toks, cfg)

    caches = model.init_caches(cfg, B, 64)
    lg, caches = model.prefill(params, toks[:, :4], caches, cfg)
    decode = jax.jit(lambda p, t, c: model.decode_step(p, t, c, cfg))
    errs = []
    for t in range(4, total):
        lg, caches = decode(params, toks[:, t : t + 1], caches)
        if t + 1 < total:
            errs.append(float(jnp.abs(lg[:, 0] - logits_full[:, t]).max()))
    # bf16 tolerance; crucially the error must NOT grow after the wrap point
    errs = np.array(errs)
    assert errs.max() < 0.25, errs
    pre_wrap = errs[: 16 - 4].max()
    post_wrap = errs[16 - 4 :].max()
    assert post_wrap < max(4 * pre_wrap, 0.25), (pre_wrap, post_wrap)


def test_ssm_decode_long_horizon_stable():
    """Mamba decode for 64 steps: states stay finite (long_500k stability)."""
    cfg = get_config("mamba2-130m", smoke=True)
    model = api.get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    caches = model.init_caches(cfg, 1, 8)
    tok = jnp.zeros((1, 1), jnp.int32)
    decode = jax.jit(lambda p, t, c: model.decode_step(p, t, c, cfg))
    for t in range(64):
        lg, caches = decode(params, tok, caches)
        tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
    assert bool(jnp.isfinite(lg.astype(jnp.float32)).all())
    assert float(jnp.abs(caches["ssm"]).max()) < 1e4
