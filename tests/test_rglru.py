"""RG-LRU: associative scan vs step recurrence; causal conv."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import rglru as RG


def _params(W=8, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {
        "w_a": jax.random.normal(ks[0], (W, W)) * 0.3,
        "b_a": jnp.zeros((W,)),
        "w_x": jax.random.normal(ks[1], (W, W)) * 0.3,
        "b_x": jnp.zeros((W,)),
        "lam": jnp.linspace(0.5, 3.0, W),
    }


def test_scan_matches_decode_steps():
    B, T, W = 2, 16, 8
    p = _params(W)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, W))
    y_scan, h_scan = RG.rg_lru_scan(x, p)
    h = jnp.zeros((B, W))
    ys = []
    for t in range(T):
        y, h = RG.rg_lru_decode_step(x[:, t], p, h)
        ys.append(y)
    y_steps = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_steps), np.asarray(y_scan), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_scan), rtol=2e-3, atol=2e-3)


def test_init_state_continuation():
    B, T, W = 1, 12, 8
    p = _params(W)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, T, W))
    y_full, h_full = RG.rg_lru_scan(x, p)
    y1, h1 = RG.rg_lru_scan(x[:, :6], p)
    y2, h2 = RG.rg_lru_scan(x[:, 6:], p, init_h=h1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 6:]), rtol=2e-3, atol=2e-3)


def test_decay_bounded():
    """a_t ∈ (0, 1): the recurrence is contractive (stable at 500k steps)."""
    p = _params()
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 8)) * 10
    y, h = RG.rg_lru_scan(x, p)
    assert bool(jnp.isfinite(y).all())
    assert float(jnp.abs(h).max()) < 1e3


def test_causal_conv_matches_explicit():
    B, T, W, K = 2, 10, 4, 4
    x = jax.random.normal(jax.random.PRNGKey(0), (B, T, W))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, W))
    b = jax.random.normal(jax.random.PRNGKey(2), (W,))
    y = RG.causal_conv1d(x, w, b)
    xp = np.pad(np.asarray(x), ((0, 0), (K - 1, 0), (0, 0)))
    want = np.stack(
        [sum(xp[:, t + k] * np.asarray(w)[k] for k in range(K)) for t in range(T)], 1
    ) + np.asarray(b)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-4, atol=1e-4)


def test_conv_decode_window():
    B, T, W, K = 1, 8, 4, 4
    x = jax.random.normal(jax.random.PRNGKey(5), (B, T, W))
    w = jax.random.normal(jax.random.PRNGKey(6), (K, W))
    b = jnp.zeros((W,))
    y_full = RG.causal_conv1d(x, w, b)
    win = jnp.zeros((B, K - 1, W))
    ys = []
    for t in range(T):
        y, win = RG.conv1d_decode_step(x[:, t], w, b, win)
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(ys, 1)), np.asarray(y_full), rtol=1e-3, atol=1e-3
    )
