"""Property-testing shim: real Hypothesis when installed, else a fallback.

This container is offline, so ``pip install hypothesis`` is impossible and a
bare ``from hypothesis import ...`` fails collection for every property test.
Test modules import ``given / settings / st`` from here instead.  When the
real library is importable it is re-exported unchanged; otherwise a minimal
deterministic replacement runs ``max_examples`` seeded examples per test —
no shrinking, no database, but the same decorator surface for the subset of
the API this suite uses (``st.integers``, ``st.sampled_from``, ``.map``,
``@settings(deadline=..., max_examples=...)``, ``@given(**kwargs)``).

Determinism: the RNG is seeded from a CRC of the test's qualified name, so a
failing example reproduces on every run and across machines.
"""
from __future__ import annotations

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import inspect
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw function wrapper mimicking hypothesis strategies."""

        def __init__(self, draw):
            self._draw = draw

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def example_for(self, rng):
            return self._draw(rng)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda rng: elems[int(rng.integers(len(elems)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value))
            )

    st = _StrategiesModule()

    _DEFAULT_EXAMPLES = 20

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **fixture_kw):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode("utf-8"))
                )
                for i in range(n):
                    kw = {k: s.example_for(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **kw, **fixture_kw)
                    except Exception as e:  # annotate the failing example
                        raise AssertionError(
                            f"falsifying example #{i}: {fn.__name__}({kw})"
                        ) from e

            wrapper._max_examples = getattr(fn, "_max_examples", _DEFAULT_EXAMPLES)
            wrapper.hypothesis_shim = True
            # hide the strategy kwargs from pytest's fixture resolution
            # (hypothesis does the same: the collected item takes no args)
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco

    def settings(*, max_examples=_DEFAULT_EXAMPLES, **_ignored):
        """Accepts (and ignores) hypothesis-only knobs like ``deadline``."""

        def deco(fn):
            # works in either decorator order: @given reads the stash off the
            # raw fn; applied on top it updates the wrapper's attribute
            fn._max_examples = max_examples
            return fn

        return deco
