"""The paper's conv accelerator (Fig 13): all engine formulations agree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.configs.alexnet_conv import PAPER_BINS, PAPER_SPEC, PaperAccel
from repro.core import conv as cv


def _setup(spec, bins, seed=0):
    k = jax.random.PRNGKey(seed)
    img = jax.random.normal(k, (spec.C, spec.IH, spec.IW))
    kern = jax.random.normal(jax.random.PRNGKey(seed + 1), (spec.M, spec.C, spec.KY, spec.KX))
    cb, idx = cv.quantize_conv_weights(kern, bins)
    return img, kern, cb, idx


@pytest.mark.parametrize("bins", PAPER_BINS)
def test_paper_accelerator_spec(bins):
    """§4 configuration: 5×5 image, 15 ch, 3×3 kernel, M=2 — all variants equal."""
    spec = PAPER_SPEC
    conv = spec.conv()
    img, kern, cb, idx = _setup(spec, bins)
    p = cv.ConvParams.shared(idx, cb)
    y_ws = cv.conv2d(img, p, conv)
    y_pas = cv.conv2d(img, p, conv, engine="pas_einsum")
    y_direct = cv.conv2d(
        img, cv.ConvParams.dense(cb[idx.astype(jnp.int32)]), conv, engine="einsum"
    )
    assert y_ws.shape == (2, 3, 3)
    np.testing.assert_allclose(np.asarray(y_ws), np.asarray(y_pas), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_ws), np.asarray(y_direct), rtol=1e-6, atol=1e-6)


def test_bias_relu_stride():
    """§4: stride / bias / ReLU are outside weight sharing and must agree."""
    spec = PaperAccel(IH=9, IW=9, C=4, KY=3, KX=3, M=3, stride=2)
    conv = spec.conv(bias=True, relu=True)
    img, kern, cb, idx = _setup(spec, 8)
    bias = jnp.array([0.5, -10.0, 0.1])
    p = cv.ConvParams.shared(idx, cb, bias=bias)
    a = cv.conv2d(img, p, conv)
    b = cv.conv2d(img, p, conv, engine="pas_einsum")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)
    assert float(a.min()) >= 0.0  # ReLU applied


@settings(deadline=None, max_examples=15)
@given(
    c=st.integers(1, 8),
    m=st.integers(1, 4),
    ih=st.integers(5, 12),
    bins=st.sampled_from([4, 16]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 100),
)
def test_conv_property(c, m, ih, bins, stride, seed):
    spec = PaperAccel(IH=ih, IW=ih, C=c, KY=3, KX=3, M=m, stride=stride)
    img, kern, cb, idx = _setup(spec, bins, seed)
    p = cv.ConvParams.shared(idx, cb)
    a = cv.conv2d(img, p, spec.conv())
    b = cv.conv2d(img, p, spec.conv(), engine="pas_einsum")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


def test_batched_kernel_path_matches_seed_einsum_paper_spec():
    """Acceptance: batch dim + Pallas execution ≡ the seed einsum port (§4 spec)."""
    spec = PAPER_SPEC
    conv = spec.conv()
    img, kern, cb, idx = _setup(spec, 16)
    p = cv.ConvParams.shared(idx, cb)
    imgs = jnp.stack([img, img * 0.5, img - 1.0])
    y_ws = cv.conv2d(imgs, p, conv, engine="kernel")  # fused-dequant pasm_matmul
    y_pas = cv.conv2d(imgs, p, conv, engine="pas_kernel")  # two-phase pas_matmul
    assert y_ws.shape == (3, 2, 3, 3) and y_pas.shape == (3, 2, 3, 3)
    for b in range(3):
        want = cv.conv2d(imgs[b], p, conv, engine="einsum")
        np.testing.assert_allclose(np.asarray(y_ws[b]), np.asarray(want), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y_pas[b]), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_batched_kernel_path_realistic_layer():
    """Acceptance: a realistic conv layer (K-padded reduction) on the kernels."""
    spec = PaperAccel(IH=16, IW=16, C=64, KY=3, KX=3, M=128, stride=1)  # K=576
    conv = spec.conv(bias=True, relu=True)
    img, kern, cb, idx = _setup(spec, 16, seed=3)
    imgs = jax.random.normal(jax.random.PRNGKey(9), (2, spec.C, spec.IH, spec.IW))
    bias = jnp.linspace(-0.5, 0.5, spec.M)
    p = cv.ConvParams.shared(idx, cb, bias=bias)
    y_ws = cv.conv2d(imgs, p, conv, engine="kernel")
    y_pas = cv.conv2d(imgs, p, conv, engine="pas_kernel")
    want = jnp.stack([cv.conv2d(imgs[b], p, conv, engine="einsum") for b in range(2)])
    assert y_ws.shape == (2, 128, 14, 14)
    np.testing.assert_allclose(np.asarray(y_ws), np.asarray(want), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_pas), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_conv_gemm_tensor_layout():
    """The (c,ky,kx) flat order of im2col columns matches the GEMM operand."""
    spec = PaperAccel(IH=6, IW=6, C=3, KY=3, KX=3, M=4, stride=1)
    img, kern, cb, idx = _setup(spec, 8, seed=5)
    t = cv.ConvParams.shared(idx, cb).gemm_tensor("NCHW")
    assert t.shape == (spec.C * spec.KY * spec.KX, spec.M)
    assert t.groups == 1 and not t.packed
    # dequantized GEMM operand == the dictionary-dereferenced kernel, flattened
    from repro.core import pasm as pasm_mod

    w = pasm_mod.dequantize(t)
    np.testing.assert_allclose(
        np.asarray(w), np.asarray(cb[idx.astype(jnp.int32)].reshape(spec.M, -1).T),
        rtol=1e-6, atol=1e-6,
    )


def test_batched_direct_matches_per_image():
    spec = PaperAccel(IH=9, IW=9, C=4, KY=3, KX=3, M=3, stride=2)
    conv = spec.conv()
    img, kern, cb, idx = _setup(spec, 8)
    p = cv.ConvParams.dense(kern)
    imgs = jnp.stack([img, 2.0 * img])
    y = cv.conv2d(imgs, p, conv, engine="einsum")
    for b in range(2):
        np.testing.assert_allclose(
            np.asarray(y[b]), np.asarray(cv.conv2d(imgs[b], p, conv, engine="einsum")),
            rtol=1e-6, atol=1e-6,
        )


def test_integer_images_bit_exact():
    """With integer images + integer codebook, PASM conv is bit-exact (§5.3)."""
    spec = PaperAccel(IH=7, IW=7, C=3, KY=3, KX=3, M=2, stride=1)
    conv = spec.conv()
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.integers(-8, 8, size=(3, 7, 7)), jnp.int32)
    idx = jnp.asarray(rng.integers(0, 4, size=(2, 3, 3, 3)), jnp.uint8)
    cb = jnp.asarray(rng.integers(-8, 8, size=4), jnp.int32)
    p = cv.ConvParams.shared(idx, cb)
    a = cv.conv2d(img, p, conv, engine="einsum")
    b = cv.conv2d(img, p, conv, engine="pas_einsum")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
