"""The paper's core identity: PASM ≡ weight-shared MAC (§2.2, §5.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import pas, pasm


def test_paper_worked_example():
    """Fig 4 / Fig 6: result = 98.8 via both formulations, same bins."""
    x = jnp.array([26.7, 3.4, 4.8, 17.7, 6.1])
    idx = jnp.array([0, 1, 2, 3, 0], dtype=jnp.uint8)
    cb = jnp.array([1.7, 0.4, 1.3, 2.0])
    ws = pas.weight_shared_dot(x, idx, cb)
    pm = pas.pasm_dot(x, idx, cb)
    assert np.isclose(float(ws), 98.8, atol=0.05)  # paper rounds to 98.8
    assert np.isclose(float(pm), float(ws), rtol=1e-6)
    bins = pas.pas_accumulate(x, idx, 4)
    np.testing.assert_allclose(np.asarray(bins), [32.8, 3.4, 4.8, 17.7], rtol=1e-6)


@settings(deadline=None, max_examples=50)
@given(
    n=st.integers(4, 200),
    bins=st.sampled_from([4, 8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bit_exact_integer(n, bins, seed):
    """§5.3: in integer arithmetic PASM is BIT-EXACT vs the weight-shared MAC."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-1000, 1000, size=n).astype(np.int64)
    idx = rng.integers(0, bins, size=n).astype(np.int64)
    cb = rng.integers(-1000, 1000, size=bins).astype(np.int64)
    direct = int(np.sum(x * cb[idx]))
    bins_acc = np.zeros(bins, np.int64)
    np.add.at(bins_acc, idx, x)  # PAS phase
    pasm_result = int(np.sum(bins_acc * cb))  # post-pass multiply
    assert direct == pasm_result  # exact, not approximate


@settings(deadline=None, max_examples=25)
@given(
    n=st.integers(4, 128),
    bins=st.sampled_from([4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_float_equivalence(n, bins, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n), jnp.float32)
    idx = jnp.asarray(rng.integers(0, bins, size=n), jnp.uint8)
    cb = jnp.asarray(rng.normal(size=bins), jnp.float32)
    a = pas.weight_shared_dot(x, idx, cb)
    b = pas.pasm_dot(x, idx, cb)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("groups", [1, 4])
@pytest.mark.parametrize("bins", [4, 16, 64])
def test_matmul_equivalence(groups, bins):
    k = jax.random.PRNGKey(0)
    w = jax.random.normal(k, (64, 48))
    t = pasm.quantize(w, bins=bins, groups=groups)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    y_ws = pas.weight_shared_matmul(x, t)
    y_pasm = pas.pasm_matmul(x, t)
    np.testing.assert_allclose(np.asarray(y_ws), np.asarray(y_pasm), rtol=1e-4, atol=1e-4)


def test_cycle_model_paper_example():
    """§2.2: 1024 inputs, B=16, 4 PAS sharing one MAC → 1088 cycles."""
    assert pas.mac_cycles(1024) == 1024
    assert pas.pasm_cycles(1024, bins=16, pas_per_mac=4) == 1088
    assert pas.pasm_cycles(1024, bins=16, pas_per_mac=1) == 1040
