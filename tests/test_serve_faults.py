"""Chaos suite: seeded, deterministic fault injection against the serve stack.

The contract under test (ISSUE 8 / DESIGN.md §2.4): with a seeded
:class:`FaultPlan` injecting NaN and raise faults,

- every NON-faulted request's token stream is **bit-identical**
  (``assert_array_equal``) to a fault-free run,
- every faulted request terminates with the right ``failed:*`` status and
  its partial output,
- the engine always drains (``run_until_drained`` completes, zero stuck),

plus the supporting machinery: seeded-plan determinism, quarantine-then-
reuse never leaks poisoned KV (the PR-7 no-KV-leak guarantee extended to
the numeric-fault path), the capped-exponential backoff schedule is pinned,
shed-expired vs reject backpressure policies, mid-decode deadline eviction
returns partial output, and kernel→dequant graceful degradation.

Deadline tests drive a DETERMINISTIC tick clock: the metrics clock reads the
engine's own tick counter, so "seconds" are ticks and every run is
identical.
"""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.models.common import quantize_params
from repro.serve.engine import Engine
from repro.serve.faults import FaultInjected, FaultPlan, FaultSpec
from repro.serve.metrics import Metrics
from repro.serve.scheduler import QueueFullError, Scheduler

KEY = jax.random.PRNGKey(0)


@functools.lru_cache(maxsize=None)
def _setup(arch: str):
    cfg = get_config(arch, smoke=True)
    model = api.get_model(cfg)
    return cfg, model.init_params(cfg, KEY)


def _tick_engine(cfg, params, **kw):
    """Engine whose metrics clock IS its tick counter — deterministic
    deadlines (slo_s is a budget in ticks)."""
    holder = []
    metrics = Metrics(clock=lambda: float(holder[0].tick) if holder else 0.0)
    eng = Engine(cfg, params, metrics=metrics, **kw)
    holder.append(eng)
    return eng


def _solo_out(cfg, params, prompt, max_new, *, slots=3, max_seq=48):
    eng = Engine(cfg, params, batch_slots=slots, max_seq=max_seq)
    r = eng.submit(prompt, max_new=max_new)
    eng.run_until_drained()
    return r.out


# ---------------------------------------------------------------------------
# FaultPlan: seeded determinism
# ---------------------------------------------------------------------------


def test_fault_plan_seeded_determinism():
    kw = dict(n_ticks=30, n_slots=4, n_requests=8, n_nan=3, n_prefill=2,
              n_decode=2, n_slow=1, slow_delay_s=5.0, n_kernel=1)
    a = FaultPlan.sample(7, **kw)
    b = FaultPlan.sample(7, **kw)
    assert a.faults == b.faults  # same seed ⇒ same injected schedule
    assert len(a.faults) == 9
    c = FaultPlan.sample(8, **kw)
    assert c.faults != a.faults  # a different seed moves the schedule
    with pytest.raises(ValueError, match="kind"):
        FaultSpec("meteor", tick=3)


def test_fault_plan_hooks_fire_deterministically():
    plan = FaultPlan([
        FaultSpec("nan", tick=3, slot=1),
        FaultSpec("decode", tick=5),
        FaultSpec("prefill", uid=2, nth=1),
        FaultSpec("slow", tick=4, delay_s=2.5),
    ])
    assert plan.poison_slots(2) == [] and plan.poison_slots(3) == [1]
    assert plan.on_tick(4) == 2.5 and plan.on_tick(3) == 0.0
    plan.on_decode(4)  # no fault scheduled: no raise
    with pytest.raises(FaultInjected):
        plan.on_decode(5)
    plan.on_prefill(1, 1)  # uid 1 never faulted
    with pytest.raises(FaultInjected):
        plan.on_prefill(2, 1)  # uid 2, first attempt
    plan.on_prefill(2, 6)  # second attempt succeeds (nth=1 only)
    assert [f[0] for f in plan.fired] == ["nan", "slow", "decode", "prefill"]


# ---------------------------------------------------------------------------
# tentpole acceptance: chaos run — unaffected slots bit-identical, faulted
# requests terminal with partial output, engine drains (transformer+encdec)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["stablelm-3b", "whisper-tiny"])
def test_chaos_unaffected_requests_bit_identical(arch):
    cfg, params = _setup(arch)
    rng = np.random.default_rng(31)
    prompts = [rng.integers(0, cfg.vocab, size=n) for n in (5, 7, 4, 6)]

    # fault-free reference run: 4 requests over 3 slots
    ref = Engine(cfg, params, batch_slots=3, max_seq=48)
    ref_reqs = [ref.submit(p, max_new=8) for p in prompts]
    ref.run_until_drained()
    assert all(r.done for r in ref_reqs)

    # chaos run, max_retries=0 so faulted requests are terminal:
    # - NaN into slot 1 (second request) at tick 3 → failed:numeric
    # - uid 4's first prefill raises → failed:error
    # - a transient decode raise at tick 2 → whole tick replayed, no effect
    plan = FaultPlan([
        FaultSpec("nan", tick=3, slot=1),
        FaultSpec("prefill", uid=4, nth=1),
        FaultSpec("decode", tick=2),
    ])
    eng = Engine(cfg, params, batch_slots=3, max_seq=48, faults=plan,
                 max_retries=0)
    reqs = [eng.submit(p, max_new=8) for p in prompts]
    eng.run_until_drained()  # the engine always drains
    roll = eng.metrics.rollup()
    assert roll["n_stuck"] == 0

    # faulted requests: terminal failed:* with partial output preserved
    r_nan, r_err = reqs[1], reqs[3]
    assert r_nan.status == "failed:numeric"
    assert 0 < len(r_nan.out) < 8  # partial output, not silently empty/full
    assert r_err.status == "failed:error" and r_err.out == []
    assert roll["n_quarantined"] == 1 and roll["failed_numeric_n"] == 1
    assert roll["failed_error_n"] == 1 and roll["n_faults_decode"] == 1

    # every unaffected request: bit-identical to the fault-free run
    for got, want in ((reqs[0], ref_reqs[0]), (reqs[2], ref_reqs[2])):
        assert got.done
        np.testing.assert_array_equal(np.asarray(got.out), np.asarray(want.out))


@pytest.mark.parametrize("arch", ["stablelm-3b", "whisper-tiny"])
def test_quarantine_then_reuse_never_leaks_kv(arch):
    """PR-7's no-KV-leak guarantee extended to the quarantine path: a slot
    whose occupant was NaN-poisoned is re-grafted from the fresh template,
    and its next occupant matches a solo run bit for bit."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(37)
    victim_p = rng.integers(0, cfg.vocab, size=6)
    probe_p = rng.integers(0, cfg.vocab, size=5)
    want = _solo_out(cfg, params, probe_p, 6, slots=1)

    plan = FaultPlan([FaultSpec("nan", tick=2, slot=0)])
    eng = Engine(cfg, params, batch_slots=1, max_seq=48, faults=plan,
                 max_retries=0)
    victim = eng.submit(victim_p, max_new=6)
    eng.step()  # tick 1: admit victim
    eng.step()  # tick 2: decode → poisoned → quarantined
    assert victim.status == "failed:numeric"
    assert eng.sched.quarantined == {0}  # slot visibly quarantined
    assert eng.sched.free_slots == []  # and not handed out

    probe = eng.submit(probe_p, max_new=6)
    eng.run_until_drained()
    assert eng.sched.quarantined == set()  # scrubbed before reuse
    assert probe.done and probe.slot == 0
    np.testing.assert_array_equal(np.asarray(probe.out), np.asarray(want))


def test_numeric_retry_recovers_bit_exact():
    """A retryable numeric fault re-queues with backoff; the retry decodes
    fresh and lands the solo-run output exactly."""
    cfg, params = _setup("stablelm-3b")
    rng = np.random.default_rng(41)
    p = rng.integers(0, cfg.vocab, size=5)
    want = _solo_out(cfg, params, p, 6, slots=2)

    plan = FaultPlan([FaultSpec("nan", tick=2, slot=0)])
    eng = Engine(cfg, params, batch_slots=2, max_seq=48, faults=plan,
                 max_retries=2)
    r = eng.submit(p, max_new=6)
    eng.run_until_drained()
    roll = eng.metrics.rollup()
    assert r.done and r.failed is None and r.retries == 1
    assert roll["n_retried"] == 1 and roll["n_quarantined"] == 1
    np.testing.assert_array_equal(np.asarray(r.out), np.asarray(want))


def test_prefill_fault_retries_and_recovers():
    cfg, params = _setup("stablelm-3b")
    rng = np.random.default_rng(43)
    p = rng.integers(0, cfg.vocab, size=5)
    want = _solo_out(cfg, params, p, 6, slots=2)

    plan = FaultPlan([FaultSpec("prefill", uid=1, nth=1)])
    eng = Engine(cfg, params, batch_slots=2, max_seq=48, faults=plan,
                 max_retries=1)
    r = eng.submit(p, max_new=6)
    eng.run_until_drained()
    assert r.done and r.retries == 1
    assert eng.metrics.rollup()["n_retried"] == 1
    np.testing.assert_array_equal(np.asarray(r.out), np.asarray(want))


# ---------------------------------------------------------------------------
# retry backoff schedule
# ---------------------------------------------------------------------------


def test_backoff_schedule_pinned():
    """Deterministic tick-based capped exponential: delays 1, 2, 4, 8, 8…
    (base 1, cap 8) relative to the failing tick."""
    cfg, params = _setup("stablelm-3b")
    eng = Engine(cfg, params, batch_slots=1, max_seq=48, max_retries=5,
                 backoff_ticks=1, backoff_cap_ticks=8)
    from repro.serve.engine import Request

    r = Request(uid=99, prompt=np.zeros(4, np.int32))
    eng.metrics.submit(99, "lm")
    delays = []
    for _ in range(5):
        tick_before = eng.tick
        eng._fail_or_retry(r, "numeric")
        delays.append(r.retry_at - tick_before)
        eng._retry_q.clear()
    assert delays == [1, 2, 4, 8, 8]  # capped exponential, tick-based
    eng._fail_or_retry(r, "numeric")  # retries exhausted → terminal
    assert r.status == "failed:numeric"

    # deadline failures are never retryable
    r2 = Request(uid=100, prompt=np.zeros(4, np.int32))
    eng.metrics.submit(100, "lm")
    eng._fail_or_retry(r2, "deadline")
    assert r2.status == "failed:deadline" and not eng._retry_q


def test_retry_waits_out_backoff_before_readmission():
    cfg, params = _setup("stablelm-3b")
    rng = np.random.default_rng(47)
    plan = FaultPlan([FaultSpec("nan", tick=2, slot=0)])
    eng = Engine(cfg, params, batch_slots=1, max_seq=48, faults=plan,
                 max_retries=1, backoff_ticks=3)
    r = eng.submit(rng.integers(0, cfg.vocab, size=4), max_new=4)
    eng.step()  # tick 1: admit
    eng.step()  # tick 2: poisoned → retry_at = 2 + 3
    assert r.retry_at == 5 and eng._retry_q == [r]
    eng.step()  # tick 3: still backing off
    eng.step()  # tick 4: still backing off
    assert not eng.live and eng._retry_q == [r]
    eng.step()  # tick 5: re-queued and admitted
    assert r.uid in eng.live
    eng.run_until_drained()
    assert r.done


# ---------------------------------------------------------------------------
# backpressure: bounded queue policies
# ---------------------------------------------------------------------------


def test_scheduler_bounded_queue_policies():
    class R:
        def __init__(self, uid, deadline=None):
            self.uid, self.prompt, self.deadline = uid, list(range(4)), deadline

    s = Scheduler(1, max_seq=64, max_queue=2, policy="reject")
    s.submit(R(1))
    s.submit(R(2))
    with pytest.raises(QueueFullError):
        s.submit(R(3))
    assert [r.uid for r in s.waiting] == [1, 2]

    s = Scheduler(1, max_seq=64, max_queue=2, policy="shed_oldest")
    s.submit(R(1))
    s.submit(R(2))
    shed = s.submit(R(3))
    assert [r.uid for r in shed] == [1]
    assert [r.uid for r in s.waiting] == [2, 3]

    s = Scheduler(1, max_seq=64, max_queue=2, policy="shed_expired")
    s.submit(R(1, deadline=5.0), now=0.0)
    s.submit(R(2), now=0.0)
    shed = s.submit(R(3), now=10.0)  # uid 1 expired at t=10 → shed
    assert [r.uid for r in shed] == [1]
    assert [r.uid for r in s.waiting] == [2, 3]
    with pytest.raises(QueueFullError):  # nothing expired now → reject
        s.submit(R(4), now=10.0)
    with pytest.raises(ValueError, match="policy"):
        Scheduler(1, policy="drop_random")


def test_engine_reject_vs_shed_policies():
    cfg, params = _setup("stablelm-3b")
    rng = np.random.default_rng(53)
    mk = lambda: rng.integers(0, cfg.vocab, size=4)

    eng = Engine(cfg, params, batch_slots=1, max_seq=48, max_queue=1,
                 policy="reject")
    r1, r2 = eng.submit(mk(), max_new=3), eng.submit(mk(), max_new=3)
    assert r2.status == "failed:rejected"  # terminal at submit, no exception
    eng.run_until_drained()
    roll = eng.metrics.rollup()
    assert r1.done and roll["n_rejected"] == 1
    assert roll["failed_rejected_n"] == 1

    eng = Engine(cfg, params, batch_slots=1, max_seq=48, max_queue=1,
                 policy="shed_oldest")
    r1, r2 = eng.submit(mk(), max_new=3), eng.submit(mk(), max_new=3)
    assert r1.status == "failed:rejected" and r1.uid not in (
        q.uid for q in eng.sched.waiting
    )
    eng.run_until_drained()
    assert r2.done and eng.metrics.rollup()["n_shed"] == 1


def test_expired_queued_requests_shed_before_prefill():
    """A queued request whose SLO expires before a slot frees is shed —
    never admitted, never prefilled (t_admit stays nan)."""
    cfg, params = _setup("stablelm-3b")
    rng = np.random.default_rng(59)
    eng = _tick_engine(cfg, params, batch_slots=1, max_seq=48)
    hog = eng.submit(rng.integers(0, cfg.vocab, size=4), max_new=12)
    doomed = eng.submit(rng.integers(0, cfg.vocab, size=4), max_new=4, slo_s=3.0)
    eng.run_until_drained()
    roll = eng.metrics.rollup()
    assert hog.done
    assert doomed.status == "failed:deadline" and doomed.out == []
    assert roll["n_shed"] == 1 and roll["n_evicted_deadline"] == 0
    import math

    assert math.isnan(eng.metrics.timelines[doomed.uid].t_admit)  # no prefill spent


def test_deadline_eviction_returns_partial_output():
    """A live request that blows its deadline mid-decode is evicted with the
    tokens it produced so far; the freed slot serves the next request."""
    cfg, params = _setup("stablelm-3b")
    rng = np.random.default_rng(61)
    eng = _tick_engine(cfg, params, batch_slots=1, max_seq=48)
    r = eng.submit(rng.integers(0, cfg.vocab, size=4), max_new=20, slo_s=4.0)
    nxt = eng.submit(rng.integers(0, cfg.vocab, size=4), max_new=3)
    eng.run_until_drained()
    roll = eng.metrics.rollup()
    assert r.status == "failed:deadline"
    assert 0 < len(r.out) < 20  # partial output returned, not discarded
    assert roll["n_evicted_deadline"] == 1
    assert roll["failed_deadline_n"] == 1
    assert nxt.done  # the evicted slot was reusable immediately

    # eviction is configurable: with it off, the same request just finishes
    # late (and is counted as an SLO miss, not killed)
    eng2 = _tick_engine(cfg, params, batch_slots=1, max_seq=48,
                        deadline_eviction=False)
    r2 = eng2.submit(rng.integers(0, cfg.vocab, size=4), max_new=20, slo_s=4.0)
    eng2.run_until_drained()
    roll2 = eng2.metrics.rollup()
    assert r2.done and len(r2.out) == 20
    assert roll2["n_evicted_deadline"] == 0 and roll2["slo_missed"] == 1


def test_slow_tick_fault_advances_injected_clock_and_blows_deadline():
    """A slow-tick latency spike (injected stall) pushes the deterministic
    clock past a live request's deadline → mid-decode eviction."""
    cfg, params = _setup("stablelm-3b")
    rng = np.random.default_rng(67)
    box = [0.0]  # tick-clock with a skew the sleep hook advances
    holder = []
    metrics = Metrics(clock=lambda: (holder[0].tick if holder else 0) + box[0])
    plan = FaultPlan([FaultSpec("slow", tick=3, delay_s=50.0)])
    eng = Engine(cfg, params, batch_slots=1, max_seq=48, metrics=metrics,
                 faults=plan, sleep=lambda d: box.__setitem__(0, box[0] + d))
    holder.append(eng)
    r = eng.submit(rng.integers(0, cfg.vocab, size=4), max_new=20, slo_s=30.0)
    eng.run_until_drained()
    assert r.status == "failed:deadline" and 0 < len(r.out) < 20
    assert eng.metrics.rollup()["n_evicted_deadline"] == 1
    assert ("slow", 3, 50.0) in plan.fired


# ---------------------------------------------------------------------------
# graceful degradation: kernel → dequant, memoized, still serving
# ---------------------------------------------------------------------------


def test_kernel_failure_degrades_to_dequant_and_serves():
    cfg, params = _setup("stablelm-3b")
    qcfg = cfg.with_quant(enabled=True, bins=16, impl="kernel",
                          min_weight_elems=1024)
    qparams = quantize_params(params, qcfg)
    rng = np.random.default_rng(71)
    p = rng.integers(0, cfg.vocab, size=5)

    ref = Engine(qcfg, qparams, batch_slots=2, max_seq=48)
    want = ref.submit(p, max_new=5)
    ref.run_until_drained()
    assert ref._degraded == set()  # healthy kernels: no degradation

    plan = FaultPlan([FaultSpec("kernel", key="decode")])
    eng = Engine(qcfg, qparams, batch_slots=2, max_seq=48, faults=plan)
    with pytest.warns(RuntimeWarning, match="degrading"):
        r = eng.submit(p, max_new=5)
        eng.run_until_drained()
    assert eng._degraded == {"decode"}  # memoized: flipped exactly once
    assert eng.metrics.rollup()["n_degraded"] == 1
    assert r.done
    # the dequant oracle is the kernels' bit-exactness oracle: degraded
    # serving returns the same tokens
    np.testing.assert_array_equal(np.asarray(r.out), np.asarray(want.out))

    # degraded but SERVING: later traffic flows without re-tripping
    r2 = eng.submit(rng.integers(0, cfg.vocab, size=4), max_new=4)
    eng.run_until_drained()
    assert r2.done and eng.metrics.rollup()["n_degraded"] == 1


def test_degradation_unavailable_reraises():
    """With nothing to degrade to (dense weights), a persistent closure
    failure must surface, not loop."""
    cfg, params = _setup("stablelm-3b")
    plan = FaultPlan([FaultSpec("kernel", key="decode")])
    eng = Engine(cfg, params, batch_slots=1, max_seq=48, faults=plan)
    rng = np.random.default_rng(73)
    eng.submit(rng.integers(0, cfg.vocab, size=4), max_new=4)
    with pytest.raises(RuntimeError, match="injected persistent kernel"):
        eng.run_until_drained()
