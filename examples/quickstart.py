"""Quickstart: the PASM identity end to end in 80 lines.

1. Reproduce the paper's Fig 4 / Fig 6 worked example.
2. Weight-share a real weight matrix (k-means dictionary, Han et al. style).
3. Run the fused Pallas PASM kernel against the weight-shared baseline.
4. Show the HBM weight-byte reduction that motivates PASM on TPU.
5. PasmParams: the one container from conv to transformer — per-layer
   compression ratios and the unified linear() dispatch.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import PasmParams, pas, pasm
from repro.kernels import ops, ref
from repro.nn import layers as L

# -- 1. the paper's worked example (Figures 4 and 6) ------------------------
x = jnp.array([26.7, 3.4, 4.8, 17.7, 6.1])
bin_index = jnp.array([0, 1, 2, 3, 0], dtype=jnp.uint8)
codebook = jnp.array([1.7, 0.4, 1.3, 2.0])  # the shared "pretrained weights"

ws = pas.weight_shared_dot(x, bin_index, codebook)  # Fig 4: deref + MAC
bins = pas.pas_accumulate(x, bin_index, 4)  # Fig 6a: PAS phase (adds only)
out = pas.pas_postpass(bins, codebook)  # Fig 6b: B multiplies

print(f"weight-shared MAC : {ws:.2f}   (paper: 98.8)")
print(f"PAS bins          : {bins}     (paper: [32.8, 3.4, 4.8, 17.7])")
print(f"PASM post-pass    : {out:.2f}   — identical result, 4 multiplies not 5")

# -- 2. weight-share a layer -------------------------------------------------
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (1024, 512))
t = pasm.quantize(w, bins=16)  # 16 shared values → 4-bit indices, packed
print(
    f"\nquantized 1024x512 f32 layer → {t.bins} bins, "
    f"{t.compression_ratio:.1f}x smaller than bf16 in HBM"
)
print(f"  reconstruction |err| = {jnp.abs(w - pasm.dequantize(t)).mean():.4f}")

# -- 3. the fused kernel vs the oracle ---------------------------------------
xb = jax.random.normal(jax.random.PRNGKey(1), (8, 1024), jnp.bfloat16)
y_kernel = ops.pasm_matmul(xb, t)  # Pallas: dequant in VMEM, never in HBM
y_oracle = ref.pasm_matmul_ref(xb, t.idx, t.codebook, packed=t.packed)
print(f"\nfused-kernel max err vs oracle: {jnp.abs(y_kernel - y_oracle).max():.2e}")

# -- 4. why this matters on TPU ----------------------------------------------
dense_bytes = w.size * 2
pasm_bytes = t.nbytes_weights
print(
    f"\ndecode-step weight traffic: {dense_bytes} B (bf16) → {pasm_bytes} B (PASM)"
    f" = {dense_bytes / pasm_bytes:.1f}x less HBM traffic in the bandwidth-bound regime"
)

# -- 5. PasmParams: one container, every layer -------------------------------
# The same tagged quantize/pack container drives conv2d AND every dense
# matmul in the zoo (nn.layers.linear → kernels/ops).  Per-layer report:
D, F = 256, 1024
layers = {
    "attn.wqkv": PasmParams.quantize(
        jax.random.normal(jax.random.PRNGKey(2), (D, 3 * D)), bins=16
    ).pack(),
    "ffn.w1": PasmParams.quantize(
        jax.random.normal(jax.random.PRNGKey(3), (D, F)), bins=16, groups=4
    ),
    "ffn.w2": PasmParams.dense(jax.random.normal(jax.random.PRNGKey(4), (F, D))),
}
print("\nPasmParams per-layer compression (vs bf16):")
for name, p in layers.items():
    print(
        f"  {name:10s} kind={p.kind:6s} bins={p.bins} bits={p.bits} "
        f"groups={p.groups}  {p.compression_ratio:.2f}x"
    )
xt = jax.random.normal(jax.random.PRNGKey(5), (4, D))
y_fused = L.linear(xt, layers["attn.wqkv"], "kernel")  # fused Pallas dequant
y_ref = L.linear(xt, layers["attn.wqkv"], "dequant")  # XLA gather→matmul oracle
print(f"linear(kernel) vs dequant max err: {jnp.abs(y_fused - y_ref).max():.2e}")
