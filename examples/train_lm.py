"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps.

Exercises the full production stack on one host: config system → model zoo →
data pipeline → AdamW → checkpointing → (optional) PASM post-training
quantization of the result, reporting the compression ratio.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--params 100]

~100M params: 12 layers, d_model=768, 12 heads, d_ff=3072, vocab=32k (a
GPT-2-small-class decoder built from the qwen3 family config).
"""
import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.ckpt import checkpoint as ck
from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models import api
from repro.models.common import ShardCtx, param_count, quantize_params, weight_bytes
from repro.train import optimizer as opt
from repro.train import step as step_mod


def lm_100m() -> ArchConfig:
    return dataclasses.replace(
        get_config("qwen3-32b", smoke=True),
        name="lm-100m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        head_dim=64,
        d_ff=3072,
        vocab=32_000,
        remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/pasm_lm100m")
    args = ap.parse_args()

    cfg = lm_100m()
    model = api.get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    print(f"[example] {cfg.name}: {param_count(params)/1e6:.1f}M params")

    state = opt.init_opt_state(params)
    ocfg = opt.AdamWConfig(lr=6e-4, total_steps=args.steps, warmup_steps=20)
    dcfg = DataConfig(seed=0, vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    train_step = jax.jit(
        step_mod.make_train_step(cfg, ocfg, ShardCtx()), donate_argnums=(0, 1)
    )
    mgr = ck.CheckpointManager(args.ckpt_dir, keep=2)

    t0 = time.time()
    for step in range(args.steps):
        params, state, m = train_step(params, state, synthetic_batch(dcfg, step))
        if (step + 1) % 25 == 0 or step == 0:
            print(
                f"[example] step {step+1:4d}  loss {float(m['loss']):.4f}  "
                f"lr {float(m['lr']):.2e}  {(time.time()-t0)/(step+1)*1e3:.0f} ms/step"
            )
        if (step + 1) % 100 == 0:
            mgr.save(step + 1, (params, state))
    mgr.wait()

    # paper pipeline: post-training weight sharing of the trained model
    qcfg = cfg.with_quant(enabled=True, bins=16, impl="dequant")
    qparams = quantize_params(params, qcfg)
    wb = weight_bytes(qparams)
    print(
        f"[example] PASM 16-bin quantization: {wb['dense']/1e6:.1f} MB → "
        f"{wb['stored']/1e6:.1f} MB ({wb['ratio']:.2f}x)"
    )
    loss_q = step_mod.make_eval_step(qcfg)(qparams, synthetic_batch(dcfg, 10_000))
    loss_d = step_mod.make_eval_step(cfg)(params, synthetic_batch(dcfg, 10_000))
    print(
        f"[example] held-out loss dense {float(loss_d['loss']):.4f} vs "
        f"PASM-16 {float(loss_q['loss']):.4f} (Δ {float(loss_q['loss'])-float(loss_d['loss']):+.4f})"
    )


if __name__ == "__main__":
    main()
