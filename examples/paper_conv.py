"""The paper's own experiment: the §4 conv accelerator, all three variants.

Builds the exact configuration evaluated in the paper (5×5 image, 15
channels, 3×3 kernels, M=2, B ∈ {4,8,16}) and reports (a) numerical
equivalence of non-weight-shared / weight-shared / weight-shared-with-PASM,
(b) the calibrated hardware model's area/power/latency deltas next to the
paper's quoted numbers.  Then it scales the same accelerator up the
production path (DESIGN.md §3): a batched image stack through the Pallas
PASM GEMMs, and the full AlexNet-style CNN with per-layer dictionaries.

    PYTHONPATH=src python examples/paper_conv.py
"""
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_cnn_config
from repro.configs.alexnet_conv import PAPER_BINS, PAPER_SPEC
from repro.core import conv as cv
from repro.core import hwmodel as hw
from repro.models import cnn


def main():
    spec = PAPER_SPEC
    key = jax.random.PRNGKey(0)
    img = jax.random.normal(key, (spec.C, spec.IH, spec.IW))
    kern = jax.random.normal(jax.random.PRNGKey(1), (spec.M, spec.C, spec.KY, spec.KX))
    bias = jnp.array([0.1, -0.1])

    print(f"paper accelerator: image {spec.IH}x{spec.IW}x{spec.C}, "
          f"kernel {spec.KY}x{spec.KX}, M={spec.M}, stride={spec.stride}\n")

    for bins in PAPER_BINS:
        cb, idx = cv.quantize_conv_weights(kern, bins)
        y_nws = cv.conv2d_direct(img, kern, bias, spec=spec, relu=True)
        y_ws = cv.conv2d_weight_shared(img, idx, cb, bias, spec=spec, relu=True)
        y_pasm = cv.conv2d_pasm(img, idx, cb, bias, spec=spec, relu=True)
        equiv = float(jnp.abs(y_ws - y_pasm).max())
        qerr = float(jnp.abs(y_nws - y_ws).mean())
        asic = hw.accel_ratio_asic(bins)
        fpga = hw.accel_ratio_fpga(bins)
        lat = hw.conv_latency_ratio(bins)
        print(f"B={bins:3d}: PASM≡weight-shared max|Δ|={equiv:.1e} "
              f"(quant err vs dense {qerr:.3f})")
        print(f"        ASIC: gates x{asic['gates']:.3f}  power x{asic['power']:.3f}  "
              f"latency x{lat:.4f}")
        print(f"        FPGA: DSPs x{fpga['dsp']:.2f} (405→3)  BRAM x{fpga['bram']:.2f}  "
              f"power x{fpga['power']:.3f}\n")

    print("paper headline (B=4, 32-bit): -47.8% gates, -53.2% power, +8.5% latency")
    print("model            (B=4, 32-bit): "
          f"-{(1-hw.accel_ratio_asic(4)['gates'])*100:.1f}% gates, "
          f"-{(1-hw.accel_ratio_asic(4)['power'])*100:.1f}% power, "
          f"+{(hw.conv_latency_ratio(4)-1)*100:.1f}% latency")

    batched_fast_path(spec, kern, bias)
    cnn_stack()


def batched_fast_path(spec, kern, bias):
    """The same accelerator, batched, executing on the Pallas PASM kernels."""
    print("\n— batched fast path (DESIGN.md §3) —")
    imgs = jax.random.normal(jax.random.PRNGKey(2), (4, spec.C, spec.IH, spec.IW))
    cb, idx = cv.quantize_conv_weights(kern, 16)
    y_kernel = cv.conv2d_weight_shared(imgs, idx, cb, bias, spec=spec, relu=True)
    y_pas = cv.conv2d_pasm(imgs, idx, cb, bias, spec=spec, relu=True)
    y_ref = jnp.stack([
        cv.conv2d_weight_shared(imgs[b], idx, cb, bias, spec=spec, relu=True,
                                engine="einsum")
        for b in range(imgs.shape[0])
    ])
    print(f"batch of {imgs.shape[0]}: pasm_matmul out {tuple(y_kernel.shape)}, "
          f"max|Δ| vs einsum port {float(jnp.abs(y_kernel - y_ref).max()):.1e}, "
          f"pas_matmul max|Δ| {float(jnp.abs(y_pas - y_ref).max()):.1e}")


def cnn_stack():
    """Per-layer PASM dictionaries through a full AlexNet-style stack."""
    print("\n— AlexNet-style CNN (per-layer PASM codebooks) —")
    cfg = get_cnn_config("alexnet", smoke=True)
    params = cnn.init_params(cfg, jax.random.PRNGKey(0))
    qparams = cnn.quantize(params, cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, *cfg.in_chw))
    logits = cnn.forward(qparams, imgs, cfg)
    dense = cnn.forward_dense(params, imgs, cfg)
    import numpy as np
    corr = np.corrcoef(np.asarray(logits).ravel(), np.asarray(dense).ravel())[0, 1]
    print(f"{cfg.name}: {len(cfg.layers)} conv layers (B={cfg.bins} bins each) "
          f"→ logits {tuple(logits.shape)}; corr(dense)={corr:.3f}")
    einsum_cfg = dataclasses.replace(cfg, impl="einsum")
    delta = float(jnp.abs(logits - cnn.forward(qparams, imgs, einsum_cfg)).max())
    print(f"kernel vs einsum engines: max|Δ|={delta:.1e}")
    full = get_cnn_config("alexnet")
    print(f"full config '{full.name}': input {full.in_chw}, "
          f"{len(full.layers)} conv layers → features {cnn.feature_shape(full)} "
          f"→ {full.classes} classes")


if __name__ == "__main__":
    main()
