"""The paper's own experiment: the §4 conv accelerator, all three variants.

Builds the exact configuration evaluated in the paper (5×5 image, 15
channels, 3×3 kernels, M=2, B ∈ {4,8,16}) on the unified
``ConvParams``/``conv2d`` surface and reports (a) numerical equivalence of
non-weight-shared / weight-shared / weight-shared-with-PASM, (b) the
calibrated hardware model's area/power/latency deltas next to the paper's
quoted numbers.  Then it scales the same accelerator up the production path
(DESIGN.md §3): a batched image stack through the Pallas PASM GEMMs with the
fused bias/ReLU epilogue, torchvision-exact SAME geometry on the TPU-native
NHWC layout, and the full AlexNet-style CNN with per-layer dictionaries.

    PYTHONPATH=src python examples/paper_conv.py
"""
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_cnn_config
from repro.configs.alexnet_conv import PAPER_BINS, PAPER_SPEC
from repro.core import conv as cv
from repro.core import hwmodel as hw
from repro.models import cnn

# the §4 accelerator as a geometry-free spec: geometry rides with the images
PAPER_CONV = cv.Conv2D(
    k=(PAPER_SPEC.KY, PAPER_SPEC.KX),
    c_in=PAPER_SPEC.C,
    c_out=PAPER_SPEC.M,
    stride=PAPER_SPEC.stride,
)


def main():
    key = jax.random.PRNGKey(0)
    img = jax.random.normal(key, (PAPER_SPEC.C, PAPER_SPEC.IH, PAPER_SPEC.IW))
    kern = jax.random.normal(
        jax.random.PRNGKey(1), (PAPER_SPEC.M, PAPER_SPEC.C, PAPER_SPEC.KY, PAPER_SPEC.KX)
    )
    bias = jnp.array([0.1, -0.1])
    conv = dataclasses.replace(PAPER_CONV, relu=True)

    print(f"paper accelerator: image {PAPER_SPEC.IH}x{PAPER_SPEC.IW}x{PAPER_SPEC.C}, "
          f"kernel {PAPER_SPEC.KY}x{PAPER_SPEC.KX}, M={PAPER_SPEC.M}, "
          f"stride={PAPER_SPEC.stride}\n")

    for bins in PAPER_BINS:
        dense = cv.ConvParams.dense(kern, bias=bias)
        shared = cv.ConvParams.quantize(kern, bins, bias=bias)
        y_nws = cv.conv2d(img, dense, conv)
        y_ws = cv.conv2d(img, shared, conv)  # auto → einsum reference
        y_pasm = cv.conv2d(img, shared, conv, engine="pas_einsum")
        equiv = float(jnp.abs(y_ws - y_pasm).max())
        qerr = float(jnp.abs(y_nws - y_ws).mean())
        asic = hw.accel_ratio_asic(bins)
        fpga = hw.accel_ratio_fpga(bins)
        lat = hw.conv_latency_ratio(bins)
        print(f"B={bins:3d}: PASM≡weight-shared max|Δ|={equiv:.1e} "
              f"(quant err vs dense {qerr:.3f})")
        print(f"        ASIC: gates x{asic['gates']:.3f}  power x{asic['power']:.3f}  "
              f"latency x{lat:.4f}")
        print(f"        FPGA: DSPs x{fpga['dsp']:.2f} (405→3)  BRAM x{fpga['bram']:.2f}  "
              f"power x{fpga['power']:.3f}\n")

    print("paper headline (B=4, 32-bit): -47.8% gates, -53.2% power, +8.5% latency")
    print("model            (B=4, 32-bit): "
          f"-{(1-hw.accel_ratio_asic(4)['gates'])*100:.1f}% gates, "
          f"-{(1-hw.accel_ratio_asic(4)['power'])*100:.1f}% power, "
          f"+{(hw.conv_latency_ratio(4)-1)*100:.1f}% latency")

    batched_fast_path(kern, bias)
    same_nhwc_geometry()
    cnn_stack()


def batched_fast_path(kern, bias):
    """The same accelerator, batched: one fused pallas_call per conv layer."""
    print("\n— batched fast path (DESIGN.md §3, fused epilogue) —")
    imgs = jax.random.normal(
        jax.random.PRNGKey(2), (4, PAPER_SPEC.C, PAPER_SPEC.IH, PAPER_SPEC.IW)
    )
    conv = dataclasses.replace(PAPER_CONV, relu=True)
    shared = cv.ConvParams.quantize(kern, 16, bias=bias)
    y_kernel = cv.conv2d(imgs, shared, conv)  # auto → pasm_matmul, bias+ReLU fused
    y_pas = cv.conv2d(imgs, shared, conv, engine="pas_kernel")
    y_ref = cv.conv2d(imgs, shared, conv, engine="einsum")
    print(f"batch of {imgs.shape[0]}: pasm_matmul out {tuple(y_kernel.shape)}, "
          f"max|Δ| vs einsum port {float(jnp.abs(y_kernel - y_ref).max()):.1e}, "
          f"pas_matmul max|Δ| {float(jnp.abs(y_pas - y_ref).max()):.1e}")


def same_nhwc_geometry():
    """torchvision AlexNet layer 1 (3×224×224, k=11, s=4) under SAME + NHWC."""
    print("\n— SAME padding + NHWC (torchvision-exact geometry) —")
    conv = cv.Conv2D(k=11, c_in=3, c_out=96, stride=4, padding="same",
                     layout="NHWC", relu=True)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 224, 224, 3))
    kern = jax.random.normal(jax.random.PRNGKey(4), (96, 3, 11, 11)) * 0.05
    shared = cv.ConvParams.quantize(kern, 16, bias=jnp.zeros((96,)))
    packed = shared.pack(layout="NHWC")  # §3 K-pad: K=363 → 364, then int4
    y = cv.conv2d(x, shared, conv)
    y_packed = cv.conv2d(x, packed, conv)
    kern_q = shared.codebook[shared.idx.astype(jnp.int32)]  # dictionary deref
    ref = jax.lax.conv_general_dilated(
        x, kern_q.transpose(2, 3, 1, 0), (4, 4), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    ref = jnp.maximum(ref, 0)
    print(f"conv1 out {tuple(y.shape)} (expected (2, 56, 56, 96)); "
          f"max|Δ| vs lax oracle {float(jnp.abs(y - ref).max()):.1e}; "
          f"int4-packed max|Δ| {float(jnp.abs(y_packed - y).max()):.1e} "
          f"({packed.idx.nbytes} idx bytes vs {shared.idx.nbytes} unpacked)")


def cnn_stack():
    """Per-layer PASM dictionaries through a full AlexNet-style stack."""
    print("\n— AlexNet-style CNN (per-layer PASM codebooks) —")
    cfg = get_cnn_config("alexnet", smoke=True)
    params = cnn.init_params(cfg, jax.random.PRNGKey(0))
    qparams = cnn.quantize(params, cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, *cfg.in_chw))
    logits = cnn.forward(qparams, imgs, cfg)
    dense = cnn.forward_dense(params, imgs, cfg)
    import numpy as np
    corr = np.corrcoef(np.asarray(logits).ravel(), np.asarray(dense).ravel())[0, 1]
    print(f"{cfg.name}: {len(cfg.layers)} conv layers (B={cfg.bins} bins each) "
          f"→ logits {tuple(logits.shape)}; corr(dense)={corr:.3f}")
    einsum_cfg = dataclasses.replace(cfg, impl="einsum")
    delta = float(jnp.abs(logits - cnn.forward(qparams, imgs, einsum_cfg)).max())
    print(f"kernel vs einsum engines: max|Δ|={delta:.1e}")
    full = get_cnn_config("alexnet")
    print(f"full config '{full.name}': input {full.in_chw}, "
          f"{len(full.layers)} conv layers → features {cnn.feature_shape(full)} "
          f"→ {full.classes} classes")


if __name__ == "__main__":
    main()
