"""Serve a weight-shared model under continuous batching (the paper's use case).

Trains nothing: initializes a small qwen3-family model, applies the paper's
k-means weight sharing, and serves mixed traffic — LM requests through the
continuous-batching engine (per-slot KV positions: a free slot prefills the
moment a request arrives, other slots keep decoding) plus CNN image
classifications through the shape-bucketed batcher — then prints the
p50/p99 rollup and verifies PASM serving matches dense serving
token-for-token (§5.3: "the results ... are identical").

    PYTHONPATH=src python examples/serve_pasm.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_cnn_config, get_config
from repro.models import api, cnn
from repro.models.common import quantize_params, weight_bytes
from repro.serve.batcher import CnnBatcher, MixedBatcher
from repro.serve.engine import Engine
from repro.serve.metrics import Metrics


def main():
    cfg = get_config("stablelm-3b", smoke=True)
    model = api.get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))

    # paper pipeline: quantize the trained weights into a 256-entry dictionary
    # (large B → near-lossless; B=16 trades accuracy for 4x compression)
    qcfg = cfg.with_quant(enabled=True, bins=256, impl="dequant", min_weight_elems=1024)
    qparams = quantize_params(params, qcfg)
    wb = weight_bytes(qparams)
    print(f"[serve] weight bytes: {wb['dense']} dense → {wb['stored']} stored ({wb['ratio']:.2f}x)")

    ccfg = get_cnn_config("alexnet", smoke=True)
    cparams = cnn.quantize(cnn.init_params(ccfg, jax.random.PRNGKey(1)), ccfg)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(4, 10))) for _ in range(6)]
    images = [rng.standard_normal((3, int(rng.integers(8, 33)), int(rng.integers(8, 33))))
              .astype(np.float32) for _ in range(4)]

    results = {}
    for tag, c, p in (("dense", cfg, params), ("pasm", qcfg, qparams)):
        metrics = Metrics()
        eng = Engine(c, p, batch_slots=3, max_seq=64, metrics=metrics)
        cnn_b = CnnBatcher(ccfg, cparams, max_batch=3, metrics=metrics)
        reqs = [eng.submit(pr, max_new=8) for pr in prompts]
        # stagger the images in: the engine keeps decoding while they classify
        mix = MixedBatcher(eng, cnn_b)
        imgs = []
        for im in images:
            imgs.append(cnn_b.submit(im))
            mix.tick()
        ticks = mix.run_until_drained()
        roll = metrics.rollup()
        print(f"[serve] {tag}: {roll['lm_n']} LM + {roll['cnn_n']} CNN requests, "
              f"p50 latency {roll['lm_p50_latency_s']:.2f}s, "
              f"{roll['tok_s']:.1f} tok/s, {roll['img_s']:.1f} img/s, "
              f"occupancy {roll['mean_occupancy']:.2f}")
        assert all(r.done for r in reqs) and all(r.done for r in imgs)
        results[tag] = [tuple(r.out) for r in reqs]

    agree = sum(a == b for a, b in zip(results["dense"], results["pasm"]))
    print(f"[serve] greedy outputs identical on {agree}/{len(prompts)} requests "
          f"(256-bin dictionary ≈ lossless per step; greedy decode compounds "
          f"any single-token divergence)")


if __name__ == "__main__":
    main()
