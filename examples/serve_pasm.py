"""Serve a weight-shared model with batched requests (the paper's use case).

Trains nothing: initializes a small qwen3-family model, applies the paper's
k-means weight sharing, and serves a batch of requests through the
continuous-batching engine — verifying PASM serving matches dense serving
token-for-token (§5.3: "the results ... are identical").

    PYTHONPATH=src python examples/serve_pasm.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.models.common import quantize_params, weight_bytes
from repro.serve.engine import Engine


def main():
    cfg = get_config("stablelm-3b", smoke=True)
    model = api.get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))

    # paper pipeline: quantize the trained weights into a 256-entry dictionary
    # (large B → near-lossless; B=16 trades accuracy for 4x compression)
    qcfg = cfg.with_quant(enabled=True, bins=256, impl="dequant", min_weight_elems=1024)
    qparams = quantize_params(params, qcfg)
    wb = weight_bytes(qparams)
    print(f"[serve] weight bytes: {wb['dense']} dense → {wb['stored']} stored ({wb['ratio']:.2f}x)")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(4, 10)) for _ in range(6)]

    results = {}
    for tag, c, p in (("dense", cfg, params), ("pasm", qcfg, qparams)):
        eng = Engine(c, p, batch_slots=3, max_seq=64)
        reqs = [eng.submit(pr, max_new=8) for pr in prompts]
        t0 = time.time()
        ticks = eng.run_until_drained()
        print(f"[serve] {tag}: {len(reqs)} reqs in {ticks} ticks ({time.time()-t0:.2f}s)")
        results[tag] = [tuple(r.out) for r in reqs]

    agree = sum(a == b for a, b in zip(results["dense"], results["pasm"]))
    print(f"[serve] greedy outputs identical on {agree}/{len(prompts)} requests "
          f"(256-bin dictionary ≈ lossless)")


if __name__ == "__main__":
    main()
