"""Data pipeline: deterministic synthetic LM stream + file-backed token shards.

Deterministic-by-step: batch ``i`` is a pure function of (seed, step, shard),
so restarts resume mid-epoch without replay logs, and elastic re-sharding
(N → M hosts) re-partitions the same global stream (fault tolerance,
DESIGN.md §4).  The synthetic stream is a Zipf-ish token model with enough
sequential structure that a ~100M model's loss visibly falls within a few
hundred steps (examples/train_lm.py); :func:`synthetic_image_batch` is the
same contract for the CNN QAT loop (images + labels keyed to the step).

Input validation is typed (:class:`DataValidationError`): an indivisible
``global_batch % n_shards`` or an empty/truncated token file fails loudly at
construction, not as a silent shape surprise mid-run; transient ``OSError``
during a file-backed batch read retries with capped exponential backoff
(:func:`retry_io`) before surfacing.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from pathlib import Path
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec

__all__ = [
    "DataConfig",
    "DataValidationError",
    "retry_io",
    "synthetic_batch",
    "synthetic_image_batch",
    "batch_iterator",
    "TokenFileDataset",
    "write_token_file",
]


class DataValidationError(ValueError):
    """Typed rejection of an invalid data configuration or source: an
    indivisible shard split, or an empty/truncated token file."""


def retry_io(
    fn: Callable,
    *,
    retries: int = 3,
    backoff_s: float = 0.05,
    cap_s: float = 1.0,
    sleep: Callable[[float], None] = time.sleep,
):
    """Run ``fn()`` retrying transient ``OSError`` s with capped exponential
    backoff (``backoff_s · 2^(attempt-1)``, capped at ``cap_s``).  The final
    attempt's exception surfaces unwrapped.  ``sleep`` is injectable so
    tests (and the chaos suite) pin the schedule with zero wall clock."""
    for attempt in range(retries + 1):
        try:
            return fn()
        except OSError as e:
            if attempt >= retries:
                raise
            delay = min(backoff_s * (2 ** attempt), cap_s)
            warnings.warn(
                f"transient I/O error (attempt {attempt + 1}/{retries + 1}), "
                f"retrying in {delay:.3g}s: {e}",
                RuntimeWarning,
                stacklevel=2,
            )
            sleep(delay)


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 32_000
    seq_len: int = 1024
    global_batch: int = 8
    shard_index: int = 0
    n_shards: int = 1
    path: Optional[str] = None  # file-backed when set

    def __post_init__(self):
        if self.n_shards < 1 or self.global_batch < 1:
            raise DataValidationError(
                f"need n_shards >= 1 and global_batch >= 1, got "
                f"n_shards={self.n_shards} global_batch={self.global_batch}"
            )
        if self.global_batch % self.n_shards:
            raise DataValidationError(
                f"global_batch={self.global_batch} must divide evenly over "
                f"n_shards={self.n_shards} (per-shard batch would be ragged)"
            )
        if not (0 <= self.shard_index < self.n_shards):
            raise DataValidationError(
                f"shard_index={self.shard_index} out of range for "
                f"n_shards={self.n_shards}"
            )


def _markov_tokens(key, batch, seq_len, vocab):
    """Zipf marginal + short-range structure: t ~ f(t-1) with noise."""
    k1, k2, k3 = jax.random.split(key, 3)
    # Zipf-ish sampling via exponential transform of uniforms
    u = jax.random.uniform(k1, (batch, seq_len), minval=1e-6)
    zipf = jnp.clip((u ** -0.9 - 1.0).astype(jnp.int32), 0, vocab - 1)
    # sequential structure: with p=0.5 the next token is a fixed affine map
    # of the previous one — a learnable bigram signal
    follow = jax.random.bernoulli(k2, 0.5, (batch, seq_len))
    prev = jnp.roll(zipf, 1, axis=1)
    mapped = (prev * 31 + 7) % vocab
    return jnp.where(follow, mapped, zipf).astype(jnp.int32)


def _step_key(cfg: DataConfig, step: int):
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), cfg.shard_index
    )


def synthetic_batch(cfg: DataConfig, step: int) -> dict:
    """Pure function of (seed, step, shard) → {tokens, labels}."""
    per_shard = cfg.global_batch // cfg.n_shards
    toks = _markov_tokens(_step_key(cfg, step), per_shard, cfg.seq_len + 1, cfg.vocab)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def synthetic_image_batch(
    cfg: DataConfig, step: int, *, chw: tuple, classes: int, noise: float = 0.25
) -> dict:
    """Step-addressed image classification batch for the CNN QAT loop:
    pure function of (seed, step, shard) → {images (B, C, H, W) f32,
    labels (B,) int32}.  Labels carry a learnable planted signal — the
    class whose fixed random template correlates best with the image —
    flipped to a uniform class with probability ``noise``, so the QAT loss
    trajectory falls, not just wiggles."""
    per_shard = cfg.global_batch // cfg.n_shards
    k1, k2 = jax.random.split(_step_key(cfg, step))
    images = jax.random.normal(k1, (per_shard,) + tuple(chw), jnp.float32)
    # class = mixture of a planted linear signal and label noise
    c, h, w = chw
    probe = jax.random.normal(jax.random.PRNGKey(cfg.seed + 1), (classes, c, h, w))
    scores = jnp.einsum("bchw,kchw->bk", images, probe)
    planted = jnp.argmax(scores, axis=-1)
    rand = jax.random.randint(k2, (per_shard,), 0, classes)
    take_noise = jax.random.bernoulli(k2, noise, (per_shard,))
    labels = jnp.where(take_noise, rand, planted).astype(jnp.int32)
    return {"images": images, "labels": labels}


class TokenFileDataset:
    """Flat binary uint32 token file, memory-mapped, sharded by host.

    Construction validates the source (typed :class:`DataValidationError`
    on an empty/truncated file — fewer tokens than one ``seq_len + 1``
    sequence); :meth:`batch` retries transient ``OSError`` s (a flaky NFS
    mount, an injected ``data_io`` fault) with capped backoff before
    surfacing them."""

    def __init__(
        self,
        cfg: DataConfig,
        *,
        retries: int = 3,
        backoff_s: float = 0.05,
        cap_s: float = 1.0,
        sleep: Callable[[float], None] = time.sleep,
        fault_hook: Optional[Callable[[int], None]] = None,
    ):
        if not cfg.path:
            raise DataValidationError("TokenFileDataset needs cfg.path")
        self.cfg = cfg
        self.retries = retries
        self.backoff_s = backoff_s
        self.cap_s = cap_s
        self.sleep = sleep
        self.fault_hook = fault_hook  # chaos: train.faults plan.on_data
        self.tokens = np.memmap(cfg.path, dtype=np.uint32, mode="r")
        self.n_seqs = len(self.tokens) // (cfg.seq_len + 1)
        if self.n_seqs == 0:
            raise DataValidationError(
                f"empty/truncated token file {cfg.path}: {len(self.tokens)} "
                f"tokens < one sequence of seq_len+1={cfg.seq_len + 1}"
            )

    def _read_rows(self, step: int) -> np.ndarray:
        """One attempt at the step's row gather (the retried I/O unit)."""
        if self.fault_hook is not None:
            self.fault_hook(step)
        cfg = self.cfg
        per_shard = cfg.global_batch // cfg.n_shards
        rng = np.random.default_rng((cfg.seed, step, cfg.shard_index))
        idx = rng.integers(0, self.n_seqs, size=per_shard)
        return np.stack(
            [self.tokens[i * (cfg.seq_len + 1) : (i + 1) * (cfg.seq_len + 1)] for i in idx]
        ).astype(np.int32)

    def batch(self, step: int) -> dict:
        rows = retry_io(
            lambda: self._read_rows(step),
            retries=self.retries,
            backoff_s=self.backoff_s,
            cap_s=self.cap_s,
            sleep=self.sleep,
        )
        return {"tokens": jnp.asarray(rows[:, :-1]), "labels": jnp.asarray(rows[:, 1:])}


def write_token_file(path: str, tokens: np.ndarray) -> None:
    Path(path).parent.mkdir(parents=True, exist_ok=True)
    tokens.astype(np.uint32).tofile(path)


def batch_iterator(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    ds = TokenFileDataset(cfg) if cfg.path else None
    step = start_step
    while True:
        yield ds.batch(step) if ds else synthetic_batch(cfg, step)
        step += 1
