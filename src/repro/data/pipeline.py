"""Data pipeline: deterministic synthetic LM stream + file-backed token shards.

Deterministic-by-step: batch ``i`` is a pure function of (seed, step, shard),
so restarts resume mid-epoch without replay logs, and elastic re-sharding
(N → M hosts) re-partitions the same global stream (fault tolerance,
DESIGN.md §4).  The synthetic stream is a Zipf-ish token model with enough
sequential structure that a ~100M model's loss visibly falls within a few
hundred steps (examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec

__all__ = ["DataConfig", "synthetic_batch", "batch_iterator", "TokenFileDataset", "write_token_file"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 32_000
    seq_len: int = 1024
    global_batch: int = 8
    shard_index: int = 0
    n_shards: int = 1
    path: Optional[str] = None  # file-backed when set


def _markov_tokens(key, batch, seq_len, vocab):
    """Zipf marginal + short-range structure: t ~ f(t-1) with noise."""
    k1, k2, k3 = jax.random.split(key, 3)
    # Zipf-ish sampling via exponential transform of uniforms
    u = jax.random.uniform(k1, (batch, seq_len), minval=1e-6)
    zipf = jnp.clip((u ** -0.9 - 1.0).astype(jnp.int32), 0, vocab - 1)
    # sequential structure: with p=0.5 the next token is a fixed affine map
    # of the previous one — a learnable bigram signal
    follow = jax.random.bernoulli(k2, 0.5, (batch, seq_len))
    prev = jnp.roll(zipf, 1, axis=1)
    mapped = (prev * 31 + 7) % vocab
    return jnp.where(follow, mapped, zipf).astype(jnp.int32)


def synthetic_batch(cfg: DataConfig, step: int) -> dict:
    """Pure function of (seed, step, shard) → {tokens, labels}."""
    per_shard = cfg.global_batch // cfg.n_shards
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), cfg.shard_index
    )
    toks = _markov_tokens(key, per_shard, cfg.seq_len + 1, cfg.vocab)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class TokenFileDataset:
    """Flat binary uint32 token file, memory-mapped, sharded by host."""

    def __init__(self, cfg: DataConfig):
        assert cfg.path, "TokenFileDataset needs cfg.path"
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.uint32, mode="r")
        self.n_seqs = len(self.tokens) // (cfg.seq_len + 1)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        per_shard = cfg.global_batch // cfg.n_shards
        rng = np.random.default_rng((cfg.seed, step, cfg.shard_index))
        idx = rng.integers(0, self.n_seqs, size=per_shard)
        rows = np.stack(
            [self.tokens[i * (cfg.seq_len + 1) : (i + 1) * (cfg.seq_len + 1)] for i in idx]
        ).astype(np.int32)
        return {"tokens": jnp.asarray(rows[:, :-1]), "labels": jnp.asarray(rows[:, 1:])}


def write_token_file(path: str, tokens: np.ndarray) -> None:
    Path(path).parent.mkdir(parents=True, exist_ok=True)
    tokens.astype(np.uint32).tofile(path)


def batch_iterator(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    ds = TokenFileDataset(cfg) if cfg.path else None
    step = start_step
    while True:
        yield ds.batch(step) if ds else synthetic_batch(cfg, step)
        step += 1
