"""Production mesh definitions (TPU v5e pods; host-device placeholders in CI).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax

__all__ = [
    "make_production_mesh",
    "make_conv_mesh",
    "axis_sizes",
    "data_model_sizes",
    "n_shard_axis",
    "SINGLE_POD",
    "MULTI_POD",
]

SINGLE_POD = (16, 16)  # 256 chips
MULTI_POD = (2, 16, 16)  # 2 pods × 256 chips


def make_production_mesh(*, multi_pod: bool = False):
    import numpy as np

    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    return jax.make_mesh(
        shape,
        axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        devices=jax.devices()[:n],
    )


def make_conv_mesh(shape=None):
    """The ``("data", "model")`` mesh the sharded conv stack runs on.

    ``shape=(n_data, n_model)`` must fit the visible devices; ``None`` puts
    every device on ``data`` (pure batch sharding).  The production AlexNet
    config pins :data:`SINGLE_POD` here (``CNNConfig.mesh_shape``); CI and
    the ``--devices N`` bench mode use host-platform fake devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    import numpy as np

    if shape is None:
        shape = (len(jax.devices()), 1)
    shape = tuple(int(s) for s in shape)
    n = int(np.prod(shape))
    if n > len(jax.devices()):
        raise ValueError(
            f"mesh shape {shape} needs {n} devices but only "
            f"{len(jax.devices())} are visible"
        )
    # no axis_types: explicit-sharding AxisType postdates this jax; the conv
    # dispatch only uses the mesh through shard_map, which doesn't need it
    return jax.make_mesh(shape, ("data", "model"), devices=jax.devices()[:n])


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_model_sizes(mesh) -> tuple:
    """``(n_data, n_model)`` of a conv/GEMM mesh; absent ``model`` counts 1.

    The one definition every sharded-dispatch layer derives its axis sizes
    from (kernels/ops.py, core/conv.py, models/cnn.py)."""
    sizes = axis_sizes(mesh)
    if "data" not in sizes:
        raise ValueError(
            f"mesh needs a 'data' axis (got axes {mesh.axis_names}); build "
            "one with repro.launch.mesh.make_conv_mesh"
        )
    return int(sizes["data"]), int(sizes.get("model", 1))


def n_shard_axis(mesh, n: int):
    """The GEMM N-dimension's mesh axis: ``"model"`` when it divides, else
    ``None`` (replicate).

    THE divisibility rule of the sharded conv dispatch (DESIGN.md §4.1) —
    `models/sharding.py::conv_param_pspecs` applies the same test, so weight
    placement and compute can never disagree."""
    _, nm = data_model_sizes(mesh)
    return "model" if nm > 1 and n % nm == 0 else None
