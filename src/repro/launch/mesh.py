"""Production mesh definitions (TPU v5e pods; host-device placeholders in CI).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "axis_sizes", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = (16, 16)  # 256 chips
MULTI_POD = (2, 16, 16)  # 2 pods × 256 chips


def make_production_mesh(*, multi_pod: bool = False):
    import numpy as np

    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    return jax.make_mesh(
        shape,
        axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        devices=jax.devices()[:n],
    )


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
