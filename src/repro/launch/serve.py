"""Serving launcher: continuous-batching engine + mixed CNN traffic.

Brings up the PASM-quantized :class:`~repro.serve.engine.Engine` (per-slot
KV positions, FCFS admission over length buckets), optionally a
:class:`~repro.serve.batcher.CnnBatcher` for concurrent image traffic, runs
the requested load through the :class:`~repro.serve.batcher.MixedBatcher`
loop, and prints the serve/metrics.py rollup (p50/p99 latency + TTFT per
class, tok/s, img/s, slot occupancy) plus the failure-mode rollup
(rejected/shed/evicted/quarantined/retried/degraded counters and
per-failure-kind latency — DESIGN.md §2.4) whenever anything failed.

Backpressure is configurable (``--max-queue``/``--policy``), retries via
``--max-retries``, and ``--faults-seed`` replays the load under a seeded
:class:`~repro.serve.faults.FaultPlan` for chaos drills.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \\
        --quant pasm --requests 8 --images 4
"""
from __future__ import annotations

import argparse
import math

import jax
import numpy as np

from repro.configs import get_cnn_config, get_config
from repro.models import api, cnn
from repro.models.common import quantize_params, weight_bytes
from repro.serve.batcher import CnnBatcher, MixedBatcher
from repro.serve.engine import Engine
from repro.serve.faults import FaultPlan
from repro.serve.metrics import FAILURE_COUNTERS, Metrics


def _fmt(v, unit=""):
    if isinstance(v, float):
        return "n/a" if math.isnan(v) else f"{v:.4g}{unit}"
    return f"{v}{unit}"


def print_rollup(roll: dict, slots: int) -> None:
    print(f"[serve] requests: {roll['n_done']}/{roll['n_requests']} done, "
          f"{roll['n_stuck']} stuck; mean occupancy "
          f"{_fmt(roll['mean_occupancy'])} over {slots} slots")
    for kind, rate in (("lm", "tok_s"), ("cnn", "img_s")):
        if not roll[f"{kind}_n"]:
            continue
        print(f"[serve]   {kind}: n={roll[f'{kind}_n']}  "
              f"latency p50={_fmt(roll[f'{kind}_p50_latency_s'], 's')} "
              f"p99={_fmt(roll[f'{kind}_p99_latency_s'], 's')}  "
              f"ttft p50={_fmt(roll[f'{kind}_p50_ttft_s'], 's')} "
              f"p99={_fmt(roll[f'{kind}_p99_ttft_s'], 's')}  "
              f"{rate}={_fmt(roll[rate])}")
    if roll["slo_met"] or roll["slo_missed"]:
        print(f"[serve]   SLO: {roll['slo_met']} met, {roll['slo_missed']} missed")
    # failure-mode rollup (DESIGN.md §2.4) — only when something tripped
    tripped = {k: roll[k] for k in FAILURE_COUNTERS if roll.get(k)}
    if tripped or roll.get("n_failed"):
        counts = " ".join(f"{k[2:]}={v}" for k, v in tripped.items())
        print(f"[serve]   failures: n_failed={roll.get('n_failed', 0)}  {counts}")
        for kind in ("deadline", "numeric", "error", "rejected"):
            n = roll.get(f"failed_{kind}_n", 0)
            if n:
                print(f"[serve]     {kind}: n={n}  latency "
                      f"p50={_fmt(roll[f'failed_{kind}_p50_latency_s'], 's')} "
                      f"p99={_fmt(roll[f'failed_{kind}_p99_latency_s'], 's')}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="pasm", choices=["dense", "pasm"])
    ap.add_argument("--bins", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8, help="LM requests")
    ap.add_argument("--images", type=int, default=0, help="CNN classify requests")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="per-request latency budget (SLO accounting)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded queue depth (backpressure)")
    ap.add_argument("--policy", default="reject",
                    help="bounded-queue admission policy: reject | "
                         "shed_oldest | shed_expired")
    ap.add_argument("--max-retries", type=int, default=1)
    ap.add_argument("--faults-seed", type=int, default=None,
                    help="chaos drill: inject a FaultPlan sampled from this seed")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = api.get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.quant == "pasm":
        cfg = cfg.with_quant(enabled=True, bins=args.bins, impl="dequant")
        params = quantize_params(params, cfg)
        wb = weight_bytes(params)
        print(
            f"[serve] PASM weights: {wb['dense']/1e6:.1f} MB dense → "
            f"{wb['stored']/1e6:.1f} MB stored ({wb['ratio']:.1f}× compression)"
        )

    metrics = Metrics()
    slo_s = args.slo_ms / 1e3 if args.slo_ms else None
    faults = None
    if args.faults_seed is not None:
        faults = FaultPlan.sample(
            args.faults_seed, n_ticks=max(8, args.max_new + 2),
            n_slots=args.slots, n_requests=args.requests,
        )
        print(f"[serve] chaos drill: {len(faults.faults)} faults sampled "
              f"from seed {args.faults_seed}")
    eng = Engine(cfg, params, batch_slots=args.slots, max_seq=args.max_seq,
                 metrics=metrics, faults=faults, max_retries=args.max_retries,
                 max_queue=args.max_queue, policy=args.policy)
    rng = np.random.default_rng(args.seed)
    reqs = [
        eng.submit(rng.integers(0, cfg.vocab, size=int(rng.integers(4, 12))),
                   args.max_new, slo_s=slo_s)
        for _ in range(args.requests)
    ]

    cnn_b = None
    if args.images:
        ccfg = get_cnn_config("alexnet", smoke=args.smoke)
        cparams = cnn.quantize(cnn.init_params(ccfg, jax.random.PRNGKey(args.seed)), ccfg)
        cnn_b = CnnBatcher(ccfg, cparams, max_batch=args.slots, metrics=metrics)
        C, H, W = ccfg.in_chw
        for _ in range(args.images):
            h = int(rng.integers(8, H + 1))
            w = int(rng.integers(8, W + 1))
            cnn_b.submit(rng.standard_normal((C, h, w)).astype(np.float32), slo_s=slo_s)

    ticks = MixedBatcher(eng, cnn_b).run_until_drained()
    print(f"[serve] drained in {ticks} ticks")
    print_rollup(metrics.rollup(), args.slots)
    for r in reqs[:3]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] → {r.out[:8]}...")
    return 0


if __name__ == "__main__":
    main()
