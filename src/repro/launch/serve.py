"""Serving launcher: bring up an Engine with PASM-quantized weights.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \\
        --quant pasm --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.models.common import ShardCtx, quantize_params, weight_bytes
from repro.serve.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="pasm", choices=["dense", "pasm"])
    ap.add_argument("--bins", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = api.get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.quant == "pasm":
        cfg = cfg.with_quant(enabled=True, bins=args.bins, impl="dequant")
        params = quantize_params(params, cfg)
        wb = weight_bytes(params)
        print(
            f"[serve] PASM weights: {wb['dense']/1e6:.1f} MB dense → "
            f"{wb['stored']/1e6:.1f} MB stored ({wb['ratio']:.1f}× compression)"
        )

    eng = Engine(cfg, params, batch_slots=args.slots, max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    reqs = [
        eng.submit(rng.integers(0, cfg.vocab, size=rng.integers(4, 12)), args.max_new)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    ticks = eng.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in reqs)
    print(
        f"[serve] {len(reqs)} requests, {total_tokens} tokens in {ticks} ticks, "
        f"{dt:.2f}s ({total_tokens/dt:.1f} tok/s)"
    )
    for r in reqs[:3]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] → {r.out[:8]}...")
    return 0


if __name__ == "__main__":
    main()
