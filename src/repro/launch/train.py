"""Training launcher: end-to-end driver with checkpoint/restart + supervision.

Single-host example (the same SPMD program runs per-host on a fleet):

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --smoke \\
        --steps 200 --ckpt-dir /tmp/ckpt --resume auto

Fault tolerance: the loop runs under ``ft.Supervisor`` — any failure restores
the newest complete checkpoint and continues; the data pipeline is
step-addressed so no batches are replayed or skipped (DESIGN.md §4).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp

from repro import ft
from repro.ckpt import checkpoint as ckpt
from repro.configs import get_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models import api
from repro.models.common import ShardCtx, quantize_params
from repro.train import optimizer as opt
from repro.train import step as step_mod


def build_state(cfg, key, quant: str):
    model = api.get_model(cfg)
    params = model.init_params(cfg, key)
    if quant == "pasm" or quant == "qat":
        qcfg = cfg.with_quant(enabled=True, impl="kernel" if quant == "pasm" else "dequant")
        params = quantize_params(params, qcfg)
        cfg = qcfg
    return cfg, params


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quant", default="dense", choices=["dense", "pasm", "qat"])
    ap.add_argument("--compress-grads", type=int, default=0, help="bins; 0=off")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="no", choices=["no", "auto"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    ocfg = opt.AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(args.steps // 20, 5))
    dcfg = DataConfig(
        seed=args.seed, vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch
    )
    mgr = ckpt.CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    detector = ft.StragglerDetector(n_hosts=jax.process_count())

    def loop(resume_step: Optional[int]) -> int:
        cfg_t, params = build_state(cfg, jax.random.PRNGKey(args.seed), args.quant)
        opt_state = opt.init_opt_state(params)
        start = 0
        if mgr and args.resume == "auto" and ckpt.latest_step(mgr.dir) is not None:
            (params, opt_state), manifest = mgr.restore_latest((params, opt_state))
            start = manifest["step"]
            print(f"[train] resumed from step {start}")

        train_step = jax.jit(
            step_mod.make_train_step(
                cfg_t,
                ocfg,
                ShardCtx(),
                microbatches=args.microbatches,
                compress_grads_bins=args.compress_grads,
            ),
            donate_argnums=(0, 1),
        )

        for step in range(start, args.steps):
            t0 = time.time()
            batch = synthetic_batch(dcfg, step)
            params, opt_state, metrics = train_step(params, opt_state, batch)
            if (step + 1) % args.log_every == 0 or step == start:
                loss = float(metrics["loss"])
                dt = time.time() - t0
                detector.record(0, dt)
                tps = args.batch * args.seq / dt
                print(
                    f"[train] step {step+1:5d} loss {loss:.4f} "
                    f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.2f} "
                    f"{dt*1e3:.0f} ms/step ({tps:,.0f} tok/s)"
                )
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, (params, opt_state), extra={"arch": args.arch})
        if mgr:
            mgr.save(args.steps, (params, opt_state), extra={"arch": args.arch})
            mgr.wait()
        if detector.stragglers():
            print(f"[train] stragglers detected: {detector.stragglers()}")
        return args.steps

    sup = ft.Supervisor(ft.RestartPolicy(max_restarts=3))
    last = sup.run(loop)
    print(f"[train] done at step {last} (restarts: {sup.restarts})")
    return last


if __name__ == "__main__":
    main()
