"""Training launcher: end-to-end driver with checkpoint/restart + supervision.

Single-host example (the same SPMD program runs per-host on a fleet):

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --smoke \\
        --steps 200 --ckpt-dir /tmp/ckpt --resume auto

Fault tolerance (DESIGN.md §4): the loop is ``train/loop.py::run_loop``
under ``ft.Supervisor``.  Every jitted step carries the fused non-finite
guard — a NaN/inf batch skips its update bit-exactly and ``--guard-max-skip``
consecutive skips escalate to a restorable error; checkpoints are CRC32'd
and fsync'd, and restore falls back past a corrupt newest checkpoint to the
newest *valid* one; the supervisor classifies failures (same step failing
the same way twice across a restore → fail fast as deterministic; anything
else → backoff restart threading the failure's ``resume_step`` hint); the
data pipeline is step-addressed so no batches are replayed or skipped, and
per-step wall times feed the straggler detector every step.

Flags beyond the obvious:

``--guard-max-skip K``   escalate after K consecutive non-finite steps (3)
``--keep N``             checkpoint rotation depth (3)
``--max-restarts N``     supervisor restart budget (3)
``--faults-seed S``      chaos drill: run under a seeded
                         ``train.faults.TrainFaultPlan`` sampled from S
                         (crash / data-io / ckpt-io / nan / spike / slow —
                         the same plans the chaos suite asserts on)
``--resume auto``        restore the newest checkpoint passing integrity;
                         with no ``--ckpt-dir``, a supervisor restart warns
                         LOUDLY that all progress is lost and re-runs from
                         step 0.
"""
from __future__ import annotations

import argparse
import warnings
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp

from repro import ft
from repro.ckpt import checkpoint as ckpt
from repro.configs import get_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models import api
from repro.models.common import ShardCtx, quantize_params
from repro.train import faults as train_faults
from repro.train import loop as loop_mod
from repro.train import optimizer as opt
from repro.train import step as step_mod


def build_state(cfg, key, quant: str):
    model = api.get_model(cfg)
    params = model.init_params(cfg, key)
    if quant == "pasm" or quant == "qat":
        qcfg = cfg.with_quant(enabled=True, impl="kernel" if quant == "pasm" else "dequant")
        params = quantize_params(params, qcfg)
        cfg = qcfg
    return cfg, params


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quant", default="dense", choices=["dense", "pasm", "qat"])
    ap.add_argument("--compress-grads", type=int, default=0, help="bins; 0=off")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3, help="checkpoint rotation depth")
    ap.add_argument("--resume", default="no", choices=["no", "auto"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--guard-max-skip", type=int, default=3,
                    help="consecutive non-finite steps before escalating")
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--faults-seed", type=int, default=None,
                    help="chaos drill: sample a TrainFaultPlan from this seed")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    ocfg = opt.AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(args.steps // 20, 5))
    dcfg = DataConfig(
        seed=args.seed, vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch
    )
    mgr = ckpt.CheckpointManager(args.ckpt_dir, keep=args.keep) if args.ckpt_dir else None
    detector = ft.StragglerDetector(n_hosts=jax.process_count())
    plan = (
        train_faults.TrainFaultPlan.sample(args.faults_seed, n_steps=args.steps)
        if args.faults_seed is not None
        else None
    )
    sup = ft.Supervisor(ft.RestartPolicy(max_restarts=args.max_restarts))
    losses: dict = {}
    step_times: dict = {}

    def loop(resume_step: Optional[int]) -> int:
        if sup.restarts and mgr is None:
            warnings.warn(
                "supervisor restart with no --ckpt-dir: ALL training progress "
                "is lost and the run re-executes from step 0 — pass --ckpt-dir "
                "to make restarts resume instead",
                RuntimeWarning,
                stacklevel=2,
            )
        cfg_t, params = build_state(cfg, jax.random.PRNGKey(args.seed), args.quant)
        opt_state = opt.init_opt_state(params)
        start = 0
        if mgr and args.resume == "auto" and ckpt.latest_step(mgr.dir) is not None:
            # restore the resume hint when the supervisor threaded one
            # through, else the newest checkpoint passing integrity
            if resume_step is not None:
                (params, opt_state), manifest = ckpt.restore(
                    mgr.dir, (params, opt_state), step=resume_step
                )
            else:
                (params, opt_state), manifest = mgr.restore_latest((params, opt_state))
            start = manifest["step"]
            print(f"[train] resumed from step {start}")

        train_step = jax.jit(
            step_mod.make_train_step(
                cfg_t,
                ocfg,
                ShardCtx(),
                microbatches=args.microbatches,
                compress_grads_bins=args.compress_grads,
            ),
            donate_argnums=(0, 1),
        )

        res = loop_mod.run_loop(
            train_step,
            (params, opt_state),
            lambda s: synthetic_batch(dcfg, s),
            steps=args.steps,
            start_step=start,
            mgr=mgr,
            ckpt_every=args.ckpt_every,
            ckpt_extra={"arch": args.arch},
            faults=plan,
            detector=detector,
            max_consecutive_nonfinite=args.guard_max_skip,
            log_every=args.log_every,
            losses=losses,
            step_times=step_times,
        )
        if res.n_skipped:
            print(f"[train] guard skipped {res.n_skipped} non-finite steps")
        if res.n_ckpt_failures:
            print(f"[train] {res.n_ckpt_failures} checkpoint saves failed (training continued)")
        if detector.stragglers():
            print(f"[train] stragglers detected: {detector.stragglers()}")
        return res.last_step

    last = sup.run(loop)
    if plan is not None:
        print(f"[train] chaos drill: {len(plan.fired)} injections fired: "
              f"{[f[0] for f in plan.fired]}")
    print(f"[train] done at step {last} (restarts: {sup.restarts})")
    return last


if __name__ == "__main__":
    main()
