import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes, prove memory fit, and extract the roofline terms.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run needs 512 host-platform placeholder devices to build
the 16×16 single-pod and 2×16×16 multi-pod meshes.  (Smoke tests and benches
must NOT import this module — they want 1 device.)

Scan-cost correction: XLA's cost model counts a while-loop body ONCE, so a
scanned L-layer model under-reports FLOPs/bytes/collectives by ~L×.  Each
cell is therefore lowered twice — the full scanned config and a small
UNROLLED variant with 2 scan units — and the per-unit cost is solved from
the pair:  B = unrolled₂ − scanned,  corrected = scanned + (L−1)·B.
Memory stats come from the full scanned config (the realistic executable).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import roofline as RL
from repro.configs import SHAPES, all_cells, cell_supported, get_config
from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch.mesh import axis_sizes, make_production_mesh
from repro.models import api, sharding
from repro.models.common import ShardCtx, quantize_params
from repro.train import optimizer as opt
from repro.train import step as train_step_mod

DEFAULT_OUT = Path("experiments/dryrun")


def _abstract_params(cfg: ArchConfig, dtype, quant: str, kv_bits: int = 16):
    model = api.get_model(cfg)
    if quant != "dense":
        cfg_q = cfg.with_quant(enabled=True, impl="dequant", kv_bits=kv_bits)

        def build(key):
            return quantize_params(model.init_params(cfg_q, key, dtype), cfg_q)

        return jax.eval_shape(build, jax.ShapeDtypeStruct((2,), jnp.uint32)), cfg_q
    return (
        jax.eval_shape(
            lambda k: model.init_params(cfg, k, dtype), jax.ShapeDtypeStruct((2,), jnp.uint32)
        ),
        cfg,
    )


def _unrolled_variant(cfg: ArchConfig) -> tuple[ArchConfig, int]:
    """(2-scan-unit unrolled config, scan trip count of the full config)."""
    if cfg.family in ("dense", "moe", "vlm"):
        n_dense = min(cfg.moe.first_dense_layers, cfg.n_layers) if (cfg.moe and cfg.moe.n_experts) else 0
        trip = cfg.n_layers - n_dense
        small = dataclasses.replace(cfg, n_layers=n_dense + 2, scan_layers=False)
    elif cfg.family == "ssm":
        trip = cfg.n_layers
        small = dataclasses.replace(cfg, n_layers=2, scan_layers=False)
    elif cfg.family == "hybrid":
        pat = len(cfg.hybrid.pattern)
        trip = cfg.n_layers // pat
        tail = cfg.n_layers - trip * pat
        small = dataclasses.replace(cfg, n_layers=2 * pat + tail, scan_layers=False)
    elif cfg.family == "audio":
        assert cfg.encoder_layers == cfg.n_layers, "two-point correction assumes enc==dec depth"
        trip = cfg.n_layers
        small = dataclasses.replace(cfg, n_layers=2, encoder_layers=2, scan_layers=False)
    else:
        raise ValueError(cfg.family)
    return small, trip


def _lower_one(
    cfg: ArchConfig,
    shape: ShapeSpec,
    mesh,
    quant: str,
    fsdp: bool = False,
    microbatches: int = 1,
    remat: bool | None = None,
    kv_bits: int = 16,
):
    """Lower + compile one config.  Returns raw cost/HLO/memory artifacts."""
    sizes = axis_sizes(mesh)
    multi_pod = "pod" in sizes
    batch = sharding.batch_axes(
        multi_pod, shape.global_batch, sizes.get("data", 16), sizes.get("pod", 1)
    )
    model = api.get_model(cfg)
    specs = api.input_specs(cfg, shape)
    in_pspecs = sharding.input_pspecs(specs, batch)
    dp = 1
    for a in batch:
        dp *= sizes.get(a, 1)
    sctx = ShardCtx(batch=batch if batch else (), active=True, dp=max(dp, 1))
    dtype = jnp.float32 if shape.kind == "train" else jnp.bfloat16
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    params_abs, cfg_used = _abstract_params(cfg, dtype, quant, kv_bits)
    p_pspecs = sharding.param_pspecs(params_abs, sizes)
    if fsdp:  # ZeRO-3: params also sharded over data; all-gathered per layer
        p_pspecs = sharding.opt_state_pspecs(params_abs, p_pspecs, sizes)

    t0 = time.time()
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            ocfg = opt.AdamWConfig()
            opt_abs = jax.eval_shape(opt.init_opt_state, params_abs)
            zspec = sharding.opt_state_pspecs(params_abs, p_pspecs, sizes)
            o_pspecs = opt.OptState(step=P(), mu=zspec, nu=zspec)
            ts = train_step_mod.make_train_step(cfg_used, ocfg, sctx, microbatches=microbatches)
            jitted = jax.jit(
                ts,
                in_shardings=(p_pspecs, o_pspecs, in_pspecs),
                out_shardings=(p_pspecs, o_pspecs, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, specs)
        else:
            caches_abs = jax.eval_shape(
                lambda: model.init_caches(
                    cfg_used, shape.global_batch, api.cache_len(cfg_used, shape)
                )
            )
            c_pspecs = sharding.cache_pspecs(cfg_used, caches_abs, sizes, batch)
            if shape.kind == "prefill":

                def fn(params, caches, inputs):
                    kw = {k: v for k, v in inputs.items() if k == "frontend_embeds"}
                    return model.prefill(params, inputs["tokens"], caches, cfg_used, sctx, **kw)

            else:

                def fn(params, caches, inputs):
                    return model.decode_step(params, inputs["tokens"], caches, cfg_used, sctx)

            jitted = jax.jit(
                fn,
                in_shardings=(p_pspecs, c_pspecs, in_pspecs),
                out_shardings=(None, c_pspecs),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_abs, caches_abs, specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis()
    return {
        "cost": cost,
        "hlo": compiled.as_text(),
        "mem": compiled.memory_analysis(),
        "t_lower": t_lower,
        "t_compile": t_compile,
    }


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    quant: str = "auto",
    mesh=None,
    verbose: bool = True,
    correct_scan: bool = True,
    fsdp: bool = False,
    microbatches: int = 1,
    remat: bool | None = None,
    kv_bits: int = 16,
):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "reason": why}
    if quant == "auto":
        # paper is inference-focused: PASM on serve cells, dense training
        quant = "dense" if shape.kind == "train" else "pasm"
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    sizes = axis_sizes(mesh)
    n_dev = 1
    for v in sizes.values():
        n_dev *= v
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    full = _lower_one(
        cfg, shape, mesh, quant, fsdp=fsdp, microbatches=microbatches, remat=remat, kv_bits=kv_bits
    )
    flops = float(full["cost"].get("flops", 0.0))
    byts = float(full["cost"].get("bytes accessed", 0.0))
    coll = RL.parse_collective_bytes(full["hlo"]).total_bytes
    coll_counts = RL.parse_collective_bytes(full["hlo"]).count_by_kind

    if correct_scan:
        small_cfg, trip = _unrolled_variant(cfg)
        small = _lower_one(
            small_cfg, shape, mesh, quant, fsdp=fsdp, microbatches=microbatches,
            remat=remat, kv_bits=kv_bits,
        )
        b_flops = max(float(small["cost"].get("flops", 0.0)) - flops, 0.0)
        b_bytes = max(float(small["cost"].get("bytes accessed", 0.0)) - byts, 0.0)
        b_coll = max(RL.parse_collective_bytes(small["hlo"]).total_bytes - coll, 0.0)
        flops += (trip - 1) * b_flops
        byts += (trip - 1) * b_bytes
        coll += (trip - 1) * b_coll

    # MODEL_FLOPS: 6·N·D train (fwd+bwd), 2·N·D inference, per step.
    n_params = cfg.n_active_params() if cfg.moe else cfg.n_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    model_flops = (6.0 if shape.kind == "train" else 2.0) * n_params * tokens

    mem = full["mem"]
    report = RL.roofline_terms(
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name,
        n_devices=n_dev,
        cost={"flops": flops, "bytes accessed": byts},
        hlo_text="",  # collective bytes passed via override below
        model_flops=model_flops,
        extra={
            "quant": quant,
            "lower_s": round(full["t_lower"], 1),
            "compile_s": round(full["t_compile"], 1),
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "scan_corrected": correct_scan,
            "fsdp": fsdp,
            "collective_counts_body": coll_counts,
        },
    )
    # inject corrected collective bytes (roofline_terms parsed the empty string)
    report.collective_bytes = coll
    report.collective_s = coll / (RL.LINK_BW * RL.N_LINKS)
    terms = {
        "compute": report.compute_s,
        "memory": report.memory_s,
        "collective": report.collective_s,
    }
    report.bottleneck = max(terms, key=terms.get)

    if verbose:
        hbm = 16 * 2**30
        fit = (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / hbm
        print(f"--- {arch} × {shape_name} × {mesh_name} (quant={quant}) ---")
        print(
            f"  args {mem.argument_size_in_bytes/2**30:.2f} GiB/dev + temp "
            f"{mem.temp_size_in_bytes/2**30:.2f} GiB/dev = {fit*100:.0f}% of v5e HBM"
        )
        print(
            f"  flops/dev {report.flops_per_device:.3e}  bytes/dev {report.bytes_per_device:.3e}  "
            f"coll B/dev {report.collective_bytes:.3e}"
        )
        print(
            f"  terms: compute {report.compute_s*1e3:.2f} ms | memory {report.memory_s*1e3:.2f} ms | "
            f"collective {report.collective_s*1e3:.2f} ms → {report.bottleneck}-bound; "
            f"useful-flops {report.useful_flops_frac:.2f}, roofline frac {report.roofline_fraction:.3f}"
        )
        print(f"  lower {full['t_lower']:.0f}s compile {full['t_compile']:.0f}s")
    return {"arch": arch, "shape": shape_name, "status": "ok", "report": report}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--quant", default="auto", choices=["auto", "dense", "pasm"])
    ap.add_argument("--no-scan-correction", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="default", choices=["default", "on", "off"])
    ap.add_argument("--kv-bits", type=int, default=16, choices=[8, 16])
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = [(a, s) for a, s, ok, _ in all_cells()]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        for arch, shape in cells:
            tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}_{args.quant}" + ("_fsdp" if args.fsdp else "")
            try:
                res = lower_cell(
                    arch,
                    shape,
                    multi_pod=mp,
                    quant=args.quant,
                    mesh=mesh,
                    correct_scan=not args.no_scan_correction,
                    fsdp=args.fsdp,
                    microbatches=args.microbatches,
                    remat=None if args.remat == "default" else args.remat == "on",
                    kv_bits=args.kv_bits,
                )
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append(tag)
                (out / f"{tag}.json").write_text(
                    json.dumps({"arch": arch, "shape": shape, "status": "error", "error": repr(e)})
                )
                continue
            if res["status"] == "ok":
                (out / f"{tag}.json").write_text(res["report"].to_json())
            else:
                (out / f"{tag}.json").write_text(json.dumps(res))
                print(f"--- {arch} × {shape}: SKIPPED ({res['reason']})")
    if failures:
        print(f"\nFAILED cells: {failures}")
        raise SystemExit(1)
    print("\nall requested cells lowered + compiled OK")


if __name__ == "__main__":
    main()
