"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pasm as _pasm

__all__ = ["pasm_matmul_ref", "pas_matmul_ref", "dequant_ref", "apply_epilogue",
           "im2col_patches", "max_pool_rows"]


def im2col_patches(
    x: jax.Array, *, nhwc: bool, ky: int, kx: int, stride: int,
    oh: int, ow: int, c_in: int, pad: tuple,
) -> jax.Array:
    """Explicit batched im2col, geometry resolved: ``(B, img) → (B·P, K)``.

    THE definition of patch extraction — NCHW flattens in the paper's
    ``(c, ky, kx)`` loop order, NHWC channels-minor ``(ky, kx, c)``;
    ``pad = ((lo_h, hi_h), (lo_w, hi_w))`` is the spatial zero-pad.  Both
    the conv front-end (:func:`repro.core.conv._im2col`) and the implicit
    path's col2im backward (``ops._geom_patches``) delegate here, and the
    in-kernel ``patch_tile`` gather is oracled against it, so forward and
    backward can never drift.  Pure jnp, no pallas dependency.
    """
    ph, pw = pad
    if any(ph) or any(pw):
        cfg = ((0, 0), ph, pw, (0, 0)) if nhwc else ((0, 0), (0, 0), ph, pw)
        x = jnp.pad(x, cfg)
    kyr, kxr = jnp.arange(ky), jnp.arange(kx)
    oyr = jnp.arange(oh) * stride
    oxr = jnp.arange(ow) * stride
    if nhwc:
        rows = oyr[:, None, None, None] + kyr[None, None, :, None]  # (oh,1,KY,1)
        cols = oxr[None, :, None, None] + kxr[None, None, None, :]  # (1,ow,1,KX)
        patches = x[:, rows, cols, :]  # (B, oh, ow, KY, KX, C)
    else:
        c = jnp.arange(c_in)[None, None, :, None, None]
        rows = oyr[:, None, None, None, None] + kyr[None, None, None, :, None]
        cols = oxr[None, :, None, None, None] + kxr[None, None, None, None, :]
        patches = x[:, c, rows, cols]  # (B, oh, ow, C, KY, KX)
    return patches.reshape(x.shape[0] * oh * ow, c_in * ky * kx)


def apply_epilogue(y: jax.Array, bias, relu: bool) -> jax.Array:
    """The bias/ReLU epilogue the kernels fuse, as plain XLA (oracle form).

    Also the einsum reference path of :func:`repro.core.conv.conv2d` — one
    definition so kernel oracle and conv reference can never drift.  The
    ReLU clamp keeps ``y``'s dtype (integer inputs stay integer, §5.3).
    """
    if bias is not None:
        y = y + bias
    if relu:
        y = jnp.maximum(y, 0)
    return y


def max_pool_rows(y: jax.Array, pool: int) -> jax.Array:
    """Window-major row pooling: ``(R·pool², N) → (R, N)`` max per group.

    The oracle of the kernels' fused max-pool epilogue (each consecutive
    ``pool²`` rows are one non-overlapping pool window) — also the function
    the pooled custom VJPs differentiate through, so the backward's argmax
    routing is *defined* by this reduction.
    """
    if pool == 1:
        return y
    pw = pool * pool
    return y.reshape(y.shape[0] // pw, pw, y.shape[1]).max(axis=1)


def dequant_ref(idx: jax.Array, codebook: jax.Array, *, packed: bool) -> jax.Array:
    """(K, N) f32 weights from indices + (G, B) codebook."""
    if packed:
        idx = _pasm.unpack_int4(idx)
    K, N = idx.shape
    G, B = codebook.shape
    idxg = idx.reshape(G, K // G, N)
    w = jax.vmap(lambda cb, ix: cb[ix.astype(jnp.int32)])(codebook, idxg)
    return w.reshape(K, N)


def pasm_matmul_ref(
    x: jax.Array, idx: jax.Array, codebook: jax.Array, *, packed: bool
) -> jax.Array:
    """Oracle for the dequant-fused kernel: dequantize then f32-accum GEMM."""
    w = dequant_ref(idx, codebook, packed=packed).astype(x.dtype)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def pas_matmul_ref(x: jax.Array, idx: jax.Array, codebook: jax.Array) -> jax.Array:
    """Oracle for the PAS-formulation kernel: histogram bins then post-pass."""
    B = codebook.shape[-1]
    onehot = jax.nn.one_hot(idx, B, dtype=x.dtype)  # (K, N, B)
    s = jnp.einsum(
        "mk,knb->mnb", x, onehot, preferred_element_type=jnp.float32
    )  # PAS bins
    return jnp.einsum("mnb,b->mn", s, codebook.reshape(-1).astype(jnp.float32))
