"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pasm as _pasm

__all__ = ["pasm_matmul_ref", "pas_matmul_ref", "dequant_ref", "apply_epilogue"]


def apply_epilogue(y: jax.Array, bias, relu: bool) -> jax.Array:
    """The bias/ReLU epilogue the kernels fuse, as plain XLA (oracle form).

    Also the einsum reference path of :func:`repro.core.conv.conv2d` — one
    definition so kernel oracle and conv reference can never drift.  The
    ReLU clamp keeps ``y``'s dtype (integer inputs stay integer, §5.3).
    """
    if bias is not None:
        y = y + bias
    if relu:
        y = jnp.maximum(y, 0)
    return y


def dequant_ref(idx: jax.Array, codebook: jax.Array, *, packed: bool) -> jax.Array:
    """(K, N) f32 weights from indices + (G, B) codebook."""
    if packed:
        idx = _pasm.unpack_int4(idx)
    K, N = idx.shape
    G, B = codebook.shape
    idxg = idx.reshape(G, K // G, N)
    w = jax.vmap(lambda cb, ix: cb[ix.astype(jnp.int32)])(codebook, idxg)
    return w.reshape(K, N)


def pasm_matmul_ref(
    x: jax.Array, idx: jax.Array, codebook: jax.Array, *, packed: bool
) -> jax.Array:
    """Oracle for the dequant-fused kernel: dequantize then f32-accum GEMM."""
    w = dequant_ref(idx, codebook, packed=packed).astype(x.dtype)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def pas_matmul_ref(x: jax.Array, idx: jax.Array, codebook: jax.Array) -> jax.Array:
    """Oracle for the PAS-formulation kernel: histogram bins then post-pass."""
    B = codebook.shape[-1]
    onehot = jax.nn.one_hot(idx, B, dtype=x.dtype)  # (K, N, B)
    s = jnp.einsum(
        "mk,knb->mnb", x, onehot, preferred_element_type=jnp.float32
    )  # PAS bins
    return jnp.einsum("mnb,b->mn", s, codebook.reshape(-1).astype(jnp.float32))
