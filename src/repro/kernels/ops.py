"""Public jit'd wrappers around the Pallas kernels.

Handles: shape padding to tile multiples, block-size selection, packed-int4
plumbing, interpret-mode fallback on CPU, and a custom VJP so PASM layers are
differentiable (gradient w.r.t. activations flows through the dequantized
weight; quantized weights are leaves without gradients — QAT uses
``repro.core.qat`` on the dense master copy instead).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pasm as _pasm
from repro.kernels import ref as _ref
from repro.kernels.pas_histogram import pas_matmul_kernel_call
from repro.kernels.pasm_matmul import pasm_matmul_kernel_call

__all__ = ["pasm_matmul", "pas_matmul", "matmul_flops", "pasm_hbm_bytes"]


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pick_blocks(M: int, K: int, N: int, group_size: int, packed: bool):
    """Tile plan for an (M, K)·(K, N) PASM matmul.

    Returns ``(bm, bn, bk, gs_pad)`` where ``gs_pad`` is the padded per-group
    reduction length (``== group_size`` when the group tiles exactly).  A
    group that fits one k-tile (``group_size <= 512``) is never padded — this
    keeps the seed's tiling (and its numerics) on every aligned shape.  Larger
    groups must split into 128-aligned k-tiles; when no such divisor exists
    (e.g. conv im2col reductions like K = C·KY·KX = 2400) the group is padded
    up to the next 128 multiple and :func:`_pad_operands` maps the pad rows to
    a reserved zero-codebook bin instead of the former hard ``ValueError``.
    """
    bm = min(128, _round_up(M, 8))
    bn = min(128, _round_up(N, 128))
    if group_size <= 512 and not (packed and group_size % 2):
        return bm, bn, group_size, group_size  # one k-tile per group
    bk = 512
    while bk >= 128 and group_size % bk:
        bk //= 2
    if bk >= 128:
        return bm, bn, bk, group_size
    if packed and group_size % 2:
        # packed nibbles straddle the group boundary: no consistent layout
        raise ValueError(f"packed int4 needs an even group size, got {group_size}")
    gs_pad = _round_up(group_size, 128)
    bk = min(512, gs_pad)
    while gs_pad % bk:
        bk //= 2
    return bm, bn, bk, gs_pad


def _pad_operands(x, idx, codebook, bm, bn, gs_pad, packed):
    """Pad (x, idx, codebook) to the tile plan; returns logical (M, N, Kp).

    M/N padding is plain zero/edge padding (sliced off the output).  K padding
    appends ``gs_pad - group_size`` rows per group: the pad rows of ``x`` are
    zero AND their indices point at a reserved all-zero codebook bin (appended
    as bin ``B`` when representable), so padded positions are doubly inert in
    both the fused-dequant and the PAS-histogram formulation.  When the pad
    bin is not representable (packed int4 at B=16, or B=256 saturating uint8)
    bin 0 is used instead — still exact, because the paired activations are
    zero.  Grouped codebooks pad per group so the kernel's ``k-block → group``
    index map stays a pure division.
    """
    M, K = x.shape
    N = idx.shape[1]
    G, B = codebook.shape
    gs = K // G
    if gs_pad != gs:
        pad = gs_pad - gs
        if not packed and B < 256:
            codebook = jnp.pad(codebook, ((0, 0), (0, 1)))  # reserved zero bin
            pad_bin = B
        else:
            pad_bin = 0
        if packed:
            idxg = idx.reshape(G, gs // 2, N)
            idx = jnp.pad(idxg, ((0, 0), (0, pad // 2), (0, 0))).reshape(-1, N)
        else:
            idxg = idx.reshape(G, gs, N)
            idx = jnp.pad(
                idxg, ((0, 0), (0, pad), (0, 0)), constant_values=pad_bin
            ).reshape(-1, N)
        x = jnp.pad(x.reshape(M, G, gs), ((0, 0), (0, 0), (0, pad)))
        x = x.reshape(M, G * gs_pad)
        K = G * gs_pad
    Mp, Np = _round_up(M, bm), _round_up(N, bn)
    x = jnp.pad(x, ((0, Mp - M), (0, 0))) if Mp != M else x
    idx = jnp.pad(idx, ((0, 0), (0, Np - N))) if Np != N else idx
    return x, idx, codebook, (M, N, K)


@functools.partial(
    jax.jit,
    static_argnames=("packed", "logical_k", "gather", "interpret", "use_ref", "relu"),
)
def _pasm_matmul_fwd_impl(
    x, idx, codebook, bias=None, *, packed, logical_k, gather, interpret, use_ref,
    relu=False,
):
    if use_ref:
        y = _ref.pasm_matmul_ref(x, idx, codebook, packed=packed)
        return _ref.apply_epilogue(y, bias, relu)
    G, B = codebook.shape
    group_size = logical_k // G
    bm, bn, bk, gs_pad = _pick_blocks(
        x.shape[0], logical_k, idx.shape[1], group_size, packed
    )
    xp, idxp, cbp, (M, N, Kp) = _pad_operands(x, idx, codebook, bm, bn, gs_pad, packed)
    bias_row = None
    if bias is not None:
        bias_row = jnp.pad(bias.astype(jnp.float32), (0, idxp.shape[1] - N))
        bias_row = bias_row.reshape(1, -1)
    out = pasm_matmul_kernel_call(
        xp,
        idxp,
        cbp,
        bias_row,
        packed=packed,
        logical_k=Kp,
        bm=bm,
        bn=bn,
        bk=bk,
        gather=gather,
        relu=relu,
        interpret=interpret,
    )
    return out[:M, :N]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _pasm_matmul(x, idx, codebook, packed, gather, interpret):
    logical_k = x.shape[-1]
    return _pasm_matmul_fwd_impl(
        x,
        idx,
        codebook,
        packed=packed,
        logical_k=logical_k,
        gather=gather,
        interpret=interpret,
        use_ref=False,
    )


def _pasm_fwd(x, idx, codebook, packed, gather, interpret):
    return _pasm_matmul(x, idx, codebook, packed, gather, interpret), (x, idx, codebook)


def _pasm_bwd(packed, gather, interpret, res, g):
    x, idx, codebook = res
    w = _ref.dequant_ref(idx, codebook, packed=packed).astype(x.dtype)
    dx = jnp.dot(g.astype(x.dtype), w.T)
    # codebook grad: Σ of (xᵀg) entries binned by index — the PAS identity on
    # the backward pass.  idx gets no gradient (integer).
    xg = jnp.dot(x.T.astype(jnp.float32), g.astype(jnp.float32))  # (K, N)
    li = _pasm.unpack_int4(idx) if packed else idx
    K, N = li.shape
    G, B = codebook.shape
    seg = li.reshape(G, K // G, N).astype(jnp.int32)
    xgg = xg.reshape(G, K // G, N)
    dcb = jax.vmap(
        lambda s, v: jax.ops.segment_sum(v.reshape(-1), s.reshape(-1), num_segments=B)
    )(seg, xgg)
    return dx, None, dcb.astype(codebook.dtype)


_pasm_matmul.defvjp(_pasm_fwd, _pasm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _pasm_matmul_ep(x, idx, codebook, bias, packed, gather, interpret, relu):
    """The fused-epilogue variant: bias/ReLU applied inside the kernel."""
    return _pasm_matmul_fwd_impl(
        x,
        idx,
        codebook,
        bias,
        packed=packed,
        logical_k=x.shape[-1],
        gather=gather,
        interpret=interpret,
        use_ref=False,
        relu=relu,
    )


def _pasm_ep_fwd(x, idx, codebook, bias, packed, gather, interpret, relu):
    y = _pasm_matmul_ep(x, idx, codebook, bias, packed, gather, interpret, relu)
    return y, (x, idx, codebook, bias, y)


def _pasm_ep_bwd(packed, gather, interpret, relu, res, g):
    x, idx, codebook, bias, y = res
    if relu:
        g = g * (y > 0).astype(g.dtype)  # mask through the fused ReLU
    dx, _, dcb = _pasm_bwd(packed, gather, interpret, (x, idx, codebook), g)
    dbias = g.sum(axis=0).astype(bias.dtype)
    return dx, None, dcb, dbias


_pasm_matmul_ep.defvjp(_pasm_ep_fwd, _pasm_ep_bwd)


def pasm_matmul(
    x: jax.Array,
    t: _pasm.PASMTensor,
    *,
    bias: Optional[jax.Array] = None,
    relu: bool = False,
    gather: str = "take",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``x @ t`` with the fused dequant kernel.  x: (..., K) → (..., N) f32.

    ``bias (N,)`` / ``relu`` fuse into the kernel's last-k-step write-through
    (one pallas_call per layer, no XLA epilogue).  Differentiable in ``x``,
    ``t.codebook`` and ``bias``.
    """
    if interpret is None:
        interpret = _interpret_default()
    K = t.shape[0]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, K)
    if bias is None and not relu:
        y = _pasm_matmul(x2, t.idx, t.codebook, t.packed, gather, interpret)
    else:
        b = jnp.zeros((t.shape[1],), jnp.float32) if bias is None else bias
        y = _pasm_matmul_ep(x2, t.idx, t.codebook, b, t.packed, gather, interpret, relu)
    return y.reshape(*lead, t.shape[1])


@functools.partial(jax.jit, static_argnames=("relu", "interpret"))
def _pas_matmul_impl(x, idx, codebook, bias=None, *, relu=False, interpret):
    M, K = x.shape
    N = idx.shape[1]
    bm, bn, bk, gs_pad = _pick_blocks(M, K, N, K, packed=False)
    xp, idxp, cbp, (M, N, _) = _pad_operands(
        x, idx, codebook, bm, bn, gs_pad, packed=False
    )
    bias_row = None
    if bias is not None:
        bias_row = jnp.pad(bias.astype(jnp.float32), (0, idxp.shape[1] - N))
        bias_row = bias_row.reshape(1, -1)
    out = pas_matmul_kernel_call(
        xp, idxp, cbp, bias_row, bm=bm, bn=bn, bk=bk, relu=relu, interpret=interpret
    )
    return out[:M, :N]


def pas_matmul(
    x: jax.Array,
    t: _pasm.PASMTensor,
    *,
    bias: Optional[jax.Array] = None,
    relu: bool = False,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Paper-faithful PASM two-phase matmul (single dictionary).

    ``bias (N,)`` / ``relu`` fuse into the post-pass write-through.
    """
    if interpret is None:
        interpret = _interpret_default()
    idx = _pasm.logical_idx(t)
    lead = x.shape[:-1]
    y = _pas_matmul_impl(
        x.reshape(-1, t.shape[0]), idx, t.codebook, bias, relu=relu,
        interpret=interpret,
    )
    return y.reshape(*lead, t.shape[1])


# ---------------------------------------------------------------------------
# roofline bookkeeping helpers
# ---------------------------------------------------------------------------


def matmul_flops(M: int, K: int, N: int) -> int:
    return 2 * M * K * N


def pasm_hbm_bytes(t: _pasm.PASMTensor, M: int, act_bytes: int = 2) -> int:
    """Bytes one (M,K)@(K,N) PASM matmul actually moves: x + idx + cb + out.

    Tile-plan aware (audited against :attr:`PASMTensor.nbytes_weights`): the
    kernel streams the *padded* operands, so shapes that route through the §3
    K-pad move ``G·gs_pad`` reduction rows (plus one reserved codebook bin per
    group), and M/N round up to the block plan.  The output is written f32
    (4 B) — the seed counted it at ``act_bytes``, under-reporting the store
    traffic.  On tile-aligned shapes the weight term equals
    ``t.nbytes_weights`` exactly.
    """
    K, N = t.shape
    G, B = t.codebook.shape
    bm, bn, bk, gs_pad = _pick_blocks(M, K, N, K // G, t.packed)
    Kp = G * gs_pad
    Mp, Np = _round_up(M, bm), _round_up(N, bn)
    idx_bytes = (Kp // 2 if t.packed else Kp) * Np
    padded_k = gs_pad != K // G
    cb_bytes = G * (B + (1 if padded_k and not t.packed and B < 256 else 0)) * 4
    return Mp * Kp * act_bytes + idx_bytes + cb_bytes + Mp * Np * 4


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused flash attention.  q (B,Sq,H,hd); k,v (B,Sk,KV,hd) → (B,Sq,H,hd).

    GQA: query heads are regrouped under their KV head so each K/V tile is
    read once per group.  Pads Sq/Sk to tile multiples (pad keys masked).
    """
    from repro.kernels.flash_attention import flash_attention_kernel_call

    if interpret is None:
        interpret = _interpret_default()
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    bq = min(bq, max(8, 1 << (Sq - 1).bit_length()))
    bk = min(bk, max(8, 1 << (Sk - 1).bit_length()))
    Sqp, Skp = _round_up(Sq, bq), _round_up(Sk, bk)
    qg = jnp.moveaxis(q.reshape(B, Sq, KV, G, hd), 1, 3)  # (B, KV, G, Sq, hd)
    qg = qg.reshape(B * KV, G, Sq, hd)
    kg = jnp.moveaxis(k, 1, 2).reshape(B * KV, Sk, hd)
    vg = jnp.moveaxis(v, 1, 2).reshape(B * KV, Sk, hd)
    if Sqp != Sq:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    if Skp != Sk:
        kg = jnp.pad(kg, ((0, 0), (0, Skp - Sk), (0, 0)))
        vg = jnp.pad(vg, ((0, 0), (0, Skp - Sk), (0, 0)))
    o = flash_attention_kernel_call(
        qg, kg, vg, causal=causal, sk_orig=Sk, bq=bq, bk=bk, interpret=interpret
    )
    o = o[:, :, :Sq].reshape(B, KV, G, Sq, hd)
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd)
