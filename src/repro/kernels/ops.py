"""Public jit'd wrappers around the Pallas kernels.

Handles: shape padding to tile multiples, block-size selection, packed-int4
plumbing, interpret-mode fallback on CPU, and a custom VJP so PASM layers are
differentiable (gradient w.r.t. activations flows through the dequantized
weight; quantized weights are leaves without gradients — QAT uses
``repro.core.qat`` on the dense master copy instead).

Every public wrapper additionally takes ``mesh=``: a ``jax.sharding.Mesh``
with a ``data`` axis (and optionally ``model``) routes the call through
``shard_map`` — rows/batch shard over ``data``, the output-channel N
dimension over ``model`` when it divides, and the per-shard call is the SAME
single-device impl on the *local* shapes.  The reduction axis K is never
sharded and the k-tile plan (``bk``/``gs_pad``) is a pure function of
K/groups alone, so every output element sees the identical accumulation
order on any mesh — sharded outputs are bit-exact vs single-device
(DESIGN.md §4.1).  Codebooks (and the PAS formulation's in-kernel bin
counters) stay per-shard-replicated; bias follows the N sharding.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pasm as _pasm
from repro.kernels import ref as _ref
from repro.kernels.pas_histogram import pas_conv_kernel_call, pas_matmul_kernel_call
from repro.kernels.pasm_matmul import (
    ConvGeom,
    SlabPlan,
    pasm_conv_kernel_call,
    pasm_matmul_kernel_call,
)

__all__ = [
    "pasm_matmul",
    "pas_matmul",
    "pasm_conv2d",
    "pas_conv2d",
    "ConvGeom",
    "SlabPlan",
    "conv_slab_plan",
    "conv_whole_image_fits",
    "IMPLICIT_VMEM_BUDGET",
    "matmul_flops",
    "pasm_hbm_bytes",
    "conv_hbm_bytes",
    "pool_plan_exists",
]

# Per-grid-step VMEM budget (bytes) the slab planner sizes the implicit conv
# engines against.  Suits a ~16 MiB-VMEM TPU core with headroom for Mosaic's
# own allocations; per-call targets override via ``vmem_budget=``.  Keep in
# sync with ``repro.core.conv._IMPLICIT_VMEM_BUDGET`` (the dispatch-level
# default that conv2d resolves and threads down here).
IMPLICIT_VMEM_BUDGET = 6 * 1024 * 1024


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


# ---------------------------------------------------------------------------
# mesh plumbing (the shard_map sharded path)
# ---------------------------------------------------------------------------


def _mesh_sizes(mesh) -> tuple:
    """``(n_data, n_model)`` — one definition, in :mod:`repro.launch.mesh`."""
    from repro.launch.mesh import data_model_sizes

    return data_model_sizes(mesh)


def _n_spec(mesh, n: int):
    """N over ``model`` when divisible, else replicate — the shared
    :func:`repro.launch.mesh.n_shard_axis` rule (indivisible ``c_out`` keeps
    idx/bias N-replicated while ``data`` still shards the rows)."""
    from repro.launch.mesh import n_shard_axis

    return n_shard_axis(mesh, n)


def _shard_map(fn, mesh, in_specs, out_specs):
    from jax.experimental.shard_map import shard_map

    # check_rep=False: the N-replicated fallback computes identical outputs
    # on every model-axis device, which the rep checker cannot prove through
    # a pallas_call.
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _shard_gemm(mesh, n_cols, local_fn, operands, *, x_rank, out_rank,
                bias=None, gather_output=False):
    """The one shard_map dispatch every sharded wrapper routes through.

    ``operands = (x, idx, codebook)`` (+ ``bias`` appended when given): x
    shards its leading dim over ``data``, idx rides ``P(None, ns)`` with
    ``ns`` the shared N rule, the codebook replicates, bias follows the N
    sharding, and the output puts ``data`` leading / ``ns`` trailing at
    ``out_rank``.  ``local_fn`` is the per-shard single-device impl —
    callers keep their own bias/no-bias *impl* split so the sharded call
    mirrors the single-device branch structure exactly (part of the bitwise
    guarantee), but the spec plumbing lives only here.

    ``gather_output=True`` fuses the inter-layer all-gather into the kernel
    epilogue: when N actually shards over ``model``, each shard's output is
    ``all_gather``'d (tiled, axis-index order — the exact N-tile layout)
    *inside* the shard_map body right after the pallas_call, and the out
    spec drops the trailing ``ns`` (model-replicated activations).  The next
    layer's x operand is then already replicated over ``model``, so XLA has
    no reshard to insert between consecutive pallas_calls (DESIGN.md §4.1).
    Tiled all-gather concatenates the per-device N tiles in order — the
    bitwise-identical full-N output.  Differentiable: the all-gather's
    transpose is a psum_scatter, so the fused collective rides the existing
    custom VJPs unchanged.
    """
    from jax.sharding import PartitionSpec as P

    ns = _n_spec(mesh, n_cols)
    in_specs = (P("data", *([None] * (x_rank - 1))), P(None, ns), P(None, None))
    if bias is not None:
        in_specs += (P(ns),)
        operands = operands + (bias,)
    fn, out_ns = local_fn, ns
    if gather_output and ns is not None:
        def fn(*ops):
            return jax.lax.all_gather(local_fn(*ops), ns, axis=-1, tiled=True)

        out_ns = None
    out_spec = P("data", *([None] * (out_rank - 2)), out_ns)
    return _shard_map(fn, mesh, in_specs, out_spec)(*operands)


def _pick_blocks(M: int, K: int, N: int, group_size: int, packed: bool):
    """Tile plan for an (M, K)·(K, N) PASM matmul.

    Returns ``(bm, bn, bk, gs_pad)`` where ``gs_pad`` is the padded per-group
    reduction length (``== group_size`` when the group tiles exactly).  A
    group that fits one k-tile (``group_size <= 512``) is never padded — this
    keeps the seed's tiling (and its numerics) on every aligned shape.  Larger
    groups must split into 128-aligned k-tiles; when no such divisor exists
    (e.g. conv im2col reductions like K = C·KY·KX = 2400) the group is padded
    up to the next 128 multiple and :func:`_pad_operands` maps the pad rows to
    a reserved zero-codebook bin instead of the former hard ``ValueError``.
    """
    bm = min(128, _round_up(M, 8))
    bn = min(128, _round_up(N, 128))
    if group_size <= 512 and not (packed and group_size % 2):
        return bm, bn, group_size, group_size  # one k-tile per group
    bk = 512
    while bk >= 128 and group_size % bk:
        bk //= 2
    if bk >= 128:
        return bm, bn, bk, group_size
    if packed and group_size % 2:
        # packed nibbles straddle the group boundary: no consistent layout
        raise ValueError(f"packed int4 needs an even group size, got {group_size}")
    gs_pad = _round_up(group_size, 128)
    bk = min(512, gs_pad)
    while gs_pad % bk:
        bk //= 2
    return bm, bn, bk, gs_pad


def _pool_row_align(pool: int) -> int:
    """Rows a pooled block must be a multiple of: ``lcm(pool², 8)`` — whole
    pool windows (the epilogue max is a ``(bm/pool², pool², bn)`` reshape)
    at MXU row alignment."""
    pw = pool * pool
    return pw * 8 // math.gcd(pw, 8)


def pool_plan_exists(pool: int) -> bool:
    """Whether a pool-aligned tile plan exists (``lcm(pool², 8) ≤ 256``
    rows).  THE source of truth shared by :func:`_pool_bm` and
    ``conv2d``'s fuse dispatch (:func:`repro.core.conv._pool_fusible`), so
    the two can never drift apart."""
    return pool == 1 or _pool_row_align(pool) <= 256


def _pool_bm(bm: int, pool: int) -> int:
    """Align ``bm`` to whole pool windows for the fused max-pool epilogue.

    Returns the largest :func:`_pool_row_align` multiple ≤ the unpooled
    ``bm`` (at least one window row group).  ``conv2d``'s dispatch only
    fuses when :func:`pool_plan_exists`, so the ValueError is a guard
    against direct misuse, not a reachable fallback.
    """
    if pool == 1:
        return bm
    a = _pool_row_align(pool)
    if not pool_plan_exists(pool):
        raise ValueError(
            f"no pool-aligned tile plan for pool={pool}: lcm(pool², 8)={a} "
            "exceeds the 256-row block cap — use the unfused reduce_window "
            "fallback (conv2d pool dispatch does this automatically)"
        )
    return max(a, bm - bm % a)


def _check_pool_operand(x, pool: int, mesh=None, n_data: int = 1) -> None:
    """The shared ``pool=`` preconditions of the explicit GEMM wrappers: a
    2-D window-major operand (``pool²`` consecutive rows per window), and —
    under ``mesh=`` — rows that split over ``data`` in whole pool windows
    (``conv2d`` guarantees this: it pads the batch to divide the axis, and
    each image contributes ``P_rows`` window-major rows, a multiple of
    ``pool²``, so per-image row runs never straddle a shard boundary)."""
    if x.ndim != 2 or x.shape[0] % (pool * pool):
        raise ValueError(
            "pool= needs a 2-D window-major x (pool² consecutive rows "
            f"per window), got shape {x.shape} with pool={pool}"
        )
    if mesh is not None and x.shape[0] % (n_data * pool * pool):
        raise ValueError(
            f"pool= under mesh= needs the window-major rows ({x.shape[0]}) "
            f"to split over the data axis ({n_data}) in whole pool windows; "
            "conv2d(mesh=) guarantees this by padding the batch first"
        )


def _conv_block_vmem_bytes(*, bm: int, bn: int, bk: int, bins: int,
                           packed: bool = False, pas: bool = False,
                           has_bias: bool = True, pool: int = 1) -> int:
    """Non-image VMEM bytes of one implicit-conv grid step.

    Counts what actually sits in VMEM next to the image block: the idx tile
    (uint8, halved when packed), the codebook row (+1 reserved pad bin, the
    worst case), the bias row, the output block — each ×2 because Pallas
    double-buffers every pipelined operand — plus the un-double-buffered
    scratch accumulator (PAS bin counters always; the pasm pooled
    accumulator when the pool is fused).
    """
    pw = pool * pool
    idx = 2 * (bk // 2 if packed else bk) * bn
    cb = 2 * (bins + 1) * 4
    bias = 2 * bn * 4 if has_bias else 0
    out = 2 * (bm // pw) * bn * 4
    if pas:
        scratch = bm * bn * bins * 4
    else:
        scratch = bm * bn * 4 if pool > 1 else 0
    return idx + cb + bias + out + scratch


def conv_whole_image_fits(
    geom: ConvGeom, hp: int, wp: int, *, bm: int, bn: int, bk: int, bins: int,
    packed: bool = False, pas: bool = False, has_bias: bool = True,
    vmem_budget: Optional[int] = None, itemsize: int = 4,
) -> bool:
    """Whether the whole padded image (``hp × wp``) stays VMEM-resident.

    THE accounting shared by :func:`conv_slab_plan` and ``conv2d``'s
    :func:`repro.core.conv._implicit_fits` predicate: the image block counts
    **twice** (Pallas prefetches image ``b+1`` across the batch grid
    dimension — the double buffer is real VMEM) on top of every non-image
    per-grid-step block from :func:`_conv_block_vmem_bytes`.
    """
    budget = IMPLICIT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    fixed = _conv_block_vmem_bytes(bm=bm, bn=bn, bk=bk, bins=bins,
                                   packed=packed, pas=pas, has_bias=has_bias,
                                   pool=geom.pool)
    return fixed + 2 * hp * geom.c_in * wp * itemsize <= budget


def _halo_block_rows(band_rows: int, overlap: int) -> int:
    """Halo block size: the smallest divisor of ``band_rows`` ≥ the needed
    row overlap ``max(ky - stride, 0)`` (0 when no overlap).  Divisibility
    makes the halo offset ``(slab+1)·band_rows`` block-aligned, which is all
    the halo BlockSpec needs — ``band_rows`` itself stays unconstrained."""
    if overlap <= 0:
        return 0
    d = overlap
    while band_rows % d:
        d += 1
    return d


def conv_slab_plan(
    geom: ConvGeom, hp: int, wp: int, *, bm: int, bn: int, bk: int, bins: int,
    packed: bool = False, pas: bool = False, has_bias: bool = True,
    vmem_budget: Optional[int] = None, itemsize: int = 4,
) -> SlabPlan:
    """Size the row-band slab pipeline for one implicit conv (DESIGN.md §3.3).

    Whole image first: when the double-buffered image plus every non-image
    block fits ``vmem_budget``, the plan is a single slab — the legacy
    schedule, bit-for-bit (existing byte pins survive).  Otherwise the
    padded image is tiled into the largest row bands whose double-buffered
    footprint fits:

    * a slab covers ``blocks_per_slab`` output-row blocks with
      ``(blocks_per_slab·bmp) % owp == 0`` — whole pooled output rows, so
      pool windows never straddle a seam and the band index map is a pure
      division — giving ``band_rows = slab_out_rows·stride`` image rows;
    * the minimal ``blocks_per_slab`` is ``owp / gcd(bmp, owp)`` (scaled up
      until the band covers the ``ky - stride`` overlap); the planner then
      grows it greedily in those multiples while the footprint fits;
    * the halo block is :func:`_halo_block_rows`; ``rows_total`` is what the
      kernel operand must carry.

    Best-effort: when even the minimal slab exceeds the budget (or the
    geometry is unsplittable — one slab would cover everything), the plan
    degrades to the closest schedule rather than raising; the budget is a
    sizing target, not a hard capacity.
    """
    budget = IMPLICIT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    pw = geom.pool * geom.pool
    bmp = bm // pw
    n_blocks = max(1, -(-geom.P_out // bmp))
    row_bytes = geom.c_in * wp * itemsize
    whole = SlabPlan(1, n_blocks, hp, 0, hp)
    if conv_whole_image_fits(geom, hp, wp, bm=bm, bn=bn, bk=bk, bins=bins,
                             packed=packed, pas=pas, has_bias=has_bias,
                             vmem_budget=budget, itemsize=itemsize):
        return whole
    fixed = _conv_block_vmem_bytes(bm=bm, bn=bn, bk=bk, bins=bins,
                                   packed=packed, pas=pas, has_bias=has_bias,
                                   pool=geom.pool)
    overlap = max(geom.ky - geom.stride, 0)
    owp = geom.owp

    def band(bps):  # image rows a bps-block slab advances by
        return (bps * bmp // owp) * geom.pool * geom.stride

    bps_min = owp // math.gcd(bmp, owp)
    if overlap > 0 and band(bps_min) < overlap:
        bps_min *= -(-overlap // band(bps_min))
    if bps_min >= n_blocks:
        return whole  # unsplittable: one slab would already cover everything

    def foot(bps):
        s = band(bps)
        return fixed + 2 * (s + _halo_block_rows(s, overlap)) * row_bytes

    bps = bps_min
    while bps + bps_min < n_blocks and foot(bps + bps_min) <= budget:
        bps += bps_min
    s = band(bps)
    halo = _halo_block_rows(s, overlap)
    n_slabs = -(-n_blocks // bps)
    return SlabPlan(n_slabs, bps, s, halo, n_slabs * s + halo)


def _pad_weight_operands(idx, codebook, bn, gs_pad, packed):
    """K-pad (idx, codebook) per group and N-pad idx to the tile plan.

    K padding appends ``gs_pad - group_size`` index rows per group pointing
    at a reserved all-zero codebook bin (appended as bin ``B`` when
    representable), so padded positions are inert in both the fused-dequant
    and the PAS-histogram formulation — their paired activations are zero
    too (explicit path: zero-padded ``x`` rows; implicit path: the masked
    :func:`~repro.kernels.pasm_matmul.patch_tile` gather).  When the pad bin
    is not representable (packed int4 at B=16, or B=256 saturating uint8)
    bin 0 is used instead — still exact, because the paired activations are
    zero.  Grouped codebooks pad per group so the kernel's
    ``k-block → group`` index map stays a pure division.  Returns
    ``(idx, codebook, N)`` with ``N`` the logical output width.
    """
    N = idx.shape[1]
    G, B = codebook.shape
    gs = idx.shape[0] * (2 if packed else 1) // G
    if gs_pad != gs:
        pad = gs_pad - gs
        if not packed and B < 256:
            codebook = jnp.pad(codebook, ((0, 0), (0, 1)))  # reserved zero bin
            pad_bin = B
        else:
            pad_bin = 0
        if packed:
            idxg = idx.reshape(G, gs // 2, N)
            idx = jnp.pad(idxg, ((0, 0), (0, pad // 2), (0, 0))).reshape(-1, N)
        else:
            idxg = idx.reshape(G, gs, N)
            idx = jnp.pad(
                idxg, ((0, 0), (0, pad), (0, 0)), constant_values=pad_bin
            ).reshape(-1, N)
    Np = _round_up(N, bn)
    idx = jnp.pad(idx, ((0, 0), (0, Np - N))) if Np != N else idx
    return idx, codebook, N


def _pad_operands(x, idx, codebook, bm, bn, gs_pad, packed):
    """Pad (x, idx, codebook) to the tile plan; returns logical (M, N, Kp).

    M/N padding is plain zero padding (sliced off the output); K padding is
    :func:`_pad_weight_operands` plus matching zero rows in ``x`` so padded
    positions are doubly inert.
    """
    M, K = x.shape
    G = codebook.shape[0]
    gs = K // G
    idx, codebook, N = _pad_weight_operands(idx, codebook, bn, gs_pad, packed)
    if gs_pad != gs:
        x = jnp.pad(x.reshape(M, G, gs), ((0, 0), (0, 0), (0, gs_pad - gs)))
        x = x.reshape(M, G * gs_pad)
        K = G * gs_pad
    Mp = _round_up(M, bm)
    x = jnp.pad(x, ((0, Mp - M), (0, 0))) if Mp != M else x
    return x, idx, codebook, (M, N, K)


@functools.partial(
    jax.jit,
    static_argnames=(
        "packed", "logical_k", "gather", "interpret", "use_ref", "relu", "pool"
    ),
)
def _pasm_matmul_fwd_impl(
    x, idx, codebook, bias=None, *, packed, logical_k, gather, interpret, use_ref,
    relu=False, pool=1,
):
    if use_ref:
        y = _ref.pasm_matmul_ref(x, idx, codebook, packed=packed)
        return _ref.max_pool_rows(_ref.apply_epilogue(y, bias, relu), pool)
    G, B = codebook.shape
    group_size = logical_k // G
    bm, bn, bk, gs_pad = _pick_blocks(
        x.shape[0], logical_k, idx.shape[1], group_size, packed
    )
    bm = _pool_bm(bm, pool)
    xp, idxp, cbp, (M, N, Kp) = _pad_operands(x, idx, codebook, bm, bn, gs_pad, packed)
    bias_row = None
    if bias is not None:
        bias_row = jnp.pad(bias.astype(jnp.float32), (0, idxp.shape[1] - N))
        bias_row = bias_row.reshape(1, -1)
    out = pasm_matmul_kernel_call(
        xp,
        idxp,
        cbp,
        bias_row,
        packed=packed,
        logical_k=Kp,
        bm=bm,
        bn=bn,
        bk=bk,
        gather=gather,
        relu=relu,
        pool=pool,
        interpret=interpret,
    )
    return out[: M // (pool * pool), :N]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _pasm_matmul(x, idx, codebook, packed, gather, interpret):
    logical_k = x.shape[-1]
    return _pasm_matmul_fwd_impl(
        x,
        idx,
        codebook,
        packed=packed,
        logical_k=logical_k,
        gather=gather,
        interpret=interpret,
        use_ref=False,
    )


def _pasm_fwd(x, idx, codebook, packed, gather, interpret):
    return _pasm_matmul(x, idx, codebook, packed, gather, interpret), (x, idx, codebook)


def _pasm_bwd(packed, gather, interpret, res, g):
    x, idx, codebook = res
    w = _ref.dequant_ref(idx, codebook, packed=packed).astype(x.dtype)
    dx = jnp.dot(g.astype(x.dtype), w.T)
    # codebook grad: Σ of (xᵀg) entries binned by index — the PAS identity on
    # the backward pass.  idx gets no gradient (integer).
    xg = jnp.dot(x.T.astype(jnp.float32), g.astype(jnp.float32))  # (K, N)
    li = _pasm.unpack_int4(idx) if packed else idx
    K, N = li.shape
    G, B = codebook.shape
    seg = li.reshape(G, K // G, N).astype(jnp.int32)
    xgg = xg.reshape(G, K // G, N)
    dcb = jax.vmap(
        lambda s, v: jax.ops.segment_sum(v.reshape(-1), s.reshape(-1), num_segments=B)
    )(seg, xgg)
    return dx, None, dcb.astype(codebook.dtype)


_pasm_matmul.defvjp(_pasm_fwd, _pasm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _pasm_matmul_ep(x, idx, codebook, bias, packed, gather, interpret, relu, pool):
    """The fused-epilogue variant: bias/ReLU (and the ``pool`` max-reduce
    over window-major rows) applied inside the kernel."""
    return _pasm_matmul_fwd_impl(
        x,
        idx,
        codebook,
        bias,
        packed=packed,
        logical_k=x.shape[-1],
        gather=gather,
        interpret=interpret,
        use_ref=False,
        relu=relu,
        pool=pool,
    )


def _pasm_ep_fwd(x, idx, codebook, bias, packed, gather, interpret, relu, pool):
    y = _pasm_matmul_ep(x, idx, codebook, bias, packed, gather, interpret, relu,
                        pool)
    # y is a residual only for the ReLU mask (pool == 1: the pooled output
    # can't recover the pre-pool mask — the backward recomputes it instead)
    return y, (x, idx, codebook, bias, y if relu and pool == 1 else None)


def _pasm_ep_bwd(packed, gather, interpret, relu, pool, res, g):
    x, idx, codebook, bias, y = res
    if pool > 1:
        # the fused forward never materializes the pre-pool activations —
        # recompute them and route g through the pool argmax + ReLU masks
        # (max_pool_rows' own VJP defines the argmax routing)
        w = _ref.dequant_ref(idx, codebook, packed=packed).astype(x.dtype)
        y_lin = jnp.dot(x, w, preferred_element_type=jnp.float32)
        _, vjp_post = jax.vjp(
            lambda yl: _ref.max_pool_rows(_ref.apply_epilogue(yl, bias, relu),
                                          pool),
            y_lin,
        )
        g, = vjp_post(g)
    elif relu:
        g = g * (y > 0).astype(g.dtype)  # mask through the fused ReLU
    dx, _, dcb = _pasm_bwd(packed, gather, interpret, (x, idx, codebook), g)
    dbias = g.sum(axis=0).astype(bias.dtype)
    return dx, None, dcb, dbias


_pasm_matmul_ep.defvjp(_pasm_ep_fwd, _pasm_ep_bwd)


def pasm_matmul(
    x: jax.Array,
    t: _pasm.PASMTensor,
    *,
    bias: Optional[jax.Array] = None,
    relu: bool = False,
    gather: str = "take",
    interpret: Optional[bool] = None,
    mesh=None,
    pool: int = 1,
) -> jax.Array:
    """``x @ t`` with the fused dequant kernel.  x: (..., K) → (..., N) f32.

    ``bias (N,)`` / ``relu`` fuse into the kernel's last-k-step write-through
    (one pallas_call per layer, no XLA epilogue).  Differentiable in ``x``,
    ``t.codebook`` and ``bias``.  With ``mesh=`` the rows shard over
    ``data`` (M padded up to the axis size when uneven) and N over ``model``
    when divisible — bit-exact vs the single-device call.

    ``pool > 1`` fuses a non-overlapping max-pool into the same
    write-through: ``x`` must be 2-D with **window-major** rows (each
    consecutive ``pool²`` rows one pool window — the explicit conv path's
    ``_pool_order_patches`` ordering) and the result is the pooled
    ``(M/pool², N)``.  Under ``mesh=`` the window-major rows must split
    over ``data`` in whole pool windows (``conv2d`` guarantees this by
    padding the batch to divide the axis — each image's ``P_rows`` rows are
    a multiple of ``pool²``, so shard boundaries land between windows and
    the explicit engines fuse pooling under a mesh too).
    """
    if interpret is None:
        interpret = _interpret_default()
    K, N = t.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, K)
    if pool > 1:
        nd = _mesh_sizes(mesh)[0] if mesh is not None else 1
        _check_pool_operand(x, pool, mesh, nd)
        b = jnp.zeros((N,), jnp.float32) if bias is None else bias
        if mesh is not None:
            return _shard_gemm(
                mesh, N,
                lambda xl, il, cl, bl: _pasm_matmul_ep(
                    xl, il, cl, bl, t.packed, gather, interpret, relu, pool
                ),
                (x2, t.idx, t.codebook), x_rank=2, out_rank=2, bias=b,
            )
        return _pasm_matmul_ep(
            x2, t.idx, t.codebook, b, t.packed, gather, interpret, relu, pool
        )
    if mesh is not None:
        nd, _ = _mesh_sizes(mesh)
        M = x2.shape[0]
        pad_m = -M % nd
        if pad_m:
            x2 = jnp.pad(x2, ((0, pad_m), (0, 0)))
        if bias is None and not relu:
            y = _shard_gemm(
                mesh, N,
                lambda xl, il, cl: _pasm_matmul(
                    xl, il, cl, t.packed, gather, interpret
                ),
                (x2, t.idx, t.codebook), x_rank=2, out_rank=2,
            )
        else:
            b = jnp.zeros((N,), jnp.float32) if bias is None else bias
            y = _shard_gemm(
                mesh, N,
                lambda xl, il, cl, bl: _pasm_matmul_ep(
                    xl, il, cl, bl, t.packed, gather, interpret, relu, 1
                ),
                (x2, t.idx, t.codebook), x_rank=2, out_rank=2, bias=b,
            )
        return y[:M].reshape(*lead, N)
    if bias is None and not relu:
        y = _pasm_matmul(x2, t.idx, t.codebook, t.packed, gather, interpret)
    else:
        b = jnp.zeros((N,), jnp.float32) if bias is None else bias
        y = _pasm_matmul_ep(
            x2, t.idx, t.codebook, b, t.packed, gather, interpret, relu, 1
        )
    return y.reshape(*lead, N)


@functools.partial(jax.jit, static_argnames=("relu", "pool", "interpret"))
def _pas_matmul_impl(x, idx, codebook, bias=None, *, relu=False, pool=1,
                     interpret):
    M, K = x.shape
    N = idx.shape[1]
    bm, bn, bk, gs_pad = _pick_blocks(M, K, N, K, packed=False)
    bm = _pool_bm(bm, pool)
    xp, idxp, cbp, (M, N, _) = _pad_operands(
        x, idx, codebook, bm, bn, gs_pad, packed=False
    )
    bias_row = None
    if bias is not None:
        bias_row = jnp.pad(bias.astype(jnp.float32), (0, idxp.shape[1] - N))
        bias_row = bias_row.reshape(1, -1)
    out = pas_matmul_kernel_call(
        xp, idxp, cbp, bias_row, bm=bm, bn=bn, bk=bk, relu=relu, pool=pool,
        interpret=interpret,
    )
    return out[: M // (pool * pool), :N]


def pas_matmul(
    x: jax.Array,
    t: _pasm.PASMTensor,
    *,
    bias: Optional[jax.Array] = None,
    relu: bool = False,
    interpret: Optional[bool] = None,
    mesh=None,
    pool: int = 1,
) -> jax.Array:
    """Paper-faithful PASM two-phase matmul (single dictionary).

    ``bias (N,)`` / ``relu`` fuse into the post-pass write-through, and
    ``pool > 1`` max-reduces window-major row groups there too (2-D x only,
    whole windows per ``data`` shard — same contract as
    :func:`pasm_matmul`).  With ``mesh=`` rows shard over ``data``, N over
    ``model`` when divisible; the in-kernel PAS bin counters are per-shard
    VMEM scratch, so they replicate with the kernel itself.
    """
    if interpret is None:
        interpret = _interpret_default()
    idx = _pasm.logical_idx(t)
    K, N = t.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, K)
    if pool > 1:
        nd = _mesh_sizes(mesh)[0] if mesh is not None else 1
        _check_pool_operand(x, pool, mesh, nd)
        if mesh is not None:
            if bias is None:
                return _shard_gemm(
                    mesh, N,
                    lambda xl, il, cl: _pas_matmul_impl(
                        xl, il, cl, relu=relu, pool=pool, interpret=interpret
                    ),
                    (x2, idx, t.codebook), x_rank=2, out_rank=2,
                )
            return _shard_gemm(
                mesh, N,
                lambda xl, il, cl, bl: _pas_matmul_impl(
                    xl, il, cl, bl, relu=relu, pool=pool, interpret=interpret
                ),
                (x2, idx, t.codebook), x_rank=2, out_rank=2, bias=bias,
            )
        return _pas_matmul_impl(
            x2, idx, t.codebook, bias, relu=relu, pool=pool, interpret=interpret
        )
    if mesh is not None:
        nd, _ = _mesh_sizes(mesh)
        M = x2.shape[0]
        pad_m = -M % nd
        if pad_m:
            x2 = jnp.pad(x2, ((0, pad_m), (0, 0)))
        if bias is None:
            y = _shard_gemm(
                mesh, N,
                lambda xl, il, cl: _pas_matmul_impl(
                    xl, il, cl, relu=relu, interpret=interpret
                ),
                (x2, idx, t.codebook), x_rank=2, out_rank=2,
            )
        else:
            y = _shard_gemm(
                mesh, N,
                lambda xl, il, cl, bl: _pas_matmul_impl(
                    xl, il, cl, bl, relu=relu, interpret=interpret
                ),
                (x2, idx, t.codebook), x_rank=2, out_rank=2, bias=bias,
            )
        return y[:M].reshape(*lead, N)
    y = _pas_matmul_impl(x2, idx, t.codebook, bias, relu=relu, interpret=interpret)
    return y.reshape(*lead, N)


# ---------------------------------------------------------------------------
# implicit-GEMM convolution (no materialized patch matrix)
# ---------------------------------------------------------------------------


def _pad_image(x, geom: ConvGeom):
    """Apply the spatial zero-pad of ``geom`` to an image batch (SAME halo)."""
    ph, pw = geom.pad
    if any(ph) or any(pw):
        cfg = ((0, 0), ph, pw, (0, 0)) if geom.nhwc else ((0, 0), (0, 0), ph, pw)
        x = jnp.pad(x, cfg)
    return x


def _geom_patches(x, geom: ConvGeom):
    """Explicit im2col from a :class:`ConvGeom` — backward/oracle use ONLY.

    The forward implicit path never materializes this ``(B·P, K)`` matrix;
    only the custom VJP does (col2im backward, per the initial
    implicit-GEMM scope).  Delegates to the one shared gather definition.
    """
    return _ref.im2col_patches(
        x, nhwc=geom.nhwc, ky=geom.ky, kx=geom.kx, stride=geom.stride,
        oh=geom.oh, ow=geom.ow, c_in=geom.c_in, pad=geom.pad,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "geom", "packed", "gather", "interpret", "relu", "use_pas",
        "vmem_budget",
    ),
)
def _conv_fwd_impl(
    x, idx, codebook, bias=None, *, geom, packed, gather="take", interpret=False,
    relu=False, use_pas=False, vmem_budget=None,
):
    """Shared implicit-conv forward: tile plan + weight padding + kernel call.

    The reduction tiling (``bn``/``bk``/``gs_pad``) is a pure function of
    K/N/groups in :func:`_pick_blocks`, so the implicit kernel walks the
    exact k-tile sequence of the explicit path — that is what makes it
    bit-exact against explicit im2col.  Only ``bm`` differs: it is picked
    from the *per-image* row count (the conv grid is per-image), so small-P
    layers don't pad each image's output up to a batch-derived 128 rows.
    ``geom.pool > 1`` switches the rows to window-major (``geom.P_rows``)
    and aligns ``bm`` to whole pool windows — the k-tile sequence is
    untouched, so the fused pool stays bit-exact vs conv + reduce_window.

    Images whose double-buffered whole-image footprint exceeds
    ``vmem_budget`` stream through the kernel as row-band slabs
    (:func:`conv_slab_plan`): the padded image is sliced/zero-padded to the
    plan's ``rows_total`` (sliced rows are provably never gathered — the
    bottom band covers the last output row's receptive field; padded rows
    are only replayed by clamped M-pad windows) and the kernel's image
    operand becomes the double-buffered band(+halo) pair.  The GEMM
    schedule is untouched, so slabbed output is bit-exact too.
    """
    G, _ = codebook.shape
    K = idx.shape[0] * (2 if packed else 1)
    N = idx.shape[1]
    P = geom.P_rows
    gs = K // G
    bm, bn, bk, gs_pad = _pick_blocks(P, K, N, gs, packed)
    bm = _pool_bm(bm, geom.pool)
    idxp, cbp, _ = _pad_weight_operands(idx, codebook, bn, gs_pad, packed)
    xp = _pad_image(x, geom)
    rows_ax = 1 if geom.nhwc else 2
    hp = xp.shape[rows_ax]
    wp = xp.shape[2 if geom.nhwc else 3]
    slab = conv_slab_plan(
        geom, hp, wp, bm=bm, bn=bn, bk=bk, bins=codebook.shape[1],
        packed=packed, pas=use_pas, has_bias=bias is not None,
        vmem_budget=vmem_budget,
    )
    if slab.n_slabs > 1 and slab.rows_total != hp:
        if slab.rows_total < hp:
            xp = jax.lax.slice_in_dim(xp, 0, slab.rows_total, axis=rows_ax)
        else:
            cfg = [(0, 0)] * 4
            cfg[rows_ax] = (0, slab.rows_total - hp)
            xp = jnp.pad(xp, cfg)
    bias_row = None
    if bias is not None:
        bias_row = jnp.pad(bias.astype(jnp.float32), (0, idxp.shape[1] - N))
        bias_row = bias_row.reshape(1, -1)
    if use_pas:
        out = pas_conv_kernel_call(
            xp, idxp, cbp, bias_row, geom=geom, gs=gs, gs_pad=gs_pad,
            bm=bm, bn=bn, bk=bk, relu=relu, slab=slab, interpret=interpret,
        )
    else:
        out = pasm_conv_kernel_call(
            xp, idxp, cbp, bias_row, geom=geom, packed=packed, gs=gs,
            gs_pad=gs_pad, bm=bm, bn=bn, bk=bk, gather=gather, relu=relu,
            slab=slab, interpret=interpret,
        )
    return out[:, : geom.P_out, :N]


def _pool_rowmajor_ref(y, geom, batch):
    """Row-major conv output ``(B·P, N) → (B·P_out, N)`` pooled reference.

    The backward's oracle for the fused pool: floor-crops to whole windows,
    max-reduces each ``(pool, pool)`` window.  The pooled VJPs differentiate
    through this, so ``jnp.max``'s own VJP defines the argmax cotangent
    routing (remainder rows/cols the fused kernel never computes get zero).
    """
    p = geom.pool
    N = y.shape[-1]
    yb = y.reshape(batch, geom.oh, geom.ow, N)
    yb = yb[:, : geom.ohp * p, : geom.owp * p]
    yb = yb.reshape(batch, geom.ohp, p, geom.owp, p, N)
    return yb.max(axis=(2, 4)).reshape(batch * geom.P_out, N)


def _conv_bwd_core(geom, packed, gather, interpret, relu, res, g):
    """Backward through the implicit conv via explicit col2im (initial scope):
    materialize patches, reuse the GEMM VJP, scatter back through im2colᵀ.

    With ``geom.pool > 1`` the fused forward never stores the pre-pool
    activations, so they are recomputed here (patches @ w + epilogue) and
    ``g`` routes through the pool argmax + ReLU masks before the GEMM VJP.
    The returned cotangent ``g2`` is always the one at the *linear* conv
    output, so the caller's ``dbias = g2.sum(axis=0)`` holds on both paths.
    """
    x, idx, codebook, bias, y = res
    g2 = g.reshape(-1, g.shape[-1])
    K = idx.shape[0] * (2 if packed else 1)
    patches, vjp_patch = jax.vjp(
        functools.partial(_geom_patches, geom=geom), x
    )
    if K != geom.conv_k:  # §3 pack-time K-pad rows carry zero activations
        patches = jnp.pad(patches, ((0, 0), (0, K - geom.conv_k)))
    if geom.pool > 1:
        w = _ref.dequant_ref(idx, codebook, packed=packed).astype(patches.dtype)
        y_lin = jnp.dot(patches, w, preferred_element_type=jnp.float32)
        _, vjp_post = jax.vjp(
            lambda yl: _pool_rowmajor_ref(
                _ref.apply_epilogue(yl, bias, relu), geom, x.shape[0]
            ),
            y_lin,
        )
        g2, = vjp_post(g2)
    elif relu:
        g2 = g2 * (y.reshape(g2.shape) > 0).astype(g2.dtype)
    dp, _, dcb = _pasm_bwd(packed, gather, interpret, (patches, idx, codebook), g2)
    dx, = vjp_patch(dp[:, : geom.conv_k])
    return dx, dcb, g2


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _pasm_conv(x, idx, codebook, geom, packed, gather, interpret, vmem_budget):
    return _conv_fwd_impl(
        x, idx, codebook, geom=geom, packed=packed, gather=gather,
        interpret=interpret, vmem_budget=vmem_budget,
    )


def _pasm_conv_fwd(x, idx, codebook, geom, packed, gather, interpret,
                   vmem_budget):
    y = _pasm_conv(x, idx, codebook, geom, packed, gather, interpret,
                   vmem_budget)
    return y, (x, idx, codebook)


def _pasm_conv_bwd(geom, packed, gather, interpret, vmem_budget, res, g):
    x, idx, codebook = res
    dx, dcb, _ = _conv_bwd_core(
        geom, packed, gather, interpret, False, (x, idx, codebook, None, None), g
    )
    return dx, None, dcb


_pasm_conv.defvjp(_pasm_conv_fwd, _pasm_conv_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _pasm_conv_ep(x, idx, codebook, bias, geom, packed, gather, interpret,
                  relu, vmem_budget):
    """The fused-epilogue implicit conv: bias/ReLU applied inside the kernel."""
    return _conv_fwd_impl(
        x, idx, codebook, bias, geom=geom, packed=packed, gather=gather,
        interpret=interpret, relu=relu, vmem_budget=vmem_budget,
    )


def _pasm_conv_ep_fwd(x, idx, codebook, bias, geom, packed, gather, interpret,
                      relu, vmem_budget):
    y = _pasm_conv_ep(x, idx, codebook, bias, geom, packed, gather, interpret,
                      relu, vmem_budget)
    # y is a residual only for the ReLU mask (and only when unpooled — the
    # pooled output can't recover the pre-pool mask; the backward recomputes)
    return y, (x, idx, codebook, bias, y if relu and geom.pool == 1 else None)


def _pasm_conv_ep_bwd(geom, packed, gather, interpret, relu, vmem_budget, res,
                      g):
    x, idx, codebook, bias, y = res
    dx, dcb, g2 = _conv_bwd_core(
        geom, packed, gather, interpret, relu, (x, idx, codebook, bias, y), g
    )
    dbias = g2.sum(axis=0).astype(bias.dtype)
    return dx, None, dcb, dbias


_pasm_conv_ep.defvjp(_pasm_conv_ep_fwd, _pasm_conv_ep_bwd)


def pasm_conv2d(
    x: jax.Array,
    t: _pasm.PASMTensor,
    geom: ConvGeom,
    *,
    bias: Optional[jax.Array] = None,
    relu: bool = False,
    gather: str = "take",
    interpret: Optional[bool] = None,
    mesh=None,
    vmem_budget: Optional[int] = None,
    gather_output: bool = True,
) -> jax.Array:
    """Implicit-GEMM conv on the fused-dequant kernel: ``(B, img) → (B, P, N)``.

    One ``pallas_call`` over the (spatially padded) image batch — the im2col
    patch tiles are assembled inside the kernel, so no ``(B·P, K)`` patch
    matrix exists in HBM.  ``bias (N,)`` / ``relu`` fuse into the last-k-step
    write-through exactly as in :func:`pasm_matmul`, and ``geom.pool > 1``
    additionally max-reduces each ``(pool, pool)`` output window there — the
    whole conv/ReLU/pool stage is ONE pallas_call and the pre-pool
    activations never touch HBM.  Differentiable in ``x``, ``t.codebook``
    and ``bias`` (the backward pass materializes patches explicitly — col2im
    — and recomputes the pre-pool map for the argmax routing, for now).
    Pool windows live inside single images, so the fused pool shards over
    ``data`` unchanged.  With ``mesh=`` the image batch
    shards over ``data`` (the batch must already divide the axis — the
    ``conv2d`` front-end pads uneven remainders) and N over ``model`` when
    divisible; each shard derives its tile plan from the local shapes, and
    ``gather_output=True`` (the default) all-gathers N inside the sharded
    body so the returned activations are model-replicated — consecutive
    sharded conv layers see no XLA resharding between their pallas_calls.
    ``vmem_budget`` bounds the per-slab image footprint: images whose
    double-buffered whole-image residency would blow the budget stream as
    row-band slabs (:func:`conv_slab_plan`), bit-exact vs whole-image.
    """
    if interpret is None:
        interpret = _interpret_default()
    if mesh is not None:
        nd, _ = _mesh_sizes(mesh)
        if x.shape[0] % nd:
            raise ValueError(
                f"batch {x.shape[0]} does not divide the data axis ({nd}); "
                "pad the batch first (conv2d(mesh=) handles the remainder)"
            )
        if bias is None and not relu and geom.pool == 1:
            return _shard_gemm(
                mesh, t.shape[1],
                lambda xl, il, cl: _pasm_conv(
                    xl, il, cl, geom, t.packed, gather, interpret, vmem_budget
                ),
                (x, t.idx, t.codebook), x_rank=4, out_rank=3,
                gather_output=gather_output,
            )
        b = jnp.zeros((t.shape[1],), jnp.float32) if bias is None else bias
        return _shard_gemm(
            mesh, t.shape[1],
            lambda xl, il, cl, bl: _pasm_conv_ep(
                xl, il, cl, bl, geom, t.packed, gather, interpret, relu,
                vmem_budget,
            ),
            (x, t.idx, t.codebook), x_rank=4, out_rank=3, bias=b,
            gather_output=gather_output,
        )
    # geom.pool > 1 always rides the epilogue variant: its VJP owns the
    # pooled (argmax-routed) backward
    if bias is None and not relu and geom.pool == 1:
        return _pasm_conv(
            x, t.idx, t.codebook, geom, t.packed, gather, interpret, vmem_budget
        )
    b = jnp.zeros((t.shape[1],), jnp.float32) if bias is None else bias
    return _pasm_conv_ep(
        x, t.idx, t.codebook, b, geom, t.packed, gather, interpret, relu,
        vmem_budget,
    )


def pas_conv2d(
    x: jax.Array,
    t: _pasm.PASMTensor,
    geom: ConvGeom,
    *,
    bias: Optional[jax.Array] = None,
    relu: bool = False,
    interpret: Optional[bool] = None,
    mesh=None,
    vmem_budget: Optional[int] = None,
    gather_output: bool = True,
) -> jax.Array:
    """Implicit-GEMM conv on the paper-faithful two-phase PAS formulation.

    Single dictionary, forward-only — mirrors :func:`pas_matmul` (and its
    ``mesh=`` sharding: batch over ``data``, N over ``model`` when
    divisible, per-shard bin counters).  ``vmem_budget`` /
    ``gather_output`` behave exactly as in :func:`pasm_conv2d`.
    """
    if interpret is None:
        interpret = _interpret_default()
    idx = _pasm.logical_idx(t)
    if mesh is not None:
        nd, _ = _mesh_sizes(mesh)
        if x.shape[0] % nd:
            raise ValueError(
                f"batch {x.shape[0]} does not divide the data axis ({nd}); "
                "pad the batch first (conv2d(mesh=) handles the remainder)"
            )
        if bias is None:
            return _shard_gemm(
                mesh, t.shape[1],
                lambda xl, il, cl: _conv_fwd_impl(
                    xl, il, cl, geom=geom, packed=False, interpret=interpret,
                    relu=relu, use_pas=True, vmem_budget=vmem_budget,
                ),
                (x, idx, t.codebook), x_rank=4, out_rank=3,
                gather_output=gather_output,
            )
        return _shard_gemm(
            mesh, t.shape[1],
            lambda xl, il, cl, bl: _conv_fwd_impl(
                xl, il, cl, bl, geom=geom, packed=False, interpret=interpret,
                relu=relu, use_pas=True, vmem_budget=vmem_budget,
            ),
            (x, idx, t.codebook), x_rank=4, out_rank=3, bias=bias,
            gather_output=gather_output,
        )
    return _conv_fwd_impl(
        x, idx, t.codebook, bias, geom=geom, packed=False, interpret=interpret,
        relu=relu, use_pas=True, vmem_budget=vmem_budget,
    )


# ---------------------------------------------------------------------------
# roofline bookkeeping helpers
# ---------------------------------------------------------------------------


def matmul_flops(M: int, K: int, N: int) -> int:
    return 2 * M * K * N


def pasm_hbm_bytes(t: _pasm.PASMTensor, M: int, act_bytes: int = 2) -> int:
    """Bytes one (M,K)@(K,N) PASM matmul actually moves: x + idx + cb + out.

    Tile-plan aware (audited against :attr:`PASMTensor.nbytes_weights`): the
    kernel streams the *padded* operands, so shapes that route through the §3
    K-pad move ``G·gs_pad`` reduction rows (plus one reserved codebook bin per
    group), and M/N round up to the block plan.  The output is written f32
    (4 B) — the seed counted it at ``act_bytes``, under-reporting the store
    traffic.  On tile-aligned shapes the weight term equals
    ``t.nbytes_weights`` exactly.
    """
    K, N = t.shape
    G, B = t.codebook.shape
    bm, bn, bk, gs_pad = _pick_blocks(M, K, N, K // G, t.packed)
    Kp = G * gs_pad
    Mp, Np = _round_up(M, bm), _round_up(N, bn)
    idx_bytes = (Kp // 2 if t.packed else Kp) * Np
    padded_k = gs_pad != K // G
    cb_bytes = G * (B + (1 if padded_k and not t.packed and B < 256 else 0)) * 4
    return Mp * Kp * act_bytes + idx_bytes + cb_bytes + Mp * Np * 4


def conv_hbm_bytes(
    t: _pasm.PASMTensor,
    geom: ConvGeom,
    batch: int,
    ih: int,
    iw: int,
    *,
    implicit: bool,
    act_bytes: int = 4,
    shards: tuple = (1, 1),
    vmem_budget: Optional[int] = None,
) -> int:
    """Modeled HBM bytes of one conv layer on the PASM GEMM, tile-plan aware.

    ``implicit=False`` (explicit im2col): the ``(B·P, K)`` patch matrix is
    *written* by the XLA front-end and *read back* by the kernel — the
    activation term is twice the padded patch-matrix bytes, inflating input
    traffic by up to ``ky·kx/stride²`` over the raw image.

    ``implicit=True``: the padded image streams once per reuse window (each
    image block or row-band slab stays VMEM-resident across its whole tile
    loop), so the activation term is the slab plan's **fetched rows**
    (:attr:`SlabPlan.fetched_rows` — the padded image bytes when the whole
    image fits ``vmem_budget`` double-buffered, else ``n_slabs·(band+halo)``
    rows, the halo re-fetched once per seam).  Weight/codebook/output terms
    follow the same padded-operand accounting as :func:`pasm_hbm_bytes`.
    The logical-shape (plan-free) counterpart is
    :func:`repro.core.hwmodel.conv_hbm_traffic`.

    ``shards=(n_data, n_model)`` models the **per-device** bytes of the
    sharded path: the batch splits over ``data`` (uneven remainders round up
    — the padded images are real traffic), N over ``model`` when divisible
    (else the weights replicate, per the sharded dispatch rule), and the
    codebook replicates on every device.  The tile plan is recomputed from
    the local shapes, exactly as each shard does.

    ``geom.pool > 1`` models the **fused conv/ReLU/max-pool stage**: the
    GEMM walks the window-major ``P_rows`` (floor-remainder pixels never
    computed) and the store shrinks to the pooled ``P_out`` map — the
    pre-pool activations never touch HBM, which is exactly the bytes the
    separate ``reduce_window`` pass would have re-read and re-written.
    """
    K, N = t.shape
    G, B = t.codebook.shape
    P = geom.P_rows
    pw = geom.pool * geom.pool
    n_data, n_model = shards
    batch = -(-batch // n_data)  # per-device share, remainder rounded up
    if n_model > 1 and N % n_model == 0:
        N = N // n_model
    # bm mirrors the kernels: per-image rows on the implicit grid, batch-wide
    # rows explicit, aligned to whole pool windows when the pool is fused
    bm, bn, bk, gs_pad = _pick_blocks(
        P if implicit else batch * P, K, N, K // G, t.packed
    )
    bm = _pool_bm(bm, geom.pool)
    Kp = G * gs_pad
    Np = _round_up(N, bn)
    idx_bytes = (Kp // 2 if t.packed else Kp) * Np
    padded_k = gs_pad != K // G
    cb_bytes = G * (B + (1 if padded_k and not t.packed and B < 256 else 0)) * 4
    if implicit:
        (plh, phh), (plw, phw) = geom.pad
        hp, wp = ih + plh + phh, iw + plw + phw
        plan = conv_slab_plan(
            geom, hp, wp, bm=bm, bn=bn, bk=bk, bins=B, packed=t.packed,
            pas=False, has_bias=True, vmem_budget=vmem_budget,
        )
        x_bytes = batch * geom.c_in * plan.fetched_rows * wp * act_bytes
        out_bytes = batch * _round_up(geom.P_out, bm // pw) * Np * 4
    else:
        Mp = _round_up(batch * P, bm)
        x_bytes = 2 * Mp * Kp * act_bytes  # im2col store + kernel stream
        out_bytes = (Mp // pw) * Np * 4
    return x_bytes + idx_bytes + cb_bytes + out_bytes


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused flash attention.  q (B,Sq,H,hd); k,v (B,Sk,KV,hd) → (B,Sq,H,hd).

    GQA: query heads are regrouped under their KV head so each K/V tile is
    read once per group.  Pads Sq/Sk to tile multiples (pad keys masked).
    """
    from repro.kernels.flash_attention import flash_attention_kernel_call

    if interpret is None:
        interpret = _interpret_default()
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    bq = min(bq, max(8, 1 << (Sq - 1).bit_length()))
    bk = min(bk, max(8, 1 << (Sk - 1).bit_length()))
    Sqp, Skp = _round_up(Sq, bq), _round_up(Sk, bk)
    qg = jnp.moveaxis(q.reshape(B, Sq, KV, G, hd), 1, 3)  # (B, KV, G, Sq, hd)
    qg = qg.reshape(B * KV, G, Sq, hd)
    kg = jnp.moveaxis(k, 1, 2).reshape(B * KV, Sk, hd)
    vg = jnp.moveaxis(v, 1, 2).reshape(B * KV, Sk, hd)
    if Sqp != Sq:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    if Skp != Sk:
        kg = jnp.pad(kg, ((0, 0), (0, Skp - Sk), (0, 0)))
        vg = jnp.pad(vg, ((0, 0), (0, Skp - Sk), (0, 0)))
    o = flash_attention_kernel_call(
        qg, kg, vg, causal=causal, sk_orig=Sk, bq=bq, bk=bk, interpret=interpret
    )
    o = o[:, :, :Sq].reshape(B, KV, G, Sq, hd)
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd)
