"""Version shims for the Pallas TPU API surface.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` in newer JAX;
kernel modules import :data:`CompilerParams` from here so the same source runs
on both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["CompilerParams"]

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
