"""Pallas TPU kernel: the paper-faithful PASM two-phase GEMM.

This is the literal TPU mapping of the PASM circuit (paper §2.2):

  PAS phase    — per k-tile, image values are accumulated into ``B`` bin
                 accumulators that live in a VMEM scratch block
                 (``S[m, n, b] += x[m, k]·[idx[k, n] = b]``); the bin
                 accumulators are the VMEM analogue of the PAS register file.
  post-pass    — at the *last* k step only, one multiply per bin folds the
                 codebook in: ``y[m, n] = Σ_b S[m, n, b]·cb[b]`` — the
                 "shared post-pass MAC" of the paper, amortized over the
                 whole reduction.

The PAS phase is expressed as ``x_tile @ one_hot(idx_tile)`` so it runs on
the MXU, but the one-hot expansion makes it cost ``B×`` the MACs of a direct
product — on a fixed systolic array the paper's gate-level win does not
transfer (DESIGN.md §2).  This kernel exists to (a) demonstrate the faithful
formulation end-to-end, (b) let benchmarks *measure* that trade-off against
``pasm_matmul`` instead of assuming it.

VMEM budget: scratch ``(bm, bn, B)`` f32 = 128·128·16·4 = 1 MiB at defaults.

:func:`pas_conv_kernel_call` is the implicit-GEMM conv variant: the ``x``
operand is the raw padded image batch and the ``(bm, bk)`` patch tile is
assembled in VMEM by :func:`repro.kernels.pasm_matmul.patch_tile` — same PAS
phase and post-pass, no ``(B·P, K)`` patch matrix in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.kernels.pasm_matmul import (
    ConvGeom,
    SlabPlan,
    _image_specs,
    _slab_image,
    patch_tile,
)
from repro.kernels.ref import max_pool_rows

__all__ = ["pas_matmul_kernel_call", "pas_conv_kernel_call"]


def _pas_step(
    x_tile, idx_ref, cb_ref, b_ref, o_ref, s_ref, *, k, n_k: int, bins: int,
    relu: bool, pool: int = 1,
):
    """The shared per-k-step body of BOTH entry points: PAS-phase one-hot
    accumulate into the VMEM bin scratch, then the post-pass multiply (plus
    the fused bias/ReLU epilogue) at the last k step only.  ``o_ref`` may
    carry a leading length-1 batch axis (the conv grid).  ``pool > 1``
    max-reduces each group of ``pool²`` window-major rows in the post-pass
    write-through (the fused max-pool epilogue) — the bin scratch already
    holds the whole pre-pool block, so no extra accumulator is needed."""
    idx = idx_ref[...]  # (bk, bn)
    bm, bk = x_tile.shape
    bn = idx.shape[1]
    # PAS phase: one-hot selection network. (bk, bn, B) → (bk, bn·B) so the
    # accumulate runs as a single MXU matmul per tile.
    onehot = (idx[:, :, None] == jax.lax.broadcasted_iota(jnp.uint8, (1, 1, bins), 2))
    onehot = onehot.astype(x_tile.dtype).reshape(bk, bn * bins)
    s_ref[...] += jnp.dot(x_tile, onehot, preferred_element_type=jnp.float32).reshape(
        bm, bn, bins
    )

    # post-pass multiply: executed once, after all accumulation — B multiplies
    # per output element instead of K.  The bias/ReLU epilogue rides the same
    # write-through (the paper's shared post-pass MAC carries the bias too).
    @pl.when(k == n_k - 1)
    def _postpass():
        cb = cb_ref[0].astype(jnp.float32)  # (B,)
        y = jnp.einsum("mnb,b->mn", s_ref[...], cb)
        if b_ref is not None:
            y = y + b_ref[...]  # (1, bn) broadcasts over rows
        if relu:
            y = jnp.maximum(y, 0.0)
        o_ref[...] = max_pool_rows(y, pool).reshape(o_ref.shape)


def _kernel(x_ref, idx_ref, cb_ref, *rest, bins: int, n_k: int, relu: bool,
            pool: int):
    b_ref, o_ref, s_ref = rest if len(rest) == 3 else (None, *rest)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        s_ref[...] = jnp.zeros_like(s_ref)

    _pas_step(
        x_ref[...], idx_ref, cb_ref, b_ref, o_ref, s_ref,
        k=k, n_k=n_k, bins=bins, relu=relu, pool=pool,
    )


def pas_matmul_kernel_call(
    x: jax.Array,
    idx: jax.Array,
    codebook: jax.Array,
    bias: "jax.Array | None" = None,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    relu: bool = False,
    pool: int = 1,
    interpret: bool = False,
) -> jax.Array:
    """``x (M,K) · idx (K,N) · codebook (1,B) → (M,N) f32`` (single dictionary).

    Paper-faithful: one dictionary per layer (groups == 1).  ``bias (1, N)``
    and ``relu`` fuse into the post-pass; ``pool > 1`` expects window-major
    rows and max-reduces each ``pool²`` group there too, returning the
    pooled ``(M/pool², N)``.  Shape preconditions as for
    :func:`pasm_matmul_kernel_call`.
    """
    M, K = x.shape
    N = idx.shape[1]
    G, B = codebook.shape
    assert G == 1, "PAS-formulation kernel is paper-faithful: one dictionary"
    pw = pool * pool
    assert bm % pw == 0 and M % pw == 0, (bm, M, pool)
    n_k = K // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        pl.BlockSpec((1, B), lambda i, j, k: (0, 0)),
    ]
    operands = [x, idx, codebook]
    if bias is not None:
        assert bias.shape == (1, N), bias.shape
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        operands.append(bias)

    return pl.pallas_call(
        functools.partial(_kernel, bins=B, n_k=n_k, relu=relu, pool=pool),
        grid=(M // bm, N // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm // pw, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M // pw, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn, B), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)


def _conv_kernel(
    x_ref, *refs, geom: ConvGeom, bins: int, n_k: int,
    relu: bool, bm: int, bk: int, gs: int, gs_pad: int, slab=None,
):
    """Implicit-GEMM body: gather the patch tile instead of reading an
    explicit x block, then the same :func:`_pas_step`."""
    if slab is not None and slab.halo_rows:
        halo_ref, refs = refs[0], refs[1:]
    else:
        halo_ref = None
    idx_ref, cb_ref, *rest = refs
    b_ref, o_ref, s_ref = rest if len(rest) == 3 else (None, *rest)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _zero():
        s_ref[...] = jnp.zeros_like(s_ref)

    img, row0 = _slab_image(x_ref, halo_ref, geom, slab)
    patch = patch_tile(
        img, pl.program_id(1) * bm, k * bk,
        geom=geom, bm=bm, bk=bk, gs=gs, gs_pad=gs_pad, row0=row0,
    )
    _pas_step(
        patch, idx_ref, cb_ref, b_ref, o_ref, s_ref,
        k=k, n_k=n_k, bins=bins, relu=relu, pool=geom.pool,
    )


def pas_conv_kernel_call(
    x: jax.Array,
    idx: jax.Array,
    codebook: jax.Array,
    bias: "jax.Array | None" = None,
    *,
    geom: ConvGeom,
    gs: int,
    gs_pad: int,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    relu: bool = False,
    slab: "SlabPlan | None" = None,
    interpret: bool = False,
) -> jax.Array:
    """Implicit-GEMM conv on the paper-faithful two-phase formulation.

    ``x (B, img...)`` padded per ``geom`` · ``idx (Kp, Np)`` · ``codebook
    (1, B)`` → ``(B, Pp, Np) f32`` (real rows sliced by the caller; pooled
    when ``geom.pool > 1``, the fused max-pool epilogue riding the
    post-pass).  ``slab`` streams the image as double-buffered row bands
    exactly as in :func:`~repro.kernels.pasm_matmul.pasm_conv_kernel_call`.
    Single dictionary only, like :func:`pas_matmul_kernel_call`.
    """
    B_img = x.shape[0]
    G, B = codebook.shape
    assert G == 1, "PAS-formulation kernel is paper-faithful: one dictionary"
    Np = idx.shape[1]
    Kp = idx.shape[0]
    assert Kp == gs_pad and gs_pad % bk == 0, (Kp, gs_pad, bk)
    pw = geom.pool * geom.pool
    assert bm % pw == 0, (bm, geom.pool)
    bmp = bm // pw  # stored (pooled) rows per block
    n_k = Kp // bk
    Pp = (geom.P_out + bmp - 1) // bmp * bmp
    if slab is not None and slab.n_slabs == 1:
        slab = None  # single slab ≡ the legacy whole-image schedule

    img_specs, operands = _image_specs(x, geom, slab)
    in_specs = img_specs + [
        pl.BlockSpec((bk, bn), lambda b, i, j, k: (k, j)),
        pl.BlockSpec((1, B), lambda b, i, j, k: (0, 0)),
    ]
    operands = operands + [idx, codebook]
    if bias is not None:
        assert bias.shape == (1, Np), bias.shape
        in_specs.append(pl.BlockSpec((1, bn), lambda b, i, j, k: (0, j)))
        operands.append(bias)

    return pl.pallas_call(
        functools.partial(
            _conv_kernel, geom=geom, bins=B, n_k=n_k, relu=relu,
            bm=bm, bk=bk, gs=gs, gs_pad=gs_pad, slab=slab,
        ),
        grid=(B_img, Pp // bmp, Np // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bmp, bn), lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B_img, Pp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn, B), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)
