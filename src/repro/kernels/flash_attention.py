"""Pallas TPU kernel: fused flash attention (forward), GQA-aware.

The §Perf log identifies attention-chunk HBM round-trips as the dominant
residual memory term for the 32 k-prefill cells: the pure-XLA online-softmax
scan spills its (m, l, o) carries to HBM every KV block.  This kernel keeps
the whole running state in VMEM/VREG — HBM traffic collapses to one read of
Q/K/V and one write of O, the flash-attention bound.

Layout: q (BKV, G, Sq, hd) — query heads regrouped under their KV head so
K/V tiles are shared by the whole group; grid (BKV, G, Sq/bq) with the KV
sequence loop *inside* the kernel (fori over bk-sized slices of the VMEM-
resident K/V block).  Causal masking prunes fully-masked KV blocks via the
loop upper bound.

VMEM budget per program: K,V (Sk·hd bf16 ≈ 8 MiB each at 32 k × 128) +
q/acc tiles — within the ~128 MiB v5e VMEM for the assigned shapes; longer
contexts would tile K/V over a second grid axis (not needed for the 40 cells).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["flash_attention_kernel_call"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, sk: int, sk_orig: int,
            scale: float, causal: bool):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, hd)
    hd = q.shape[-1]
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    n_blocks = sk // bk
    if causal:
        # highest KV block any row of this q tile can see
        last = (qi + 1) * bq  # exclusive
        n_live = (last + bk - 1) // bk
        upper = jnp.minimum(n_blocks, n_live)
    else:
        upper = n_blocks

    def step(j, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(j * bk, bk), :].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = k_pos < sk_orig
        if causal:
            mask &= q_pos >= k_pos
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((bq, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, upper, step, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel_call(
    q: jax.Array,  # (BKV, G, Sq, hd)
    k: jax.Array,  # (BKV, Sk, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    sk_orig: int | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BKV, G, Sq, hd = q.shape
    Sk = k.shape[1]
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    scale = hd ** -0.5
    return pl.pallas_call(
        functools.partial(
            _kernel, bq=bq, bk=bk, sk=Sk, sk_orig=sk_orig or Sk, scale=scale,
            causal=causal,
        ),
        grid=(BKV, G, Sq // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, g, i: (b, g, i, 0)),
            pl.BlockSpec((1, Sk, hd), lambda b, g, i: (b, 0, 0)),
            pl.BlockSpec((1, Sk, hd), lambda b, g, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, g, i: (b, g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BKV, G, Sq, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")
        ),
        interpret=interpret,
    )(q, k, v)
