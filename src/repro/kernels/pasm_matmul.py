"""Pallas TPU kernel: codebook-dequant-fused matmul (the production PASM path).

``y = x @ W`` where ``W`` never exists in HBM: only ``log2(B)``-bit indices
(uint8, or two 4-bit indices packed per byte) plus a ``(G, B)`` codebook are
streamed.  Dequantization happens on the fly in VMEM, tile by tile — this is
the TPU adaptation of the paper's insight (DESIGN.md §2): HBM weight traffic
drops 4–8× versus bf16 weights, directly scaling the memory-roofline term in
the bandwidth-bound regimes (decode serving) where weights dominate bytes.

Tiling: grid ``(M/bm, N/bn, K/bk)`` with the reduction innermost; a VMEM
f32 accumulator block is zeroed at ``k==0`` and written through at the last
``k`` step — where the optional bias-add/ReLU epilogue is fused, so a conv
layer with bias+activation is a single ``pallas_call`` (no XLA epilogue).  Block shapes are MXU-aligned (multiples of 128 on N, 8/128 on
M/K per dtype tiling).  The codebook block is ``(1, B)`` — ≤ 1 KiB, resident
in VMEM for the whole tile loop; group selection is an index-map function of
``k`` (requires ``group_size % bk == 0``).

Weight gather strategies (``gather=``):
  * ``"take"``    — vector gather from the VMEM codebook (default).
  * ``"onehot"``  — ``one_hot(idx) @ codebook``: guaranteed Mosaic lowering on
                    older toolchains, costs B extra VPU ops per element.

Two entry points share the kernel body:

  * :func:`pasm_matmul_kernel_call` — the plain GEMM: ``x`` is an explicit
    ``(M, K)`` operand (the conv path materializes an im2col patch matrix in
    HBM first).
  * :func:`pasm_conv_kernel_call` — **implicit-GEMM convolution**: ``x`` is
    the raw (spatially padded) image batch; each ``(bm, bk)`` patch tile is
    assembled *inside* the kernel from the VMEM-resident image
    (:func:`patch_tile`), so no ``(B·P, K)`` patch matrix ever exists in HBM.
    The grid grows a leading batch dimension and the output is per-image
    ``(B, P, N)``.  Identical tile plan + accumulation order ⇒ bit-exact
    with the explicit path (asserted in tests/test_conv_implicit.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.kernels.ref import max_pool_rows

__all__ = ["pasm_matmul_kernel_call", "pasm_conv_kernel_call", "ConvGeom",
           "SlabPlan", "patch_tile"]


class ConvGeom(NamedTuple):
    """Static conv geometry the implicit-GEMM kernels close over.

    Built by :func:`repro.core.conv.conv_geom`; hashable so it rides jit
    static args and ``custom_vjp`` nondiff args.  ``pad`` is the spatial
    zero-pad already applied to the image the kernel sees
    (``((lo_h, hi_h), (lo_w, hi_w))`` — SAME windowing happens *outside*,
    the kernel only ever gathers in-bounds).  ``pool > 1`` fuses a
    non-overlapping ``(pool, pool)`` max-pool into the kernel epilogue:
    GEMM rows switch to **window-major** order (each consecutive ``pool²``
    rows are one pool window) and the output is the pooled ``P_out`` map —
    pre-pool activations never leave VMEM (DESIGN.md §3.2).
    """

    nhwc: bool  # channels-minor (kkc) vs paper (ckk) reduction order
    ky: int
    kx: int
    stride: int
    oh: int
    ow: int
    c_in: int
    pad: tuple
    pool: int = 1  # fused non-overlapping max-pool window (1 = no pooling)

    @property
    def P(self) -> int:
        """Pre-pool output pixels per image."""
        return self.oh * self.ow

    @property
    def conv_k(self) -> int:
        """The true im2col reduction length ``c_in·ky·kx``."""
        return self.c_in * self.ky * self.kx

    @property
    def ohp(self) -> int:
        """Pooled output height (floor / VALID windowing)."""
        return self.oh // self.pool

    @property
    def owp(self) -> int:
        """Pooled output width (floor / VALID windowing)."""
        return self.ow // self.pool

    @property
    def P_out(self) -> int:
        """Stored output pixels per image (``== P`` when ``pool == 1``)."""
        return self.ohp * self.owp

    @property
    def P_rows(self) -> int:
        """GEMM rows per image: window pixels only — floor-dropped remainder
        rows/cols of the pre-pool map are never computed (``== P`` when
        ``pool == 1``)."""
        return self.P_out * self.pool * self.pool


class SlabPlan(NamedTuple):
    """Row-band slab pipeline plan for the implicit-GEMM conv engines.

    Built by :func:`repro.kernels.ops.conv_slab_plan`; hashable so it rides
    jit static args.  ``n_slabs == 1`` is the legacy whole-image-resident
    schedule (one image block per grid step, no halo operand).  With
    ``n_slabs > 1`` the padded image streams through VMEM as **row bands**:
    the kernel's x operand becomes a ``band_rows``-row block whose index map
    advances every ``blocks_per_slab`` output-row blocks, plus (when the
    conv window overlaps band seams, ``ky > stride``) a second ``halo_rows``
    block of the SAME array covering the first rows of the next band.
    Pallas's built-in block pipeline then double-buffers the next band while
    the current one computes — the slab DMA overlaps patch assembly with no
    manual async copies, and revisited block indices are never refetched.

    Invariants (enforced by the planner):

    * ``band_rows = (blocks_per_slab·bmp // owp)·pool·stride`` with
      ``(blocks_per_slab·bmp) % owp == 0`` — every slab covers whole pooled
      output rows, so pool windows never straddle a slab seam and the band
      index map stays a pure division of the row-block grid index.
    * ``halo_rows`` is the smallest **divisor** of ``band_rows`` that is
      ≥ ``max(ky - stride, 0)`` (0 when no overlap is needed): divisibility
      makes the halo offset ``(slab+1)·band_rows`` block-aligned for the
      halo BlockSpec without constraining ``band_rows`` itself.
    * ``rows_total = n_slabs·band_rows + halo_rows`` is the row count the
      kernel operand must carry — the wrapper slices/zero-pads the padded
      image to it (sliced rows are provably never gathered; padded rows are
      only touched by clamped M-pad rows, which replay valid windows).
    """

    n_slabs: int
    blocks_per_slab: int
    band_rows: int
    halo_rows: int
    rows_total: int

    @property
    def fetched_rows(self) -> int:
        """Image rows HBM streams per image: ``rows_total`` when the whole
        image is resident, else each slab refetches its halo."""
        if self.n_slabs == 1:
            return self.rows_total
        return self.n_slabs * (self.band_rows + self.halo_rows)


def _dequant_tile(idx_tile, cb_row, gather: str, dtype):
    """(bk, bn) uint8 indices + (B,) codebook → (bk, bn) weights."""
    B = cb_row.shape[0]
    if gather == "take":
        return cb_row[idx_tile.astype(jnp.int32)].astype(dtype)
    # one-hot contraction: Σ_b cb[b]·[idx=b] — the PAS selection network in
    # vectorized form; guaranteed-lowerable everywhere.
    w = jnp.zeros(idx_tile.shape, dtype=jnp.float32)
    for b in range(B):
        w = jnp.where(idx_tile == b, cb_row[b], w)
    return w.astype(dtype)


def _unpack_int4_tile(packed):
    """(bk//2, bn) packed → (bk, bn): row 2i = lo nibble, row 2i+1 = hi."""
    lo = packed & 0x0F
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=1)  # (bk//2, 2, bn)
    return out.reshape(packed.shape[0] * 2, packed.shape[1])


def patch_tile(img, m0, q0, *, geom: ConvGeom, bm: int, bk: int, gs: int,
               gs_pad: int, row0=0):
    """Assemble one ``(bm, bk)`` im2col tile from the VMEM-resident image.

    ``img`` is a single padded image (``(H, W, C)`` when ``geom.nhwc`` else
    ``(C, H, W)``); rows are output pixels ``[m0, m0+bm)``, columns are
    *padded* GEMM reduction positions ``[q0, q0+bk)``.  ``row0`` rebases the
    image-row coordinate when ``img`` is a slab (band+halo) rather than the
    whole image: the gather reads ``img[iy - row0]`` where ``row0`` is the
    slab's first image row (0 for the whole-image schedule — the slab
    planner guarantees every row a slab's output blocks touch lands in
    ``[row0, row0 + band_rows + halo_rows)``).  Each padded position is
    unmapped to its logical ``(c, ky, kx)`` patch element:

      ``g = q // gs_pad`` picks the codebook group, ``r = q % gs_pad`` the
      row within it; rows with ``r >= gs`` are the tile-plan K-pad and rows
      with ``g·gs + r >= conv_k`` the §3 pack-time K-pad — both read **zero**
      (the in-kernel analogue of the zero patch columns the explicit path
      pads in), pairing with the reserved zero-codebook bin.  M-pad rows
      clamp to the last pixel/window and are sliced off outside.

    With ``geom.pool > 1`` rows are **window-major**: row ``m`` is within-
    window offset ``s = m % pool²`` of pooled pixel ``pp = m // pool²``, so
    each consecutive ``pool²`` rows form one pool window and the fused
    epilogue can max-reduce them with a pure reshape.  M-pad rows clamp at
    *window* granularity (``pp`` clamps, ``s`` keeps cycling), so a pad
    window replays the last valid window — never a mix of valid and garbage
    rows, which is what makes the pooled write-through safe without any
    ``-inf`` row masking.  ``pool == 1`` degenerates to the row-major pixel
    unmapping exactly (``pp = m``, ``s = 0``).
    """
    m = m0 + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
    pw = geom.pool * geom.pool
    pp = jnp.minimum(m // pw, geom.P_out - 1)
    s = m % pw
    oy = (pp // geom.owp) * geom.pool + s // geom.pool
    ox = (pp % geom.owp) * geom.pool + s % geom.pool
    q = q0 + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    g, r = q // gs_pad, q % gs_pad
    ql = g * gs + jnp.minimum(r, gs - 1)
    valid = (r < gs) & (ql < geom.conv_k)
    ql = jnp.minimum(ql, geom.conv_k - 1)
    if geom.nhwc:  # channels-minor (ky, kx, c)
        dy = ql // (geom.kx * geom.c_in)
        dx = (ql // geom.c_in) % geom.kx
        c = ql % geom.c_in
    else:  # paper (c, ky, kx) loop order
        c = ql // (geom.ky * geom.kx)
        dy = (ql // geom.kx) % geom.ky
        dx = ql % geom.kx
    iy = oy * geom.stride + dy - row0  # (bm, bk) via broadcast
    ix = ox * geom.stride + dx
    c = jnp.broadcast_to(c, iy.shape)
    vals = img[iy, ix, c] if geom.nhwc else img[c, iy, ix]
    return jnp.where(valid, vals, jnp.zeros((), img.dtype))


def _fused_dequant_step(
    x_tile, idx_ref, cb_ref, b_ref, o_ref, acc_ref=None, *, k, n_k: int,
    packed: bool, gather: str, relu: bool, pool: int = 1,
):
    """The shared per-k-step body of BOTH entry points: unpack+dequant the
    idx tile, accumulate ``x_tile @ w``, and fuse the bias-add / ReLU
    epilogue into the last-k-step write-through — so a conv layer with
    bias+activation stays a single pallas_call.  ``o_ref`` may carry a
    leading length-1 batch axis (the conv grid); the accumulate reshapes to
    it and ``(1, bn)`` bias broadcasting covers both ranks.

    ``pool > 1`` additionally max-reduces each group of ``pool²``
    window-major rows in the write-through (after bias/ReLU, matching the
    unfused conv→epilogue→``reduce_window`` order), so the stored block is
    the pooled ``(bm/pool², bn)`` shape and the pre-pool activations never
    leave VMEM.  The pre-pool accumulator then lives in the ``acc_ref``
    VMEM scratch instead of ``o_ref`` (their shapes differ).
    """
    idx_tile = idx_ref[...]
    if packed:
        idx_tile = _unpack_int4_tile(idx_tile)
    w = _dequant_tile(idx_tile, cb_ref[0], gather, x_tile.dtype)
    acc = jnp.dot(x_tile, w, preferred_element_type=jnp.float32)
    if pool == 1:
        o_ref[...] += acc.reshape(o_ref.shape)

        if b_ref is not None or relu:

            @pl.when(k == n_k - 1)
            def _finish():
                y = o_ref[...]
                if b_ref is not None:
                    y = y + b_ref[...]  # (1, bn) broadcasts over rows
                if relu:
                    y = jnp.maximum(y, 0.0)
                o_ref[...] = y

        return
    acc_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _finish_pooled():
        y = acc_ref[...]
        if b_ref is not None:
            y = y + b_ref[...]
        if relu:
            y = jnp.maximum(y, 0.0)
        o_ref[...] = max_pool_rows(y, pool).reshape(o_ref.shape)


def _kernel(
    x_ref, idx_ref, cb_ref, *rest, packed: bool, gather: str, n_k: int,
    relu: bool, pool: int,
):
    if pool > 1:
        acc_ref, rest = rest[-1], rest[:-1]
    else:
        acc_ref = None
    b_ref, o_ref = rest if len(rest) == 2 else (None, rest[0])
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        if pool > 1:
            acc_ref[...] = jnp.zeros_like(acc_ref)
        else:
            o_ref[...] = jnp.zeros_like(o_ref)

    _fused_dequant_step(
        x_ref[...], idx_ref, cb_ref, b_ref, o_ref, acc_ref,
        k=k, n_k=n_k, packed=packed, gather=gather, relu=relu, pool=pool,
    )


def pasm_matmul_kernel_call(
    x: jax.Array,
    idx: jax.Array,
    codebook: jax.Array,
    bias: "jax.Array | None" = None,
    *,
    packed: bool,
    logical_k: int,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    gather: str = "take",
    relu: bool = False,
    pool: int = 1,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call; shape plumbing/padding lives in :mod:`repro.kernels.ops`.

    ``x (M, K) · idx (K or K//2, N) · codebook (G, B) → (M, N) f32``.
    ``bias (1, N)`` and ``relu`` are the fused epilogue, applied inside the
    last reduction step.  ``pool > 1`` expects **window-major** x rows (each
    consecutive ``pool²`` rows one max-pool window — the conv2d front-end's
    ordering) and returns the pooled ``(M/pool², N)``, max-reduced in the
    same write-through.  Preconditions (enforced by ops.py):
    M % bm == N % bn == K % bk == 0, group_size % bk == 0, bk even when
    packed, bm % pool² == 0.
    """
    M, K = x.shape
    N = idx.shape[1]
    assert K == logical_k
    G, B = codebook.shape
    group_size = K // G
    assert group_size % bk == 0, (group_size, bk)
    pw = pool * pool
    assert bm % pw == 0 and M % pw == 0, (bm, M, pool)
    n_k = K // bk

    # index maps return BLOCK indices (scaled by block_shape internally)
    idx_block = (bk // 2, bn) if packed else (bk, bn)
    blocks_per_group = group_size // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec(idx_block, lambda i, j, k: (k, j)),
        pl.BlockSpec((1, B), lambda i, j, k: (k // blocks_per_group, 0)),
    ]
    operands = [x, idx, codebook]
    if bias is not None:
        assert bias.shape == (1, N), bias.shape
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        operands.append(bias)

    return pl.pallas_call(
        functools.partial(
            _kernel, packed=packed, gather=gather, n_k=n_k, relu=relu, pool=pool
        ),
        grid=(M // bm, N // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm // pw, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M // pw, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)] if pool > 1 else [],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)


def _slab_image(x_ref, halo_ref, geom: ConvGeom, slab):
    """Kernel-side slab assembly shared by both implicit conv bodies.

    Whole-image schedule (``slab is None``): the block IS the padded image.
    Slab schedule: concatenate the band block with its halo block (the first
    ``halo_rows`` rows of the next band — same array, second operand) along
    the image-row axis, and return the slab's first image row so
    :func:`patch_tile` can rebase its gather coordinates.
    """
    img = x_ref[0]
    if slab is None:
        return img, 0
    if halo_ref is not None:
        img = jnp.concatenate([img, halo_ref[0]], axis=0 if geom.nhwc else 1)
    row0 = (pl.program_id(1) // slab.blocks_per_slab) * slab.band_rows
    return img, row0


def _image_specs(x, geom: ConvGeom, slab):
    """BlockSpecs (+ operands) for the implicit kernels' image input.

    Whole-image: one ``(1, img...)`` block pinned at the origin.  Slabbed:
    a ``band_rows`` row-band block whose index map advances every
    ``blocks_per_slab`` row-blocks — Pallas's block pipeline prefetches the
    next band while the current one computes and skips refetching unchanged
    indices — plus, when ``halo_rows > 0``, the SAME array again as a
    ``halo_rows``-row block at offset ``(slab+1)·band_rows`` (block-aligned
    because ``halo_rows`` divides ``band_rows``).
    """
    if slab is None:
        return [pl.BlockSpec((1,) + x.shape[1:],
                             lambda b, i, j, k: (b, 0, 0, 0))], [x]
    S, Hh, bps = slab.band_rows, slab.halo_rows, slab.blocks_per_slab
    rows_ax = 1 if geom.nhwc else 2
    assert x.shape[rows_ax] == slab.rows_total, (x.shape, slab)
    if geom.nhwc:
        band = (1, S, x.shape[2], x.shape[3])
        bmap = lambda b, i, j, k: (b, i // bps, 0, 0)
        halo = (1, Hh, x.shape[2], x.shape[3])
        hmap = lambda b, i, j, k: (b, (i // bps + 1) * S // Hh, 0, 0)
    else:
        band = (1, x.shape[1], S, x.shape[3])
        bmap = lambda b, i, j, k: (b, 0, i // bps, 0)
        halo = (1, x.shape[1], Hh, x.shape[3])
        hmap = lambda b, i, j, k: (b, 0, (i // bps + 1) * S // Hh, 0)
    specs, ops = [pl.BlockSpec(band, bmap)], [x]
    if Hh:
        specs.append(pl.BlockSpec(halo, hmap))
        ops.append(x)
    return specs, ops


def _conv_kernel(
    x_ref, *refs, geom: ConvGeom, packed: bool, gather: str,
    n_k: int, relu: bool, bm: int, bk: int, gs: int, gs_pad: int, slab=None,
):
    """Implicit-GEMM body: gather the patch tile instead of reading an
    explicit x block, then the same :func:`_fused_dequant_step`."""
    if slab is not None and slab.halo_rows:
        halo_ref, refs = refs[0], refs[1:]
    else:
        halo_ref = None
    idx_ref, cb_ref, *rest = refs
    if geom.pool > 1:
        acc_ref, rest = rest[-1], rest[:-1]
    else:
        acc_ref = None
    b_ref, o_ref = rest if len(rest) == 2 else (None, rest[0])
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _zero():
        if geom.pool > 1:
            acc_ref[...] = jnp.zeros_like(acc_ref)
        else:
            o_ref[...] = jnp.zeros_like(o_ref)

    img, row0 = _slab_image(x_ref, halo_ref, geom, slab)
    patch = patch_tile(
        img, pl.program_id(1) * bm, k * bk,
        geom=geom, bm=bm, bk=bk, gs=gs, gs_pad=gs_pad, row0=row0,
    )
    _fused_dequant_step(
        patch, idx_ref, cb_ref, b_ref, o_ref, acc_ref,
        k=k, n_k=n_k, packed=packed, gather=gather, relu=relu, pool=geom.pool,
    )


def pasm_conv_kernel_call(
    x: jax.Array,
    idx: jax.Array,
    codebook: jax.Array,
    bias: "jax.Array | None" = None,
    *,
    geom: ConvGeom,
    packed: bool,
    gs: int,
    gs_pad: int,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    gather: str = "take",
    relu: bool = False,
    slab: "SlabPlan | None" = None,
    interpret: bool = False,
) -> jax.Array:
    """Implicit-GEMM conv pallas_call: the image IS the ``x`` operand.

    ``x (B, img...)`` spatially padded per ``geom`` · ``idx (Kp or Kp//2, Np)``
    · ``codebook (G, B)`` → ``(B, Pp, Np) f32`` where ``Pp`` rounds
    ``geom.P_out`` up to the per-block *output* rows (real rows sliced off by
    the caller).  Default (``slab`` None or single-slab): one whole padded
    image is the per-grid-step ``x`` block — resident in VMEM across the
    entire ``(i, j, k)`` tile loop of its batch element, so HBM streams the
    image once per reuse window instead of ``ky·kx/stride²``× as patch rows.
    With a multi-slab :class:`SlabPlan` the image streams as double-buffered
    row bands instead (x pre-sliced/padded to ``slab.rows_total`` rows by
    ops.py), so images past the VMEM budget run implicit too — the k-tile
    sequence is untouched, so slab output stays bit-exact.  With
    ``geom.pool > 1`` the grid walks window-major pre-pool rows (``bm`` per
    block) but stores only the pooled ``bm/pool²`` rows — the fused
    conv/ReLU/max-pool stage (slabs cover whole pooled rows, so windows
    never straddle a seam).  Preconditions (enforced by ops.py):
    ``gs_pad % bk == 0``, ``Np % bn == 0``, ``bm % pool² == 0``, bias
    ``(1, Np)``.
    """
    B_img = x.shape[0]
    G, B = codebook.shape
    Np = idx.shape[1]
    Kp = idx.shape[0] * (2 if packed else 1)
    assert Kp == G * gs_pad, (Kp, G, gs_pad)
    assert gs_pad % bk == 0, (gs_pad, bk)
    pw = geom.pool * geom.pool
    assert bm % pw == 0, (bm, geom.pool)
    bmp = bm // pw  # stored (pooled) rows per block
    n_k = Kp // bk
    Pp = (geom.P_out + bmp - 1) // bmp * bmp
    blocks_per_group = gs_pad // bk
    if slab is not None and slab.n_slabs == 1:
        slab = None  # single slab ≡ the legacy whole-image schedule

    idx_block = (bk // 2, bn) if packed else (bk, bn)
    img_specs, operands = _image_specs(x, geom, slab)
    in_specs = img_specs + [
        pl.BlockSpec(idx_block, lambda b, i, j, k: (k, j)),
        pl.BlockSpec((1, B), lambda b, i, j, k: (k // blocks_per_group, 0)),
    ]
    operands = operands + [idx, codebook]
    if bias is not None:
        assert bias.shape == (1, Np), bias.shape
        in_specs.append(pl.BlockSpec((1, bn), lambda b, i, j, k: (0, j)))
        operands.append(bias)

    return pl.pallas_call(
        functools.partial(
            _conv_kernel, geom=geom, packed=packed, gather=gather, n_k=n_k,
            relu=relu, bm=bm, bk=bk, gs=gs, gs_pad=gs_pad, slab=slab,
        ),
        grid=(B_img, Pp // bmp, Np // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bmp, bn), lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B_img, Pp, Np), jnp.float32),
        scratch_shapes=(
            [pltpu.VMEM((bm, bn), jnp.float32)] if geom.pool > 1 else []
        ),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)
