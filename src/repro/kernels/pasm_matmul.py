"""Pallas TPU kernel: codebook-dequant-fused matmul (the production PASM path).

``y = x @ W`` where ``W`` never exists in HBM: only ``log2(B)``-bit indices
(uint8, or two 4-bit indices packed per byte) plus a ``(G, B)`` codebook are
streamed.  Dequantization happens on the fly in VMEM, tile by tile — this is
the TPU adaptation of the paper's insight (DESIGN.md §2): HBM weight traffic
drops 4–8× versus bf16 weights, directly scaling the memory-roofline term in
the bandwidth-bound regimes (decode serving) where weights dominate bytes.

Tiling: grid ``(M/bm, N/bn, K/bk)`` with the reduction innermost; a VMEM
f32 accumulator block is zeroed at ``k==0`` and written through at the last
``k`` step — where the optional bias-add/ReLU epilogue is fused, so a conv
layer with bias+activation is a single ``pallas_call`` (no XLA epilogue).  Block shapes are MXU-aligned (multiples of 128 on N, 8/128 on
M/K per dtype tiling).  The codebook block is ``(1, B)`` — ≤ 1 KiB, resident
in VMEM for the whole tile loop; group selection is an index-map function of
``k`` (requires ``group_size % bk == 0``).

Weight gather strategies (``gather=``):
  * ``"take"``    — vector gather from the VMEM codebook (default).
  * ``"onehot"``  — ``one_hot(idx) @ codebook``: guaranteed Mosaic lowering on
                    older toolchains, costs B extra VPU ops per element.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["pasm_matmul_kernel_call"]


def _dequant_tile(idx_tile, cb_row, gather: str, dtype):
    """(bk, bn) uint8 indices + (B,) codebook → (bk, bn) weights."""
    B = cb_row.shape[0]
    if gather == "take":
        return cb_row[idx_tile.astype(jnp.int32)].astype(dtype)
    # one-hot contraction: Σ_b cb[b]·[idx=b] — the PAS selection network in
    # vectorized form; guaranteed-lowerable everywhere.
    w = jnp.zeros(idx_tile.shape, dtype=jnp.float32)
    for b in range(B):
        w = jnp.where(idx_tile == b, cb_row[b], w)
    return w.astype(dtype)


def _unpack_int4_tile(packed):
    """(bk//2, bn) packed → (bk, bn): row 2i = lo nibble, row 2i+1 = hi."""
    lo = packed & 0x0F
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=1)  # (bk//2, 2, bn)
    return out.reshape(packed.shape[0] * 2, packed.shape[1])


def _kernel(
    x_ref, idx_ref, cb_ref, *rest, packed: bool, gather: str, n_k: int, relu: bool
):
    b_ref, o_ref = rest if len(rest) == 2 else (None, rest[0])
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx_tile = idx_ref[...]
    if packed:
        idx_tile = _unpack_int4_tile(idx_tile)
    w = _dequant_tile(idx_tile, cb_ref[0], gather, x_ref.dtype)
    o_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)

    # fused epilogue: bias-add / ReLU in the last-k-step write-through, so a
    # conv layer with bias+activation stays a single pallas_call
    if b_ref is not None or relu:

        @pl.when(k == n_k - 1)
        def _finish():
            y = o_ref[...]
            if b_ref is not None:
                y = y + b_ref[...]  # (1, bn) broadcasts over rows
            if relu:
                y = jnp.maximum(y, 0.0)
            o_ref[...] = y


def pasm_matmul_kernel_call(
    x: jax.Array,
    idx: jax.Array,
    codebook: jax.Array,
    bias: "jax.Array | None" = None,
    *,
    packed: bool,
    logical_k: int,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    gather: str = "take",
    relu: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call; shape plumbing/padding lives in :mod:`repro.kernels.ops`.

    ``x (M, K) · idx (K or K//2, N) · codebook (G, B) → (M, N) f32``.
    ``bias (1, N)`` and ``relu`` are the fused epilogue, applied inside the
    last reduction step.  Preconditions (enforced by ops.py):
    M % bm == N % bn == K % bk == 0, group_size % bk == 0, bk even when packed.
    """
    M, K = x.shape
    N = idx.shape[1]
    assert K == logical_k
    G, B = codebook.shape
    group_size = K // G
    assert group_size % bk == 0, (group_size, bk)
    n_k = K // bk

    # index maps return BLOCK indices (scaled by block_shape internally)
    idx_block = (bk // 2, bn) if packed else (bk, bn)
    blocks_per_group = group_size // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec(idx_block, lambda i, j, k: (k, j)),
        pl.BlockSpec((1, B), lambda i, j, k: (k // blocks_per_group, 0)),
    ]
    operands = [x, idx, codebook]
    if bias is not None:
        assert bias.shape == (1, N), bias.shape
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        operands.append(bias)

    return pl.pallas_call(
        functools.partial(_kernel, packed=packed, gather=gather, n_k=n_k, relu=relu),
        grid=(M // bm, N // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)
