"""The crash-safe training loop (DESIGN.md §4) — one loop, three drivers.

``run_loop`` is the step engine shared by ``launch/train.py`` (the CLI),
``benchmarks/train_bench.py`` (the BENCH_train.json trajectory) and the
chaos suite (tests/test_train_faults.py).  Per step it:

1. fetches the step-addressed batch (``batch_fn(step)``) through the capped
   -backoff I/O retry (:func:`repro.data.pipeline.retry_io`) — a transient
   ``data_io`` fault costs a retry, not the run;
2. applies the fault plan's ``loss_scale`` (NaN / spike poisoning rides the
   batch into the jitted step — the model code never sees the plan);
3. runs the jitted guarded train step: a non-finite loss/grad SKIPS the
   update bit-exactly (``metrics["skipped"]``), and ``K`` consecutive skips
   escalate to :class:`NonFiniteEscalation` — a
   :class:`repro.ft.RestorableError` carrying the step and the newest
   checkpoint hint, so the supervisor restores-and-retries once and fails
   fast (``ft.DeterministicFailure``) if the same step escalates again;
4. records the step time with the straggler detector EVERY step (virtual
   ``slow`` stalls included — zero wall clock in tests);
5. fires the plan's ``crash`` hook (after the update, before the step's
   checkpoint — the worst-case kill point for resume);
6. checkpoints every ``ckpt_every`` steps through the integrity-checked
   manager; an injected/real ``OSError`` at save time warns and counts
   (``n_ckpt_failures``) instead of killing training — the next interval
   retries, and restore falls back past any torn write.

The loss/step-time trajectories are written into the caller's ``history``
dicts keyed by step, so a supervised (crash + restore) run accumulates one
coherent trajectory across attempts — the chaos suite asserts it equals the
uninterrupted run's **bit-exactly** (`assert_array_equal`; the data is
step-addressed, the jitted step deterministic).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Optional

import jax.numpy as jnp

from repro import ft
from repro.data.pipeline import retry_io

__all__ = ["NonFiniteEscalation", "LoopResult", "run_loop"]


class NonFiniteEscalation(ft.RestorableError):
    """K consecutive non-finite steps: the guard stopped skipping and
    escalated.  Restorable — a transient numeric storm (flaky interconnect,
    a bad HBM read) clears after restore; a deterministic one (poisoned
    data) repeats at the same ``step`` and the supervisor then fails fast."""

    def __init__(self, step: int, n_consecutive: int, resume_step: Optional[int]):
        super().__init__(
            f"{n_consecutive} consecutive non-finite steps ending at step "
            f"{step}: escalating for restore"
        )
        self.step = step
        self.n_consecutive = n_consecutive
        self.resume_step = resume_step


@dataclasses.dataclass
class LoopResult:
    """What one (possibly resumed) loop attempt produced."""

    last_step: int
    state: Any  # (params, opt_state) after the final executed step
    losses: dict  # step -> float loss (NaN on guarded-skip steps)
    step_times: dict  # step -> seconds (virtual slow stalls included)
    n_skipped: int = 0
    n_ckpt_failures: int = 0


def run_loop(
    train_step: Callable,
    state: tuple,
    batch_fn: Callable[[int], dict],
    *,
    steps: int,
    start_step: int = 0,
    mgr=None,
    ckpt_every: int = 0,
    ckpt_extra: Optional[dict] = None,
    faults=None,
    detector: Optional[ft.StragglerDetector] = None,
    host: int = 0,
    max_consecutive_nonfinite: int = 3,
    data_retries: int = 3,
    data_backoff_s: float = 0.0,
    io_sleep: Callable[[float], None] = time.sleep,
    time_fn: Callable[[], float] = time.perf_counter,
    log_every: int = 0,
    log_fn: Callable[[str], None] = print,
    losses: Optional[dict] = None,
    step_times: Optional[dict] = None,
) -> LoopResult:
    """Run ``train_step`` from ``start_step`` to ``steps`` crash-safely.

    ``state`` is ``(params, opt_state)`` (any pytree pair the jitted
    ``train_step(params, opt_state, batch)`` accepts).  ``losses`` /
    ``step_times`` are optional caller-owned dicts accumulated across
    supervisor restarts.  Checkpoints save at steps ``s+1`` divisible by
    ``ckpt_every`` plus a final save at ``steps``.
    """
    params, opt_state = state
    losses = {} if losses is None else losses
    step_times = {} if step_times is None else step_times
    n_skipped = n_ckpt_failures = 0
    skip_streak = 0
    last_saved: Optional[int] = start_step if start_step else None

    def _save(at_step: int) -> None:
        nonlocal n_ckpt_failures, last_saved
        try:
            if faults is not None:
                faults.on_ckpt_save(at_step)
            mgr.save(at_step, (params, opt_state), extra=ckpt_extra)
            last_saved = at_step
        except OSError as e:
            n_ckpt_failures += 1
            warnings.warn(
                f"checkpoint save at step {at_step} failed ({e}); training "
                f"continues — the next interval retries and restore falls "
                f"back past torn writes",
                RuntimeWarning,
                stacklevel=2,
            )

    for s in range(start_step, steps):
        t0 = time_fn()
        if faults is not None:
            # the fault hook rides the retried fetch: nth-keyed data_io
            # faults are absorbed exactly like a real transient OSError
            batch = retry_io(
                lambda: (faults.on_data(s), batch_fn(s))[1],
                retries=data_retries, backoff_s=data_backoff_s, sleep=io_sleep,
            )
            scale = faults.loss_scale(s)
            if scale is not None:
                batch = dict(batch, loss_scale=jnp.float32(scale))
        else:
            batch = retry_io(
                lambda: batch_fn(s),
                retries=data_retries, backoff_s=data_backoff_s, sleep=io_sleep,
            )

        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])  # blocks: the step is done on-device
        skipped = bool(int(metrics.get("skipped", 0)))

        dt = time_fn() - t0
        if faults is not None:
            dt += faults.slow_delay(s)
        if detector is not None:
            detector.record(host, dt)  # EVERY step: medians are real samples
        losses[s] = loss
        step_times[s] = dt

        if skipped:
            n_skipped += 1
            skip_streak += 1
            if skip_streak >= max_consecutive_nonfinite:
                raise NonFiniteEscalation(s, skip_streak, last_saved)
        else:
            skip_streak = 0

        if log_every and ((s + 1) % log_every == 0 or s == start_step):
            log_fn(
                f"[train] step {s + 1:5d} loss {loss:.4f} "
                f"lr {float(metrics.get('lr', float('nan'))):.2e} "
                f"{dt * 1e3:.0f} ms/step"
                + (f" (skipped, streak {skip_streak})" if skipped else "")
            )

        if faults is not None:
            faults.crash(s)  # post-update, pre-checkpoint: worst-case kill

        if mgr is not None and ckpt_every and (s + 1) % ckpt_every == 0:
            _save(s + 1)

    if mgr is not None:
        if last_saved != steps:
            _save(steps)
        mgr.wait()
    return LoopResult(
        last_step=steps,
        state=(params, opt_state),
        losses=losses,
        step_times=step_times,
        n_skipped=n_skipped,
        n_ckpt_failures=n_ckpt_failures,
    )
