"""train_step / eval_step factories: loss, grads, microbatching, QAT hook."""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api
from repro.models.common import ShardCtx
from repro.train import optimizer as opt

__all__ = ["make_train_step", "make_eval_step"]


def _loss_fn(params, batch, cfg: ArchConfig, sctx: ShardCtx, model):
    kw = {}
    if "frontend_embeds" in batch:
        kw["frontend_embeds"] = batch["frontend_embeds"]
    logits, aux = model.forward(params, batch["tokens"], cfg, sctx, **kw)
    loss = api.lm_loss(logits, batch["labels"], batch.get("loss_mask"))
    if aux.get("moe_load_balance") is not None and cfg.moe:
        loss = loss + 0.01 * aux["moe_load_balance"] / max(cfg.n_layers, 1)
    return loss, aux


def make_train_step(
    cfg: ArchConfig,
    ocfg: opt.AdamWConfig,
    sctx: ShardCtx = ShardCtx(),
    *,
    microbatches: int = 1,
    compress_grads_bins: int = 0,
):
    """Returns train_step(params, opt_state, batch) → (params, opt_state, metrics).

    ``microbatches > 1`` accumulates gradients over sequential micro-batches
    (activation-memory relief at fixed global batch).  ``compress_grads_bins``
    applies the PASM-style dictionary compression to the gradient payload
    before the optimizer (beyond-paper, DESIGN.md §4).
    """
    model = api.get_model(cfg)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, aux), grads = jax.value_and_grad(_loss_fn, has_aux=True)(
                params, batch, cfg, sctx, model
            )
        else:
            # python-unrolled accumulation: keeps every microbatch visible to
            # the XLA cost model (a fori_loop body is counted once, breaking
            # the dry-run's roofline accounting) and lets the scheduler
            # overlap the grad all-reduce of microbatch i with compute of i+1
            grads = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
            loss = jnp.zeros((), jnp.float32)
            for i in range(microbatches):
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // microbatches), x.shape[0] // microbatches, 0
                    ),
                    batch,
                )
                (l, _), g = jax.value_and_grad(_loss_fn, has_aux=True)(
                    params, mb, cfg, sctx, model
                )
                grads = jax.tree.map(jnp.add, grads, g)
                loss = loss + l
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            aux = {}
        if compress_grads_bins:
            grads = opt.compress_grads(grads, compress_grads_bins)
        params, opt_state, metrics = opt.adamw_update(params, grads, opt_state, ocfg)
        metrics = dict(metrics, loss=loss, **{k: v for k, v in aux.items()})
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ArchConfig, sctx: ShardCtx = ShardCtx()):
    model = api.get_model(cfg)

    def eval_step(params, batch):
        loss, aux = _loss_fn(params, batch, cfg, sctx, model)
        return {"loss": loss, **aux}

    return eval_step
