"""train_step / eval_step factories: loss, grads, microbatching, QAT hook.

Every train step built here carries the **fused non-finite guard**
(DESIGN.md §4): one ``isfinite`` reduction over loss + all grads folded into
the jitted step (``optimizer.nonfinite_probe``).  A non-finite step *skips*
the update — params and opt_state come back bit-identical (``tree_select``
copies the old leaves; the optimizer's garbage outputs are discarded and the
step counter does not advance) — and reports ``metrics["skipped"] == 1`` so
the host loop (train/loop.py) can count skips and escalate after K
consecutive ones.  ``batch["loss_scale"]`` (optional scalar) multiplies the
loss *inside* the differentiated function — the mixed-precision loss-scaling
hook, and the injection point train/faults.py uses to poison a step
(NaN / overflow) without touching model code.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api
from repro.models.common import ShardCtx
from repro.train import optimizer as opt

__all__ = ["make_train_step", "make_eval_step", "make_cnn_train_step",
           "cnn_qat_loss"]


def _loss_fn(params, batch, cfg: ArchConfig, sctx: ShardCtx, model, scale=None):
    kw = {}
    if "frontend_embeds" in batch:
        kw["frontend_embeds"] = batch["frontend_embeds"]
    logits, aux = model.forward(params, batch["tokens"], cfg, sctx, **kw)
    loss = api.lm_loss(logits, batch["labels"], batch.get("loss_mask"))
    if aux.get("moe_load_balance") is not None and cfg.moe:
        loss = loss + 0.01 * aux["moe_load_balance"] / max(cfg.n_layers, 1)
    if scale is not None:
        loss = loss * scale  # inside the grad: a poisoned scale poisons grads
    return loss, aux


def _guarded_update(params, opt_state, loss, grads, ocfg, *, guard: bool):
    """AdamW + the fused non-finite guard: ONE probe scalar decides between
    the updated tree and the bit-identical old one."""
    new_p, new_s, metrics = opt.adamw_update(params, grads, opt_state, ocfg)
    if not guard:
        return new_p, new_s, dict(metrics, skipped=jnp.zeros((), jnp.int32))
    ok = opt.nonfinite_probe(loss, grads)
    params = opt.tree_select(ok, new_p, params)
    opt_state = opt.tree_select(ok, new_s, opt_state)
    metrics = dict(metrics, skipped=jnp.where(ok, 0, 1).astype(jnp.int32))
    return params, opt_state, metrics


def _split_scale(batch):
    """Pop the optional scalar ``loss_scale`` out of the batch (it must not
    ride the microbatch axis-0 slicing)."""
    if "loss_scale" not in batch:
        return batch, None
    return {k: v for k, v in batch.items() if k != "loss_scale"}, batch["loss_scale"]


def make_train_step(
    cfg: ArchConfig,
    ocfg: opt.AdamWConfig,
    sctx: ShardCtx = ShardCtx(),
    *,
    microbatches: int = 1,
    compress_grads_bins: int = 0,
    guard_nonfinite: bool = True,
):
    """Returns train_step(params, opt_state, batch) → (params, opt_state, metrics).

    ``microbatches > 1`` accumulates gradients over sequential micro-batches
    (activation-memory relief at fixed global batch).  ``compress_grads_bins``
    applies the PASM-style dictionary compression to the gradient payload
    before the optimizer (beyond-paper, DESIGN.md §4).  ``guard_nonfinite``
    (default on) folds the fused non-finite guard into the step: a NaN/inf
    loss or gradient skips the update bit-exactly and sets
    ``metrics["skipped"]``.
    """
    model = api.get_model(cfg)

    def train_step(params, opt_state, batch):
        batch, scale = _split_scale(batch)
        if microbatches == 1:
            (loss, aux), grads = jax.value_and_grad(_loss_fn, has_aux=True)(
                params, batch, cfg, sctx, model, scale
            )
        else:
            # python-unrolled accumulation: keeps every microbatch visible to
            # the XLA cost model (a fori_loop body is counted once, breaking
            # the dry-run's roofline accounting) and lets the scheduler
            # overlap the grad all-reduce of microbatch i with compute of i+1
            grads = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
            loss = jnp.zeros((), jnp.float32)
            for i in range(microbatches):
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // microbatches), x.shape[0] // microbatches, 0
                    ),
                    batch,
                )
                (l, _), g = jax.value_and_grad(_loss_fn, has_aux=True)(
                    params, mb, cfg, sctx, model, scale
                )
                grads = jax.tree.map(jnp.add, grads, g)
                loss = loss + l
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            aux = {}
        if compress_grads_bins:
            grads = opt.compress_grads(grads, compress_grads_bins)
        params, opt_state, metrics = _guarded_update(
            params, opt_state, loss, grads, ocfg, guard=guard_nonfinite
        )
        metrics = dict(metrics, loss=loss, **{k: v for k, v in aux.items()})
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ArchConfig, sctx: ShardCtx = ShardCtx()):
    model = api.get_model(cfg)

    def eval_step(params, batch):
        loss, aux = _loss_fn(params, batch, cfg, sctx, model)
        return {"loss": loss, **aux}

    return eval_step


# ---------------------------------------------------------------------------
# CNN QAT: the AlexNet-family weight-shared training step (DESIGN.md §6)
# ---------------------------------------------------------------------------


def cnn_qat_loss(tree: dict, batch: dict, cfg, *, mesh=None, scale=None):
    """Softmax cross-entropy through the STE-snapped conv stack.

    ``tree = {"params": cnn dense masters, "codebooks": [per-layer dicts]}``
    — both differentiable (``cnn.qat_forward``: masters get straight-through
    grads, codebook entries the bin-summed grads of their assigned weights).
    """
    from repro.models import cnn

    logits = cnn.qat_forward(
        tree["params"], tree["codebooks"], batch["images"], cfg, mesh=mesh
    )
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)
    loss = jnp.mean(nll)
    if scale is not None:
        loss = loss * scale
    return loss


def make_cnn_train_step(
    cfg,
    ocfg: opt.AdamWConfig,
    *,
    mesh=None,
    guard_nonfinite: bool = True,
) -> Callable:
    """QAT train step for the conv stack: ``(tree, opt_state, batch) →
    (tree, opt_state, metrics)`` where ``tree`` holds the dense masters AND
    the per-layer codebooks (the trained dictionary — freeze with
    ``cnn.qat_requantize`` for serving).

    ``mesh=`` runs the forward sharded on the ``("data", "model")`` mesh
    (``cnn.qat_forward(mesh=)`` — the conv layers and head run under
    shard_map; the backward is jax's transpose of the same shard_map, the
    explicit col2im path).  The fused non-finite guard and
    ``batch["loss_scale"]`` behave exactly as in :func:`make_train_step`.
    """

    def train_step(tree, opt_state, batch):
        batch, scale = _split_scale(batch)
        loss, grads = jax.value_and_grad(cnn_qat_loss)(
            tree, batch, cfg, mesh=mesh, scale=scale
        )
        tree, opt_state, metrics = _guarded_update(
            tree, opt_state, loss, grads, ocfg, guard=guard_nonfinite
        )
        return tree, opt_state, dict(metrics, loss=loss)

    return train_step
