"""Deterministic fault injection for the training loop (DESIGN.md §4).

The serve stack's chaos discipline (serve/faults.py) applied to training: a
:class:`TrainFaultPlan` is a *seeded, fully reproducible* schedule of faults
keyed to the integer **training step** — no wall clock anywhere — consulted
by train/loop.py at its phase boundaries (data fetch, loss, post-update,
checkpoint save).  Because the data pipeline is step-addressed and every
fault is step-keyed, the chaos suite (tests/test_train_faults.py) can assert
the two training invariants *bit-exactly* with ``assert_array_equal``:

- resume-after-crash reproduces the uninterrupted loss trajectory and final
  params (the crashed steps are recomputed from the restored checkpoint on
  the identical step-addressed batches);
- a poisoned step (NaN loss / gradient spike) leaves params and opt_state
  bit-identical to the pre-step state (the fused guard's skip path).

Fault kinds (``TrainFaultSpec.kind``):

============  ==========================================================
``nan_loss``  ``loss_scale(step)`` returns NaN — the loss (and through
              the chain rule every gradient) goes non-finite; exercises
              the fused guard's skip path
``grad_spike``  ``loss_scale(step)`` returns ``spec.scale`` (default
              ``inf``) — the loss and every gradient blow up to inf,
              modelling an overflow rather than a NaN payload
``ckpt_io``   ``on_ckpt_save(step)`` raises :class:`OSError` on the
              ``nth`` save attempt at ``step`` (torn/failed write; the
              loop warns, counts, and keeps training)
``data_io``   ``on_data(step)`` raises :class:`OSError` on the ``nth``
              fetch attempt at ``step`` (transient storage flake; the
              capped-backoff retry in data/pipeline.py absorbs it)
``crash``     ``crash(step)`` raises :class:`SimulatedCrash` on the
              ``nth`` visit of ``step`` — after the update, before the
              step's checkpoint (the worst spot: the supervisor must
              restore an OLDER checkpoint and recompute)
``slow``      ``slow_delay(step)`` returns ``delay_s`` — a virtual
              straggler stall the loop adds to its recorded step time
              (zero wall clock)
============  ==========================================================
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Optional, Tuple

import numpy as np

__all__ = ["TRAIN_FAULT_KINDS", "SimulatedCrash", "TrainFaultSpec", "TrainFaultPlan"]

TRAIN_FAULT_KINDS = ("nan_loss", "grad_spike", "ckpt_io", "data_io", "crash", "slow")


class SimulatedCrash(RuntimeError):
    """An injected mid-run kill.  Carries ``step`` so ft.Supervisor can
    classify a repeat at the same step as deterministic."""

    def __init__(self, step: int):
        super().__init__(f"injected crash at step {step}")
        self.step = step


@dataclasses.dataclass(frozen=True)
class TrainFaultSpec:
    """One scheduled training fault.  Only the fields its ``kind`` reads
    matter: ``step`` keys every kind; ``nth`` makes ``ckpt_io``/``data_io``/
    ``crash`` one-shot per attempt count (1 = first attempt fails, the retry
    or restart passes); ``scale`` is the ``grad_spike`` loss multiplier;
    ``delay_s`` the ``slow`` stall."""

    kind: str
    step: int = 0
    nth: int = 1
    scale: float = float("inf")  # guaranteed non-finite in any float dtype
    delay_s: float = 0.0

    def __post_init__(self):
        if self.kind not in TRAIN_FAULT_KINDS:
            raise ValueError(
                f"kind must be one of {TRAIN_FAULT_KINDS}, got {self.kind!r}"
            )


class TrainFaultPlan:
    """A reproducible training fault schedule plus the hooks the loop calls.

    Build explicitly from :class:`TrainFaultSpec` s, or sample a schedule
    from a seed with :meth:`sample` (same seed ⇒ identical schedule — the
    plan never reads a clock or unseeded RNG).  ``fired`` records every hook
    activation in order, for test assertions.  Attempt counters
    (``nth``-keyed kinds) are instance state: a plan replayed across
    supervisor restarts keeps counting, so a ``crash`` with ``nth=1`` fires
    once and lets the restarted attempt pass.
    """

    def __init__(self, faults: Iterable[TrainFaultSpec] = ()):
        self.faults: Tuple[TrainFaultSpec, ...] = tuple(faults)
        self.fired: List[tuple] = []
        self._attempts: dict = {}  # (kind, step) -> attempts observed

    @classmethod
    def sample(
        cls,
        seed: int,
        *,
        n_steps: int,
        n_nan: int = 1,
        n_spike: int = 1,
        n_ckpt_io: int = 1,
        n_data_io: int = 1,
        n_crash: int = 1,
        n_slow: int = 0,
        slow_delay_s: float = 0.0,
        first_step: int = 1,
    ) -> "TrainFaultPlan":
        """Draw a schedule from ``seed``: every fault lands on a step in
        ``[first_step, n_steps)`` (step 0 is left clean so the first update
        always establishes a baseline)."""
        rng = np.random.default_rng(seed)
        lo, hi = first_step, max(first_step + 1, n_steps)
        pick = lambda: int(rng.integers(lo, hi))  # noqa: E731
        faults: List[TrainFaultSpec] = []
        for _ in range(n_nan):
            faults.append(TrainFaultSpec("nan_loss", step=pick()))
        for _ in range(n_spike):
            faults.append(TrainFaultSpec("grad_spike", step=pick()))
        for _ in range(n_ckpt_io):
            faults.append(TrainFaultSpec("ckpt_io", step=pick()))
        for _ in range(n_data_io):
            faults.append(TrainFaultSpec("data_io", step=pick()))
        for _ in range(n_crash):
            faults.append(TrainFaultSpec("crash", step=pick()))
        for _ in range(n_slow):
            faults.append(TrainFaultSpec("slow", step=pick(), delay_s=slow_delay_s))
        return cls(faults)

    def _nth_hit(self, kind: str, step: int) -> Optional[TrainFaultSpec]:
        """Count an attempt of (kind, step); return the spec if its ``nth``
        attempt is the one scheduled to fail."""
        specs = [f for f in self.faults if f.kind == kind and f.step == step]
        if not specs:
            return None
        key = (kind, step)
        n = self._attempts.get(key, 0) + 1
        self._attempts[key] = n
        for f in specs:
            if f.nth == n:
                return f
        return None

    # -- hooks the train loop calls at its phase boundaries ------------------

    def loss_scale(self, step: int) -> Optional[float]:
        """NaN (``nan_loss``) or the spike multiplier (``grad_spike``)
        scheduled for this step's loss; None when the step is clean."""
        for f in self.faults:
            if f.step == step and f.kind == "nan_loss":
                self.fired.append(("nan_loss", step))
                return float("nan")
            if f.step == step and f.kind == "grad_spike":
                self.fired.append(("grad_spike", step, f.scale))
                return f.scale
        return None

    def on_data(self, step: int) -> None:
        """Raise ``OSError`` if this step's ``nth`` data fetch is scheduled
        to fail (transient — the pipeline's capped-backoff retry absorbs it)."""
        f = self._nth_hit("data_io", step)
        if f is not None:
            self.fired.append(("data_io", step, f.nth))
            raise OSError(f"injected data I/O error at step {step}")

    def on_ckpt_save(self, step: int) -> None:
        """Raise ``OSError`` if this step's ``nth`` checkpoint save is
        scheduled to fail."""
        f = self._nth_hit("ckpt_io", step)
        if f is not None:
            self.fired.append(("ckpt_io", step, f.nth))
            raise OSError(f"injected checkpoint I/O error at step {step}")

    def crash(self, step: int) -> None:
        """Raise :class:`SimulatedCrash` on the scheduled visit of ``step``
        (fires after the update, before the step's checkpoint)."""
        f = self._nth_hit("crash", step)
        if f is not None:
            self.fired.append(("crash", step, f.nth))
            raise SimulatedCrash(step)

    def slow_delay(self, step: int) -> float:
        """Total virtual straggler stall scheduled at this step (0.0 = none)."""
        d = sum(f.delay_s for f in self.faults if f.kind == "slow" and f.step == step)
        if d:
            self.fired.append(("slow", step, d))
        return d

    @property
    def poison_steps(self) -> set:
        """Steps whose update the guard is expected to skip."""
        return {f.step for f in self.faults if f.kind in ("nan_loss", "grad_spike")}

    @property
    def trajectory_preserving(self) -> bool:
        """True when no fault alters the math (no nan/spike): the faulted
        run's loss trajectory must then be bit-exact vs fault-free."""
        return not self.poison_steps and not any(
            math.isnan(f.delay_s) for f in self.faults
        )
