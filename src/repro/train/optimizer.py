"""AdamW with cosine schedule, gradient clipping and a PASM compression hook.

Self-contained (no optax in this container).  Moments live in f32 and are
ZeRO-1 sharded over the ``data`` axis (sharding.opt_state_pspecs); the update
math is pure tree ops so XLA schedules the reduce-scatter/all-gather pair the
out-shardings imply.

``compress_grads`` optionally weight-shares the gradient payload before the
DP all-reduce (the paper's dictionary compression applied to the collective —
beyond-paper; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "adamw_update", "cosine_lr",
           "global_norm", "compress_grads", "nonfinite_probe", "tree_select"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def _f32_like(t):
    # integer leaves (PASM idx) get placeholder scalars — never updated
    return jax.tree.map(
        lambda x: jnp.zeros(x.shape if jnp.issubdtype(x.dtype, jnp.floating) else (), jnp.float32),
        t,
    )


def init_opt_state(params: Any) -> OptState:
    return OptState(step=jnp.zeros((), jnp.int32), mu=_f32_like(params), nu=_f32_like(params))


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
        if jnp.issubdtype(x.dtype, jnp.floating)
    ]
    return jnp.sqrt(sum(leaves))


def nonfinite_probe(loss: jax.Array, grads: Any) -> jax.Array:
    """ONE fused finiteness check over loss + every floating grad leaf.

    Returns a scalar bool: True iff the loss and *all* gradient elements are
    finite.  The reduction is a single ``isfinite`` on one accumulated
    scalar: each leaf contributes ``sum(g * 0)``, which is exactly ``0.0``
    when the leaf is all-finite and NaN otherwise (``inf * 0`` and
    ``nan * 0`` are both NaN in IEEE-754, and XLA does not strength-reduce
    float ``x * 0``), so the whole tree folds into one probe scalar inside
    the jitted step — no per-leaf host loop, no N boolean reductions
    (mirrors the serve engine's fused per-tick guard, DESIGN.md §2.4/§4).
    """
    z = loss.astype(jnp.float32)
    for g in jax.tree.leaves(grads):
        if jnp.issubdtype(g.dtype, jnp.floating):
            z = z + jnp.sum(g.astype(jnp.float32) * 0.0)
    return jnp.isfinite(z)


def tree_select(pred: jax.Array, on_true: Any, on_false: Any) -> Any:
    """Per-leaf ``where(pred, a, b)`` — the skip path of the non-finite
    guard: selecting the OLD leaves keeps params/opt_state bit-identical
    (no arithmetic touches them, ``where`` copies the operand bits)."""
    return jax.tree.map(lambda a, b: jnp.where(pred, a, b), on_true, on_false)


def adamw_update(
    params: Any, grads: Any, state: OptState, cfg: AdamWConfig
) -> tuple[Any, OptState, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return p, m, v  # integer leaves (PASM indices) are frozen
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# gradient compression (beyond paper): weight-share the all-reduce payload
# ---------------------------------------------------------------------------


def compress_grads(grads: Any, bins: int = 256) -> Any:
    """Quantize each gradient tensor to a ``bins``-entry dictionary (uniform
    quantiles of |g|) before the DP all-reduce — 2-byte bf16 → 1-byte index.

    This is the PASM storage trick applied to the collective payload.  The
    collective-bytes reduction shows up directly in the roofline collective
    term; the quantization error is bounded by the bin width (tested in
    tests/test_optimizer.py).
    """

    def one(g):
        if g.ndim < 2:
            return g
        gf = g.astype(jnp.float32)
        amax = jnp.max(jnp.abs(gf)) + 1e-12
        # symmetric uniform codebook — O(1) to build, deterministic
        scale = (bins / 2 - 1) / amax
        q = jnp.clip(jnp.round(gf * scale), -(bins / 2 - 1), bins / 2 - 1)
        return (q / scale).astype(g.dtype)

    return jax.tree.map(one, grads)
