"""FCFS slot scheduler for the continuous-batching engine.

The scheduler owns the waiting queue and the slot table; the engine asks it
each tick which requests to prefill into which free slots.  Admission is
strictly FCFS — a request is admitted the moment a slot is free (continuous
batching; no wave gate).  Prompts are padded up to a *length bucket* so the
per-bucket jitted prefill closures stay bounded: attention families use
power-of-two buckets (``pow2_bucket``), recurrent families (ssm/hybrid) use
exact lengths (``exact_bucket`` — their scans fold pad tokens into state, so
padded prompts are unsupported; see ``ssm_lm.prefill``).

Deadline/SLO accounting rides on :class:`repro.serve.metrics.Metrics`: each
request may carry a latency budget (``slo_s``) stamped into its Timeline at
submit; the rollup counts met/missed.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional

__all__ = ["pow2_bucket", "exact_bucket", "SlotPlan", "Scheduler"]


def pow2_bucket(n: int, *, lo: int = 8, hi: Optional[int] = None) -> int:
    """Smallest power of two ≥ max(n, lo), capped at ``hi``."""
    b = lo
    while b < n:
        b *= 2
    return min(b, hi) if hi is not None else b


def exact_bucket(n: int, *, lo: int = 1, hi: Optional[int] = None) -> int:
    """Bucket granularity 1 — for families that cannot pad prompts."""
    b = max(n, lo)
    return min(b, hi) if hi is not None else b


@dataclasses.dataclass
class SlotPlan:
    """One admission decision: request → slot, prompt padded to ``bucket``."""

    req: object  # engine Request (has .uid and .prompt)
    slot: int
    bucket: int


class Scheduler:
    """FCFS admission over length buckets + slot lifecycle."""

    def __init__(
        self,
        n_slots: int,
        *,
        bucket_fn: Callable[[int], int] = pow2_bucket,
        max_seq: Optional[int] = None,
    ):
        self.n_slots = n_slots
        self.bucket_fn = bucket_fn
        self.max_seq = max_seq
        self.waiting: Deque[object] = deque()
        self.slot_owner: List[Optional[int]] = [None] * n_slots  # uid per slot

    # -- queue/slot state ----------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return len(self.waiting)

    @property
    def free_slots(self) -> List[int]:
        return [i for i, uid in enumerate(self.slot_owner) if uid is None]

    @property
    def live_slots(self) -> int:
        return self.n_slots - len(self.free_slots)

    def submit(self, req) -> None:
        if self.max_seq is not None and len(req.prompt) > self.max_seq:
            raise ValueError(
                f"prompt length {len(req.prompt)} exceeds max_seq {self.max_seq}"
            )
        self.waiting.append(req)

    def admit(self) -> List[SlotPlan]:
        """FCFS: fill free slots from the head of the queue, in order."""
        plans: List[SlotPlan] = []
        free = self.free_slots
        while free and self.waiting:
            req = self.waiting.popleft()
            slot = free.pop(0)
            self.slot_owner[slot] = req.uid
            bucket = self.bucket_fn(len(req.prompt))
            if self.max_seq is not None:
                bucket = min(bucket, self.max_seq)
            plans.append(SlotPlan(req=req, slot=slot, bucket=bucket))
        return plans

    def release(self, slot: int) -> None:
        """Evict a completed request; the slot is immediately reusable."""
        self.slot_owner[slot] = None
