"""FCFS slot scheduler with bounded-queue admission control.

The scheduler owns the waiting queue and the slot table; the engine asks it
each tick which requests to prefill into which free slots.  Admission is
strictly FCFS — a request is admitted the moment a slot is free (continuous
batching; no wave gate).  Prompts are padded up to a *length bucket* so the
per-bucket jitted prefill closures stay bounded: attention families use
power-of-two buckets (``pow2_bucket``), recurrent families (ssm/hybrid) use
exact lengths (``exact_bucket`` — their scans fold pad tokens into state, so
padded prompts are unsupported; see ``ssm_lm.prefill``).

Fault tolerance (DESIGN.md §2.4):

- **Bounded queue + policy**: ``max_queue`` caps the waiting deque; an
  overflowing submit follows ``policy`` — ``"reject"`` (refuse the new
  request: :class:`QueueFullError`), ``"shed_oldest"`` (drop the head of the
  queue to make room), or ``"shed_expired"`` (first shed queued requests
  whose deadline already passed; reject only if none had).
- **Deadline shedding**: :meth:`shed_expired` removes queued requests whose
  ``deadline`` (absolute, stamped by the engine from ``slo_s``) has passed —
  prefill compute is never spent on a request that already blew its SLO.
- **Quarantine**: a slot whose occupant hit a numeric fault is quarantined —
  excluded from ``free_slots`` until the engine re-grafts the fresh cache
  template over its stripe and calls :meth:`release` — so poisoned KV never
  leaks to the next occupant.
- **Total-footprint validation**: submit validates
  ``len(prompt) + max_new - 1 <= max_seq`` (prefill writes the prompt, each
  subsequent decode writes one token), not just the prompt length — a long
  prompt with a default ``max_new`` used to decode past the KV cache end and
  silently wrap/clobber.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional

__all__ = [
    "pow2_bucket",
    "exact_bucket",
    "SlotPlan",
    "Scheduler",
    "QueueFullError",
    "ADMISSION_POLICIES",
]

ADMISSION_POLICIES = ("reject", "shed_oldest", "shed_expired")


class QueueFullError(RuntimeError):
    """Bounded queue overflow under ``policy="reject"`` (or no shed victim).

    ``shed`` carries requests the policy removed from the queue before the
    refusal (``shed_expired`` may shed and STILL reject when nothing had
    expired) — the caller must mark them failed even on this path.
    """

    def __init__(self, msg: str, shed: Optional[list] = None):
        super().__init__(msg)
        self.shed = list(shed or [])


def pow2_bucket(n: int, *, lo: int = 8, hi: Optional[int] = None) -> int:
    """Smallest power of two ≥ max(n, lo), capped at ``hi``."""
    b = lo
    while b < n:
        b *= 2
    return min(b, hi) if hi is not None else b


def exact_bucket(n: int, *, lo: int = 1, hi: Optional[int] = None) -> int:
    """Bucket granularity 1 — for families that cannot pad prompts."""
    b = max(n, lo)
    return min(b, hi) if hi is not None else b


@dataclasses.dataclass
class SlotPlan:
    """One admission decision: request → slot, prompt padded to ``bucket``."""

    req: object  # engine Request (has .uid and .prompt)
    slot: int
    bucket: int


class Scheduler:
    """FCFS admission over length buckets + slot lifecycle + backpressure."""

    def __init__(
        self,
        n_slots: int,
        *,
        bucket_fn: Callable[[int], int] = pow2_bucket,
        max_seq: Optional[int] = None,
        max_queue: Optional[int] = None,
        policy: str = "reject",
    ):
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"policy must be one of {ADMISSION_POLICIES}, got {policy!r}")
        self.n_slots = n_slots
        self.bucket_fn = bucket_fn
        self.max_seq = max_seq
        self.max_queue = max_queue
        self.policy = policy
        self.waiting: Deque[object] = deque()
        self.slot_owner: List[Optional[int]] = [None] * n_slots  # uid per slot
        self.quarantined: set[int] = set()

    # -- queue/slot state ----------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return len(self.waiting)

    @property
    def free_slots(self) -> List[int]:
        return [
            i
            for i, uid in enumerate(self.slot_owner)
            if uid is None and i not in self.quarantined
        ]

    @property
    def live_slots(self) -> int:
        return sum(uid is not None for uid in self.slot_owner)

    # -- admission control ---------------------------------------------------

    def validate(self, req) -> None:
        """Reject a request whose KV footprint cannot fit: prefill writes
        ``len(prompt)`` positions, then each of the ``max_new - 1`` decode
        steps writes one more (the first token comes from prefill)."""
        if self.max_seq is None:
            return
        n = len(req.prompt)
        if n > self.max_seq:
            raise ValueError(f"prompt length {n} exceeds max_seq {self.max_seq}")
        max_new = int(getattr(req, "max_new", 0))
        footprint = n + max(max_new, 1) - 1
        if footprint > self.max_seq:
            raise ValueError(
                f"prompt ({n}) + max_new ({max_new}) needs {footprint} KV "
                f"positions but max_seq is {self.max_seq} — decode would wrap "
                f"past the cache end"
            )

    def submit(self, req, *, now: Optional[float] = None) -> list:
        """Enqueue ``req``; returns requests the policy shed to make room.

        Raises :class:`QueueFullError` (carrying any shed victims) when the
        bounded queue stays full — ``"reject"`` always, ``"shed_expired"``
        when no queued request had expired.  ``now`` is the engine clock,
        used only for expiry decisions.
        """
        self.validate(req)
        shed: list = []
        if self.max_queue is not None and len(self.waiting) >= self.max_queue:
            if self.policy == "shed_expired" and now is not None:
                shed = self.shed_expired(now)
            elif self.policy == "shed_oldest" and self.waiting:
                shed = [self.waiting.popleft()]
            if len(self.waiting) >= self.max_queue:
                raise QueueFullError(
                    f"queue full ({len(self.waiting)}/{self.max_queue}) under "
                    f"policy={self.policy!r}",
                    shed=shed,
                )
        self.waiting.append(req)
        return shed

    def shed_expired(self, now: float) -> list:
        """Remove and return queued requests whose deadline has passed."""
        keep: Deque[object] = deque()
        shed: list = []
        for r in self.waiting:
            deadline = getattr(r, "deadline", None)
            if deadline is not None and now > deadline:
                shed.append(r)
            else:
                keep.append(r)
        self.waiting = keep
        return shed

    def requeue(self, req) -> None:
        """Re-enter a retryable request at the queue tail.  Retries bypass
        the bounded-queue policy: the request was already admitted once, and
        rejecting internal retry traffic would turn a transient fault into a
        capacity failure."""
        self.waiting.append(req)

    # -- slot lifecycle ------------------------------------------------------

    def admit(self) -> List[SlotPlan]:
        """FCFS: fill free (non-quarantined) slots from the queue head."""
        plans: List[SlotPlan] = []
        free = self.free_slots
        while free and self.waiting:
            req = self.waiting.popleft()
            slot = free.pop(0)
            self.slot_owner[slot] = req.uid
            bucket = self.bucket_fn(len(req.prompt))
            if self.max_seq is not None:
                bucket = min(bucket, self.max_seq)
            plans.append(SlotPlan(req=req, slot=slot, bucket=bucket))
        return plans

    def quarantine(self, slot: int) -> None:
        """Mark a slot's cache stripe poisoned: no reuse until the engine
        re-grafts the fresh template and calls :meth:`release`."""
        self.slot_owner[slot] = None
        self.quarantined.add(slot)

    def release(self, slot: int) -> None:
        """Evict a completed (or scrubbed) request; the slot is reusable."""
        self.slot_owner[slot] = None
        self.quarantined.discard(slot)
