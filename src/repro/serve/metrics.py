"""Per-request serving metrics: timestamps → p50/p99 rollups.

Every request carries a :class:`Timeline` of wall-clock marks
(queue → admit → first token → done-or-failed).  :class:`Metrics` owns the
timelines plus slot-occupancy and failure-mode counters and rolls them up
into the serving numbers the launcher prints and
``benchmarks/serve_bench.py`` emits as BENCH_serve.json: p50/p99 end-to-end
latency, p50/p99 time-to-first-token, tok/s, img/s, mean slot occupancy,
SLO hit/miss counts, the fault-tolerance counters
(``n_rejected``/``n_shed``/``n_evicted_deadline``/``n_quarantined``/
``n_retried``/``n_degraded``), and per-failure-kind latency rows
(``failed_<kind>_{n,p50,p99}_latency_s``).

A failed request's timeline is terminal (``t_done`` is stamped at failure)
but is EXCLUDED from the ``done`` population — throughput, latency
percentiles, and SLO accounting describe successfully served requests only;
the failure rows describe the rest.

The clock is injectable (``Metrics(clock=...)``) so tests can drive
deterministic timelines; everything here is pure Python — no jax.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, Optional

__all__ = ["Timeline", "Metrics", "percentile", "FAILURE_COUNTERS"]

# every rollup carries these (0 when never incremented), so bench gates and
# dashboards can read them unconditionally
FAILURE_COUNTERS = (
    "n_rejected",  # refused at submit (bounded queue, policy="reject")
    "n_shed",  # dropped from the queue (expired SLO or shed_oldest victim)
    "n_evicted_deadline",  # evicted mid-decode after blowing the deadline
    "n_quarantined",  # slots quarantined by the numeric (isfinite) guard
    "n_retried",  # re-queued with backoff after a retryable fault
    "n_degraded",  # closures flipped kernel → dequant dispatch
    "n_faults_decode",  # transient decode faults (tick replayed, no state change)
)


@dataclasses.dataclass
class Timeline:
    """Wall-clock marks for one request (seconds, from the Metrics clock)."""

    kind: str  # "lm" | "cnn"
    t_submit: float
    t_admit: float = math.nan
    t_first: float = math.nan  # first decode token / classification result
    t_done: float = math.nan  # terminal stamp: completion OR failure
    n_out: int = 0  # tokens generated (lm) or images classified (cnn: 1)
    slo_s: Optional[float] = None  # per-request latency budget
    stuck: bool = False
    failed: Optional[str] = None  # deadline | numeric | error | rejected

    @property
    def queue_s(self) -> float:
        return self.t_admit - self.t_submit

    @property
    def ttft_s(self) -> float:
        return self.t_first - self.t_submit

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def slo_met(self) -> Optional[bool]:
        if self.slo_s is None or math.isnan(self.t_done) or self.failed:
            return None
        return self.latency_s <= self.slo_s


def percentile(xs, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); nan on empty input."""
    xs = sorted(x for x in xs if not math.isnan(x))
    if not xs:
        return math.nan
    rank = max(0, min(len(xs) - 1, math.ceil(q / 100.0 * len(xs)) - 1))
    return xs[rank]


class Metrics:
    """Request timelines + occupancy/failure counters with a p50/p99 rollup."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.timelines: Dict[int, Timeline] = {}
        self.counters: Dict[str, int] = {}
        self._occ_ticks = 0
        self._occ_sum = 0.0

    # -- per-request marks ---------------------------------------------------

    def submit(self, uid, kind: str = "lm", *, slo_s: Optional[float] = None) -> Timeline:
        tl = Timeline(kind=kind, t_submit=self.clock(), slo_s=slo_s)
        self.timelines[uid] = tl
        return tl

    def mark_admit(self, uid):
        self.timelines[uid].t_admit = self.clock()

    def mark_first(self, uid):
        tl = self.timelines[uid]
        if math.isnan(tl.t_first):
            tl.t_first = self.clock()

    def mark_done(self, uid, n_out: int):
        tl = self.timelines[uid]
        tl.t_done = self.clock()
        tl.n_out = n_out

    def mark_failed(self, uid, kind: str, n_out: int = 0):
        """Terminal failure stamp: the request is over (its partial output,
        if any, is in ``n_out``) but never counts as served."""
        tl = self.timelines[uid]
        tl.t_done = self.clock()
        tl.failed = kind
        tl.n_out = n_out

    def mark_stuck(self, uid):
        self.timelines[uid].stuck = True

    def incr(self, name: str, n: int = 1):
        self.counters[name] = self.counters.get(name, 0) + n

    def tick_occupancy(self, live: int, slots: int):
        self._occ_ticks += 1
        self._occ_sum += live / max(slots, 1)

    # -- rollup --------------------------------------------------------------

    def rollup(self) -> dict:
        """All serving numbers in one dict (nan where no sample exists)."""
        done = [
            t
            for t in self.timelines.values()
            if not math.isnan(t.t_done) and t.failed is None
        ]
        out: dict = {"n_requests": len(self.timelines), "n_done": len(done),
                     "n_stuck": sum(t.stuck for t in self.timelines.values())}
        for kind, rate_name in (("lm", "tok_s"), ("cnn", "img_s")):
            ks = [t for t in done if t.kind == kind]
            lat = [t.latency_s for t in ks]
            out[f"{kind}_n"] = len(ks)
            out[f"{kind}_p50_latency_s"] = percentile(lat, 50)
            out[f"{kind}_p99_latency_s"] = percentile(lat, 99)
            out[f"{kind}_p50_ttft_s"] = percentile([t.ttft_s for t in ks], 50)
            out[f"{kind}_p99_ttft_s"] = percentile([t.ttft_s for t in ks], 99)
            if ks:
                t0 = min(t.t_submit for t in ks)
                t1 = max(t.t_done for t in ks)
                n = sum(t.n_out for t in ks)
                out[rate_name] = n / max(t1 - t0, 1e-9)
            else:
                out[rate_name] = math.nan
        slo = [t.slo_met for t in done if t.slo_met is not None]
        out["slo_met"] = sum(slo)
        out["slo_missed"] = len(slo) - sum(slo)
        out["mean_occupancy"] = (
            self._occ_sum / self._occ_ticks if self._occ_ticks else math.nan
        )
        # -- failure domains (DESIGN.md §2.4) --------------------------------
        for name in FAILURE_COUNTERS:
            out[name] = self.counters.get(name, 0)
        failed = [t for t in self.timelines.values() if t.failed]
        out["n_failed"] = len(failed)
        for kind in sorted({t.failed for t in failed}):
            ks = [t for t in failed if t.failed == kind]
            lat = [t.latency_s for t in ks]
            out[f"failed_{kind}_n"] = len(ks)
            out[f"failed_{kind}_p50_latency_s"] = percentile(lat, 50)
            out[f"failed_{kind}_p99_latency_s"] = percentile(lat, 99)
        return out
