"""Per-request serving metrics: timestamps → p50/p99 rollups.

Every request carries a :class:`Timeline` of wall-clock marks
(queue → admit → first token → done).  :class:`Metrics` owns the timelines
plus slot-occupancy counters and rolls them up into the serving numbers the
launcher prints and ``benchmarks/serve_bench.py`` emits as BENCH_serve.json:
p50/p99 end-to-end latency, p50/p99 time-to-first-token, tok/s, img/s,
mean slot occupancy, and SLO hit/miss counts.

The clock is injectable (``Metrics(clock=...)``) so tests can drive
deterministic timelines; everything here is pure Python — no jax.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, Optional

__all__ = ["Timeline", "Metrics", "percentile"]


@dataclasses.dataclass
class Timeline:
    """Wall-clock marks for one request (seconds, from the Metrics clock)."""

    kind: str  # "lm" | "cnn"
    t_submit: float
    t_admit: float = math.nan
    t_first: float = math.nan  # first decode token / classification result
    t_done: float = math.nan
    n_out: int = 0  # tokens generated (lm) or images classified (cnn: 1)
    slo_s: Optional[float] = None  # per-request latency budget
    stuck: bool = False

    @property
    def queue_s(self) -> float:
        return self.t_admit - self.t_submit

    @property
    def ttft_s(self) -> float:
        return self.t_first - self.t_submit

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def slo_met(self) -> Optional[bool]:
        if self.slo_s is None or math.isnan(self.t_done):
            return None
        return self.latency_s <= self.slo_s


def percentile(xs, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); nan on empty input."""
    xs = sorted(x for x in xs if not math.isnan(x))
    if not xs:
        return math.nan
    rank = max(0, min(len(xs) - 1, math.ceil(q / 100.0 * len(xs)) - 1))
    return xs[rank]


class Metrics:
    """Request timelines + occupancy counters with a p50/p99 rollup."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.timelines: Dict[int, Timeline] = {}
        self._occ_ticks = 0
        self._occ_sum = 0.0

    # -- per-request marks ---------------------------------------------------

    def submit(self, uid: int, kind: str = "lm", *, slo_s: Optional[float] = None) -> Timeline:
        tl = Timeline(kind=kind, t_submit=self.clock(), slo_s=slo_s)
        self.timelines[uid] = tl
        return tl

    def mark_admit(self, uid: int):
        self.timelines[uid].t_admit = self.clock()

    def mark_first(self, uid: int):
        tl = self.timelines[uid]
        if math.isnan(tl.t_first):
            tl.t_first = self.clock()

    def mark_done(self, uid: int, n_out: int):
        tl = self.timelines[uid]
        tl.t_done = self.clock()
        tl.n_out = n_out

    def mark_stuck(self, uid: int):
        self.timelines[uid].stuck = True

    def tick_occupancy(self, live: int, slots: int):
        self._occ_ticks += 1
        self._occ_sum += live / max(slots, 1)

    # -- rollup --------------------------------------------------------------

    def rollup(self) -> dict:
        """All serving numbers in one dict (nan where no sample exists)."""
        done = [t for t in self.timelines.values() if not math.isnan(t.t_done)]
        out: dict = {"n_requests": len(self.timelines), "n_done": len(done),
                     "n_stuck": sum(t.stuck for t in self.timelines.values())}
        for kind, rate_name in (("lm", "tok_s"), ("cnn", "img_s")):
            ks = [t for t in done if t.kind == kind]
            lat = [t.latency_s for t in ks]
            out[f"{kind}_n"] = len(ks)
            out[f"{kind}_p50_latency_s"] = percentile(lat, 50)
            out[f"{kind}_p99_latency_s"] = percentile(lat, 99)
            out[f"{kind}_p50_ttft_s"] = percentile([t.ttft_s for t in ks], 50)
            out[f"{kind}_p99_ttft_s"] = percentile([t.ttft_s for t in ks], 99)
            if ks:
                t0 = min(t.t_submit for t in ks)
                t1 = max(t.t_done for t in ks)
                n = sum(t.n_out for t in ks)
                out[rate_name] = n / max(t1 - t0, 1e-9)
            else:
                out[rate_name] = math.nan
        slo = [t.slo_met for t in done if t.slo_met is not None]
        out["slo_met"] = sum(slo)
        out["slo_missed"] = len(slo) - sum(slo)
        out["mean_occupancy"] = (
            self._occ_sum / self._occ_ticks if self._occ_ticks else math.nan
        )
        return out
