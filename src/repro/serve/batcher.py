"""Continuous batcher for mixed LM + CNN traffic.

LM decode slots live in :class:`repro.serve.engine.Engine`; this module adds
the image-classification side and the loop that serves both:

- :class:`CnnBatcher` queues variable-sized images, rounds each up to an
  H×W *shape bucket* (host-side zero-pad), and flushes every bucket through
  ONE jitted classify closure per bucket.  Inside the jit the bucket pads up
  to the model's native ``cfg.in_chw`` — the fused conv2d stack has a fixed
  input geometry, so bucketing caps closure count while arbitrary (smaller)
  images still classify.  Zero-padding is exact for the PASM conv stack:
  SAME/VALID conv over zero rows adds zero patches, and the classifier head
  sees the same feature map as a natively-sized zero-extended image.
- :class:`MixedBatcher` interleaves one engine tick (admit + decode every
  live LM slot) with a CNN flush per service tick, so both traffic classes
  share the process continuously — neither waits for the other to drain.

Metrics ride the same :class:`repro.serve.metrics.Metrics` rollup (img/s,
p50/p99 latency) using ``"cnn-<n>"`` uids so a shared Metrics instance never
collides with the engine's integer LM uids.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.metrics import Metrics

__all__ = ["CnnRequest", "CnnBatcher", "MixedBatcher", "default_hw_buckets"]


def default_hw_buckets(native_hw: Tuple[int, int]) -> List[Tuple[int, int]]:
    """Power-of-two-ish H×W ladder up to (and including) the native size."""
    H, W = native_hw
    ladder = []
    h = 8
    while h < max(H, W):
        ladder.append((min(h, H), min(h, W)))
        h *= 2
    ladder.append((H, W))
    return sorted(set(ladder))


@dataclasses.dataclass
class CnnRequest:
    uid: str
    image: np.ndarray  # (C, H, W) float32
    bucket: Tuple[int, int]
    cls: Optional[int] = None
    done: bool = False
    stuck: bool = False


class CnnBatcher:
    """Shape-bucketed image classification through the fused conv2d stack."""

    def __init__(
        self,
        cfg,  # CNNConfig
        params,
        *,
        max_batch: int = 8,
        buckets: Optional[List[Tuple[int, int]]] = None,
        metrics: Optional[Metrics] = None,
        clock: Callable[[], float] = time.perf_counter,
        interpret: Optional[bool] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        C, H, W = cfg.in_chw
        self.native_hw = (H, W)
        self.buckets = sorted(buckets or default_hw_buckets((H, W)))
        self.metrics = metrics if metrics is not None else Metrics(clock=clock)
        self.interpret = interpret
        self.waiting: deque[CnnRequest] = deque()
        self._n = 0
        self._classify: Dict[Tuple[int, int], Callable] = {}

    def _bucket_for(self, h: int, w: int) -> Tuple[int, int]:
        for bh, bw in self.buckets:
            if h <= bh and w <= bw:
                return (bh, bw)
        raise ValueError(
            f"image {h}x{w} exceeds native input {self.native_hw} "
            f"(buckets: {self.buckets})"
        )

    def _classify_fn(self, bucket: Tuple[int, int]) -> Callable:
        if bucket not in self._classify:
            from repro.models import cnn as _cnn

            cfg, (bh, bw) = self.cfg, bucket
            C, (H, W) = cfg.in_chw[0], self.native_hw

            def f(params, images):  # (max_batch, C, bh, bw) → (max_batch, classes)
                x = jnp.pad(images, ((0, 0), (0, 0), (0, H - bh), (0, W - bw)))
                if cfg.layout == "NHWC":
                    x = jnp.transpose(x, (0, 2, 3, 1))
                return _cnn.forward(params, x, cfg, interpret=self.interpret)

            self._classify[bucket] = jax.jit(f)
        return self._classify[bucket]

    # -- request lifecycle ---------------------------------------------------

    def submit(self, image: np.ndarray, *, slo_s: Optional[float] = None) -> CnnRequest:
        image = np.asarray(image, np.float32)
        if image.ndim != 3 or image.shape[0] != self.cfg.in_chw[0]:
            raise ValueError(f"expected (C={self.cfg.in_chw[0]}, H, W), got {image.shape}")
        self._n += 1
        r = CnnRequest(
            uid=f"cnn-{self._n}", image=image,
            bucket=self._bucket_for(image.shape[1], image.shape[2]),
        )
        self.waiting.append(r)
        self.metrics.submit(r.uid, "cnn", slo_s=slo_s)
        return r

    def flush(self) -> List[CnnRequest]:
        """Serve every waiting image: group by bucket, pad, classify."""
        by_bucket: Dict[Tuple[int, int], List[CnnRequest]] = {}
        while self.waiting:
            r = self.waiting.popleft()
            by_bucket.setdefault(r.bucket, []).append(r)
        served: List[CnnRequest] = []
        for bucket, reqs in by_bucket.items():
            bh, bw = bucket
            C = self.cfg.in_chw[0]
            for i in range(0, len(reqs), self.max_batch):
                chunk = reqs[i : i + self.max_batch]
                imgs = np.zeros((self.max_batch, C, bh, bw), np.float32)
                for j, r in enumerate(chunk):
                    h, w = r.image.shape[1:]
                    imgs[j, :, :h, :w] = r.image
                    self.metrics.mark_admit(r.uid)
                logits = self._classify_fn(bucket)(self.params, jnp.asarray(imgs))
                cls = np.asarray(jnp.argmax(logits, axis=-1))
                for j, r in enumerate(chunk):
                    r.cls = int(cls[j])
                    r.done = True
                    self.metrics.mark_first(r.uid)
                    self.metrics.mark_done(r.uid, 1)
                served.extend(chunk)
        return served


class MixedBatcher:
    """One service loop over both traffic classes: every tick runs one LM
    engine step (continuous admit + batched decode) and one CNN flush."""

    def __init__(self, engine, cnn: Optional[CnnBatcher] = None):
        self.engine = engine
        self.cnn = cnn

    @property
    def drained(self) -> bool:
        # engine.busy covers live slots, the queue, AND pending retries —
        # a backoff-delayed retry keeps the loop ticking until it resolves
        lm_done = not self.engine.busy
        cnn_done = self.cnn is None or not self.cnn.waiting
        return lm_done and cnn_done

    def tick(self):
        self.engine.step()
        if self.cnn is not None:
            self.cnn.flush()

    def run_until_drained(self, max_ticks: int = 1000, *, strict: bool = True) -> int:
        t = 0
        while not self.drained and t < max_ticks:
            self.tick()
            t += 1
        if not self.drained:
            msg = f"MixedBatcher: traffic undrained after {max_ticks} ticks"
            if strict:
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return t
