"""Deterministic fault injection for the serve stack.

A :class:`FaultPlan` is a *seeded, fully reproducible* schedule of faults —
no wall-clock anywhere: every fault is keyed to the engine's integer tick
counter, a slot index, or a request uid.  The engine calls the plan's thin
hook interface at its phase boundaries (tick start, prefill, decode,
closure dispatch), so chaos tests can assert three things about the same
injected schedule every run:

- unaffected requests' token streams stay **bit-identical** to a fault-free
  run (injection is side-effect-free outside the targeted slot/request);
- affected requests terminate with the right ``failed:*`` status;
- the engine always drains.

Fault kinds (``FaultSpec.kind``):

=========  ===============================================================
``nan``    poison slot ``slot``'s decode logits with NaN at tick ``tick``
           (exercises the numeric guard + slot quarantine path)
``prefill``  raise :class:`FaultInjected` on request ``uid``'s ``nth``
           admission attempt (transient error → retry with backoff)
``decode`` raise :class:`FaultInjected` before the batched decode at tick
           ``tick`` (whole-tick transient: the tick is a side-effect-free
           no-op and is replayed next tick — bit-exactness preserved)
``slow``   a latency spike: the engine sleeps ``delay_s`` at tick ``tick``
           (with an injected tick-clock this deterministically blows
           deadlines; with the real clock it is a genuine stall)
``kernel`` persistent per-closure failure: ``kernel_broken(key)`` stays
           true until the engine degrades that closure's dispatch from the
           Pallas kernel to the dequant oracle path (graceful degradation)
=========  ===============================================================
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Tuple

import numpy as np

__all__ = ["FAULT_KINDS", "FaultInjected", "FaultSpec", "FaultPlan"]

FAULT_KINDS = ("nan", "prefill", "decode", "slow", "kernel")


class FaultInjected(RuntimeError):
    """Raised by a :class:`FaultPlan` hook at the scheduled phase boundary."""

    def __init__(self, kind: str, detail: str = ""):
        super().__init__(f"injected {kind} fault" + (f" ({detail})" if detail else ""))
        self.kind = kind


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.  Only the fields its ``kind`` reads matter."""

    kind: str
    tick: int = 0  # nan | decode | slow: engine tick the fault fires on
    slot: int = 0  # nan: logits row to poison
    uid: int = 0  # prefill: target request uid
    nth: int = 1  # prefill: which admission attempt fails (1 = first)
    delay_s: float = 0.0  # slow: clock advance / sleep
    key: str = "decode"  # kernel: closure key ("decode" | "prefill:<bucket>")

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"kind must be one of {FAULT_KINDS}, got {self.kind!r}")


class FaultPlan:
    """A reproducible fault schedule plus the hooks the engine calls.

    Build explicitly from :class:`FaultSpec` s, or sample a schedule from a
    seed with :meth:`sample` (same seed ⇒ identical schedule, always — the
    plan never reads a clock or unseeded RNG).  ``fired`` records every hook
    activation ``(kind, detail...)`` in order, for test assertions.
    """

    def __init__(self, faults: Iterable[FaultSpec] = ()):
        self.faults: Tuple[FaultSpec, ...] = tuple(faults)
        self.fired: List[tuple] = []
        self._prefill_seen: dict = {}  # uid -> admission attempts observed

    @classmethod
    def sample(
        cls,
        seed: int,
        *,
        n_ticks: int,
        n_slots: int,
        n_requests: int,
        n_nan: int = 1,
        n_prefill: int = 1,
        n_decode: int = 1,
        n_slow: int = 0,
        slow_delay_s: float = 0.0,
        n_kernel: int = 0,
    ) -> "FaultPlan":
        """Draw a schedule from ``seed``: NaN/decode/slow faults land on
        ticks in ``[2, n_ticks)`` (tick 1 is the first admissions tick),
        prefill faults target uids in ``[1, n_requests]``."""
        rng = np.random.default_rng(seed)
        lo, hi = 2, max(3, n_ticks)
        faults: List[FaultSpec] = []
        for _ in range(n_nan):
            faults.append(FaultSpec("nan", tick=int(rng.integers(lo, hi)),
                                    slot=int(rng.integers(0, n_slots))))
        for _ in range(n_prefill):
            faults.append(FaultSpec("prefill", uid=int(rng.integers(1, n_requests + 1))))
        for _ in range(n_decode):
            faults.append(FaultSpec("decode", tick=int(rng.integers(lo, hi))))
        for _ in range(n_slow):
            faults.append(FaultSpec("slow", tick=int(rng.integers(lo, hi)),
                                    delay_s=slow_delay_s))
        for _ in range(n_kernel):
            faults.append(FaultSpec("kernel"))
        return cls(faults)

    # -- hooks the engine calls at its phase boundaries ----------------------

    def on_tick(self, tick: int) -> float:
        """Total ``slow`` delay scheduled at this tick (0.0 when none)."""
        d = sum(f.delay_s for f in self.faults if f.kind == "slow" and f.tick == tick)
        if d:
            self.fired.append(("slow", tick, d))
        return d

    def on_prefill(self, uid: int, tick: int) -> None:
        """Raise if ``uid``'s current admission attempt is scheduled to fail."""
        n = self._prefill_seen.get(uid, 0) + 1
        self._prefill_seen[uid] = n
        for f in self.faults:
            if f.kind == "prefill" and f.uid == uid and f.nth == n:
                self.fired.append(("prefill", uid, n, tick))
                raise FaultInjected("prefill", f"uid={uid} attempt={n}")

    def on_decode(self, tick: int) -> None:
        """Raise (transient, whole tick) if a decode fault lands on this tick."""
        for f in self.faults:
            if f.kind == "decode" and f.tick == tick:
                self.fired.append(("decode", tick))
                raise FaultInjected("decode", f"tick={tick}")

    def poison_slots(self, tick: int) -> List[int]:
        """Slots whose decode logits get NaN-poisoned at this tick."""
        slots = [f.slot for f in self.faults if f.kind == "nan" and f.tick == tick]
        if slots:
            self.fired.append(("nan", tick, tuple(slots)))
        return slots

    def kernel_broken(self, key: str) -> bool:
        """Persistent per-closure kernel failure — true on EVERY consult
        until the engine degrades the closure (the engine stops consulting
        once ``key`` is on the dequant path)."""
        hit = any(f.kind == "kernel" and f.key == key for f in self.faults)
        if hit:
            self.fired.append(("kernel", key))
        return hit
