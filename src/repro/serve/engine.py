"""Serving engine: continuous batching over prefill/decode with PASM weights.

Admission is CONTINUOUS: the moment a slot is free, the next waiting request
prefills into it while every other slot keeps decoding — no wave gate.  The
machinery that makes this exact:

- ``KVCache.pos`` is per-slot (``(B,)`` — nn/attention.py), so each slot's
  reads/writes are masked at its own position and a mid-decode prefill never
  advances a counter under a live slot.
- Prefill runs batch-of-one against a FRESH single-slot cache, padded to a
  length bucket (one jitted closure per bucket), then the resulting cache is
  grafted into the batched cache at the slot index along each leaf's batch
  axis.  A reused slot therefore never sees the previous occupant's KV, and
  a request's prefill is the *same computation* loaded or alone — the basis
  for the bit-exactness proof in tests/test_serve.py.
- The batch axis of every cache leaf is inferred once by diffing
  ``jax.eval_shape`` of ``init_caches`` at two batch sizes (works for all
  four families without per-family graft code).

Scheduling (FCFS, length buckets, slot eviction) lives in
serve/scheduler.py; per-request SLO/latency accounting in serve/metrics.py.
Weights are PASM-quantized by default: decode is bandwidth-bound, so the
4–8× weight-byte reduction is the paper's win applied where it matters
(DESIGN.md §2; measured in benchmarks/serve_bench.py).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api
from repro.serve.metrics import Metrics
from repro.serve.scheduler import Scheduler, exact_bucket, pow2_bucket

__all__ = ["Request", "Engine"]

# Families whose prefill supports right-padded prompts (``lengths=``).  The
# recurrent scans (ssm/hybrid) fold every input token into state, so they
# prefill at exact length (bucket granularity 1 — see ssm_lm.prefill).
_PADDED_FAMILIES = ("dense", "moe", "vlm", "audio")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    slo_s: Optional[float] = None
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    stuck: bool = False
    slot: int = -1


def _infer_batch_axes(model, cfg, max_seq):
    """Per-leaf batch axis of the cache pytree (eval_shape diff at B=2 vs 3)."""
    s2 = jax.eval_shape(lambda: model.init_caches(cfg, 2, max_seq))
    s3 = jax.eval_shape(lambda: model.init_caches(cfg, 3, max_seq))

    def ax(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        if len(diffs) != 1:
            raise ValueError(f"cache leaf has no unique batch axis: {a.shape} vs {b.shape}")
        return diffs[0]

    return jax.tree.map(ax, s2, s3)


class Engine:
    """Continuously batched autoregressive server for any registered arch."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        batch_slots: int = 4,
        max_seq: int = 256,
        greedy: bool = True,
        clock: Callable[[], float] = time.perf_counter,
        metrics: Optional[Metrics] = None,
    ):
        self.cfg = cfg
        self.model = api.get_model(cfg)
        self.params = params
        self.batch = batch_slots
        self.max_seq = max_seq
        self.greedy = greedy
        self.supports_lengths = cfg.family in _PADDED_FAMILIES
        bucket = pow2_bucket if self.supports_lengths else exact_bucket
        self.sched = Scheduler(
            batch_slots,
            bucket_fn=functools.partial(bucket, hi=max_seq),
            max_seq=max_seq,
        )
        self.metrics = metrics if metrics is not None else Metrics(clock=clock)
        self.live: dict[int, Request] = {}
        self._uid = 0

        # one long-lived batched cache + a fresh single-slot template for
        # every admission (prefill never mutates its input)
        self.caches = self.model.init_caches(cfg, self.batch, max_seq)
        self._one_template = self.model.init_caches(cfg, 1, max_seq)
        self._slot_axes = _infer_batch_axes(self.model, cfg, max_seq)

        def _decode(params, tokens, caches):
            return self.model.decode_step(params, tokens, caches, cfg)

        def _graft(big, one, slot):
            return jax.tree.map(
                lambda b, o, a: jax.lax.dynamic_update_slice_in_dim(
                    b, o.astype(b.dtype), slot, axis=a
                ),
                big, one, self._slot_axes,
            )

        self._decode = jax.jit(_decode)
        self._graft = jax.jit(_graft)
        self._prefill_by_bucket: dict[int, Callable] = {}

    # -- jitted prefill per length bucket ------------------------------------

    def _prefill_fn(self, bucket: int) -> Callable:
        if bucket not in self._prefill_by_bucket:
            if self.supports_lengths:
                def f(params, tokens, lengths, caches):
                    return self.model.prefill(
                        params, tokens, caches, self.cfg, lengths=lengths
                    )
            else:  # exact-length prompt: no pads, lengths unused
                def f(params, tokens, lengths, caches):
                    del lengths
                    return self.model.prefill(params, tokens, caches, self.cfg)
            self._prefill_by_bucket[bucket] = jax.jit(f)
        return self._prefill_by_bucket[bucket]

    # -- request lifecycle ---------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new: int = 16,
               *, slo_s: Optional[float] = None) -> Request:
        self._uid += 1
        r = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                    max_new=max_new, slo_s=slo_s)
        self.sched.submit(r)
        self.metrics.submit(r.uid, "lm", slo_s=slo_s)
        return r

    @property
    def waiting(self):
        return self.sched.waiting

    def _admit(self):
        """Continuous admission: prefill each planned request immediately.

        Batch-of-one prefill against the fresh template, right-padded to the
        scheduler's length bucket, then graft into the batched cache at the
        slot — live slots keep their per-slot positions untouched.
        """
        for plan in self.sched.admit():
            r = plan.req
            S = max(plan.bucket, len(r.prompt))
            toks = np.zeros((1, S), np.int32)
            toks[0, : len(r.prompt)] = r.prompt  # right-pad (left-aligned)
            lengths = jnp.array([len(r.prompt)], jnp.int32)
            logits, one_caches = self._prefill_fn(S)(
                self.params, jnp.asarray(toks), lengths, self._one_template
            )
            self.caches = self._graft(
                self.caches, one_caches, jnp.asarray(plan.slot, jnp.int32)
            )
            r.slot = plan.slot
            r.out.append(int(np.asarray(jnp.argmax(logits[0, -1], axis=-1))))
            self.live[r.uid] = r
            self.metrics.mark_admit(r.uid)
            self.metrics.mark_first(r.uid)

    def step(self):
        """One engine tick: admit waiting requests, then decode one token
        for every live slot (dead slots decode a dummy token, ignored)."""
        self._admit()
        if not self.live:
            return
        toks = np.zeros((self.batch, 1), np.int32)
        for r in self.live.values():
            toks[r.slot, 0] = r.out[-1]
        logits, self.caches = self._decode(self.params, jnp.asarray(toks), self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        finished = []
        for r in self.live.values():
            r.out.append(int(nxt[r.slot]))
            if len(r.out) >= r.max_new:
                r.done = True
                finished.append(r)
        for r in finished:
            del self.live[r.uid]
            self.sched.release(r.slot)
            self.metrics.mark_done(r.uid, len(r.out))
        self.metrics.tick_occupancy(len(self.live) + len(finished), self.batch)

    def run_until_drained(self, max_ticks: int = 1000, *, strict: bool = True) -> int:
        """Tick until every request finishes.  If ``max_ticks`` hits with
        requests still live/queued, mark them ``stuck`` and raise (or warn
        when ``strict=False``) instead of silently returning."""
        t = 0
        while (self.live or self.sched.waiting) and t < max_ticks:
            self.step()
            t += 1
        leftover = list(self.live.values()) + list(self.sched.waiting)
        if leftover:
            for r in leftover:
                r.stuck = True
                self.metrics.mark_stuck(r.uid)
            msg = (
                f"run_until_drained: {len(leftover)} request(s) undrained after "
                f"{max_ticks} ticks (uids {[r.uid for r in leftover]})"
            )
            if strict:
                raise RuntimeError(msg)
            print(f"[engine] WARNING: {msg}")
        return t
