"""Serving engine: continuous batching over prefill/decode with PASM weights.

The engine owns jitted ``prefill`` and ``decode_step`` closures and a slot
table.  Requests join a waiting queue; free slots get prefilled (one prompt
at a time here — a fleet deployment maps slots across the batch dim of the
production mesh) and every engine tick decodes ONE token for all live slots.
Weights are PASM-quantized by default: decode is bandwidth-bound, so the
4–8× weight-byte reduction is the paper's win applied where it matters
(DESIGN.md §2; measured in benchmarks/pasm_roofline.py).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api
from repro.models.common import ShardCtx, quantize_params

__all__ = ["Request", "Engine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    slot: int = -1


class Engine:
    """Batched autoregressive server for any registered arch."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        batch_slots: int = 4,
        max_seq: int = 256,
        greedy: bool = True,
    ):
        self.cfg = cfg
        self.model = api.get_model(cfg)
        self.params = params
        self.batch = batch_slots
        self.max_seq = max_seq
        self.greedy = greedy
        self.caches = self.model.init_caches(cfg, batch_slots, max_seq)
        self.live: dict[int, Request] = {}
        self.waiting: deque[Request] = deque()
        self._uid = 0

        def _prefill(params, tokens, caches):
            return self.model.prefill(params, tokens, caches, cfg)

        def _decode(params, tokens, caches):
            return self.model.decode_step(params, tokens, caches, cfg)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    # -- request lifecycle ---------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> Request:
        self._uid += 1
        r = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32), max_new=max_new)
        self.waiting.append(r)
        return r

    def _admit(self):
        """Prefill waiting requests into free slots.

        The per-slot cache model here assumes slot-aligned prompts (all slots
        share one position counter); the production path pads prompts to a
        common length per admission wave — standard continuous-batching
        behaviour for step-synchronized decoders.
        """
        free = [s for s in range(self.batch) if s not in {r.slot for r in self.live.values()}]
        admitted = []
        while free and self.waiting:
            r = self.waiting.popleft()
            r.slot = free.pop(0)
            admitted.append(r)
        if not admitted:
            return
        # batch the admitted prompts (padded to equal length)
        S = max(len(r.prompt) for r in admitted)
        toks = np.zeros((self.batch, S), np.int32)
        for r in admitted:
            toks[r.slot, S - len(r.prompt):] = r.prompt  # left-pad
        logits, self.caches = self._prefill(self.params, jnp.asarray(toks), self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for r in admitted:
            r.out.append(int(nxt[r.slot]))
            self.live[r.uid] = r

    def step(self):
        """One engine tick: admit + decode one token for every live slot."""
        self._admit()
        if not self.live:
            return
        toks = np.zeros((self.batch, 1), np.int32)
        for r in self.live.values():
            toks[r.slot, 0] = r.out[-1]
        logits, self.caches = self._decode(self.params, jnp.asarray(toks), self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        finished = []
        for r in self.live.values():
            r.out.append(int(nxt[r.slot]))
            if len(r.out) >= r.max_new:
                r.done = True
                finished.append(r.uid)
        for uid in finished:
            del self.live[uid]

    def run_until_drained(self, max_ticks: int = 1000):
        t = 0
        while (self.live or self.waiting) and t < max_ticks:
            self.step()
            t += 1
        return t
