"""Serving engine: continuous batching over prefill/decode with PASM weights.

The engine owns jitted ``prefill`` and ``decode_step`` closures and a slot
table.  Requests join a waiting queue and are admitted in WAVES: when no
slot is live, up to ``batch_slots`` waiting prompts prefill together against
fresh caches (a fleet deployment maps slots across the batch dim of the
production mesh) and every engine tick decodes ONE token for all live slots.
Wave admission exists because the KV caches share one position counter —
see :meth:`Engine._admit` for the invariant and DESIGN.md §2 for the
serving context.
Weights are PASM-quantized by default: decode is bandwidth-bound, so the
4–8× weight-byte reduction is the paper's win applied where it matters
(DESIGN.md §2; measured in benchmarks/pasm_roofline.py).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api
from repro.models.common import ShardCtx, quantize_params

__all__ = ["Request", "Engine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    slot: int = -1


class Engine:
    """Batched autoregressive server for any registered arch."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        batch_slots: int = 4,
        max_seq: int = 256,
        greedy: bool = True,
    ):
        self.cfg = cfg
        self.model = api.get_model(cfg)
        self.params = params
        self.batch = batch_slots
        self.max_seq = max_seq
        self.greedy = greedy
        self.caches = None  # built fresh per admission wave (see _admit)
        self.live: dict[int, Request] = {}
        self.waiting: deque[Request] = deque()
        self._uid = 0

        def _prefill(params, tokens, caches):
            return self.model.prefill(params, tokens, caches, cfg)

        def _decode(params, tokens, caches):
            return self.model.decode_step(params, tokens, caches, cfg)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    # -- request lifecycle ---------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> Request:
        self._uid += 1
        r = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32), max_new=max_new)
        self.waiting.append(r)
        return r

    def _admit(self):
        """Prefill waiting requests into slots — one WAVE at a time.

        Admission is gated to ticks with no live slot.  The cache model is
        slot-batched but shares ONE position counter (``KVCache.pos`` is a
        scalar), so a mid-decode prefill would run the whole batch — zero
        tokens in live slots — through ``prefill``, overwriting live slots'
        KV entries at the current position and advancing the shared counter
        under them (the bug regression-tested in tests/test_engine.py).
        Per-slot position counters (true continuous batching) are a ROADMAP
        item; until then waves are the correct admission unit for
        step-synchronized decoders.
        """
        if self.live:
            return
        admitted = []
        free = list(range(self.batch))
        while free and self.waiting:
            r = self.waiting.popleft()
            r.slot = free.pop(0)
            admitted.append(r)
        if not admitted:
            return
        # fresh caches per wave: the previous wave's KV must not be a visible
        # attention prefix for the new prompts (pos never rewinds mid-wave)
        self.caches = self.model.init_caches(self.cfg, self.batch, self.max_seq)
        # batch the admitted prompts (padded to equal length)
        S = max(len(r.prompt) for r in admitted)
        toks = np.zeros((self.batch, S), np.int32)
        for r in admitted:
            toks[r.slot, S - len(r.prompt):] = r.prompt  # left-pad
        logits, self.caches = self._prefill(self.params, jnp.asarray(toks), self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for r in admitted:
            r.out.append(int(nxt[r.slot]))
            self.live[r.uid] = r

    def step(self):
        """One engine tick: admit + decode one token for every live slot."""
        self._admit()
        if not self.live:
            return
        toks = np.zeros((self.batch, 1), np.int32)
        for r in self.live.values():
            toks[r.slot, 0] = r.out[-1]
        logits, self.caches = self._decode(self.params, jnp.asarray(toks), self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        finished = []
        for r in self.live.values():
            r.out.append(int(nxt[r.slot]))
            if len(r.out) >= r.max_new:
                r.done = True
                finished.append(r.uid)
        for uid in finished:
            del self.live[uid]

    def run_until_drained(self, max_ticks: int = 1000):
        t = 0
        while (self.live or self.waiting) and t < max_ticks:
            self.step()
            t += 1
        return t
