"""Serving engine: continuous batching over prefill/decode with PASM weights.

Admission is CONTINUOUS: the moment a slot is free, the next waiting request
prefills into it while every other slot keeps decoding — no wave gate.  The
machinery that makes this exact:

- ``KVCache.pos`` is per-slot (``(B,)`` — nn/attention.py), so each slot's
  reads/writes are masked at its own position and a mid-decode prefill never
  advances a counter under a live slot.
- Prefill runs batch-of-one against a FRESH single-slot cache, padded to a
  length bucket (one jitted closure per bucket), then the resulting cache is
  grafted into the batched cache at the slot index along each leaf's batch
  axis.  A reused slot therefore never sees the previous occupant's KV, and
  a request's prefill is the *same computation* loaded or alone — the basis
  for the bit-exactness proof in tests/test_serve.py.
- The batch axis of every cache leaf is inferred once by diffing
  ``jax.eval_shape`` of ``init_caches`` at two batch sizes (works for all
  four families without per-family graft code).

Fault tolerance (DESIGN.md §2.4) — every leg flows through ``step()``:

- **Deadlines + backpressure**: the scheduler's queue is bounded with an
  admission policy (``reject | shed_oldest | shed_expired``); queued
  requests whose ``slo_s`` expired are shed before prefill is spent on
  them, and (``deadline_eviction=True``) a live request that blows its
  deadline mid-decode is evicted, its partial output returned with
  ``failed="deadline"``.
- **Numeric guards + quarantine**: ONE fused ``isfinite`` reduction per
  tick (per-slot bool, fused into the argmax jit — never a per-element
  host loop) detects NaN/Inf logits; the slot is quarantined and its cache
  stripe re-grafted from the fresh template before reuse, so poisoned KV
  never leaks to the next occupant.
- **Retry + degradation**: retryable failures (numeric, injected transient
  errors) re-enter the queue up to ``max_retries`` with capped exponential
  tick-based backoff; a persistent kernel failure at a jit boundary flips
  that closure's dispatch from the Pallas ``kernel`` path to the
  ``dequant`` oracle once, memoized — degraded but serving.
- **Fault hooks**: a seeded :class:`~repro.serve.faults.FaultPlan` injects
  NaN/raise/slow faults at the engine's phase boundaries, fully
  deterministic (tick/slot/uid keyed — no wall clock).

Scheduling (FCFS, length buckets, quarantine, backpressure) lives in
serve/scheduler.py; per-request SLO/latency/failure accounting in
serve/metrics.py.  Weights are PASM-quantized by default: decode is
bandwidth-bound, so the 4–8× weight-byte reduction is the paper's win
applied where it matters (DESIGN.md §2; measured in
benchmarks/serve_bench.py).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api
from repro.serve.faults import FaultInjected, FaultPlan
from repro.serve.metrics import Metrics
from repro.serve.scheduler import QueueFullError, Scheduler, exact_bucket, pow2_bucket

__all__ = ["Request", "Engine"]

# Families whose prefill supports right-padded prompts (``lengths=``).  The
# recurrent scans (ssm/hybrid) fold every input token into state, so they
# prefill at exact length (bucket granularity 1 — see ssm_lm.prefill).
_PADDED_FAMILIES = ("dense", "moe", "vlm", "audio")

# failure kinds that re-enter the queue (deadline/rejected are final: the
# latency budget is spent / the queue refused them)
_RETRYABLE = ("numeric", "error")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    slo_s: Optional[float] = None
    deadline: Optional[float] = None  # absolute, on the metrics clock
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    stuck: bool = False
    failed: Optional[str] = None  # deadline | numeric | error | rejected
    retries: int = 0
    retry_at: int = 0  # engine tick the next attempt may re-queue at
    slot: int = -1

    @property
    def status(self) -> str:
        """Terminal taxonomy: ``done | stuck | failed:<kind>`` (else pending)."""
        if self.done:
            return "done"
        if self.failed:
            return f"failed:{self.failed}"
        if self.stuck:
            return "stuck"
        return "pending"


def _infer_batch_axes(model, cfg, max_seq):
    """Per-leaf batch axis of the cache pytree (eval_shape diff at B=2 vs 3)."""
    s2 = jax.eval_shape(lambda: model.init_caches(cfg, 2, max_seq))
    s3 = jax.eval_shape(lambda: model.init_caches(cfg, 3, max_seq))

    def ax(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        if len(diffs) != 1:
            raise ValueError(f"cache leaf has no unique batch axis: {a.shape} vs {b.shape}")
        return diffs[0]

    return jax.tree.map(ax, s2, s3)


class Engine:
    """Continuously batched autoregressive server for any registered arch."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        batch_slots: int = 4,
        max_seq: int = 256,
        greedy: bool = True,
        clock: Callable[[], float] = time.perf_counter,
        metrics: Optional[Metrics] = None,
        faults: Optional[FaultPlan] = None,
        max_retries: int = 1,
        backoff_ticks: int = 1,
        backoff_cap_ticks: int = 8,
        max_queue: Optional[int] = None,
        policy: str = "reject",
        deadline_eviction: bool = True,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.cfg = cfg
        self.model = api.get_model(cfg)
        self.params = params
        self.batch = batch_slots
        self.max_seq = max_seq
        self.greedy = greedy
        self.supports_lengths = cfg.family in _PADDED_FAMILIES
        bucket = pow2_bucket if self.supports_lengths else exact_bucket
        self.sched = Scheduler(
            batch_slots,
            bucket_fn=lambda n: bucket(n, hi=max_seq),
            max_seq=max_seq,
            max_queue=max_queue,
            policy=policy,
        )
        self.metrics = metrics if metrics is not None else Metrics(clock=clock)
        self.faults = faults
        self.max_retries = max_retries
        self.backoff_ticks = backoff_ticks
        self.backoff_cap_ticks = backoff_cap_ticks
        self.deadline_eviction = deadline_eviction
        self.live: dict[int, Request] = {}
        self.tick = 0
        self._uid = 0
        self._sleep = sleep
        self._retry_q: list[Request] = []
        self._needs_scrub: set[int] = set()
        # graceful degradation: closures that fell back to the dequant oracle
        # (kernel → dequant is a one-way, memoized flip; None when the config
        # has nothing to degrade to — dense or already-dequant dispatch)
        self._degraded: set[str] = set()
        q = cfg.quant
        self._degraded_cfg = (
            cfg.with_quant(impl="dequant")
            if q.enabled and q.impl not in ("dequant", "dense")
            else None
        )

        # one long-lived batched cache + a fresh single-slot template for
        # every admission and every quarantine scrub (prefill never mutates
        # its input; the template stripe is what a clean slot looks like)
        self.caches = self.model.init_caches(cfg, self.batch, max_seq)
        self._one_template = self.model.init_caches(cfg, 1, max_seq)
        self._slot_axes = _infer_batch_axes(self.model, cfg, max_seq)

        def _graft(big, one, slot):
            return jax.tree.map(
                lambda b, o, a: jax.lax.dynamic_update_slice_in_dim(
                    b, o.astype(b.dtype), slot, axis=a
                ),
                big, one, self._slot_axes,
            )

        def _guard(logits):
            # numeric guard + argmax in ONE jitted call: a single fused
            # isfinite reduction over each slot's logits (never per-element
            # on the host), returning (next_token, finite?) per slot
            fin = jnp.all(jnp.isfinite(logits), axis=tuple(range(1, logits.ndim)))
            return jnp.argmax(logits[:, 0], axis=-1), fin

        self._graft = jax.jit(_graft)
        self._guard = jax.jit(_guard)
        self._decode_by_impl: dict[str, Callable] = {}
        self._prefill_by_bucket: dict[tuple, Callable] = {}

    # -- jitted closures (per cfg-impl, so degradation can rebuild) ----------

    def _impl_key(self, cfg) -> str:
        return cfg.quant.impl if cfg.quant.enabled else "dense"

    def _decode_fn(self, cfg) -> Callable:
        key = self._impl_key(cfg)
        if key not in self._decode_by_impl:
            model = self.model

            def f(params, tokens, caches):
                return model.decode_step(params, tokens, caches, cfg)

            self._decode_by_impl[key] = jax.jit(f)
        return self._decode_by_impl[key]

    def _prefill_fn(self, bucket: int, cfg) -> Callable:
        key = (bucket, self._impl_key(cfg))
        if key not in self._prefill_by_bucket:
            model = self.model
            if self.supports_lengths:
                def f(params, tokens, lengths, caches):
                    return model.prefill(params, tokens, caches, cfg, lengths=lengths)
            else:  # exact-length prompt: no pads, lengths unused
                def f(params, tokens, lengths, caches):
                    del lengths
                    return model.prefill(params, tokens, caches, cfg)
            self._prefill_by_bucket[key] = jax.jit(f)
        return self._prefill_by_bucket[key]

    def _call(self, key: str, build: Callable, *args):
        """Run a jitted closure with one-shot kernel→dequant degradation.

        A persistent failure at the jit boundary (``pallas_call``
        lowering/VMEM errors — or an injected FaultPlan ``kernel`` fault)
        flips THIS closure's dispatch to the dequant oracle path, memoized,
        and replays the call: degraded but serving.  :class:`FaultInjected`
        (transient, handled per-request or per-tick) passes through.
        """
        degraded = key in self._degraded
        cfg = self._degraded_cfg if degraded else self.cfg
        try:
            if (not degraded and self.faults is not None
                    and self.faults.kernel_broken(key)):
                raise RuntimeError(f"injected persistent kernel failure: {key}")
            return build(cfg)(*args)
        except FaultInjected:
            raise
        except Exception as e:  # noqa: BLE001 — degradation boundary
            if degraded or self._degraded_cfg is None:
                raise
            self._degraded.add(key)
            self.metrics.incr("n_degraded")
            warnings.warn(
                f"engine: closure {key!r} failed on the "
                f"{self.cfg.quant.impl!r} path ({type(e).__name__}: {e}); "
                f"degrading its dispatch to impl='dequant'",
                RuntimeWarning,
                stacklevel=2,
            )
            return build(self._degraded_cfg)(*args)

    # -- request lifecycle ---------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new: int = 16,
               *, slo_s: Optional[float] = None) -> Request:
        """Submit a request.  Under a bounded queue the returned request may
        already be terminal (``failed="rejected"``) — check ``.status``."""
        self._uid += 1
        r = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                    max_new=max_new, slo_s=slo_s)
        self.sched.validate(r)  # raises before any registration
        now = self.metrics.clock()
        if slo_s is not None:
            r.deadline = now + slo_s
        self.metrics.submit(r.uid, "lm", slo_s=slo_s)
        try:
            shed = self.sched.submit(r, now=now)
        except QueueFullError as e:
            r.failed = "rejected"
            self.metrics.incr("n_rejected")
            self.metrics.mark_failed(r.uid, "rejected")
            shed = e.shed
        for victim in shed:
            self._mark_shed(victim, now)
        return r

    @property
    def waiting(self):
        return self.sched.waiting

    @property
    def busy(self) -> bool:
        """Work anywhere in the engine: live slots, queue, or pending retries."""
        return bool(self.live or self.sched.waiting or self._retry_q)

    # -- failure paths -------------------------------------------------------

    def _mark_shed(self, r: Request, now: float) -> None:
        """A queued request dropped by backpressure: ``deadline`` when its SLO
        had expired, ``rejected`` when it was a capacity (shed_oldest) victim."""
        kind = (
            "deadline"
            if r.deadline is not None and now > r.deadline
            else "rejected"
        )
        r.failed = kind
        self.metrics.incr("n_shed")
        self.metrics.mark_failed(r.uid, kind, n_out=len(r.out))

    def _fail_or_retry(self, r: Request, kind: str) -> None:
        """Retryable fault: re-queue with capped exponential tick backoff
        (``backoff_ticks · 2^(attempt-1)``, capped); else terminal failure
        with the partial output preserved on the request."""
        if kind in _RETRYABLE and r.retries < self.max_retries:
            r.retries += 1
            delay = min(
                self.backoff_ticks * (2 ** (r.retries - 1)), self.backoff_cap_ticks
            )
            r.retry_at = self.tick + delay
            r.slot = -1
            r.out = []  # the retry re-prefills and decodes fresh
            self._retry_q.append(r)
            self.metrics.incr("n_retried")
        else:
            r.failed = kind
            self.metrics.mark_failed(r.uid, kind, n_out=len(r.out))

    def _quarantine(self, r: Request, kind: str = "numeric") -> None:
        """Numeric fault in ``r``'s slot: quarantine the slot (no reuse until
        its cache stripe is re-grafted from the fresh template) and fail or
        retry the occupant."""
        self.sched.quarantine(r.slot)
        self._needs_scrub.add(r.slot)
        self.metrics.incr("n_quarantined")
        self.live.pop(r.uid, None)
        self._fail_or_retry(r, kind)

    def _scrub_quarantined(self) -> None:
        """Re-initialize quarantined slots' cache stripes from the fresh
        template, then release them — poisoned KV never reaches a new
        occupant."""
        for slot in sorted(self._needs_scrub):
            self.caches = self._graft(
                self.caches, self._one_template, jnp.asarray(slot, jnp.int32)
            )
            self.sched.release(slot)
        self._needs_scrub.clear()

    def _shed_expired_queued(self, now: float) -> None:
        """Shed queued requests whose SLO already expired — prefill compute
        is never spent on a request that cannot meet its deadline."""
        for r in self.sched.shed_expired(now):
            r.failed = "deadline"
            self.metrics.incr("n_shed")
            self.metrics.mark_failed(r.uid, "deadline", n_out=len(r.out))

    def _evict_deadline(self, now: float) -> None:
        """Mid-decode eviction: a live request past its deadline frees the
        slot immediately; its partial output stays on ``r.out``."""
        for r in list(self.live.values()):
            if r.deadline is not None and now > r.deadline:
                del self.live[r.uid]
                self.sched.release(r.slot)
                r.failed = "deadline"
                self.metrics.incr("n_evicted_deadline")
                self.metrics.mark_failed(r.uid, "deadline", n_out=len(r.out))

    def _requeue_retries(self) -> None:
        ready = [r for r in self._retry_q if r.retry_at <= self.tick]
        if ready:
            self._retry_q = [r for r in self._retry_q if r.retry_at > self.tick]
            for r in ready:
                self.sched.requeue(r)

    # -- admission -----------------------------------------------------------

    def _admit(self):
        """Continuous admission: prefill each planned request immediately.

        Batch-of-one prefill against the fresh template, right-padded to the
        scheduler's length bucket, then graft into the batched cache at the
        slot — live slots keep their per-slot positions untouched.  Injected
        prefill faults (and real prefill errors surfacing as FaultInjected)
        fail the request into the retry path; the first-token logits pass
        the same fused numeric guard decode uses.
        """
        self._scrub_quarantined()
        for plan in self.sched.admit():
            r = plan.req
            try:
                if self.faults is not None:
                    self.faults.on_prefill(r.uid, self.tick)
                S = max(plan.bucket, len(r.prompt))
                toks = np.zeros((1, S), np.int32)
                toks[0, : len(r.prompt)] = r.prompt  # right-pad (left-aligned)
                lengths = jnp.array([len(r.prompt)], jnp.int32)
                logits, one_caches = self._call(
                    f"prefill:{S}",
                    lambda cfg, S=S: self._prefill_fn(S, cfg),
                    self.params, jnp.asarray(toks), lengths, self._one_template,
                )
            except FaultInjected:
                self.sched.release(plan.slot)
                self._fail_or_retry(r, "error")
                continue
            tok, ok = self._guard(logits[:, -1:])
            if not bool(np.asarray(ok)[0]):
                # poisoned prefill: never graft; quarantine scrubs the slot
                r.slot = plan.slot
                self.live[r.uid] = r
                self._quarantine(r)
                continue
            self.caches = self._graft(
                self.caches, one_caches, jnp.asarray(plan.slot, jnp.int32)
            )
            r.slot = plan.slot
            r.out.append(int(np.asarray(tok)[0]))
            self.live[r.uid] = r
            self.metrics.mark_admit(r.uid)
            self.metrics.mark_first(r.uid)

    # -- the tick ------------------------------------------------------------

    def step(self):
        """One engine tick: enforce deadlines/backpressure, re-queue ready
        retries, admit, then decode one token for every live slot (dead
        slots decode a dummy token, ignored)."""
        self.tick += 1
        now = self.metrics.clock()
        if self.faults is not None:
            delay = self.faults.on_tick(self.tick)
            if delay:
                self._sleep(delay)
                now = self.metrics.clock()
        self._shed_expired_queued(now)
        self._requeue_retries()
        if self.deadline_eviction:
            self._evict_deadline(now)
        self._admit()
        if not self.live:
            return
        toks = np.zeros((self.batch, 1), np.int32)
        for r in self.live.values():
            toks[r.slot, 0] = r.out[-1]
        try:
            if self.faults is not None:
                self.faults.on_decode(self.tick)
            logits, caches = self._call(
                "decode", self._decode_fn, self.params, jnp.asarray(toks), self.caches
            )
        except FaultInjected:
            # transient decode fault: the tick is a side-effect-free no-op
            # (caches untouched) and replays next tick — bit-exactness holds
            self.metrics.incr("n_faults_decode")
            return
        self.caches = caches
        if self.faults is not None:
            for s in self.faults.poison_slots(self.tick):
                logits = logits.at[s].set(jnp.nan)
        nxt, ok = self._guard(logits)
        nxt, ok = np.asarray(nxt), np.asarray(ok)
        finished, poisoned = [], []
        for r in self.live.values():
            if not ok[r.slot]:
                poisoned.append(r)
                continue
            r.out.append(int(nxt[r.slot]))
            if len(r.out) >= r.max_new:
                r.done = True
                finished.append(r)
        for r in poisoned:
            self._quarantine(r)
        for r in finished:
            del self.live[r.uid]
            self.sched.release(r.slot)
            self.metrics.mark_done(r.uid, len(r.out))
        self.metrics.tick_occupancy(
            len(self.live) + len(finished) + len(poisoned), self.batch
        )

    def run_until_drained(self, max_ticks: int = 1000, *, strict: bool = True) -> int:
        """Tick until every request reaches a terminal status.  If
        ``max_ticks`` hits with requests still live/queued/retrying, mark
        them ``stuck`` and raise (or ``warnings.warn`` when
        ``strict=False``) instead of silently returning."""
        t = 0
        while self.busy and t < max_ticks:
            self.step()
            t += 1
        leftover = (
            list(self.live.values()) + list(self.sched.waiting) + list(self._retry_q)
        )
        if leftover:
            for r in leftover:
                r.stuck = True
                self.metrics.mark_stuck(r.uid)
            msg = (
                f"run_until_drained: {len(leftover)} request(s) undrained after "
                f"{max_ticks} ticks (uids {[r.uid for r in leftover]})"
            )
            if strict:
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return t
