"""mamba2-130m: attention-free SSD (state-space duality).  [arXiv:2405.21060]"""
from repro.configs.base import ArchConfig, SSMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=0,  # attention-free
        n_kv_heads=0,
        head_dim=64,  # SSD head dim
        d_ff=0,
        vocab=50_280,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=128),
        source="arXiv:2405.21060",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        head_dim=16,
        d_ff=0,
        vocab=256,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=8),
        remat=False,
    )
