"""qwen3-32b: dense, qk_norm, GQA kv=8.  [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=25_600,
        vocab=151_936,
        act="swiglu",
        qk_norm=True,
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen3-8B (scaled per assignment)",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-32b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=256,
        act="swiglu",
        qk_norm=True,
        remat=False,
    )
