"""whisper-tiny: enc-dec, conv frontend (stub).  [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,  # decoder depth
        encoder_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab=51_865,
        act="gelu",
        tie_embeddings=True,
        frontend="audio",
        frontend_tokens=1500,  # 30 s of audio at 50 Hz after the conv stub
        frontend_dim=384,
        max_seq=33_000,  # learned decoder positions sized for the decode_32k cell
        source="arXiv:2212.04356",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny-smoke",
        family="audio",
        n_layers=2,
        encoder_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=256,
        act="gelu",
        tie_embeddings=True,
        frontend="audio",
        frontend_tokens=16,
        frontend_dim=64,
        max_seq=64,
        remat=False,
    )
