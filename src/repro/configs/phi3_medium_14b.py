"""phi3-medium-14b: dense, RoPE SwiGLU GQA kv=10.  [arXiv:2404.14219]"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="phi3-medium-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=10,
        head_dim=128,
        d_ff=17_920,
        vocab=100_352,
        act="swiglu",
        rope_theta=10_000.0,
        source="arXiv:2404.14219",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="phi3-medium-14b-smoke",
        family="dense",
        n_layers=2,
        d_model=80,
        n_heads=5,
        n_kv_heads=5,
        head_dim=16,
        d_ff=160,
        vocab=256,
        act="swiglu",
        remat=False,
    )
