"""Config system: architecture, shape, quantization and parallelism configs."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

__all__ = ["PASMQuant", "MoEConfig", "SSMConfig", "HybridConfig", "ArchConfig", "ShapeSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class PASMQuant:
    """Weight-sharing (PASM) settings — the paper's technique as a config knob.

    ``impl``:
      dense      — no weight sharing (paper's "non-weight-shared" baseline)
      dequant    — weight-shared: indices+codebook in HBM, XLA gather→matmul
                   (paper's "weight-shared MAC" baseline; distribution-safe)
      kernel     — fused Pallas dequant matmul (production PASM path)
      pas_kernel — paper-faithful PAS two-phase kernel (measurement path)
    """

    enabled: bool = False
    bins: int = 16
    groups: int = 1  # 1 = paper-faithful single dictionary per weight
    impl: str = "dequant"
    quantize_embed: bool = False  # embedding/lm_head tables too
    kv_bits: int = 16  # 8 → int8 PASM-style KV cache (beyond paper)
    min_weight_elems: int = 1 << 16  # don't quantize tiny weights (B ≪ N rule)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 2
    d_expert: int = 0
    n_shared: int = 0
    d_shared: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 1  # leading dense-FFN layers (deepseek/kimi style)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style pattern: ``pattern`` per layer, tiled."""

    pattern: Sequence[str] = ("recurrent", "recurrent", "attention")
    lru_width: int = 0
    conv_width: int = 4
    local_window: int = 2048


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 → d_model // n_heads
    act: str = "swiglu"  # swiglu | sq_relu | gelu
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # modality frontends (vit: precomputed patch embeddings; audio: real
    # log-mel + PASM conv stem — repro.models.encdec)
    frontend: str = "none"  # none | vit | audio
    frontend_tokens: int = 0  # patches / frames per example
    frontend_dim: int = 0  # vit embedding dim (projected to d_model)
    n_mels: int = 80  # audio: log-mel channels into the conv stem
    encoder_layers: int = 0  # enc-dec (whisper): encoder depth
    max_seq: int = 8192  # learned-pos archs only (whisper)
    scan_layers: bool = True
    remat: bool = True
    attn_chunk: int = 1024  # KV-chunk for online-softmax attention
    quant: PASMQuant = PASMQuant()
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def with_quant(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, quant=dataclasses.replace(self.quant, **kw))

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + per-layer), for 6·N·D."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        attn = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd + self.n_heads * hd * D
        if self.act == "swiglu":
            ffn = 3 * D * F
        else:
            ffn = 2 * D * F
        per_layer = attn + ffn
        n = 0
        if self.moe and self.moe.n_experts:
            m = self.moe
            e_ffn = 3 * D * m.d_expert
            moe_layer = attn + m.n_experts * e_ffn + m.n_shared * 3 * D * m.d_shared + D * m.n_experts
            dense_layers = min(m.first_dense_layers, self.n_layers)
            n += dense_layers * per_layer + (self.n_layers - dense_layers) * moe_layer
        elif self.family == "ssm" and self.ssm:
            s = self.ssm
            d_in = s.expand * D
            per = D * (2 * d_in + 2 * s.n_groups * s.d_state + d_in // s.head_dim) + d_in * D
            n += self.n_layers * per
        elif self.hybrid:
            h = self.hybrid
            w = h.lru_width or D
            rec = D * 2 * w + w * D + 2 * w * h.conv_width + 3 * w  # in/out proj + conv + gates
            n_att = sum(1 for i in range(self.n_layers) if h.pattern[i % len(h.pattern)] == "attention")
            n += n_att * (attn + ffn) + (self.n_layers - n_att) * (rec + ffn)
        else:
            n += self.n_layers * per_layer
        n += V * D * (1 if self.tie_embeddings else 2)
        if self.encoder_layers:
            n += self.encoder_layers * per_layer
        return n

    def n_active_params(self) -> int:
        """Active params per token (MoE: top-k + shared only) for 6·N_active·D."""
        if not (self.moe and self.moe.n_experts):
            return self.n_params()
        D = self.d_model
        hd = self.hd
        m = self.moe
        attn = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd + self.n_heads * hd * D
        act_ffn = m.top_k * 3 * D * m.d_expert + m.n_shared * 3 * D * m.d_shared
        dense_layers = min(m.first_dense_layers, self.n_layers)
        n = dense_layers * (attn + 3 * D * self.d_ff if self.d_ff else attn + act_ffn)
        n += (self.n_layers - dense_layers) * (attn + act_ffn + D * m.n_experts)
        n += self.vocab * D * 2
        return n


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
