"""The paper's own accelerator configuration (§4): one AlexNet-style conv
layer — 5×5 image, 15 channels, 3×3 kernel, 2 output channels, stride 1 —
with B ∈ {4, 8, 16} weight bins.  This is the faithful-reproduction target
for Figs 14–22; see benchmarks/ and tests/test_conv.py.

Beyond the single paper layer, :class:`CNNConfig` scales the same accelerator
to a full AlexNet-style conv stack (the network the paper's layer is drawn
from): per-stage geometry-free :class:`repro.core.conv.Conv2D` specs with one
PASM dictionary per conv layer and a dense classifier head, running on the
batched Pallas conv path (DESIGN.md §3).  The ``padding`` knob selects the
windowing stack-wide: the default ``valid_centred`` keeps the paper's
kernel-centred loop bounds; ``same`` reproduces torchvision-exact AlexNet/VGG
geometries.  ``layout`` picks NCHW (paper loop order) or NHWC (TPU-native,
channels-minor im2col), and ``packed`` int4-packs every conv dictionary.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from typing import NamedTuple

from repro.core.conv import Conv2D


class PaperAccel(NamedTuple):
    """The paper's §4 accelerator dims (image geometry + layer shape).

    Image H/W live here — NOT in :class:`Conv2D` — because this names the
    paper's fixed evaluation configuration (Figs 14–22), where the 5×5 image
    is part of the spec.
    """

    IH: int = 5
    IW: int = 5
    C: int = 15
    KY: int = 3
    KX: int = 3
    M: int = 2
    stride: int = 1

    def conv(self, *, relu: bool = False, bias: bool = False) -> Conv2D:
        """The geometry-free layer spec (paper kernel-centred windowing)."""
        return Conv2D(
            k=(self.KY, self.KX), c_in=self.C, c_out=self.M,
            stride=self.stride, padding="valid_centred", layout="NCHW",
            bias=bias, relu=relu,
        )


PAPER_SPEC = PaperAccel()
PAPER_BINS = (4, 8, 16)
PAPER_BITWIDTHS = (8, 32)  # kernel bit-widths evaluated in the paper


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    """An AlexNet-family CNN on the weight-shared conv accelerator."""

    name: str
    in_chw: tuple  # (C, H, W) input images (C leads regardless of layout)
    layers: Sequence[Conv2D]  # per-stage specs (relu baked in; c_in chained)
    pools: Sequence[int]  # per-stage max-pool window == stride; 1 = none
    classes: int
    bins: int = 16  # PASM dictionary size, one dictionary per conv layer
    groups: int = 1  # reduction-axis codebook groups per layer (1 = paper rule)
    impl: str = "kernel"  # auto | einsum | kernel | kernel_implicit | pas_kernel
    padding: str = "valid_centred"  # stack-wide: valid_centred | valid | same
    layout: str = "NCHW"  # stack-wide: NCHW | NHWC
    packed: bool = False  # int4-pack the conv dictionaries at quantize time
    # image-block VMEM budget (bytes) for the auto engine's implicit-GEMM
    # preference; None = the core default (~6 MiB, a 16 MiB-VMEM TPU core)
    vmem_budget: Optional[int] = None
    # conv2d(pool_impl=) policy for the per-stage max-pools: "auto" fuses the
    # pool into the conv kernel epilogue where possible (one pallas_call per
    # conv/ReLU/pool stage), "unfused" keeps the separate reduce_window,
    # "fused" demands fusion (raises where impossible) — bit-exact either way
    pool_impl: str = "auto"
    # (n_data, n_model) for launch.mesh.make_conv_mesh — the mesh the stack
    # shards over (conv2d(mesh=), DESIGN.md §4.1); None = single device
    mesh_shape: Optional[tuple] = None
    family: str = "cnn"  # models/api dispatch key

    def __post_init__(self):
        if len(self.layers) != len(self.pools):
            raise ValueError(
                f"{self.name}: {len(self.layers)} conv layers but "
                f"{len(self.pools)} pool entries — the sequences are parallel"
            )
        c_in = self.in_chw[0]
        for i, conv in enumerate(self.layers):
            if conv.c_in != c_in:
                raise ValueError(
                    f"{self.name}: layer {i} expects c_in={conv.c_in} but the "
                    f"stack feeds it {c_in} channels"
                )
            c_in = conv.c_out


def _stack(c_in: int, *stages: tuple) -> tuple:
    """(c_out, k, stride) stages → chained Conv2D specs with ReLU."""
    layers = []
    for c_out, k, stride in stages:
        layers.append(Conv2D(k=k, c_in=c_in, c_out=c_out, stride=stride, relu=True))
        c_in = c_out
    return tuple(layers)


def config() -> CNNConfig:
    """Full AlexNet-style stack at the paper's ImageNet-scale layer sizes.

    ``mesh_shape`` pins the production single-pod mesh
    (:data:`repro.launch.mesh.SINGLE_POD`): batch over 16-way ``data``,
    output channels over 16-way ``model`` (96/256/384 all divide 16; the
    1000-class head falls back to replicated per the divisibility rule).
    """
    from repro.launch.mesh import SINGLE_POD

    return CNNConfig(
        name="alexnet",
        in_chw=(3, 224, 224),
        layers=_stack(
            3,
            (96, 11, 4),  # 224→54→27 (valid_centred; SAME: 224→56→28)
            (256, 5, 1),  # 27→23→11
            (384, 3, 1),  # 11→9
            (384, 3, 1),  # 9→7
            (256, 3, 1),  # 7→5→2
        ),
        pools=(2, 2, 1, 1, 2),
        classes=1000,
        mesh_shape=SINGLE_POD,
    )


def smoke_config() -> CNNConfig:
    """CIFAR-sized stack: same code path, CPU-testable in interpret mode."""
    return CNNConfig(
        name="alexnet-smoke",
        in_chw=(3, 32, 32),
        layers=_stack(
            3,
            (16, 3, 1),  # 32→30→15
            (32, 3, 1),  # 15→13→6
            (32, 3, 1),  # 6→4→2
        ),
        pools=(2, 2, 2),
        classes=10,
    )
