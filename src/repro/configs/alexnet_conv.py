"""The paper's own accelerator configuration (§4): one AlexNet-style conv
layer — 5×5 image, 15 channels, 3×3 kernel, 2 output channels, stride 1 —
with B ∈ {4, 8, 16} weight bins.  This is the faithful-reproduction target
for Figs 14–22; see benchmarks/ and tests/test_conv.py.

Beyond the single paper layer, :class:`CNNConfig` scales the same accelerator
to a full AlexNet-style conv stack (the network the paper's layer is drawn
from): conv/ReLU/pool layers with one PASM dictionary per conv layer and a
dense classifier head, running on the batched Pallas conv path
(DESIGN.md §3).  Windowing stays the paper's kernel-centred VALID bounds, so
spatial dims differ slightly from the padded torchvision AlexNet.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.conv import ConvSpec

PAPER_SPEC = ConvSpec(IH=5, IW=5, C=15, KY=3, KX=3, M=2, stride=1)
PAPER_BINS = (4, 8, 16)
PAPER_BITWIDTHS = (8, 32)  # kernel bit-widths evaluated in the paper


@dataclasses.dataclass(frozen=True)
class ConvLayerSpec:
    """One conv/ReLU(/pool) stage of the stack."""

    c_out: int
    k: int
    stride: int = 1
    pool: int = 1  # max-pool window == stride; 1 = no pool
    relu: bool = True


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    """An AlexNet-family CNN on the weight-shared conv accelerator."""

    name: str
    in_chw: tuple  # (C, H, W) input images
    layers: Sequence[ConvLayerSpec]
    classes: int
    bins: int = 16  # PASM dictionary size, one dictionary per conv layer
    impl: str = "kernel"  # einsum | kernel (pasm_matmul) | pas_kernel
    family: str = "cnn"  # models/api dispatch key


def config() -> CNNConfig:
    """Full AlexNet-style stack at the paper's ImageNet-scale layer sizes."""
    return CNNConfig(
        name="alexnet",
        in_chw=(3, 224, 224),
        layers=(
            ConvLayerSpec(96, 11, stride=4, pool=2),  # 224→54→27
            ConvLayerSpec(256, 5, pool=2),            # 27→23→11
            ConvLayerSpec(384, 3),                    # 11→9
            ConvLayerSpec(384, 3),                    # 9→7
            ConvLayerSpec(256, 3, pool=2),            # 7→5→2
        ),
        classes=1000,
    )


def smoke_config() -> CNNConfig:
    """CIFAR-sized stack: same code path, CPU-testable in interpret mode."""
    return CNNConfig(
        name="alexnet-smoke",
        in_chw=(3, 32, 32),
        layers=(
            ConvLayerSpec(16, 3, pool=2),  # 32→30→15
            ConvLayerSpec(32, 3, pool=2),  # 15→13→6
            ConvLayerSpec(32, 3, pool=2),  # 6→4→2
        ),
        classes=10,
    )
