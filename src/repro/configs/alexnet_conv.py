"""The paper's own accelerator configuration (§4): one AlexNet-style conv
layer — 5×5 image, 15 channels, 3×3 kernel, 2 output channels, stride 1 —
with B ∈ {4, 8, 16} weight bins.  This is the faithful-reproduction target
for Figs 14–22; see benchmarks/ and tests/test_conv.py.
"""
from repro.core.conv import ConvSpec

PAPER_SPEC = ConvSpec(IH=5, IW=5, C=15, KY=3, KX=3, M=2, stride=1)
PAPER_BINS = (4, 8, 16)
PAPER_BITWIDTHS = (8, 32)  # kernel bit-widths evaluated in the paper
