"""nemotron-4-340b: dense, GQA kv=8, squared-ReLU.  [arXiv:2402.16819]"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-340b",
        family="dense",
        n_layers=96,
        d_model=18_432,
        n_heads=96,
        n_kv_heads=8,
        head_dim=192,
        d_ff=73_728,
        vocab=256_000,
        act="sq_relu",
        rope_theta=10_000.0,
        source="arXiv:2402.16819",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-340b-smoke",
        family="dense",
        n_layers=2,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab=256,
        act="sq_relu",
        remat=False,
    )
