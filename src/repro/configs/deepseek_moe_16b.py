"""deepseek-moe-16b: fine-grained MoE, 2 shared + 64 routed top-6.  [arXiv:2401.06066]"""
from repro.configs.base import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=10_944,  # the single leading dense layer's FFN (published width)
        vocab=102_400,
        act="swiglu",
        rope_theta=10_000.0,
        moe=MoEConfig(
            n_experts=64,
            top_k=6,
            d_expert=1408,  # assignment d_ff applies per expert
            n_shared=2,
            d_shared=1408,
            capacity_factor=1.25,
            first_dense_layers=1,
        ),
        source="arXiv:2401.06066",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=256,
        act="swiglu",
        moe=MoEConfig(
            n_experts=8,
            top_k=2,
            d_expert=32,
            n_shared=2,
            d_shared=32,
            capacity_factor=1.5,
            first_dense_layers=1,
        ),
        remat=False,
    )
