"""recurrentgemma-2b: hybrid RG-LRU + local attn, pattern (R,R,A).  [arXiv:2402.19427]"""
from repro.configs.base import ArchConfig, HybridConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab=256_000,
        act="swiglu",
        rope_theta=10_000.0,
        hybrid=HybridConfig(
            pattern=("recurrent", "recurrent", "attention"),
            lru_width=2560,
            conv_width=4,
            local_window=2048,
        ),
        source="arXiv:2402.19427",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-2b-smoke",
        family="hybrid",
        n_layers=5,  # 1 scanned (R,R,A) group + 2-layer recurrent tail
        d_model=64,
        n_heads=2,
        n_kv_heads=1,
        head_dim=32,
        d_ff=128,
        vocab=256,
        act="swiglu",
        hybrid=HybridConfig(
            pattern=("recurrent", "recurrent", "attention"),
            lru_width=64,
            conv_width=4,
            local_window=16,
        ),
        remat=False,
    )
