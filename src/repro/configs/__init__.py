"""Architecture registry: ``--arch <id>`` resolution for every entry point."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ArchConfig, PASMQuant, ShapeSpec  # noqa: F401

_MODULES = {
    "qwen3-32b": "qwen3_32b",
    "nemotron-4-340b": "nemotron4_340b",
    "phi3-medium-14b": "phi3_medium_14b",
    "stablelm-3b": "stablelm_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "internvl2-26b": "internvl2_26b",
    "mamba2-130m": "mamba2_130m",
    "whisper-tiny": "whisper_tiny",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, *, smoke: bool = False) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.smoke_config() if smoke else mod.config()


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]


# CNN (vision) registry — separate from the LM cells above: CNNConfig is not
# an ArchConfig and the conv stack has no prefill/decode surface.
CNN_IDS = ("alexnet",)


def get_cnn_config(name: str, *, smoke: bool = False):
    if name not in CNN_IDS:
        raise KeyError(f"unknown cnn {name!r}; known: {CNN_IDS}")
    from repro.configs import alexnet_conv as mod

    return mod.smoke_config() if smoke else mod.config()


# cells skipped by design (sub-quadratic requirement / no decoder):
# full-attention archs skip long_500k (assignment sheet; DESIGN.md §5).
_SUBQUADRATIC = {"mamba2-130m", "recurrentgemma-2b"}


def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch not in _SUBQUADRATIC:
        return False, "full-attention arch: O(s²) at 524k ctx — skipped by design"
    return True, ""


def all_cells():
    """The 40 assigned (arch × shape) cells, with supported flag + reason."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            ok, why = cell_supported(a, s)
            out.append((a, s, ok, why))
    return out
