"""stablelm-3b: dense, MHA (kv=32=H).  [hf:stabilityai/stablelm-2-1_6b family]"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="stablelm-3b",
        family="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=6912,
        vocab=50_304,
        act="swiglu",
        rope_theta=10_000.0,
        source="hf:stabilityai/stablelm-2-1_6b (scaled per assignment)",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="stablelm-3b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=256,
        act="swiglu",
        remat=False,
    )
