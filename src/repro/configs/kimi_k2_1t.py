"""kimi-k2-1t-a32b: trillion-param MoE, 384 experts top-8.  [arXiv:2501.kimi2, paper-table]"""
from repro.configs.base import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=18_432,  # the single leading dense layer's FFN (published width)
        vocab=163_840,
        act="swiglu",
        rope_theta=50_000.0,
        moe=MoEConfig(
            n_experts=384,
            top_k=8,
            d_expert=2048,  # assignment d_ff applies per expert
            n_shared=1,
            d_shared=2048,
            capacity_factor=1.25,
            first_dense_layers=1,
        ),
        source="arXiv:2501.kimi2 (paper table)",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="kimi-k2-1t-a32b-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=256,
        act="swiglu",
        moe=MoEConfig(
            n_experts=16,
            top_k=4,
            d_expert=32,
            n_shared=1,
            d_shared=32,
            capacity_factor=1.5,
            first_dense_layers=1,
        ),
        remat=False,
    )
