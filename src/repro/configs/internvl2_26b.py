"""internvl2-26b: VLM — InternViT frontend (stub) + InternLM2 backbone.  [arXiv:2404.16821]"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-26b",
        family="vlm",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16_384,
        vocab=92_553,
        act="swiglu",
        rope_theta=1_000_000.0,
        frontend="vit",
        frontend_tokens=256,  # pixel-shuffled InternViT patches per image
        frontend_dim=3200,  # InternViT-6B hidden size (stub embeddings)
        source="arXiv:2404.16821",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-26b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=256,
        act="swiglu",
        frontend="vit",
        frontend_tokens=8,
        frontend_dim=48,
        remat=False,
    )
