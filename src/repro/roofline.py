"""Three-term roofline analysis from compiled XLA artifacts (no hardware).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / (links × link_bw)

Sources: ``compiled.cost_analysis()`` provides per-device FLOPs and bytes;
collective bytes are parsed from the post-SPMD optimized HLO
(``compiled.as_text()``) by summing the result sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, weighted by
the ring-algorithm payload factor 2·(g−1)/g for all-reduce and (g−1)/g for
gather/scatter, where g is the replica-group size parsed per op.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (assignment sheet).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

__all__ = ["HW", "CollectiveStats", "parse_collective_bytes", "roofline_terms", "RooflineReport"]

# TPU v5e per-chip constants (assignment sheet)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link
N_LINKS = 4  # 2-D torus: 4 links usable per chip


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    link_bw: float = LINK_BW
    n_links: int = N_LINKS


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(.*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)  # iota form: replica_groups=[ngroups,gsize]<=[N]
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_BRACE_RE.search(line)  # explicit form: {{0,1,2,...},...}
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device collective payload bytes from optimized HLO text.

    Result sizes are per-device (the SPMD partitioner emits per-device
    shapes).  Ring-payload weighting: all-reduce moves ≈ 2·(g−1)/g × bytes,
    all-gather/reduce-scatter (g−1)/g, all-to-all (g−1)/g, permute 1×.
    """
    bytes_by_kind: dict = {}
    count_by_kind: dict = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if kind + "(" not in line and kind + "-start(" not in line:
            continue
        if "-done(" in line:  # result of async pair — counted at -start
            continue
        size = _shape_bytes(shape_str)
        g = _group_size(line)
        if kind == "all-reduce":
            w = 2.0 * (g - 1) / g
        elif kind == "collective-permute":
            w = 1.0
        else:
            w = (g - 1) / g
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0.0) + size * w
        count_by_kind[kind] = count_by_kind.get(kind, 0) + 1
    return CollectiveStats(bytes_by_kind, count_by_kind)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_flops_frac: float
    n_devices: int
    collectives: dict
    extra: dict

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: max of the three terms (perfect overlap bound)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the roofline bound (the §Perf score):
        MODEL_FLOPs time at peak ÷ the bound-achieving step time."""
        ideal = self.model_flops / self.n_devices / PEAK_FLOPS
        return ideal / max(self.step_time_s, 1e-30)

    @property
    def memory_efficiency(self) -> float:
        """For memory-bound cells (decode): ideal bytes (weights+cache read
        once per step = the argument bytes) ÷ actual HLO bytes."""
        ideal = self.extra.get("argument_bytes_per_device", 0) / HBM_BW
        return ideal / max(self.memory_s, 1e-30)


def roofline_terms(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_devices: int,
    cost: dict,
    hlo_text: str,
    model_flops: float,
    hw: HW = HW(),
    extra: Optional[dict] = None,
) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))  # per-device (XLA reports post-SPMD)
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll = parse_collective_bytes(hlo_text)
    compute_s = flops / hw.peak_flops
    memory_s = bytes_acc / hw.hbm_bw
    collective_s = coll.total_bytes / (hw.link_bw * hw.n_links)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / n_devices / max(flops, 1.0)
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        flops_per_device=flops,
        bytes_per_device=bytes_acc,
        collective_bytes=coll.total_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_flops_frac=useful,
        n_devices=n_devices,
        collectives={
            "bytes": coll.bytes_by_kind,
            "counts": coll.count_by_kind,
        },
        extra=extra or {},
    )


# ---------------------------------------------------------------------------
# HLO profiling: per-op-kind byte/flop attribution (hypothesis formation)
# ---------------------------------------------------------------------------

_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(")


def hlo_bytes_by_op(hlo_text: str, top: int = 15) -> list:
    """Result bytes summed per HLO op kind — a coarse 'where do bytes go'.

    Counts each op's RESULT size only (operand reads double-count through
    producers).  While-loop bodies count once, mirroring cost_analysis —
    apply the same (L−1)·B correction externally if needed.
    """
    agg: dict = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        if b:
            agg[kind] = agg.get(kind, 0) + b
    return sorted(agg.items(), key=lambda kv: -kv[1])[:top]


def hlo_biggest_tensors(hlo_text: str, top: int = 12) -> list:
    """Largest single result tensors (op kind, bytes, shape snippet)."""
    out = []
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        b = _shape_bytes(m.group(1))
        if b:
            out.append((b, m.group(2), m.group(1)[:60]))
    out.sort(reverse=True)
    return out[:top]
