"""Fault tolerance: heartbeats, straggler detection, restart policy.

On a real fleet every host runs the same SPMD program; coordination happens
through (a) the distributed runtime's barrier and (b) this module's
host-side policies.  In this single-process container the same code runs
with n_hosts=1 and is unit-tested with synthetic timing traces.

* **Heartbeat / straggler detection**: per-step wall-times are all-gathered
  (here: recorded); hosts slower than ``k × median`` over a sliding window
  are flagged.  The launcher's response is configurable: log, re-shard
  around the straggler (elastic restart), or abort-and-restore.
* **Restart policy**: exponential-backoff supervisor around the train loop;
  any exception triggers restore-from-latest-checkpoint, preserving the
  deterministic data stream (data pipeline is a pure function of step).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

__all__ = ["StragglerDetector", "RestartPolicy", "Supervisor"]


@dataclasses.dataclass
class StragglerDetector:
    """Flag hosts whose step time exceeds ``threshold ×`` the fleet median."""

    n_hosts: int
    window: int = 20
    threshold: float = 1.5

    def __post_init__(self):
        self._times = [deque(maxlen=self.window) for _ in range(self.n_hosts)]

    def record(self, host: int, step_time: float) -> None:
        self._times[host].append(step_time)

    def medians(self) -> list[float]:
        out = []
        for dq in self._times:
            s = sorted(dq)
            out.append(s[len(s) // 2] if s else 0.0)
        return out

    def stragglers(self) -> list[int]:
        meds = [m for m in self.medians() if m > 0]
        if not meds:
            return []
        fleet = sorted(meds)[len(meds) // 2]
        return [
            h
            for h, m in enumerate(self.medians())
            if m > self.threshold * fleet and m > 0
        ]


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    backoff_s: float = 1.0
    backoff_mult: float = 2.0

    def delays(self):
        d = self.backoff_s
        for _ in range(self.max_restarts):
            yield d
            d *= self.backoff_mult


class Supervisor:
    """Run ``loop_fn(resume_step) -> last_step`` under the restart policy.

    ``loop_fn`` must be restartable from a checkpoint (launch/train.py is:
    it restores the latest manifest and the data stream is step-addressed).
    """

    def __init__(self, policy: RestartPolicy, *, sleep: Callable[[float], None] = time.sleep):
        self.policy = policy
        self.sleep = sleep
        self.restarts = 0
        self.failures: list[str] = []

    def run(self, loop_fn: Callable[[Optional[int]], int], resume_step: Optional[int] = None) -> int:
        delays = self.policy.delays()
        while True:
            try:
                return loop_fn(resume_step)
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — supervisor boundary
                self.failures.append(repr(e))
                try:
                    delay = next(delays)
                except StopIteration:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.policy.max_restarts}; "
                        f"failures: {self.failures}"
                    ) from e
                self.restarts += 1
                self.sleep(delay)
                resume_step = None  # loop_fn re-resolves latest checkpoint
