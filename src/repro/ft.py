"""Fault tolerance: heartbeats, straggler detection, restart policy.

On a real fleet every host runs the same SPMD program; coordination happens
through (a) the distributed runtime's barrier and (b) this module's
host-side policies.  In this single-process container the same code runs
with n_hosts=1 and is unit-tested with synthetic timing traces.

* **Heartbeat / straggler detection**: per-step wall-times are all-gathered
  (here: recorded — EVERY step, so medians are real, not log-step samples);
  hosts slower than ``k × median`` over a sliding window are flagged.  The
  launcher's response is configurable: log, re-shard around the straggler
  (elastic restart), or abort-and-restore.
* **Restart policy with failure classification**: the supervisor around the
  train loop restores from the latest *valid* checkpoint on failure, but
  first CLASSIFIES the failure (DESIGN.md §4).  Exceptions that identify
  the failing step (a ``.step`` attribute — ``train.faults.SimulatedCrash``,
  ``train.loop.NonFiniteEscalation``, or a :class:`StepFailure` wrapper)
  build a failure signature ``(type, step)``: the SAME signature twice in a
  row means restore-and-retry already ran the step again and it failed the
  same way — the failure is *deterministic* (bad data, a bug, a poisoned
  batch that survives the guard) and the supervisor **fails fast** with
  :class:`DeterministicFailure` instead of burning the restart budget.
  Everything else is treated as transient: exponential-backoff restart,
  threading the exception's ``resume_step`` hint (when it carries one)
  into the next ``loop_fn(resume_step)`` call so the loop re-enters at the
  right checkpoint without re-resolving.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

__all__ = [
    "StragglerDetector",
    "RestartPolicy",
    "Supervisor",
    "RestorableError",
    "DeterministicFailure",
    "StepFailure",
]


class RestorableError(RuntimeError):
    """An error for which restore-from-checkpoint-and-continue is a
    meaningful response (e.g. the non-finite guard's escalation after K
    consecutive skipped steps: a transient numeric storm clears; a
    deterministic one repeats at the same step and is then failed fast)."""


class DeterministicFailure(RuntimeError):
    """The same step failed the same way twice across a restore — restarting
    again cannot help.  Raised by :class:`Supervisor` instead of burning the
    remaining restart budget; chains the underlying exception."""


class StepFailure(RuntimeError):
    """Wrapper a train loop may raise to attach step/resume info to an
    exception that has none: ``step`` is the failing step (classification
    key), ``resume_step`` the checkpoint hint for the next attempt."""

    def __init__(self, step: int, cause: BaseException, resume_step: Optional[int] = None):
        super().__init__(f"step {step} failed: {cause!r}")
        self.step = step
        self.cause = cause
        self.resume_step = resume_step


@dataclasses.dataclass
class StragglerDetector:
    """Flag hosts whose step time exceeds ``threshold ×`` the fleet median."""

    n_hosts: int
    window: int = 20
    threshold: float = 1.5

    def __post_init__(self):
        self._times = [deque(maxlen=self.window) for _ in range(self.n_hosts)]

    def record(self, host: int, step_time: float) -> None:
        self._times[host].append(step_time)

    def medians(self) -> list[float]:
        out = []
        for dq in self._times:
            s = sorted(dq)
            out.append(s[len(s) // 2] if s else 0.0)
        return out

    def stragglers(self) -> list[int]:
        meds = [m for m in self.medians() if m > 0]
        if not meds:
            return []
        fleet = sorted(meds)[len(meds) // 2]
        return [
            h
            for h, m in enumerate(self.medians())
            if m > self.threshold * fleet and m > 0
        ]


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    backoff_s: float = 1.0
    backoff_mult: float = 2.0

    def delays(self):
        d = self.backoff_s
        for _ in range(self.max_restarts):
            yield d
            d *= self.backoff_mult


def failure_signature(exc: BaseException) -> Optional[tuple]:
    """``(type_name, step)`` when the exception identifies its failing step
    (a ``.step`` attribute, including :class:`StepFailure` — which keys on
    its *cause*'s type); None for stepless exceptions, which cannot be
    distinguished across attempts and stay on the legacy transient path."""
    step = getattr(exc, "step", None)
    if step is None:
        return None
    cause = getattr(exc, "cause", None)
    name = type(cause).__name__ if cause is not None else type(exc).__name__
    return (name, int(step))


class Supervisor:
    """Run ``loop_fn(resume_step) -> last_step`` under the restart policy.

    ``loop_fn`` must be restartable from a checkpoint (launch/train.py is:
    it restores the latest *valid* manifest and the data stream is
    step-addressed).  Failures are classified per :func:`failure_signature`:
    a repeated same-step failure raises :class:`DeterministicFailure`
    immediately; transient ones restart with backoff, threading the
    exception's ``resume_step`` hint into the next attempt (None when the
    exception carries none — the loop then re-resolves the newest valid
    checkpoint itself).
    """

    def __init__(self, policy: RestartPolicy, *, sleep: Callable[[float], None] = time.sleep):
        self.policy = policy
        self.sleep = sleep
        self.restarts = 0
        self.failures: list[str] = []
        self.classified: list[tuple] = []  # (signature-or-None, verdict)

    def run(self, loop_fn: Callable[[Optional[int]], int], resume_step: Optional[int] = None) -> int:
        delays = self.policy.delays()
        last_sig: Optional[tuple] = None
        while True:
            try:
                return loop_fn(resume_step)
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — supervisor boundary
                self.failures.append(repr(e))
                sig = failure_signature(e)
                if sig is not None and sig == last_sig:
                    self.classified.append((sig, "deterministic"))
                    raise DeterministicFailure(
                        f"step {sig[1]} failed twice with {sig[0]} across a "
                        f"restore — deterministic, not restarting "
                        f"(restarts so far: {self.restarts})"
                    ) from e
                self.classified.append((sig, "transient"))
                last_sig = sig
                try:
                    delay = next(delays)
                except StopIteration:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.policy.max_restarts}; "
                        f"failures: {self.failures}"
                    ) from e
                self.restarts += 1
                self.sleep(delay)
                # thread the failure's checkpoint hint through; loop_fn
                # re-resolves the newest valid checkpoint when None
                resume_step = getattr(e, "resume_step", None)
