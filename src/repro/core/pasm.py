"""PASM weight-sharing: codebook quantization of dense weights.

Implements the weight-sharing scheme PASM depends on (Han et al. 2015/2016, as
used by Garland & Gregg 2018): every weight of a layer is replaced by a
``log2(B)``-bit index into a tiny codebook ("dictionary") of ``B`` shared
values.  The paper uses one dictionary per layer (``groups=1``); we additionally
support group-wise codebooks along the reduction axis (a beyond-paper accuracy
feature, ``groups>1``).

The quantized weight is carried through jit as a :class:`PASMTensor` pytree —
``idx`` (uint8, optionally two 4-bit indices packed per byte) plus ``codebook``
(``(G, B)`` float32).  Dequantization happens either in the Pallas kernel
(production path) or via :func:`dequantize` (oracle path).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PASMTensor",
    "kmeans_codebook",
    "quantize",
    "dequantize",
    "pack_int4",
    "unpack_int4",
    "bits_for_bins",
]


def bits_for_bins(bins: int) -> int:
    """Index bit-width for ``bins`` dictionary entries (paper: 2^2..2^8 bins)."""
    if bins < 2 or bins > 256:
        raise ValueError(f"PASM supports 2..256 bins, got {bins}")
    return 4 if bins <= 16 else 8


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["idx", "codebook"],
    meta_fields=["shape", "bins", "bits", "packed"],
)
@dataclasses.dataclass(frozen=True)
class PASMTensor:
    """A weight-shared tensor: per-element bin indices + shared-value codebook.

    ``idx``       uint8 indices.  Logical shape is ``shape`` (always 2-D,
                  ``(K, N)`` = (reduction, output)).  When ``packed`` the K axis
                  holds two 4-bit indices per byte: physical ``(K//2, N)``.
    ``codebook``  ``(G, B)`` float32 shared weight values; group ``g`` covers
                  rows ``[g*K/G, (g+1)*K/G)`` of the reduction axis.
    """

    idx: jax.Array
    codebook: jax.Array
    shape: tuple
    bins: int
    bits: int
    packed: bool

    @property
    def groups(self) -> int:
        return self.codebook.shape[0]

    @property
    def nbytes_weights(self) -> int:
        """HBM bytes for the weight payload (what the memory roofline sees)."""
        return int(np.prod(self.idx.shape)) * 1 + self.codebook.size * 4

    @property
    def nbytes_dense_bf16(self) -> int:
        return int(np.prod(self.shape)) * 2

    @property
    def compression_ratio(self) -> float:
        return self.nbytes_dense_bf16 / self.nbytes_weights


# ---------------------------------------------------------------------------
# k-means clustering (Lloyd iterations, quantile init — deterministic)
# ---------------------------------------------------------------------------


def _kmeans_1d(values: jax.Array, bins: int, iters: int) -> tuple[jax.Array, jax.Array]:
    """1-D k-means on ``values`` (flat). Returns (codebook (B,), idx (len,))."""
    # Quantile init spreads centroids across the empirical distribution —
    # deterministic and robust for weight distributions (approx. zero-mean).
    qs = (jnp.arange(bins, dtype=jnp.float32) + 0.5) / bins
    centroids = jnp.quantile(values, qs)

    def assign(c):
        d = jnp.abs(values[:, None] - c[None, :])
        return jnp.argmin(d, axis=1)

    def step(c, _):
        a = assign(c)
        one_hot = jax.nn.one_hot(a, bins, dtype=values.dtype)
        counts = one_hot.sum(axis=0)
        sums = one_hot.T @ values
        new_c = jnp.where(counts > 0, sums / jnp.maximum(counts, 1), c)
        return new_c, None

    centroids, _ = jax.lax.scan(step, centroids, None, length=iters)
    centroids = jnp.sort(centroids)
    return centroids, assign(centroids)


def kmeans_codebook(
    w: jax.Array, bins: int, *, groups: int = 1, iters: int = 16
) -> tuple[jax.Array, jax.Array]:
    """Cluster a 2-D weight ``(K, N)`` into ``groups`` codebooks of ``bins``.

    Returns ``(codebook (G, B) f32, idx (K, N) uint8)``.
    """
    if w.ndim != 2:
        raise ValueError(f"kmeans_codebook expects 2-D (K, N), got {w.shape}")
    K, N = w.shape
    if K % groups != 0:
        raise ValueError(f"K={K} not divisible by groups={groups}")
    wg = w.astype(jnp.float32).reshape(groups, K // groups * N)
    codebooks, idx = jax.vmap(lambda v: _kmeans_1d(v, bins, iters))(wg)
    idx = idx.reshape(groups, K // groups, N).reshape(K, N).astype(jnp.uint8)
    return codebooks, idx


# ---------------------------------------------------------------------------
# int4 packing (two indices per byte along the reduction axis)
# ---------------------------------------------------------------------------


def pack_int4(idx: jax.Array) -> jax.Array:
    """Pack ``(K, N)`` uint8 values < 16 into ``(K//2, N)``: lo nibble = even row."""
    K = idx.shape[0]
    if K % 2 != 0:
        raise ValueError(f"K={K} must be even to pack int4")
    lo = idx[0::2]
    hi = idx[1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4` → ``(2*Kp, N)`` uint8."""
    lo = packed & 0x0F
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=1)  # (Kp, 2, N)
    return out.reshape(packed.shape[0] * 2, *packed.shape[1:]).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# quantize / dequantize
# ---------------------------------------------------------------------------


def quantize(
    w: jax.Array,
    bins: int = 16,
    *,
    groups: int = 1,
    iters: int = 16,
    pack: Optional[bool] = None,
) -> PASMTensor:
    """Post-training weight-share a 2-D weight (paper-faithful for groups=1)."""
    bits = bits_for_bins(bins)
    if pack is None:
        pack = bits == 4
    if pack and bits != 4:
        raise ValueError("packing requires bins <= 16")
    codebook, idx = kmeans_codebook(w, bins, groups=groups, iters=iters)
    if pack:
        idx = pack_int4(idx)
    return PASMTensor(
        idx=idx,
        codebook=codebook,
        shape=tuple(w.shape),
        bins=bins,
        bits=bits,
        packed=bool(pack),
    )


def logical_idx(t: PASMTensor) -> jax.Array:
    """The ``(K, N)`` uint8 index array regardless of packing."""
    return unpack_int4(t.idx) if t.packed else t.idx


def dequantize(t: PASMTensor, dtype=jnp.float32) -> jax.Array:
    """Reconstruct the dense ``(K, N)`` weight — the weight-shared MAC's view."""
    idx = logical_idx(t)
    K, N = t.shape
    G = t.groups
    idxg = idx.reshape(G, K // G, N)
    wg = jax.vmap(lambda cb, ix: cb[ix])(t.codebook, idxg)
    return wg.reshape(K, N).astype(dtype)


def quantize_like(t: PASMTensor, w: jax.Array) -> PASMTensor:
    """Re-assign ``w`` to the nearest entries of an existing codebook (QAT path)."""
    K, N = t.shape
    G = t.groups
    wg = w.astype(jnp.float32).reshape(G, K // G, N)

    def assign(cb, v):
        return jnp.argmin(jnp.abs(v[..., None] - cb), axis=-1).astype(jnp.uint8)

    idx = jax.vmap(assign)(t.codebook, wg).reshape(K, N)
    if t.packed:
        idx = pack_int4(idx)
    return dataclasses.replace(t, idx=idx)
