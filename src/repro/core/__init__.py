"""Core PASM library: the paper's contribution as composable JAX modules.

One weight-shared container is exported here: :class:`PasmParams` (with the
dispatch helpers every model layer routes through).  The low-level
:class:`~repro.core.pasm.PASMTensor` GEMM operand and its helpers stay on
the ``repro.core.pasm`` submodule — reach for them only when handing
operands to the Pallas kernels directly.
"""
from repro.core.params import (  # noqa: F401
    PasmParams,
    as_params,
    dense_stack,
    dense_weight,
    embed_lookup,
    is_quantized,
    matmul,
)
from repro.core.pasm import (  # noqa: F401
    bits_for_bins,
    dequantize,
    kmeans_codebook,
    logical_idx,
    pack_int4,
    quantize,
    quantize_like,
    unpack_int4,
)
from repro.core.pas import (  # noqa: F401
    mac_cycles,
    pas_accumulate,
    pas_postpass,
    pasm_cycles,
    pasm_dot,
    pasm_matmul,
    weight_shared_dot,
    weight_shared_matmul,
)
