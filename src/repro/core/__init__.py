"""Core PASM library: the paper's contribution as composable JAX modules."""
from repro.core.pasm import (  # noqa: F401
    PASMTensor,
    bits_for_bins,
    dequantize,
    kmeans_codebook,
    logical_idx,
    pack_int4,
    quantize,
    quantize_like,
    unpack_int4,
)
from repro.core.pas import (  # noqa: F401
    mac_cycles,
    pas_accumulate,
    pas_postpass,
    pasm_cycles,
    pasm_dot,
    pasm_matmul,
    weight_shared_dot,
    weight_shared_matmul,
)
