"""PasmParams — the one weight-shared parameter container, conv to dense.

The paper's weight-sharing (per-layer codebooks of B shared values + small
integer indices, Garland & Gregg 2018) applies to ANY weight-bearing matmul:
a conv layer lowers onto a GEMM via im2col, a transformer FFN/attention
projection *is* a GEMM, an MoE expert is a stack of them.  This module holds
the geometry-free container those all share:

* :class:`PasmParams` — a tagged weight: ``dense`` (a plain ``(…, K, N)``
  matrix), weight-``shared`` (uint8 bin indices + a ``(…, G, B)`` codebook,
  one dictionary per layer when ``G == 1`` — the paper rule — or one per
  reduction-axis segment), or int4-``packed`` (two 4-bit indices per byte
  along K, §3 K-pad applied at pack time so odd reductions work).  Leading
  stack dims (scan-over-layers L, MoE experts E) ride the data fields while
  the logical ``(K, N)`` stays static metadata, so ``lax.scan``/``vmap``
  slicing works unchanged.
* :func:`matmul` — THE dispatch every dense layer routes through
  (:func:`repro.nn.layers.linear` is a thin alias): plain arrays and
  ``dense`` params always take the XLA dot; quantized params pick
  ``impl="dequant"`` (gather+dot oracle), ``"kernel"`` (fused-dequant Pallas
  GEMM) or ``"pas_kernel"`` (paper-faithful two-phase PAS), with the fused
  bias/ReLU epilogue and the same ``mesh=`` shard_map path conv uses — the
  kernels are distribution-safe, not just the dequant fallback.
* :func:`embed_lookup` / :func:`dense_weight` / :func:`dense_stack` — the
  non-GEMM views (embedding row gather, tied-head dense matrix, stacked
  expert dequant) so model code contains zero container ``isinstance``.

:class:`repro.core.pasm.PASMTensor` survives underneath as the low-level
Pallas GEMM *operand* (physical, pad-inclusive shapes); ``PasmParams`` is
the parameter-tree container (logical shapes + the ``pad_k`` book-keeping),
and :meth:`PasmParams.gemm_tensor` bridges the two.
:class:`repro.core.conv.ConvParams` is the conv-geometry wrapper over this
container — it flattens kernels into ``(K, c_out)`` in its layout's order
and delegates quantize/pack/GEMM-operand construction here.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pasm as _pasm

__all__ = [
    "PasmParams",
    "KINDS",
    "MATMUL_IMPLS",
    "as_params",
    "is_quantized",
    "matmul",
    "embed_lookup",
    "dense_weight",
    "dense_stack",
]

KINDS = ("dense", "shared", "packed")
# matmul impl names (PASMQuant.impl values): plain arrays / dense params take
# the XLA dot under every impl — quantized params dispatch on it.
MATMUL_IMPLS = ("dense", "dequant", "kernel", "pas_kernel")

Weight = Union[jax.Array, "PasmParams", _pasm.PASMTensor]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["w", "idx", "codebook", "bias"],
    meta_fields=["kind", "shape", "bins", "pad_k"],
)
@dataclasses.dataclass(frozen=True)
class PasmParams:
    """Tagged matmul weights: ``dense`` | weight-``shared`` | int4-``packed``.

    ``dense``   ``w (…, K, N)``; ``idx``/``codebook`` None.
    ``shared``  ``idx (…, K, N) uint8`` bin indices + ``codebook (…, G, B)``
                f32 shared values — ``G == 1`` is the paper's one dictionary
                per layer; ``G > 1`` splits the reduction axis into ``G``
                segments with one dictionary each (beyond-paper accuracy
                knob, e.g. per-expert grouped codebooks).
    ``packed``  ``idx (…, (K+pad_k)//2, N) uint8`` — two 4-bit indices per
                byte along K; ``pad_k`` records the §3 K-pad row appended so
                an odd reduction packs (mapped to a reserved all-zero
                codebook bin when representable — callers pad the matching
                activation column with zeros, which :func:`matmul` does
                automatically).
    ``bias``    ``(…, N)`` or None on every kind — never shared (paper §4).
    ``shape``   the logical ``(K, N)`` (static metadata; leading stack dims
                live on the data fields so scan/vmap slicing works).
    """

    w: Optional[jax.Array] = None
    idx: Optional[jax.Array] = None
    codebook: Optional[jax.Array] = None
    bias: Optional[jax.Array] = None
    kind: str = "dense"
    shape: tuple = ()
    bins: Optional[int] = None
    pad_k: int = 0

    # -- constructors -------------------------------------------------------

    @classmethod
    def dense(cls, w: jax.Array, *, bias: Optional[jax.Array] = None):
        """Non-weight-shared params from a plain ``(…, K, N)`` matrix."""
        if w.ndim < 2:
            raise ValueError(f"dense params need a (…, K, N) matrix, got {w.shape}")
        return cls(w=w, bias=bias, kind="dense", shape=tuple(w.shape[-2:]))

    @classmethod
    def shared(
        cls,
        idx: jax.Array,
        codebook: jax.Array,
        *,
        bias: Optional[jax.Array] = None,
    ):
        """Weight-shared params from existing bin indices + dictionary.

        ``idx (…, K, N)`` uint8; ``codebook (B,)`` (the single-dictionary
        paper rule) or ``(…, G, B)`` with one dictionary per reduction-axis
        segment.  Leading dims of ``idx`` and ``codebook`` must agree.
        """
        if idx.ndim < 2:
            raise ValueError(f"idx must be (…, K, N), got {idx.shape}")
        if codebook.ndim == 1:
            codebook = codebook[None]  # (B,) ≡ the single-dictionary rule
        if codebook.ndim != idx.ndim:
            raise ValueError(
                f"codebook rank {codebook.shape} does not match idx "
                f"{idx.shape}: leading stack dims must agree"
            )
        K = int(idx.shape[-2])
        G = int(codebook.shape[-2])
        if K % G:
            raise ValueError(f"K={K} not divisible by codebook groups={G}")
        return cls(
            idx=idx.astype(jnp.uint8),
            codebook=codebook,
            bias=bias,
            kind="shared",
            shape=tuple(idx.shape[-2:]),
            bins=int(codebook.shape[-1]),
        )

    @classmethod
    def quantize(
        cls,
        w: jax.Array,
        bins: int = 16,
        *,
        groups: int = 1,
        bias: Optional[jax.Array] = None,
        iters: int = 16,
    ):
        """K-means weight-share a dense ``(…, K, N)`` matrix.

        ``groups=1`` (default) is the paper rule — one dictionary per layer;
        ``groups > 1`` splits the reduction axis.  Leading stack dims are
        quantized per slice (one codebook set per layer/expert).  Does not
        pack — call :meth:`pack` for the int4 payload.
        """
        if w.ndim < 2:
            raise ValueError(f"quantize needs a (…, K, N) matrix, got {w.shape}")
        K, N = w.shape[-2:]
        lead = tuple(w.shape[:-2])
        flat = w.reshape((-1, K, N))
        cbs, idxs = jax.vmap(
            lambda m: _pasm.kmeans_codebook(m, bins, groups=groups, iters=iters)
        )(flat)
        return cls.shared(
            idxs.reshape(lead + (K, N)),
            cbs.reshape(lead + (groups, bins)),
            bias=bias,
        )

    def pack(self) -> "PasmParams":
        """int4-pack the dictionary indices (two 4-bit indices per byte).

        Halves weight-payload bytes.  An odd ``K`` gets the §3 K-pad first:
        one pad row is appended, mapped to a reserved all-zero codebook bin
        when representable (``bins < 16``) or to bin 0 otherwise — exact
        either way, because :func:`matmul` pairs the pad row with a zero
        activation column (``pad_k``).  This is the same reserved-zero-bin
        rule :func:`repro.kernels.ops._pad_weight_operands` applies to its
        tile-plan K padding.
        """
        if self.kind != "shared":
            raise ValueError(
                f"pack() needs shared params (got {self.kind!r}); "
                "quantize() dense weights first"
            )
        if self.bins > 16:
            raise ValueError(f"int4 packing needs bins <= 16, got {self.bins}")
        K, N = self.shape
        G = self.groups
        if G > 1 and (K // G) % 2:
            # nibble pairs must not straddle a group boundary
            raise ValueError(
                "packed int4 needs an even per-group reduction length, got "
                f"K={K} over {G} groups"
            )
        idx, codebook, bins, pad_k = self.idx, self.codebook, self.bins, 0
        if K % 2:
            pad_k = 1
            if bins < 16:
                codebook = jnp.pad(
                    codebook, [(0, 0)] * (codebook.ndim - 1) + [(0, 1)]
                )  # reserved 0-bin
                pad_bin, bins = bins, bins + 1
            else:
                pad_bin = 0  # inert anyway: matmul zero-pads the x column
            idx = jnp.pad(
                idx,
                [(0, 0)] * (idx.ndim - 2) + [(0, 1), (0, 0)],
                constant_values=pad_bin,
            )
        lead = idx.shape[:-2]
        if lead:
            flat = idx.reshape((-1,) + idx.shape[-2:])
            idx = jax.vmap(_pasm.pack_int4)(flat).reshape(
                lead + ((K + pad_k) // 2, N)
            )
        else:
            idx = _pasm.pack_int4(idx)
        return PasmParams(
            idx=idx,
            codebook=codebook,
            bias=self.bias,
            kind="packed",
            shape=self.shape,
            bins=bins,
            pad_k=pad_k,
        )

    # -- views --------------------------------------------------------------

    @property
    def groups(self) -> int:
        """Codebook groups along the reduction axis (1 = paper rule)."""
        return 1 if self.codebook is None else int(self.codebook.shape[-2])

    @property
    def packed(self) -> bool:
        return self.kind == "packed"

    @property
    def bits(self) -> Optional[int]:
        """Index bit-width (None for dense params)."""
        if self.kind == "dense":
            return None
        return 4 if self.packed else _pasm.bits_for_bins(self.bins)

    def gemm_tensor(self) -> _pasm.PASMTensor:
        """The dictionary as the physical Pallas GEMM operand.

        The returned :class:`~repro.core.pasm.PASMTensor` shape is the
        PHYSICAL ``(K + pad_k, N)`` — callers (i.e. :func:`matmul`) pad the
        activation's trailing K columns by ``pad_k`` to match.
        """
        if self.kind == "dense":
            raise ValueError(
                "dense params have no dictionary; use the dense matmul path"
            )
        K, N = self.shape
        return _pasm.PASMTensor(
            idx=self.idx,
            codebook=self.codebook.astype(jnp.float32),
            shape=(K + self.pad_k, N),
            bins=self.bins,
            bits=4 if self.packed else _pasm.bits_for_bins(self.bins),
            packed=self.packed,
        )

    def dense_matrix(self, dtype=None) -> jax.Array:
        """The logical dense ``(…, K, N)`` weight (§3 pad rows removed).

        Dtype defaults to the stored dtype for ``dense`` params (so integer
        exactness claims survive) and f32 for quantized params — the
        weight-shared MAC's dictionary-dereferenced view (Fig 3).
        """
        if self.kind == "dense":
            return self.w if dtype is None else self.w.astype(dtype)
        K, N = self.shape
        G = self.groups
        packed = self.packed

        def one(ix, cb):
            if packed:
                ix = _pasm.unpack_int4(ix)
            kp = ix.shape[0]
            wg = jax.vmap(lambda c, i: c[i.astype(jnp.int32)])(
                cb, ix.reshape(G, kp // G, N)
            )
            return wg.reshape(kp, N)[:K]

        lead = self.idx.shape[:-2]
        if lead:
            out = jax.vmap(one)(
                self.idx.reshape((-1,) + self.idx.shape[-2:]),
                self.codebook.reshape((-1,) + self.codebook.shape[-2:]),
            ).reshape(lead + (K, N))
        else:
            out = one(self.idx, self.codebook)
        return out.astype(jnp.float32 if dtype is None else dtype)

    # -- byte accounting (the weight-stream roofline's view) ----------------

    @property
    def _lead(self) -> tuple:
        a = self.w if self.kind == "dense" else self.idx
        return tuple(a.shape[:-2])

    @property
    def nbytes_weights(self) -> int:
        """HBM bytes for the weight payload (what the memory roofline sees)."""
        if self.kind == "dense":
            return int(self.w.size) * self.w.dtype.itemsize
        return int(np.prod(self.idx.shape, dtype=np.int64)) + self.codebook.size * 4

    @property
    def nbytes_dense_bf16(self) -> int:
        lead = int(np.prod(self._lead, dtype=np.int64)) if self._lead else 1
        return lead * int(np.prod(self.shape)) * 2

    @property
    def compression_ratio(self) -> float:
        """Dense-bf16 bytes over stored bytes — the bins-vs-bytes trade-off."""
        return self.nbytes_dense_bf16 / self.nbytes_weights


# ---------------------------------------------------------------------------
# the dispatch surface model code routes through (zero isinstance elsewhere)
# ---------------------------------------------------------------------------


def as_params(w: Weight) -> PasmParams:
    """Coerce any weight leaf into the container.

    Plain arrays become ``dense`` params; a raw :class:`PASMTensor` (the
    legacy container / the GEMM-operand adapter) wraps with its physical
    shape as the logical one (``pad_k = 0`` — old tensors carry no pad).
    """
    if isinstance(w, PasmParams):
        return w
    if isinstance(w, _pasm.PASMTensor):
        return PasmParams(
            idx=w.idx,
            codebook=w.codebook,
            kind="packed" if w.packed else "shared",
            shape=tuple(w.shape),
            bins=w.bins,
        )
    return PasmParams.dense(w) if w.ndim >= 2 else PasmParams(
        w=w, kind="dense", shape=tuple(w.shape)
    )


def is_quantized(w) -> bool:
    """Whether a weight leaf carries a dictionary (vs a plain dense matrix)."""
    if isinstance(w, PasmParams):
        return w.kind != "dense"
    return isinstance(w, _pasm.PASMTensor)


def matmul(
    x: jax.Array,
    w: Weight,
    *,
    impl: str = "dense",
    bias: Optional[jax.Array] = None,
    relu: bool = False,
    mesh=None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``x @ w`` for any weight leaf — THE dense-layer dispatch.

    Plain arrays and ``dense`` params always run the XLA dot regardless of
    ``impl`` (post-``quantize_params`` trees mix dense and quantized
    leaves).  Quantized params dispatch on ``impl``:

    =============  =========================================================
    impl           engine
    =============  =========================================================
    ``dequant``    dictionary gather + XLA dot (the weight-shared-MAC
                   baseline and the kernels' bit-exactness oracle)
    ``kernel``     :func:`repro.kernels.ops.pasm_matmul` — fused-dequant
                   Pallas GEMM, bias/ReLU fused into the last-k-step
                   write-through
    ``pas_kernel`` :func:`repro.kernels.ops.pas_matmul` — the paper-faithful
                   two-phase PAS formulation (single-dictionary only)
    =============  =========================================================

    ``bias`` defaults to the container's own ``bias`` field; ``mesh=`` (a
    ``("data", "model")`` mesh) runs the kernel paths through the same
    shard_map dispatch conv uses — rows over ``data``, N over ``model`` when
    divisible — bit-exact vs single-device, so the kernels are as
    distribution-safe as the dequant path.  Packed params with a §3 K-pad
    get their zero activation column appended here (``pad_k``), which is
    what makes odd reductions (odd ``d_model``) work on the kernels.
    Output dtype follows ``x``.
    """
    if impl not in MATMUL_IMPLS:
        raise ValueError(f"impl must be one of {MATMUL_IMPLS}, got {impl!r}")
    p = as_params(w)
    if bias is None:
        bias = p.bias
    if p.kind == "dense" or impl in ("dense", "dequant"):
        from repro.kernels.ref import apply_epilogue  # pallas-free

        wd = p.dense_matrix(x.dtype)
        y = jnp.dot(x, wd, preferred_element_type=jnp.float32)
        return apply_epilogue(y, bias, relu).astype(x.dtype)
    from repro.kernels import ops as _kops  # deferred: core stays pallas-free

    t = p.gemm_tensor()
    if p.pad_k:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, p.pad_k)])
    if impl == "pas_kernel":
        if p.groups > 1:
            raise ValueError(
                "the PAS formulation is paper-faithful single-dictionary; "
                "grouped codebooks need impl='kernel' or 'dequant'"
            )
        y = _kops.pas_matmul(
            x, t, bias=bias, relu=relu, mesh=mesh, interpret=interpret
        )
    else:
        y = _kops.pasm_matmul(
            x, t, bias=bias, relu=relu, mesh=mesh, interpret=interpret
        )
    return y.astype(x.dtype)


def embed_lookup(w: Weight, tokens: jax.Array) -> jax.Array:
    """Embedding-table row gather for any weight leaf.

    For quantized tables this gathers uint8 index rows and dereferences the
    dictionary — the paper's compression applied to the vocab table (no
    dense ``(V, D)`` matrix is ever materialized).  Single-dictionary
    tables only (``quantize_params`` quantizes embeddings with ``G == 1``).
    """
    p = as_params(w)
    if p.kind == "dense":
        return p.w[tokens]
    idx = _pasm.unpack_int4(p.idx) if p.packed else p.idx
    rows = idx[tokens]
    return p.codebook[0][rows.astype(jnp.int32)]


def dense_weight(w: Weight, dtype=None) -> jax.Array:
    """The logical dense ``(…, K, N)`` matrix of any weight leaf.

    The tied-LM-head path: kernels compute ``x @ W``, not ``x @ Wᵀ``, so a
    tied head dequantizes once and transposes at the call site.
    """
    return as_params(w).dense_matrix(dtype)


def dense_stack(w: Weight, dtype, constrain=None, spec=None) -> jax.Array:
    """Stacked expert weights ``(E, K, N)`` → dense, for the MoE einsum path.

    ``spec`` re-lays-out the STORED weight before use (JIT all-gather of the
    2-D-sharded storage).  For quantized weights the gather moves the
    uint8/int4 *indices* — 4–8× fewer bytes than gathering dequantized bf16,
    the paper's compression applied to the collective payload
    [§Perf iteration kimi-prefill/2].
    """
    if not is_quantized(w):
        w = w if spec is None else constrain(w, spec)
        return w.astype(dtype)
    p = as_params(w)
    idx = p.idx if spec is None else constrain(p.idx, spec)
    if p.packed:
        idx = jax.vmap(_pasm.unpack_int4)(idx)
    E = idx.shape[0]
    K, N = p.shape
    G = p.groups
    kp = K + p.pad_k
    idxg = idx.reshape(E, G, kp // G, N)
    wd = jax.vmap(jax.vmap(lambda cb, ix: cb[ix.astype(jnp.int32)]))(
        p.codebook, idxg
    )
    return wd.reshape(E, kp, N)[:, :K].astype(dtype)
