"""Weight-shared convolution — the unified `ConvParams`/`conv2d` surface.

The paper evaluates ONE accelerator in three variants (§4, Fig 13):
non-weight-shared, weight-shared, and weight-shared-with-PASM, each with
stride, bias and ReLU (bias/activation are *not* shared — §4).  This module
exposes that accelerator through two types and one entry point:

* :class:`ConvParams` — a tagged weight container: a ``dense`` kernel, a
  weight-shared dictionary (``shared``: uint8 bin indices + codebook), or an
  int4-``packed`` dictionary (two 4-bit indices per byte, §3 K-pad applied
  before packing so odd ``C·KY·KX`` reductions work).  Built via
  :meth:`ConvParams.dense` / :meth:`ConvParams.quantize` /
  :meth:`ConvParams.shared`, converted with :meth:`ConvParams.pack`.
* :class:`Conv2D` — the geometry-free layer spec: kernel size, channel
  counts, stride, ``padding="valid_centred"|"valid"|"same"``,
  ``layout="NCHW"|"NHWC"``, and the epilogue (``bias`` gate + ``relu`` flag).
  Image height/width are *not* part of the spec — they are read off the
  input, so one spec serves every image size.
* :func:`conv2d` — ``conv2d(x, params, conv, *, engine, interpret)``
  dispatches every (params kind × engine) combination:

  ===========  ================================================================
  engine       meaning
  ===========  ================================================================
  ``auto``     dense → einsum; shared/packed → implicit-GEMM Pallas kernel
               when batched (images past the VMEM budget stream as
               row-band slabs — no explicit fallback), einsum reference
               for single images
  ``einsum``   pure-XLA reference: (dequantized) dense GEMM + XLA epilogue
  ``kernel``   :func:`repro.kernels.ops.pasm_matmul` — fused-dequant Pallas
               GEMM with the bias/ReLU epilogue fused into the last-k-step
               write-through (one ``pallas_call`` per conv layer) over an
               explicitly materialized im2col patch matrix
  ``kernel_implicit``  :func:`repro.kernels.ops.pasm_conv2d` — **implicit
               im2col**: one ``pallas_call`` over the raw (padded) image;
               patch tiles are assembled inside the kernel, no ``(B·P, K)``
               patch matrix in HBM (bit-exact vs ``kernel``)
  ``pas_kernel``  :func:`repro.kernels.ops.pas_matmul` — the paper-faithful
               two-phase PAS formulation, epilogue fused into the post-pass
  ``pas_kernel_implicit``  :func:`repro.kernels.ops.pas_conv2d` — the
               two-phase formulation with implicit im2col
  ``pas_einsum``  the two-phase formulation as pure XLA (one-hot histogram +
               post-pass) — the seed's ``conv2d_pasm`` einsum port
  ===========  ================================================================

Convolution lowers onto the PASM GEMMs via im2col in the layout's column
order — ``(B, C, IH, IW) → (B·P, C·KY·KX)`` in the paper's ``(c, ky, kx)``
order for NCHW, or ``(B, IH, IW, C) → (B·P, KY·KX·C)`` channels-minor
(TPU-native) for NHWC — and the weight container flattens itself into the
matching ``(K, M)`` GEMM operand.  The explicit engines materialize that
patch matrix in HBM; the ``*_implicit`` engines assemble patch tiles inside
the kernel from the VMEM-resident image (DESIGN.md §3).

:class:`ConvParams` is the conv-geometry face of the one weight-shared
container: quantize/pack/groups/§3-K-pad semantics live in
:class:`repro.core.params.PasmParams`, and ConvParams delegates to it after
flattening kernels into the layout's ``(K, c_out)`` order — a dense FFN
weight and a conv kernel share one pack rule, one reserved-zero-bin pad,
one byte model.

The PR-1 ``ConvSpec``/``conv2d_direct``/``conv2d_weight_shared``/
``conv2d_pasm`` surface (deprecation-shimmed since PR 2) is gone; the
migration table lives in DESIGN.md §2.  ``quantize_conv_weights`` survives
as the paper's one-dictionary-per-layer helper.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import pasm as _pasm
from repro.core.params import PasmParams

__all__ = [
    "Conv2D",
    "ConvParams",
    "conv2d",
    "conv_out_hw",
    "conv_geom",
    "conv_plan",
    "max_pool2d",
    "quantize_conv_weights",
    "PADDINGS",
    "LAYOUTS",
    "POOL_IMPLS",
]

PADDINGS = ("valid_centred", "valid", "same")
LAYOUTS = ("NCHW", "NHWC")
ENGINES = (
    "auto",
    "einsum",
    "kernel",
    "kernel_implicit",
    "pas_kernel",
    "pas_kernel_implicit",
    "pas_einsum",
)
_IMPLICIT_ENGINES = ("kernel_implicit", "pas_kernel_implicit")
_PAS_ENGINES = ("pas_kernel", "pas_kernel_implicit", "pas_einsum")
# conv2d(pool=) fusion policy: "auto" fuses the max-pool into the kernel
# epilogue whenever the engine/geometry allow (reduce_window fallback
# otherwise — bit-exact either way), "fused" demands the fused path (raises
# when impossible), "unfused" always runs the separate reduce_window.
POOL_IMPLS = ("auto", "fused", "unfused")

# The implicit engines' per-image VMEM budget: the double-buffered padded
# image (or row-band slab) plus the idx / codebook / bias / output blocks
# must fit under it.  Images past the budget stream as slabs
# (``ops.conv_slab_plan``) — the budget sizes the slabs, it no longer flips
# ``auto`` to the explicit engine.  This module-level default suits a
# ~16 MiB-VMEM TPU core; per-call targets override it with
# ``conv2d(vmem_budget=)`` / ``CNNConfig.vmem_budget``.  Keep in sync with
# ``repro.kernels.ops.IMPLICIT_VMEM_BUDGET``.
_IMPLICIT_VMEM_BUDGET = 6 * 1024 * 1024

# GEMM column order per layout: NCHW flattens patches (and weights) in the
# paper's (c, ky, kx) loop-nest order (Fig 1); NHWC is channels-minor
# (ky, kx, c) — the TPU-native layout.
_ORDER = {"NCHW": "ckk", "NHWC": "kkc"}


# ---------------------------------------------------------------------------
# the layer spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Conv2D:
    """Geometry-free conv layer spec (image H/W are read off the input)."""

    k: Union[int, tuple]
    c_in: int
    c_out: int
    stride: int = 1
    padding: str = "valid_centred"
    layout: str = "NCHW"
    bias: bool = True  # apply ``params.bias`` when present
    relu: bool = False

    def __post_init__(self):
        k = (self.k, self.k) if isinstance(self.k, int) else tuple(self.k)
        object.__setattr__(self, "k", k)
        if self.padding not in PADDINGS:
            raise ValueError(f"padding must be one of {PADDINGS}, got {self.padding!r}")
        if self.layout not in LAYOUTS:
            raise ValueError(f"layout must be one of {LAYOUTS}, got {self.layout!r}")

    @property
    def ky(self) -> int:
        return self.k[0]

    @property
    def kx(self) -> int:
        return self.k[1]

    @property
    def K(self) -> int:
        """The im2col reduction length ``c_in·ky·kx``."""
        return self.c_in * self.ky * self.kx


def _axis_geometry(size: int, k: int, stride: int, padding: str) -> tuple:
    """One spatial axis → ``(out, pad_lo, pad_hi)``.

    ``same`` matches XLA/TF SAME (out = ceil(size/stride), asymmetric zero
    pad); ``valid`` is standard VALID; ``valid_centred`` is the paper's
    kernel-centred loop bounds (Fig 1) — identical to ``valid`` for odd
    kernels, one output short when an even kernel tiles the axis exactly.
    """
    if padding == "same":
        out = -(-size // stride)
        pad = max((out - 1) * stride + k - size, 0)
        return out, pad // 2, pad - pad // 2
    if padding == "valid":
        return (size - k) // stride + 1, 0, 0
    return (size - 2 * (k // 2) + stride - 1) // stride, 0, 0


def conv_out_hw(ih: int, iw: int, conv: Conv2D) -> tuple:
    """Output (OH, OW) of ``conv`` on an ``ih × iw`` image."""
    oh, _, _ = _axis_geometry(ih, conv.ky, conv.stride, conv.padding)
    ow, _, _ = _axis_geometry(iw, conv.kx, conv.stride, conv.padding)
    return oh, ow


def conv_geom(conv: Conv2D, ih: int, iw: int, pool: int = 1):
    """The static geometry the implicit-GEMM kernels consume.

    Resolves the spec against an ``ih × iw`` image into the hashable
    :class:`repro.kernels.ops.ConvGeom` (output dims + spatial pad + the
    layout's reduction order) that rides jit static args.  ``pool > 1``
    requests the fused max-pool epilogue: the kernels walk window-major rows
    and store the pooled ``(oh//pool, ow//pool)`` map (DESIGN.md §3.2).
    """
    from repro.kernels import ops as _kops  # deferred: core must not need pallas

    oh, plo_h, phi_h = _axis_geometry(ih, conv.ky, conv.stride, conv.padding)
    ow, plo_w, phi_w = _axis_geometry(iw, conv.kx, conv.stride, conv.padding)
    return _kops.ConvGeom(
        nhwc=conv.layout == "NHWC",
        ky=conv.ky,
        kx=conv.kx,
        stride=conv.stride,
        oh=oh,
        ow=ow,
        c_in=conv.c_in,
        pad=((plo_h, phi_h), (plo_w, phi_w)),
        pool=pool,
    )


def _implicit_fits(
    conv: Conv2D, ih: int, iw: int, budget: Optional[int] = None,
    params: Optional["ConvParams"] = None, pool: int = 1,
) -> bool:
    """Whole-image VMEM residency predicate for the implicit-GEMM path.

    True when the *double-buffered* padded image plus every other
    per-grid-step VMEM block — idx / codebook / bias / (pooled) output
    block, their double buffers, and the pool (or PAS bin) scratch —
    fits ``budget`` (:func:`repro.kernels.ops.conv_whole_image_fits`,
    audited against the kernels' BlockSpecs).  The seed counted only one
    copy of the raw image bytes, under-reporting residency by the pipeline
    double buffer and the whole fixed-block overhead.

    Shapes that fail no longer fall back to explicit im2col: ``auto``
    keeps the implicit engine and the kernel wrappers stream the image as
    row-band slabs sized to the same ``budget``
    (:func:`repro.kernels.ops.conv_slab_plan`).  This predicate now marks
    the whole-image/slab boundary rather than gating dispatch.

    ``budget`` is the per-call VMEM budget in bytes
    (``conv2d(vmem_budget=)``); ``None`` takes the module default.
    ``params``/``pool`` refine the block accounting (packed idx bytes,
    bins, bias presence, pool-aligned ``bm``); without ``params`` the
    defaults model a shared unpacked dictionary with bias.
    """
    if budget is None:
        budget = _IMPLICIT_VMEM_BUDGET
    oh, plo_h, phi_h = _axis_geometry(ih, conv.ky, conv.stride, conv.padding)
    ow, plo_w, phi_w = _axis_geometry(iw, conv.kx, conv.stride, conv.padding)
    if oh <= 0 or ow <= 0:
        return False
    hp, wp = ih + plo_h + phi_h, iw + plo_w + phi_w
    from repro.kernels import ops as _kops  # deferred: core must not need pallas

    geom = conv_geom(conv, ih, iw, pool=pool)
    packed = params is not None and params.kind == "packed"
    pad_k = params.pad_k if params is not None else 0
    groups = params.groups if params is not None else 1
    bins = params.bins if params is not None else 16
    has_bias = params is None or params.bias is not None
    K = conv.K + pad_k
    bm, bn, bk, _ = _kops._pick_blocks(
        geom.P_rows, K, conv.c_out, K // groups, packed
    )
    bm = _kops._pool_bm(bm, pool)
    return _kops.conv_whole_image_fits(
        geom, hp, wp, bm=bm, bn=bn, bk=bk, bins=bins, packed=packed,
        pas=False, has_bias=has_bias, vmem_budget=budget,
    )


# ---------------------------------------------------------------------------
# the weight container
# ---------------------------------------------------------------------------


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["kernel", "idx", "codebook", "bias"],
    meta_fields=["kind", "kshape", "bins", "order", "pad_k"],
)
@dataclasses.dataclass(frozen=True)
class ConvParams:
    """Tagged conv weights: ``dense`` | weight-``shared`` | int4-``packed``.

    ``dense``   ``kernel (c_out, c_in, ky, kx)``; ``idx``/``codebook`` None.
    ``shared``  ``idx (c_out, c_in, ky, kx) uint8`` bin indices +
                ``codebook (bins,)`` — one dictionary per layer (paper §4) —
                or ``(groups, bins)`` with one dictionary per segment of the
                GEMM reduction axis (beyond-paper accuracy knob; ``order``
                records which layout's flatten order the groups split).
    ``packed``  ``idx (Kp//2, c_out) uint8`` — two 4-bit indices per byte in
                the GEMM ``(K, M)`` layout of ``order`` (baked at pack time);
                ``pad_k`` zero-activation rows were appended by the §3 K-pad
                so an odd ``C·KY·KX`` packs.
    ``bias``    ``(c_out,)`` or None on every kind — never shared (paper §4).
    """

    kernel: Optional[jax.Array] = None
    idx: Optional[jax.Array] = None
    codebook: Optional[jax.Array] = None
    bias: Optional[jax.Array] = None
    kind: str = "dense"
    kshape: tuple = ()
    bins: Optional[int] = None
    order: Optional[str] = None
    pad_k: int = 0

    # -- constructors -------------------------------------------------------

    @classmethod
    def dense(cls, kernel: jax.Array, *, bias: Optional[jax.Array] = None):
        """Non-weight-shared params from a ``(c_out, c_in, ky, kx)`` kernel."""
        if kernel.ndim != 4:
            raise ValueError(f"kernel must be (c_out, c_in, ky, kx), got {kernel.shape}")
        return cls(kernel=kernel, bias=bias, kind="dense", kshape=tuple(kernel.shape))

    @classmethod
    def shared(
        cls,
        idx: jax.Array,
        codebook: jax.Array,
        *,
        bias: Optional[jax.Array] = None,
        order: Optional[str] = None,
    ):
        """Weight-shared params from existing bin indices + dictionary.

        A 1-D ``codebook (bins,)`` is the paper's one-dictionary-per-layer
        rule; a 2-D ``(groups, bins)`` splits the GEMM reduction axis into
        ``groups`` segments with one dictionary each, and then ``order``
        (``"ckk"``/``"kkc"``) must name the flatten order the grouping was
        built for — group membership is a function of the flat K position.
        """
        if idx.ndim != 4:
            raise ValueError(f"idx must be (c_out, c_in, ky, kx), got {idx.shape}")
        if codebook.ndim == 2 and codebook.shape[0] == 1:
            codebook = codebook.reshape(-1)  # (1, B) ≡ the single-dict rule
        groups = 1 if codebook.ndim == 1 else int(codebook.shape[0])
        if groups > 1 and order not in _ORDER.values():
            raise ValueError(
                "grouped codebooks split the flattened reduction axis: pass "
                f"order='ckk'|'kkc' (the layout they were built for), got {order!r}"
            )
        if int(idx[0].size) % groups:
            raise ValueError(
                f"K = c_in·ky·kx = {idx[0].size} not divisible by "
                f"groups={groups}"
            )
        return cls(
            idx=idx.astype(jnp.uint8),
            codebook=codebook,
            bias=bias,
            kind="shared",
            kshape=tuple(idx.shape),
            bins=int(codebook.shape[-1]),
            order=order if groups > 1 else None,
        )

    @classmethod
    def quantize(
        cls,
        kernel: jax.Array,
        bins: int = 16,
        *,
        bias: Optional[jax.Array] = None,
        iters: int = 16,
        groups: int = 1,
        layout: str = "NCHW",
    ):
        """K-means weight-share a dense kernel.

        ``groups=1`` (default) is the paper rule — one dictionary per layer.
        ``groups > 1`` splits the GEMM reduction axis (``K = c_in·ky·kx``,
        flattened in ``layout``'s order) into that many segments with one
        dictionary each — the ROADMAP accuracy knob for small ``bins``; the
        resulting params are pinned to ``layout`` (``gemm_tensor`` refuses a
        mismatch, like packed params do).
        """
        if groups == 1:
            cb, idx = quantize_conv_weights(kernel, bins, iters=iters)
            return cls.shared(idx, cb, bias=bias)
        if layout not in LAYOUTS:
            raise ValueError(f"layout must be one of {LAYOUTS}, got {layout!r}")
        K = int(kernel[0].size)
        if K % groups:
            raise ValueError(
                f"K = c_in·ky·kx = {K} not divisible by groups={groups}"
            )
        order = _ORDER[layout]
        flat = _flatten_kernel(kernel, order)  # (K, c_out)
        p = PasmParams.quantize(flat, bins, groups=groups, iters=iters)
        return cls.shared(
            _unflatten_kernel(p.idx, order, tuple(kernel.shape)), p.codebook,
            bias=bias, order=order,
        )

    def pack(self, *, layout: str = "NCHW") -> "ConvParams":
        """int4-pack the dictionary indices into the GEMM layout of ``layout``.

        Halves conv weight bytes (two 4-bit indices per byte).  Odd
        ``C·KY·KX`` gets the §3 K-pad first: one pad row is appended, mapped
        to a reserved all-zero codebook bin when representable (``bins < 16``)
        or to bin 0 otherwise — exact either way, because :func:`conv2d`
        pairs the pad rows with zero patch columns.
        """
        if self.kind != "shared":
            raise ValueError(
                f"pack() needs shared params (got {self.kind!r}); "
                "quantize() dense kernels first"
            )
        if layout not in LAYOUTS:
            raise ValueError(f"layout must be one of {LAYOUTS}, got {layout!r}")
        order = _ORDER[layout]
        self._check_order(order)
        # flatten into the GEMM layout, then the geometry-free container owns
        # the pack rule (bins gate, grouped evenness, §3 reserved-zero-bin pad)
        base = PasmParams.shared(
            _flatten_kernel(self.idx, order), self.codebook
        ).pack()
        return ConvParams(
            idx=base.idx,
            codebook=(base.codebook.reshape(-1) if self.codebook.ndim == 1
                      else base.codebook),
            bias=self.bias,
            kind="packed",
            kshape=self.kshape,
            bins=base.bins,
            order=order,
            pad_k=base.pad_k,
        )

    # -- views --------------------------------------------------------------

    @property
    def c_out(self) -> int:
        return self.kshape[0]

    @property
    def groups(self) -> int:
        """Codebook groups along the GEMM reduction axis (1 = paper rule)."""
        cb = self.codebook
        return 1 if cb is None or cb.ndim == 1 else int(cb.shape[0])

    def _grouped_codebook(self) -> jax.Array:
        """The ``(G, B)`` f32 codebook the kernels consume."""
        cb = self.codebook.astype(jnp.float32)
        return cb.reshape(1, -1) if cb.ndim == 1 else cb

    def _check_order(self, order: str) -> None:
        if self.order is not None and order != self.order:
            what = "packed" if self.kind == "packed" else "grouped"
            fix = "re-pack" if self.kind == "packed" else "re-quantize"
            raise ValueError(
                f"params were {what} for order {self.order!r} but this layout "
                f"needs {order!r}; {fix} for this layout"
            )

    def _as_pasm(self, order: str) -> PasmParams:
        """The geometry-free container view, idx flattened into ``order``.

        The bridge that makes ConvParams a thin wrapper: GEMM-operand and
        dense-matrix construction live on :class:`PasmParams`; this just
        supplies the conv-specific flatten.
        """
        if self.kind == "packed":
            return PasmParams(
                idx=self.idx,
                codebook=self._grouped_codebook(),
                bias=self.bias,
                kind="packed",
                shape=(self.idx.shape[0] * 2 - self.pad_k, self.c_out),
                bins=self.bins,
                pad_k=self.pad_k,
            )
        if self.kind == "shared":
            return PasmParams(
                idx=_flatten_kernel(self.idx, order),
                codebook=self._grouped_codebook(),
                bias=self.bias,
                kind="shared",
                shape=(int(self.idx[0].size), self.c_out),
                bins=self.bins,
            )
        return PasmParams.dense(
            _flatten_kernel(self.kernel, order), bias=self.bias
        )

    def gemm_tensor(self, layout: str = "NCHW") -> _pasm.PASMTensor:
        """The dictionary as the ``(K, M)`` Pallas GEMM operand for ``layout``."""
        order = _ORDER[layout]
        if self.kind == "dense":
            raise ValueError("dense params have no dictionary; use engine='einsum'")
        self._check_order(order)
        return self._as_pasm(order).gemm_tensor()

    def dense_operand(self, layout: str = "NCHW") -> jax.Array:
        """The ``(K(+pad_k), M)`` dense GEMM operand (einsum reference path).

        Dtype is preserved for dense/shared kinds so integer-exactness claims
        (§5.3) survive the reference path; packed dequantizes to f32.
        """
        if self.kind == "dense":
            return _flatten_kernel(self.kernel, _ORDER[layout])
        if self.kind == "shared":
            if self.groups == 1:
                kernel = self.codebook[self.idx.astype(jnp.int32)]
                return _flatten_kernel(kernel, _ORDER[layout])
            self._check_order(_ORDER[layout])
            idxf = _flatten_kernel(self.idx, _ORDER[layout]).astype(jnp.int32)
            K, M = idxf.shape
            wg = jax.vmap(lambda cb, ix: cb[ix])(
                self.codebook, idxf.reshape(self.groups, K // self.groups, M)
            )
            return wg.reshape(K, M)
        return _pasm.dequantize(self.gemm_tensor(layout))


def _flatten_kernel(a: jax.Array, order: str) -> jax.Array:
    """(c_out, c_in, ky, kx) → (K, c_out) flat in ``order`` ∈ {ckk, kkc}."""
    if order == "kkc":
        a = a.transpose(0, 2, 3, 1)  # (c_out, ky, kx, c_in)
    return a.reshape(a.shape[0], -1).T


def _unflatten_kernel(flat: jax.Array, order: str, kshape: tuple) -> jax.Array:
    """Inverse of :func:`_flatten_kernel`: (K, c_out) → (c_out, c_in, ky, kx)."""
    c_out, c_in, ky, kx = kshape
    a = flat.T
    if order == "kkc":
        return a.reshape(c_out, ky, kx, c_in).transpose(0, 3, 1, 2)
    return a.reshape(kshape)


# ---------------------------------------------------------------------------
# im2col (both layouts, all paddings)
# ---------------------------------------------------------------------------


def _batched4(x: jax.Array) -> tuple:
    if x.ndim == 3:
        return x[None], True
    if x.ndim == 4:
        return x, False
    raise ValueError(f"x must be a single image (3-D) or a batch (4-D), got {x.shape}")


def _im2col(xb: jax.Array, conv: Conv2D) -> tuple:
    """Batched patches in the layout's GEMM column order.

    NCHW ``(B, C, IH, IW) → (B·P, C·KY·KX)`` (paper (c, ky, kx) order);
    NHWC ``(B, IH, IW, C) → (B·P, KY·KX·C)`` (channels-minor, TPU-native).
    Returns ``(patches, (oh, ow))``.  The gather itself lives in
    :func:`repro.kernels.ref.im2col_patches` (pure jnp, pallas-free) — one
    definition shared with the implicit path's col2im backward.
    """
    from repro.kernels.ref import im2col_patches

    nhwc = conv.layout == "NHWC"
    ih, iw = (xb.shape[1], xb.shape[2]) if nhwc else (xb.shape[2], xb.shape[3])
    oh, plo_h, phi_h = _axis_geometry(ih, conv.ky, conv.stride, conv.padding)
    ow, plo_w, phi_w = _axis_geometry(iw, conv.kx, conv.stride, conv.padding)
    patches = im2col_patches(
        xb, nhwc=nhwc, ky=conv.ky, kx=conv.kx, stride=conv.stride,
        oh=oh, ow=ow, c_in=conv.c_in, pad=((plo_h, phi_h), (plo_w, phi_w)),
    )
    return patches, (oh, ow)


def _col2im(y: jax.Array, conv: Conv2D, batch: int, oh: int, ow: int, squeeze: bool):
    """GEMM output (B·P, M) → feature map in the spec's layout."""
    if conv.layout == "NHWC":
        out = y.reshape(batch, oh, ow, conv.c_out)
    else:
        out = y.reshape(batch, oh * ow, conv.c_out)
        out = jnp.moveaxis(out, -1, 1).reshape(batch, conv.c_out, oh, ow)
    return out[0] if squeeze else out


def _epilogue(y: jax.Array, bias: Optional[jax.Array], relu: bool) -> jax.Array:
    # one definition shared with the kernel oracles (repro.kernels.ref has no
    # pallas dependency, so core stays pallas-free)
    from repro.kernels.ref import apply_epilogue

    return apply_epilogue(y, bias, relu)


def max_pool2d(x: jax.Array, pool: int, layout: str) -> jax.Array:
    """Non-overlapping max pool, VALID (floor) windowing, layout-aware.

    The unfused reference (and fallback path) of ``conv2d(pool=)``; accepts
    a batched 4-D feature map or a single squeezed 3-D one.  The window init
    is the dtype's max-monoid identity: ``jnp.iinfo(dtype).min`` for
    integer/quantized activations (the former unconditional ``-jnp.inf``
    would fail the integer ``reduce_window`` dtype check), ``-inf`` for
    floats (``jnp.finfo(...).min`` would stop XLA from recognizing the max
    monoid and lose the ``reduce_window_max`` primitive — and with it the
    VJP).  Every window is fully covered (non-overlapping VALID), so the
    init never leaks into the output either way.
    """
    if pool == 1:
        return x
    # a NumPy scalar of the operand dtype: the value must equal THAT dtype's
    # max identity for jax to recognize the monoid (reduce_window_max, which
    # carries the VJP) — a weak python int or a mismatched-dtype init falls
    # into the generic non-differentiable reduce_window
    if jnp.issubdtype(x.dtype, jnp.integer):
        init = x.dtype.type(jnp.iinfo(x.dtype).min)
    else:
        init = x.dtype.type(-jnp.inf)
    if x.ndim == 4:
        window = (1, pool, pool, 1) if layout == "NHWC" else (1, 1, pool, pool)
    elif x.ndim == 3:
        window = (pool, pool, 1) if layout == "NHWC" else (1, pool, pool)
    else:
        raise ValueError(f"max_pool2d needs a 3-D or 4-D feature map, got {x.shape}")
    return jax.lax.reduce_window(x, init, jax.lax.max, window, window, "VALID")


# ---------------------------------------------------------------------------
# the entry point
# ---------------------------------------------------------------------------


def _resolve_engine(
    engine: str, params: ConvParams, squeeze: bool, conv: Conv2D, ih: int,
    iw: int, budget: Optional[int] = None,
) -> str:
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    if params.kind == "dense":
        if engine in ("auto", "einsum"):
            return "einsum"
        raise ValueError(f"dense params have no dictionary; engine {engine!r} "
                         "needs shared/packed params")
    if params.groups > 1 and engine in _PAS_ENGINES:
        raise ValueError(
            "the PAS formulation is paper-faithful single-dictionary; grouped "
            "codebooks need engine='kernel'/'kernel_implicit'/'einsum'"
        )
    if engine == "auto":
        # batched inputs ride the Pallas fast path — always implicit im2col:
        # images past the VMEM budget stream as row-band slabs instead of
        # falling back to explicit im2col (``budget``/``vmem_budget`` now
        # sizes the slabs, it no longer flips the engine); single images keep
        # the einsum reference port (the semantics the kernels are tested
        # against).  Degenerate geometry (no output pixels) keeps the
        # explicit path, whose empty patch matrix handles it.
        if squeeze:
            return "einsum"
        oh, ow = conv_out_hw(ih, iw, conv)
        return "kernel_implicit" if oh > 0 and ow > 0 else "kernel"
    return engine


def _pool_fusible(eng: str, conv: Conv2D, ih: int, iw: int, pool: int,
                  mesh) -> bool:
    """``conv2d(pool=)``'s ``auto`` fuse predicate.

    Fuses when: a Pallas engine; the pooled output is non-empty (floor
    windowing needs at least one whole window per axis); and a pool-aligned
    tile plan exists (``lcm(pool², 8) ≤ 256`` rows — the kernels reduce
    whole windows per block).  A mesh no longer blocks the explicit
    engines: ``conv2d`` pads the batch to divide ``data``, so the
    window-major patch rows split as ``(batch/n_data)·P_rows`` per shard —
    always whole pool windows (``P_rows`` is a multiple of ``pool²``) —
    and the explicit fused pool shards like the implicit one (the PR-5
    carve-out is closed).  Everything this refuses runs the bit-exact
    ``reduce_window`` fallback.
    """
    del mesh  # no longer consulted (and may be any mesh-like object)
    if pool == 1 or eng in ("einsum", "pas_einsum"):
        return False
    oh, ow = conv_out_hw(ih, iw, conv)
    if oh < pool or ow < pool:
        return False
    from repro.kernels import ops as _kops  # deferred: core must not need pallas

    return _kops.pool_plan_exists(pool)


def conv_plan(
    params: "ConvParams", conv: Conv2D, ih: int, iw: int, *,
    engine: str = "auto", pool: int = 1, pool_impl: str = "auto",
    vmem_budget: Optional[int] = None, mesh=None, batched: bool = True,
) -> tuple:
    """The ``(engine, fused_pool)`` pair :func:`conv2d` would dispatch.

    Public so benches/models can model a stage's dataflow (engine choice,
    whether the max-pool folds into the kernel) without re-implementing the
    dispatch rules — :func:`conv2d` itself routes through this, so the two
    can never drift apart.
    """
    eng = _resolve_engine(engine, params, not batched, conv, ih, iw,
                          vmem_budget)
    fused = (pool > 1 and pool_impl != "unfused"
             and _pool_fusible(eng, conv, ih, iw, pool, mesh))
    return eng, fused


def _pool_order_patches(patches: jax.Array, batch: int, oh: int, ow: int,
                        pool: int) -> jax.Array:
    """Row-major ``(B·P, K)`` patches → window-major ``(B·P_out·pool², K)``.

    The explicit fused-pool GEMM's row contract: each consecutive ``pool²``
    rows form one pool window (so the kernel's epilogue max is a pure
    reshape), floor-remainder pixels are dropped before the GEMM ever runs —
    the same rows the implicit kernel's window-major ``patch_tile`` walks.
    """
    K = patches.shape[1]
    ohp, owp = oh // pool, ow // pool
    pm = patches.reshape(batch, oh, ow, K)[:, : ohp * pool, : owp * pool]
    pm = pm.reshape(batch, ohp, pool, owp, pool, K).transpose(0, 1, 3, 2, 4, 5)
    return pm.reshape(batch * ohp * owp * pool * pool, K)


def _einsum_sharded(patches, w, bias, relu: bool, mesh):
    """The pure-XLA reference engine under shard_map (the dense-params path).

    Rows over ``data``, the N output dim over ``model`` when divisible (else
    the dense operand replicates) — the same axis mapping (and the same
    :func:`repro.launch.mesh.n_shard_axis` rule) as the Pallas engines, so
    dense params shard like dictionary params do.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.kernels.ref import apply_epilogue  # pallas-free
    from repro.launch.mesh import n_shard_axis

    ns = n_shard_axis(mesh, w.shape[1])
    if bias is None:
        return shard_map(
            lambda pt, wl: apply_epilogue(pt @ wl, None, relu),
            mesh=mesh, in_specs=(P("data", None), P(None, ns)),
            out_specs=P("data", ns), check_rep=False,
        )(patches, w)
    return shard_map(
        lambda pt, wl, bl: apply_epilogue(pt @ wl, bl, relu),
        mesh=mesh, in_specs=(P("data", None), P(None, ns), P(ns)),
        out_specs=P("data", ns), check_rep=False,
    )(patches, w, bias)


def conv2d(
    x: jax.Array,
    params: ConvParams,
    conv: Conv2D,
    *,
    engine: str = "auto",
    interpret: Optional[bool] = None,
    mesh=None,
    vmem_budget: Optional[int] = None,
    pool: int = 1,
    pool_impl: str = "auto",
) -> jax.Array:
    """The unified conv entry point: any params kind, any engine, any layout.

    ``x`` is a single image or a batch in ``conv.layout`` order.  On the
    Pallas engines the bias/ReLU epilogue is fused into the kernel's final
    reduction step, so a batched conv layer is exactly one ``pallas_call`` —
    and on the ``*_implicit`` engines that call consumes the raw (padded)
    image directly, with the im2col tiles assembled in VMEM.

    ``pool > 1`` appends a non-overlapping ``(pool, pool)`` max-pool (VALID
    floor windowing — :func:`max_pool2d` semantics).  ``pool_impl="auto"``
    fuses it into the kernel epilogue whenever :func:`_pool_fusible` allows
    — the whole conv/ReLU/pool stage is then ONE ``pallas_call`` storing
    only the pooled map (DESIGN.md §3.2) — and falls back to the separate
    ``reduce_window`` otherwise; the two paths are bit-exact.  ``"fused"``
    demands the fused path (raises when impossible), ``"unfused"`` forces
    the fallback.

    ``mesh=`` (a ``jax.sharding.Mesh`` with a ``data`` axis, optionally
    ``model``) runs the layer sharded: the batch over ``data`` (uneven
    remainders are zero-padded in and sliced off — DESIGN.md §4.1), the
    output channels over ``model`` when divisible.  Sharded outputs are
    bit-exact vs the single-device call on every engine but ``pas_einsum``
    (the single-device reference port, which refuses a mesh).

    ``vmem_budget=`` overrides the implicit engines' per-image VMEM budget
    in bytes (default ``_IMPLICIT_VMEM_BUDGET``).  Images whose
    double-buffered whole-image residency exceeds it stream through the
    kernel as row-band slabs (:func:`repro.kernels.ops.conv_slab_plan`) —
    bit-exact vs the whole-image schedule — so the budget tunes slab
    sizing per target core rather than flipping ``auto`` to the explicit
    engine.
    """
    if pool_impl not in POOL_IMPLS:
        raise ValueError(f"pool_impl must be one of {POOL_IMPLS}, got {pool_impl!r}")
    if int(pool) != pool or pool < 1:
        raise ValueError(f"pool must be a positive integer window, got {pool!r}")
    pool = int(pool)  # accept integral floats; downstream math needs an int
    xb, squeeze = _batched4(x)
    nhwc = conv.layout == "NHWC"
    c_axis = -1 if nhwc else 1
    if xb.shape[c_axis] != conv.c_in:
        raise ValueError(
            f"input {x.shape} has {xb.shape[c_axis]} channels on the "
            f"{conv.layout} channel axis; spec says c_in={conv.c_in}"
        )
    if params.kshape != (conv.c_out, conv.c_in, conv.ky, conv.kx):
        raise ValueError(
            f"params kshape {params.kshape} does not match spec "
            f"{(conv.c_out, conv.c_in, conv.ky, conv.kx)}"
        )
    ih, iw = (xb.shape[1], xb.shape[2]) if nhwc else (xb.shape[2], xb.shape[3])
    eng, fuse_pool = conv_plan(
        params, conv, ih, iw, engine=engine, pool=pool, pool_impl=pool_impl,
        vmem_budget=vmem_budget, mesh=mesh, batched=not squeeze,
    )
    bias = params.bias if conv.bias else None
    if pool_impl == "fused" and pool > 1 and not fuse_pool:
        raise ValueError(
            f"pool_impl='fused' but engine {eng!r} cannot fuse pool={pool} "
            "here (einsum engines, sub-window outputs and oversize windows "
            "all need the reduce_window fallback — pool_impl='auto' picks "
            "it automatically)"
        )

    batch = xb.shape[0]
    if mesh is not None:
        if squeeze:
            raise ValueError(
                "mesh= shards the batch over the 'data' axis; pass a batched "
                "4-D input"
            )
        if eng == "pas_einsum":
            raise ValueError(
                "pas_einsum is the single-device reference port; mesh= runs "
                "on einsum or the Pallas engines"
            )
        from repro.launch.mesh import data_model_sizes  # pallas-free, jax-only

        pad_b = -batch % data_model_sizes(mesh)[0]
        if pad_b:  # uneven batch remainder: zero images in, sliced off below
            xb = jnp.pad(xb, ((0, pad_b),) + ((0, 0),) * 3)

    if eng in _IMPLICIT_ENGINES:
        from repro.kernels import ops as _kops  # deferred: core must not need pallas

        geom = conv_geom(conv, ih, iw, pool=pool if fuse_pool else 1)
        t = params.gemm_tensor(conv.layout)
        f = _kops.pasm_conv2d if eng == "kernel_implicit" else _kops.pas_conv2d
        # resolve the budget here (not in the kernel wrappers) so per-call
        # overrides AND the module default both reach the slab planner
        y = f(xb, t, geom, bias=bias, relu=conv.relu, interpret=interpret,
              mesh=mesh,
              vmem_budget=(vmem_budget if vmem_budget is not None
                           else _IMPLICIT_VMEM_BUDGET))
        y = y.reshape(-1, conv.c_out)  # (B, P, M) → (B·P, M), after the kernel
        if fuse_pool:  # the kernel already stored the pooled map
            out = _col2im(y, conv, xb.shape[0], geom.ohp, geom.owp, squeeze)
        else:
            out = _col2im(y, conv, xb.shape[0], geom.oh, geom.ow, squeeze)
            out = max_pool2d(out, pool, conv.layout)
        return out[:batch] if mesh is not None else out

    patches, (oh, ow) = _im2col(xb, conv)
    if fuse_pool:  # explicit fused pool: window-major rows for the kernels
        patches = _pool_order_patches(patches, xb.shape[0], oh, ow, pool)

    if eng == "einsum":
        w = params.dense_operand(conv.layout)
        if params.pad_k:
            patches = jnp.pad(patches, ((0, 0), (0, params.pad_k)))
        if mesh is not None:
            y = _einsum_sharded(patches, w, bias, conv.relu, mesh)
        else:
            y = _epilogue(patches @ w, bias, conv.relu)
    elif eng == "pas_einsum":
        y = _pas_einsum(patches, params, conv.layout)
        y = _epilogue(y, bias, conv.relu)
    else:
        from repro.kernels import ops as _kops  # deferred: core must not need pallas

        t = params.gemm_tensor(conv.layout)
        if params.pad_k:
            patches = jnp.pad(patches, ((0, 0), (0, params.pad_k)))
        f = _kops.pasm_matmul if eng == "kernel" else _kops.pas_matmul
        y = f(patches, t, bias=bias, relu=conv.relu, interpret=interpret,
              mesh=mesh, pool=pool if fuse_pool else 1)
    if fuse_pool:
        out = _col2im(y, conv, xb.shape[0], oh // pool, ow // pool, squeeze)
    else:
        out = _col2im(y, conv, xb.shape[0], oh, ow, squeeze)
        out = max_pool2d(out, pool, conv.layout)
    return out[:batch] if mesh is not None else out


def _pas_einsum(patches: jax.Array, params: ConvParams, layout: str) -> jax.Array:
    """The two-phase PASM formulation in pure XLA (Fig 13, the seed's port).

    Per output pixel and channel: PAS bins via a one-hot histogram over the
    patch axis, then one multiply per bin — bit-exact on integer inputs.
    """
    if params.kind == "packed":
        idx = _pasm.logical_idx(params.gemm_tensor(layout)).T  # (M, K+pad)
        if params.pad_k:
            patches = jnp.pad(patches, ((0, 0), (0, params.pad_k)))
    else:
        idx = _flatten_kernel(params.idx, _ORDER[layout]).T  # (M, K)
    B = params.codebook.shape[-1]
    onehot = jax.nn.one_hot(idx, B, dtype=patches.dtype)  # (M, K, B)
    # PAS phase: imageBin[p, m, b] = Σ_n patches[p, n]·[idx[m, n] = b]
    image_bins = jnp.einsum("pn,mnb->pmb", patches, onehot)
    # post-pass multiply: one multiply per bin, not per element
    return jnp.einsum("pmb,b->pm", image_bins, params.codebook.astype(patches.dtype))


# ---------------------------------------------------------------------------
# kept helper: the paper's one-dictionary quantizer on raw kernels
# ---------------------------------------------------------------------------


def quantize_conv_weights(
    kernel: jax.Array, bins: int, *, iters: int = 16
) -> tuple:
    """K-means weight-share a conv kernel: one dictionary per layer (paper §4).

    Returns ``(codebook (B,), bin_idx (M, C, KY, KX) uint8)`` — the raw
    pieces for callers that build their own :meth:`ConvParams.shared`.  The
    clustering itself is :meth:`PasmParams.quantize` over the kernel
    flattened to a single column, so conv and dense layers share one
    quantizer.
    """
    p = PasmParams.quantize(kernel.reshape(-1, 1), bins, iters=iters)
    return p.codebook[0], p.idx.reshape(kernel.shape).astype(jnp.uint8)
