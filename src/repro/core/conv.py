"""Weight-shared convolution layer — JAX port of the paper's accelerator.

The paper evaluates three accelerator variants of one AlexNet-style conv
layer (§4, Fig 13): non-weight-shared, weight-shared, and
weight-shared-with-PASM, each with stride, bias and ReLU (bias/activation are
*not* shared — §4).  This module implements all three with identical
semantics:

* :func:`conv2d_direct`        — the Fig 1 pseudo-code (plain MACs)
* :func:`conv2d_weight_shared` — Fig 3/4: dictionary lookup then MAC
* :func:`conv2d_pasm`          — Fig 13: PAS bin-accumulate per output pixel,
                                 then post-pass multiply with the codebook

All three produce identical results on identical weights (the paper's §5.3
claim), property-tested in ``tests/test_conv.py``.  "VALID"-style windowing
follows the paper's loop bounds: output spans kernel-centred positions.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import pas as _pas
from repro.core import pasm as _pasm

__all__ = [
    "ConvSpec",
    "out_hw",
    "conv2d_direct",
    "conv2d_weight_shared",
    "conv2d_pasm",
    "quantize_conv_weights",
]


class ConvSpec(NamedTuple):
    """Paper's accelerator dims (§4: IH=IW=5, C=15, KY=KX=3, M=2, stride=1)."""

    IH: int = 5
    IW: int = 5
    C: int = 15
    KY: int = 3
    KX: int = 3
    M: int = 2
    stride: int = 1


def out_hw(spec: ConvSpec) -> tuple[int, int]:
    """Output dims under the paper's kernel-centred loop bounds (Fig 1)."""
    oh = (spec.IH - 2 * (spec.KY // 2) + spec.stride - 1) // spec.stride
    ow = (spec.IW - 2 * (spec.KX // 2) + spec.stride - 1) // spec.stride
    return oh, ow


def _im2col(image: jax.Array, spec: ConvSpec) -> jax.Array:
    """image (C, IH, IW) → patches (OH·OW, C·KY·KX) in the paper's loop order.

    Column order is (cIdx, kyIdx, kxIdx) — matching Fig 1's loop nest so that
    index tensors flatten identically for the PASM path.
    """
    C, IH, IW = image.shape
    oh, ow = out_hw(spec)
    ky = jnp.arange(spec.KY)
    kx = jnp.arange(spec.KX)
    oy = jnp.arange(oh) * spec.stride
    ox = jnp.arange(ow) * spec.stride
    # gather indices: (oh, ow, C, KY, KX)
    rows = oy[:, None, None, None, None] + ky[None, None, None, :, None]
    cols = ox[None, :, None, None, None] + kx[None, None, None, None, :]
    patches = image[
        jnp.arange(C)[None, None, :, None, None], rows, cols
    ]  # (oh, ow, C, KY, KX)
    return patches.reshape(oh * ow, C * spec.KY * spec.KX)


def _epilogue(y: jax.Array, bias: Optional[jax.Array], relu: bool) -> jax.Array:
    if bias is not None:
        y = y + bias
    if relu:
        y = jnp.maximum(y, 0)
    return y


def conv2d_direct(
    image: jax.Array,
    kernel: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    spec: ConvSpec,
    relu: bool = False,
) -> jax.Array:
    """Non-weight-shared accelerator (Fig 1).  kernel: (M, C, KY, KX)."""
    patches = _im2col(image, spec)  # (P, N)
    w = kernel.reshape(spec.M, -1).T  # (N, M) — same (c,ky,kx) order
    y = patches @ w  # plain MACs
    oh, ow = out_hw(spec)
    return _epilogue(y, bias, relu).T.reshape(spec.M, oh, ow)


def quantize_conv_weights(
    kernel: jax.Array, bins: int, *, iters: int = 16
) -> tuple[jax.Array, jax.Array]:
    """K-means weight-share a conv kernel: one dictionary per layer (paper §4).

    Returns ``(codebook (B,), bin_idx (M, C, KY, KX) uint8)``.
    """
    flat = kernel.reshape(1, -1)  # single group = single dictionary
    cb, idx = _pasm.kmeans_codebook(flat.T, bins, groups=1, iters=iters)
    return cb[0], idx.reshape(kernel.shape).astype(jnp.uint8)


def conv2d_weight_shared(
    image: jax.Array,
    bin_idx: jax.Array,
    codebook: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    spec: ConvSpec,
    relu: bool = False,
) -> jax.Array:
    """Weight-shared accelerator (Figs 3/4): dereference dictionary, then MAC."""
    kernel = codebook[bin_idx.astype(jnp.int32)]  # the extra indirection level
    return conv2d_direct(image, kernel, bias, spec=spec, relu=relu)


def conv2d_pasm(
    image: jax.Array,
    bin_idx: jax.Array,
    codebook: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    spec: ConvSpec,
    relu: bool = False,
) -> jax.Array:
    """Weight-shared-with-PASM accelerator (Fig 13).

    Per output pixel and output channel m:
      PAS:       ``imageBin[b] += imVal`` for every (imVal, binIdx) pair
      post-pass: ``Σ_b imageBin[b] · sk[b]``
    Vectorized: one-hot histogram over the patch axis, then a (B,)-dot.
    """
    B = codebook.shape[0]
    patches = _im2col(image, spec)  # (P, N)
    idx = bin_idx.reshape(spec.M, -1)  # (M, N) — (c,ky,kx) flat order
    onehot = jax.nn.one_hot(idx, B, dtype=patches.dtype)  # (M, N, B)
    # PAS phase: imageBin[p, m, b] = Σ_n patches[p, n]·[idx[m, n] = b]
    image_bins = jnp.einsum("pn,mnb->pmb", patches, onehot)
    # post-pass multiply: one multiply per bin, not per element
    y = jnp.einsum("pmb,b->pm", image_bins, codebook.astype(patches.dtype))
    oh, ow = out_hw(spec)
    return _epilogue(y, bias, relu).T.reshape(spec.M, oh, ow)
