"""Weight-shared convolution — the unified `ConvParams`/`conv2d` surface.

The paper evaluates ONE accelerator in three variants (§4, Fig 13):
non-weight-shared, weight-shared, and weight-shared-with-PASM, each with
stride, bias and ReLU (bias/activation are *not* shared — §4).  This module
exposes that accelerator through two types and one entry point:

* :class:`ConvParams` — a tagged weight container: a ``dense`` kernel, a
  weight-shared dictionary (``shared``: uint8 bin indices + codebook), or an
  int4-``packed`` dictionary (two 4-bit indices per byte, §3 K-pad applied
  before packing so odd ``C·KY·KX`` reductions work).  Built via
  :meth:`ConvParams.dense` / :meth:`ConvParams.quantize` /
  :meth:`ConvParams.shared`, converted with :meth:`ConvParams.pack`.
* :class:`Conv2D` — the geometry-free layer spec: kernel size, channel
  counts, stride, ``padding="valid_centred"|"valid"|"same"``,
  ``layout="NCHW"|"NHWC"``, and the epilogue (``bias`` gate + ``relu`` flag).
  Image height/width are *not* part of the spec — they are read off the
  input, so one spec serves every image size.
* :func:`conv2d` — ``conv2d(x, params, conv, *, engine, interpret)``
  dispatches every (params kind × engine) combination:

  ===========  ================================================================
  engine       meaning
  ===========  ================================================================
  ``auto``     dense → einsum; shared/packed → Pallas kernel when batched,
               einsum reference for single images (the seed's routing rule)
  ``einsum``   pure-XLA reference: (dequantized) dense GEMM + XLA epilogue
  ``kernel``   :func:`repro.kernels.ops.pasm_matmul` — fused-dequant Pallas
               GEMM with the bias/ReLU epilogue fused into the last-k-step
               write-through (one ``pallas_call`` per conv layer)
  ``pas_kernel``  :func:`repro.kernels.ops.pas_matmul` — the paper-faithful
               two-phase PAS formulation, epilogue fused into the post-pass
  ``pas_einsum``  the two-phase formulation as pure XLA (one-hot histogram +
               post-pass) — the seed's ``conv2d_pasm`` einsum port
  ===========  ================================================================

Convolution lowers onto the PASM GEMMs via a batched im2col —
``(B, C, IH, IW) → (B·P, C·KY·KX)`` in the paper's ``(c, ky, kx)`` order for
NCHW, or ``(B, IH, IW, C) → (B·P, KY·KX·C)`` channels-minor (TPU-native) for
NHWC — and the weight container flattens itself into the matching ``(K, M)``
GEMM operand.

Migration table (the old surface is kept as thin deprecation shims):

  =====================================================  ======================
  old call                                               new call
  =====================================================  ======================
  ``conv2d_direct(img, kern, bias, spec=s, relu=r)``     ``conv2d(img, ConvParams.dense(kern, bias=bias), Conv2D(k=(s.KY, s.KX), c_in=s.C, c_out=s.M, stride=s.stride, relu=r))``
  ``conv2d_weight_shared(img, idx, cb, bias, spec=s)``   ``conv2d(img, ConvParams.shared(idx, cb, bias=bias), Conv2D(...))``
  ``conv2d_pasm(img, idx, cb, bias, spec=s)``            same, with ``engine="pas_kernel"`` (batched) / ``"pas_einsum"`` (reference)
  ``quantize_conv_weights(kern, bins)``                  ``ConvParams.quantize(kern, bins)``
  ``conv_pasm_tensor(idx, cb)``                          ``ConvParams.shared(idx, cb).gemm_tensor("NCHW")``
  ``ConvSpec(IH, IW, C, KY, KX, M, stride)``             ``Conv2D(k, c_in, c_out, stride, ...)`` (geometry lives with the data)
  =====================================================  ======================
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import pasm as _pasm

__all__ = [
    "Conv2D",
    "ConvParams",
    "conv2d",
    "conv_out_hw",
    "PADDINGS",
    "LAYOUTS",
    # legacy surface (deprecation shims / kept helpers)
    "ConvSpec",
    "out_hw",
    "im2col",
    "conv_pasm_tensor",
    "conv2d_direct",
    "conv2d_weight_shared",
    "conv2d_pasm",
    "quantize_conv_weights",
]

PADDINGS = ("valid_centred", "valid", "same")
LAYOUTS = ("NCHW", "NHWC")
ENGINES = ("auto", "einsum", "kernel", "pas_kernel", "pas_einsum")

# GEMM column order per layout: NCHW flattens patches (and weights) in the
# paper's (c, ky, kx) loop-nest order (Fig 1); NHWC is channels-minor
# (ky, kx, c) — the TPU-native layout.
_ORDER = {"NCHW": "ckk", "NHWC": "kkc"}


# ---------------------------------------------------------------------------
# the layer spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Conv2D:
    """Geometry-free conv layer spec (image H/W are read off the input)."""

    k: Union[int, tuple]
    c_in: int
    c_out: int
    stride: int = 1
    padding: str = "valid_centred"
    layout: str = "NCHW"
    bias: bool = True  # apply ``params.bias`` when present
    relu: bool = False

    def __post_init__(self):
        k = (self.k, self.k) if isinstance(self.k, int) else tuple(self.k)
        object.__setattr__(self, "k", k)
        if self.padding not in PADDINGS:
            raise ValueError(f"padding must be one of {PADDINGS}, got {self.padding!r}")
        if self.layout not in LAYOUTS:
            raise ValueError(f"layout must be one of {LAYOUTS}, got {self.layout!r}")

    @property
    def ky(self) -> int:
        return self.k[0]

    @property
    def kx(self) -> int:
        return self.k[1]

    @property
    def K(self) -> int:
        """The im2col reduction length ``c_in·ky·kx``."""
        return self.c_in * self.ky * self.kx


def _axis_geometry(size: int, k: int, stride: int, padding: str) -> tuple:
    """One spatial axis → ``(out, pad_lo, pad_hi)``.

    ``same`` matches XLA/TF SAME (out = ceil(size/stride), asymmetric zero
    pad); ``valid`` is standard VALID; ``valid_centred`` is the paper's
    kernel-centred loop bounds (Fig 1) — identical to ``valid`` for odd
    kernels, one output short when an even kernel tiles the axis exactly.
    """
    if padding == "same":
        out = -(-size // stride)
        pad = max((out - 1) * stride + k - size, 0)
        return out, pad // 2, pad - pad // 2
    if padding == "valid":
        return (size - k) // stride + 1, 0, 0
    return (size - 2 * (k // 2) + stride - 1) // stride, 0, 0


def conv_out_hw(ih: int, iw: int, conv: Conv2D) -> tuple:
    """Output (OH, OW) of ``conv`` on an ``ih × iw`` image."""
    oh, _, _ = _axis_geometry(ih, conv.ky, conv.stride, conv.padding)
    ow, _, _ = _axis_geometry(iw, conv.kx, conv.stride, conv.padding)
    return oh, ow


# ---------------------------------------------------------------------------
# the weight container
# ---------------------------------------------------------------------------


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["kernel", "idx", "codebook", "bias"],
    meta_fields=["kind", "kshape", "bins", "order", "pad_k"],
)
@dataclasses.dataclass(frozen=True)
class ConvParams:
    """Tagged conv weights: ``dense`` | weight-``shared`` | int4-``packed``.

    ``dense``   ``kernel (c_out, c_in, ky, kx)``; ``idx``/``codebook`` None.
    ``shared``  ``idx (c_out, c_in, ky, kx) uint8`` bin indices +
                ``codebook (bins,)`` — one dictionary per layer (paper §4).
    ``packed``  ``idx (Kp//2, c_out) uint8`` — two 4-bit indices per byte in
                the GEMM ``(K, M)`` layout of ``order`` (baked at pack time);
                ``pad_k`` zero-activation rows were appended by the §3 K-pad
                so an odd ``C·KY·KX`` packs.
    ``bias``    ``(c_out,)`` or None on every kind — never shared (paper §4).
    """

    kernel: Optional[jax.Array] = None
    idx: Optional[jax.Array] = None
    codebook: Optional[jax.Array] = None
    bias: Optional[jax.Array] = None
    kind: str = "dense"
    kshape: tuple = ()
    bins: Optional[int] = None
    order: Optional[str] = None
    pad_k: int = 0

    # -- constructors -------------------------------------------------------

    @classmethod
    def dense(cls, kernel: jax.Array, *, bias: Optional[jax.Array] = None):
        """Non-weight-shared params from a ``(c_out, c_in, ky, kx)`` kernel."""
        if kernel.ndim != 4:
            raise ValueError(f"kernel must be (c_out, c_in, ky, kx), got {kernel.shape}")
        return cls(kernel=kernel, bias=bias, kind="dense", kshape=tuple(kernel.shape))

    @classmethod
    def shared(
        cls,
        idx: jax.Array,
        codebook: jax.Array,
        *,
        bias: Optional[jax.Array] = None,
    ):
        """Weight-shared params from existing bin indices + dictionary."""
        if idx.ndim != 4:
            raise ValueError(f"idx must be (c_out, c_in, ky, kx), got {idx.shape}")
        return cls(
            idx=idx.astype(jnp.uint8),
            codebook=codebook,
            bias=bias,
            kind="shared",
            kshape=tuple(idx.shape),
            bins=int(codebook.shape[-1]),
        )

    @classmethod
    def quantize(
        cls,
        kernel: jax.Array,
        bins: int = 16,
        *,
        bias: Optional[jax.Array] = None,
        iters: int = 16,
    ):
        """K-means weight-share a dense kernel: one dictionary per layer."""
        cb, idx = quantize_conv_weights(kernel, bins, iters=iters)
        return cls.shared(idx, cb, bias=bias)

    def pack(self, *, layout: str = "NCHW") -> "ConvParams":
        """int4-pack the dictionary indices into the GEMM layout of ``layout``.

        Halves conv weight bytes (two 4-bit indices per byte).  Odd
        ``C·KY·KX`` gets the §3 K-pad first: one pad row is appended, mapped
        to a reserved all-zero codebook bin when representable (``bins < 16``)
        or to bin 0 otherwise — exact either way, because :func:`conv2d`
        pairs the pad rows with zero patch columns.
        """
        if self.kind != "shared":
            raise ValueError(
                f"pack() needs shared params (got {self.kind!r}); "
                "quantize() dense kernels first"
            )
        if self.bins > 16:
            raise ValueError(f"int4 packing needs bins <= 16, got {self.bins}")
        if layout not in LAYOUTS:
            raise ValueError(f"layout must be one of {LAYOUTS}, got {layout!r}")
        order = _ORDER[layout]
        flat = _flatten_kernel(self.idx, order)  # (K, c_out)
        codebook, bins, pad_k = self.codebook, self.bins, 0
        if flat.shape[0] % 2:
            pad_k = 1
            if bins < 16:
                codebook = jnp.pad(codebook.reshape(-1), (0, 1))  # reserved 0-bin
                pad_bin, bins = bins, bins + 1
            else:
                pad_bin = 0  # inert anyway: conv2d zero-pads the patch column
            flat = jnp.pad(flat, ((0, 1), (0, 0)), constant_values=pad_bin)
        return ConvParams(
            idx=_pasm.pack_int4(flat),
            codebook=codebook,
            bias=self.bias,
            kind="packed",
            kshape=self.kshape,
            bins=bins,
            order=order,
            pad_k=pad_k,
        )

    # -- views --------------------------------------------------------------

    @property
    def c_out(self) -> int:
        return self.kshape[0]

    def gemm_tensor(self, layout: str = "NCHW") -> _pasm.PASMTensor:
        """The dictionary as the ``(K, M)`` Pallas GEMM operand for ``layout``."""
        order = _ORDER[layout]
        if self.kind == "packed":
            if order != self.order:
                raise ValueError(
                    f"params were packed for order {self.order!r} but layout "
                    f"{layout!r} needs {order!r}; re-pack for this layout"
                )
            K = self.idx.shape[0] * 2
            return _pasm.PASMTensor(
                idx=self.idx,
                codebook=self.codebook.reshape(1, -1).astype(jnp.float32),
                shape=(K, self.c_out),
                bins=self.bins,
                bits=4,
                packed=True,
            )
        if self.kind != "shared":
            raise ValueError("dense params have no dictionary; use engine='einsum'")
        idx = _flatten_kernel(self.idx, order)  # (K, M)
        return _pasm.PASMTensor(
            idx=idx,
            codebook=self.codebook.reshape(1, -1).astype(jnp.float32),
            shape=tuple(idx.shape),
            bins=self.bins,
            bits=_pasm.bits_for_bins(self.bins),
            packed=False,
        )

    def dense_operand(self, layout: str = "NCHW") -> jax.Array:
        """The ``(K(+pad_k), M)`` dense GEMM operand (einsum reference path).

        Dtype is preserved for dense/shared kinds so integer-exactness claims
        (§5.3) survive the reference path; packed dequantizes to f32.
        """
        if self.kind == "dense":
            return _flatten_kernel(self.kernel, _ORDER[layout])
        if self.kind == "shared":
            kernel = self.codebook[self.idx.astype(jnp.int32)]
            return _flatten_kernel(kernel, _ORDER[layout])
        return _pasm.dequantize(self.gemm_tensor(layout))


def _flatten_kernel(a: jax.Array, order: str) -> jax.Array:
    """(c_out, c_in, ky, kx) → (K, c_out) flat in ``order`` ∈ {ckk, kkc}."""
    if order == "kkc":
        a = a.transpose(0, 2, 3, 1)  # (c_out, ky, kx, c_in)
    return a.reshape(a.shape[0], -1).T


# ---------------------------------------------------------------------------
# im2col (both layouts, all paddings)
# ---------------------------------------------------------------------------


def _batched4(x: jax.Array) -> tuple:
    if x.ndim == 3:
        return x[None], True
    if x.ndim == 4:
        return x, False
    raise ValueError(f"x must be a single image (3-D) or a batch (4-D), got {x.shape}")


def _im2col(xb: jax.Array, conv: Conv2D) -> tuple:
    """Batched patches in the layout's GEMM column order.

    NCHW ``(B, C, IH, IW) → (B·P, C·KY·KX)`` (paper (c, ky, kx) order);
    NHWC ``(B, IH, IW, C) → (B·P, KY·KX·C)`` (channels-minor, TPU-native).
    Returns ``(patches, (oh, ow))``.
    """
    nhwc = conv.layout == "NHWC"
    B = xb.shape[0]
    ih, iw = (xb.shape[1], xb.shape[2]) if nhwc else (xb.shape[2], xb.shape[3])
    oh, plo_h, phi_h = _axis_geometry(ih, conv.ky, conv.stride, conv.padding)
    ow, plo_w, phi_w = _axis_geometry(iw, conv.kx, conv.stride, conv.padding)
    if plo_h or phi_h or plo_w or phi_w:
        spatial = ((plo_h, phi_h), (plo_w, phi_w))
        pad = ((0, 0), *spatial, (0, 0)) if nhwc else ((0, 0), (0, 0), *spatial)
        xb = jnp.pad(xb, pad)
    ky = jnp.arange(conv.ky)
    kx = jnp.arange(conv.kx)
    oy = jnp.arange(oh) * conv.stride
    ox = jnp.arange(ow) * conv.stride
    if nhwc:
        rows = oy[:, None, None, None] + ky[None, None, :, None]  # (oh,1,KY,1)
        cols = ox[None, :, None, None] + kx[None, None, None, :]  # (1,ow,1,KX)
        patches = xb[:, rows, cols, :]  # (B, oh, ow, KY, KX, C)
    else:
        c = jnp.arange(conv.c_in)[None, None, :, None, None]
        rows = oy[:, None, None, None, None] + ky[None, None, None, :, None]
        cols = ox[None, :, None, None, None] + kx[None, None, None, None, :]
        patches = xb[:, c, rows, cols]  # (B, oh, ow, C, KY, KX)
    return patches.reshape(B * oh * ow, conv.K), (oh, ow)


def _col2im(y: jax.Array, conv: Conv2D, batch: int, oh: int, ow: int, squeeze: bool):
    """GEMM output (B·P, M) → feature map in the spec's layout."""
    if conv.layout == "NHWC":
        out = y.reshape(batch, oh, ow, conv.c_out)
    else:
        out = y.reshape(batch, oh * ow, conv.c_out)
        out = jnp.moveaxis(out, -1, 1).reshape(batch, conv.c_out, oh, ow)
    return out[0] if squeeze else out


def _epilogue(y: jax.Array, bias: Optional[jax.Array], relu: bool) -> jax.Array:
    # one definition shared with the kernel oracles (repro.kernels.ref has no
    # pallas dependency, so core stays pallas-free)
    from repro.kernels.ref import apply_epilogue

    return apply_epilogue(y, bias, relu)


# ---------------------------------------------------------------------------
# the entry point
# ---------------------------------------------------------------------------


def _resolve_engine(engine: str, params: ConvParams, squeeze: bool) -> str:
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    if params.kind == "dense":
        if engine in ("auto", "einsum"):
            return "einsum"
        raise ValueError(f"dense params have no dictionary; engine {engine!r} "
                         "needs shared/packed params")
    if engine == "auto":
        # batched inputs ride the Pallas fast path; single images keep the
        # einsum reference port (the semantics the kernels are tested against)
        return "einsum" if squeeze else "kernel"
    return engine


def conv2d(
    x: jax.Array,
    params: ConvParams,
    conv: Conv2D,
    *,
    engine: str = "auto",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """The unified conv entry point: any params kind, any engine, any layout.

    ``x`` is a single image or a batch in ``conv.layout`` order.  On the
    Pallas engines the bias/ReLU epilogue is fused into the kernel's final
    reduction step, so a batched conv layer is exactly one ``pallas_call``.
    """
    xb, squeeze = _batched4(x)
    c_axis = -1 if conv.layout == "NHWC" else 1
    if xb.shape[c_axis] != conv.c_in:
        raise ValueError(
            f"input {x.shape} has {xb.shape[c_axis]} channels on the "
            f"{conv.layout} channel axis; spec says c_in={conv.c_in}"
        )
    if params.kshape != (conv.c_out, conv.c_in, conv.ky, conv.kx):
        raise ValueError(
            f"params kshape {params.kshape} does not match spec "
            f"{(conv.c_out, conv.c_in, conv.ky, conv.kx)}"
        )
    eng = _resolve_engine(engine, params, squeeze)
    patches, (oh, ow) = _im2col(xb, conv)
    bias = params.bias if conv.bias else None

    if eng == "einsum":
        w = params.dense_operand(conv.layout)
        if params.pad_k:
            patches = jnp.pad(patches, ((0, 0), (0, params.pad_k)))
        y = _epilogue(patches @ w, bias, conv.relu)
    elif eng == "pas_einsum":
        y = _pas_einsum(patches, params, conv.layout)
        y = _epilogue(y, bias, conv.relu)
    else:
        from repro.kernels import ops as _kops  # deferred: core must not need pallas

        t = params.gemm_tensor(conv.layout)
        if params.pad_k:
            patches = jnp.pad(patches, ((0, 0), (0, params.pad_k)))
        f = _kops.pasm_matmul if eng == "kernel" else _kops.pas_matmul
        y = f(patches, t, bias=bias, relu=conv.relu, interpret=interpret)
    return _col2im(y, conv, xb.shape[0], oh, ow, squeeze)


def _pas_einsum(patches: jax.Array, params: ConvParams, layout: str) -> jax.Array:
    """The two-phase PASM formulation in pure XLA (Fig 13, the seed's port).

    Per output pixel and channel: PAS bins via a one-hot histogram over the
    patch axis, then one multiply per bin — bit-exact on integer inputs.
    """
    if params.kind == "packed":
        idx = _pasm.logical_idx(params.gemm_tensor(layout)).T  # (M, K+pad)
        if params.pad_k:
            patches = jnp.pad(patches, ((0, 0), (0, params.pad_k)))
    else:
        idx = _flatten_kernel(params.idx, _ORDER[layout]).T  # (M, K)
    B = params.codebook.shape[-1]
    onehot = jax.nn.one_hot(idx, B, dtype=patches.dtype)  # (M, K, B)
    # PAS phase: imageBin[p, m, b] = Σ_n patches[p, n]·[idx[m, n] = b]
    image_bins = jnp.einsum("pn,mnb->pmb", patches, onehot)
    # post-pass multiply: one multiply per bin, not per element
    return jnp.einsum("pmb,b->pm", image_bins, params.codebook.astype(patches.dtype))


# ---------------------------------------------------------------------------
# legacy surface: ConvSpec + the three conv2d_* shims
# ---------------------------------------------------------------------------


class ConvSpec(NamedTuple):
    """Paper's accelerator dims (§4: IH=IW=5, C=15, KY=KX=3, M=2, stride=1).

    Deprecated: image geometry now lives with the data — see :class:`Conv2D`.
    """

    IH: int = 5
    IW: int = 5
    C: int = 15
    KY: int = 3
    KX: int = 3
    M: int = 2
    stride: int = 1


def out_hw(spec: ConvSpec) -> tuple:
    """Output dims under the paper's kernel-centred loop bounds (Fig 1)."""
    conv = _spec_to_conv2d(spec)
    return conv_out_hw(spec.IH, spec.IW, conv)


def _spec_to_conv2d(spec: ConvSpec, relu: bool = False, bias: bool = False) -> Conv2D:
    return Conv2D(
        k=(spec.KY, spec.KX),
        c_in=spec.C,
        c_out=spec.M,
        stride=spec.stride,
        padding="valid_centred",
        layout="NCHW",
        bias=bias,
        relu=relu,
    )


def _check_spec(images: jax.Array, spec: ConvSpec) -> None:
    if tuple(images.shape[1:]) != (spec.C, spec.IH, spec.IW):
        raise ValueError(f"image {images.shape[1:]} does not match spec {spec}")


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new} (migration table in repro/core/conv.py)",
        DeprecationWarning,
        stacklevel=3,
    )


def im2col(images: jax.Array, spec: ConvSpec) -> jax.Array:
    """images (B, C, IH, IW) → patches (B·OH·OW, C·KY·KX), paper loop order."""
    _check_spec(images, spec)
    patches, _ = _im2col(images, _spec_to_conv2d(spec))
    return patches


def quantize_conv_weights(
    kernel: jax.Array, bins: int, *, iters: int = 16
) -> tuple:
    """K-means weight-share a conv kernel: one dictionary per layer (paper §4).

    Returns ``(codebook (B,), bin_idx (M, C, KY, KX) uint8)``.
    """
    flat = kernel.reshape(1, -1)  # single group = single dictionary
    cb, idx = _pasm.kmeans_codebook(flat.T, bins, groups=1, iters=iters)
    return cb[0], idx.reshape(kernel.shape).astype(jnp.uint8)


def conv_pasm_tensor(bin_idx: jax.Array, codebook: jax.Array) -> _pasm.PASMTensor:
    """Deprecated: ``ConvParams.shared(idx, cb).gemm_tensor("NCHW")``."""
    _deprecated("conv_pasm_tensor", "ConvParams.shared(...).gemm_tensor(...)")
    return ConvParams.shared(bin_idx, codebook).gemm_tensor("NCHW")


def conv2d_direct(
    image: jax.Array,
    kernel: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    spec: ConvSpec,
    relu: bool = False,
) -> jax.Array:
    """Deprecated shim: non-weight-shared accelerator (Fig 1) → :func:`conv2d`."""
    _deprecated("conv2d_direct", "conv2d(x, ConvParams.dense(...), Conv2D(...))")
    images, _ = _batched4(image)
    _check_spec(images, spec)
    params = ConvParams.dense(kernel, bias=bias)
    conv = _spec_to_conv2d(spec, relu=relu, bias=bias is not None)
    return conv2d(image, params, conv, engine="einsum")


def conv2d_weight_shared(
    image: jax.Array,
    bin_idx: jax.Array,
    codebook: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    spec: ConvSpec,
    relu: bool = False,
    engine: str = "auto",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Deprecated shim: weight-shared accelerator (Figs 3/4) → :func:`conv2d`."""
    _deprecated("conv2d_weight_shared", "conv2d(x, ConvParams.shared(...), Conv2D(...))")
    images, _ = _batched4(image)
    _check_spec(images, spec)
    if engine not in ("auto", "einsum", "kernel"):
        raise ValueError(f"engine must be auto|einsum|kernel, got {engine!r}")
    params = ConvParams.shared(bin_idx, codebook, bias=bias)
    conv = _spec_to_conv2d(spec, relu=relu, bias=bias is not None)
    return conv2d(image, params, conv, engine=engine, interpret=interpret)


def conv2d_pasm(
    image: jax.Array,
    bin_idx: jax.Array,
    codebook: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    spec: ConvSpec,
    relu: bool = False,
    engine: str = "auto",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Deprecated shim: weight-shared-with-PASM accelerator (Fig 13).

    Maps the seed routing onto :func:`conv2d`: the einsum reference becomes
    ``engine="pas_einsum"``, the Pallas path ``engine="pas_kernel"``.
    """
    _deprecated("conv2d_pasm", 'conv2d(..., engine="pas_kernel")')
    images, squeeze = _batched4(image)
    _check_spec(images, spec)
    if engine not in ("auto", "einsum", "kernel"):
        raise ValueError(f"engine must be auto|einsum|kernel, got {engine!r}")
    if engine == "auto":
        eng = "pas_einsum" if squeeze else "pas_kernel"
    else:
        eng = {"einsum": "pas_einsum", "kernel": "pas_kernel"}[engine]
    params = ConvParams.shared(bin_idx, codebook, bias=bias)
    conv = _spec_to_conv2d(spec, relu=relu, bias=bias is not None)
    return conv2d(image, params, conv, engine=eng, interpret=interpret)
