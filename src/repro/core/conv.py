"""Weight-shared convolution layer — JAX port of the paper's accelerator.

The paper evaluates three accelerator variants of one AlexNet-style conv
layer (§4, Fig 13): non-weight-shared, weight-shared, and
weight-shared-with-PASM, each with stride, bias and ReLU (bias/activation are
*not* shared — §4).  This module implements all three with identical
semantics:

* :func:`conv2d_direct`        — the Fig 1 pseudo-code (plain MACs)
* :func:`conv2d_weight_shared` — Fig 3/4: dictionary lookup then MAC
* :func:`conv2d_pasm`          — Fig 13: PAS bin-accumulate per output pixel,
                                 then post-pass multiply with the codebook

All three produce identical results on identical weights (the paper's §5.3
claim), property-tested in ``tests/test_conv.py``.  "VALID"-style windowing
follows the paper's loop bounds: output spans kernel-centred positions.

Batched fast path (DESIGN.md §3): every variant accepts a single image
``(C, IH, IW)`` or a batch ``(B, C, IH, IW)``.  Convolution lowers onto the
PASM GEMMs via a batched im2col — ``(B, C, IH, IW) → (B·P, C·KY·KX)`` in the
paper's (c, ky, kx) flat order — so weight-shared variants execute on the
Pallas kernels (``pasm_matmul``: fused dequant; ``pas_matmul``: the
paper-faithful two-phase formulation).  ``engine="auto"`` routes batched
inputs through the kernels and keeps single images on the seed's einsum port
(the reference semantics the kernels are tested against).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import pas as _pas
from repro.core import pasm as _pasm

__all__ = [
    "ConvSpec",
    "out_hw",
    "im2col",
    "conv_pasm_tensor",
    "conv2d_direct",
    "conv2d_weight_shared",
    "conv2d_pasm",
    "quantize_conv_weights",
]


class ConvSpec(NamedTuple):
    """Paper's accelerator dims (§4: IH=IW=5, C=15, KY=KX=3, M=2, stride=1)."""

    IH: int = 5
    IW: int = 5
    C: int = 15
    KY: int = 3
    KX: int = 3
    M: int = 2
    stride: int = 1


def out_hw(spec: ConvSpec) -> tuple[int, int]:
    """Output dims under the paper's kernel-centred loop bounds (Fig 1)."""
    oh = (spec.IH - 2 * (spec.KY // 2) + spec.stride - 1) // spec.stride
    ow = (spec.IW - 2 * (spec.KX // 2) + spec.stride - 1) // spec.stride
    return oh, ow


def _batched(image: jax.Array) -> tuple[jax.Array, bool]:
    """Normalize (C, IH, IW) | (B, C, IH, IW) to batched; report if added."""
    if image.ndim == 3:
        return image[None], True
    if image.ndim == 4:
        return image, False
    raise ValueError(f"image must be (C,IH,IW) or (B,C,IH,IW), got {image.shape}")


def im2col(images: jax.Array, spec: ConvSpec) -> jax.Array:
    """images (B, C, IH, IW) → patches (B·OH·OW, C·KY·KX), paper loop order.

    Column order is (cIdx, kyIdx, kxIdx) — matching Fig 1's loop nest so that
    index tensors flatten identically for the PASM path.  The flattened
    leading axis is the GEMM M dim of the batched fast path: one row per
    (image, output pixel).
    """
    B, C, IH, IW = images.shape
    if (C, IH, IW) != (spec.C, spec.IH, spec.IW):
        raise ValueError(f"image {images.shape[1:]} does not match spec {spec}")
    oh, ow = out_hw(spec)
    ky = jnp.arange(spec.KY)
    kx = jnp.arange(spec.KX)
    oy = jnp.arange(oh) * spec.stride
    ox = jnp.arange(ow) * spec.stride
    # gather indices: (oh, ow, C, KY, KX)
    rows = oy[:, None, None, None, None] + ky[None, None, None, :, None]
    cols = ox[None, :, None, None, None] + kx[None, None, None, None, :]
    patches = images[
        :, jnp.arange(C)[None, None, :, None, None], rows, cols
    ]  # (B, oh, ow, C, KY, KX)
    return patches.reshape(B * oh * ow, C * spec.KY * spec.KX)


def _im2col(image: jax.Array, spec: ConvSpec) -> jax.Array:
    """Single-image im2col (seed surface): (C, IH, IW) → (OH·OW, C·KY·KX)."""
    return im2col(image[None], spec)


def _col2im(y: jax.Array, spec: ConvSpec, batch: int, squeeze: bool) -> jax.Array:
    """GEMM output (B·P, M) → feature map (B, M, OH, OW) (squeezed if asked)."""
    oh, ow = out_hw(spec)
    out = y.reshape(batch, oh * ow, spec.M)
    out = jnp.moveaxis(out, -1, 1).reshape(batch, spec.M, oh, ow)
    return out[0] if squeeze else out


def _epilogue(y: jax.Array, bias: Optional[jax.Array], relu: bool) -> jax.Array:
    if bias is not None:
        y = y + bias
    if relu:
        y = jnp.maximum(y, 0)
    return y


def conv2d_direct(
    image: jax.Array,
    kernel: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    spec: ConvSpec,
    relu: bool = False,
) -> jax.Array:
    """Non-weight-shared accelerator (Fig 1).  kernel: (M, C, KY, KX).

    Accepts a single image (C, IH, IW) or a batch (B, C, IH, IW).
    """
    images, squeeze = _batched(image)
    patches = im2col(images, spec)  # (B·P, K)
    w = kernel.reshape(spec.M, -1).T  # (K, M) — same (c,ky,kx) order
    y = patches @ w  # plain MACs
    return _col2im(_epilogue(y, bias, relu), spec, images.shape[0], squeeze)


def quantize_conv_weights(
    kernel: jax.Array, bins: int, *, iters: int = 16
) -> tuple[jax.Array, jax.Array]:
    """K-means weight-share a conv kernel: one dictionary per layer (paper §4).

    Returns ``(codebook (B,), bin_idx (M, C, KY, KX) uint8)``.
    """
    flat = kernel.reshape(1, -1)  # single group = single dictionary
    cb, idx = _pasm.kmeans_codebook(flat.T, bins, groups=1, iters=iters)
    return cb[0], idx.reshape(kernel.shape).astype(jnp.uint8)


def conv_pasm_tensor(bin_idx: jax.Array, codebook: jax.Array) -> _pasm.PASMTensor:
    """View conv weight-share state as the GEMM operand of the Pallas kernels.

    ``bin_idx (M, C, KY, KX) uint8`` + ``codebook (B,)`` → a single-dictionary
    :class:`PASMTensor` of logical shape ``(K, M)`` with ``K = C·KY·KX`` in
    the paper's (c, ky, kx) flat order — exactly the transpose layout
    ``im2col`` patches contract against.
    """
    M = bin_idx.shape[0]
    idx = bin_idx.reshape(M, -1).T.astype(jnp.uint8)  # (K, M)
    bins = codebook.shape[0]
    return _pasm.PASMTensor(
        idx=idx,
        codebook=codebook.reshape(1, bins).astype(jnp.float32),
        shape=tuple(idx.shape),
        bins=bins,
        bits=_pasm.bits_for_bins(bins),
        packed=False,
    )


def _resolve_engine(engine: str, squeeze: bool) -> str:
    if engine == "auto":
        # batched inputs ride the Pallas fast path; single images keep the
        # seed's einsum port (the reference the kernels are tested against)
        return "einsum" if squeeze else "kernel"
    if engine not in ("einsum", "kernel"):
        raise ValueError(f"engine must be auto|einsum|kernel, got {engine!r}")
    return engine


def conv2d_weight_shared(
    image: jax.Array,
    bin_idx: jax.Array,
    codebook: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    spec: ConvSpec,
    relu: bool = False,
    engine: str = "auto",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Weight-shared accelerator (Figs 3/4): dereference dictionary, then MAC.

    ``engine="kernel"`` (default for batched input) lowers onto
    :func:`repro.kernels.ops.pasm_matmul` — the fused-dequant Pallas kernel —
    via the batched im2col; ``engine="einsum"`` is the seed's pure-XLA port.
    """
    images, squeeze = _batched(image)
    if _resolve_engine(engine, squeeze) == "einsum":
        kernel = codebook[bin_idx.astype(jnp.int32)]  # the extra indirection
        return conv2d_direct(image, kernel, bias, spec=spec, relu=relu)
    from repro.kernels import ops as _kops  # deferred: core must not need pallas

    patches = im2col(images, spec)  # (B·P, K)
    t = conv_pasm_tensor(bin_idx, codebook)
    y = _kops.pasm_matmul(patches, t, interpret=interpret)  # (B·P, M)
    return _col2im(_epilogue(y, bias, relu), spec, images.shape[0], squeeze)


def conv2d_pasm(
    image: jax.Array,
    bin_idx: jax.Array,
    codebook: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    spec: ConvSpec,
    relu: bool = False,
    engine: str = "auto",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Weight-shared-with-PASM accelerator (Fig 13).

    Per output pixel and output channel m:
      PAS:       ``imageBin[b] += imVal`` for every (imVal, binIdx) pair
      post-pass: ``Σ_b imageBin[b] · sk[b]``
    Vectorized: one-hot histogram over the patch axis, then a (B,)-dot.

    ``engine="kernel"`` (default for batched input) runs the same two-phase
    formulation inside :func:`repro.kernels.ops.pas_matmul` — PAS bins live in
    a VMEM scratch accumulator, the codebook multiply happens once at the last
    reduction step.
    """
    images, squeeze = _batched(image)
    if _resolve_engine(engine, squeeze) == "kernel":
        from repro.kernels import ops as _kops  # deferred import, see above

        patches = im2col(images, spec)  # (B·P, K)
        t = conv_pasm_tensor(bin_idx, codebook)
        y = _kops.pas_matmul(patches, t, interpret=interpret)  # (B·P, M)
        return _col2im(_epilogue(y, bias, relu), spec, images.shape[0], squeeze)
    B = codebook.shape[0]
    patches = im2col(images, spec)  # (B·P, N)
    idx = bin_idx.reshape(spec.M, -1)  # (M, N) — (c,ky,kx) flat order
    onehot = jax.nn.one_hot(idx, B, dtype=patches.dtype)  # (M, N, B)
    # PAS phase: imageBin[p, m, b] = Σ_n patches[p, n]·[idx[m, n] = b]
    image_bins = jnp.einsum("pn,mnb->pmb", patches, onehot)
    # post-pass multiply: one multiply per bin, not per element
    y = jnp.einsum("pmb,b->pm", image_bins, codebook.astype(patches.dtype))
    return _col2im(_epilogue(y, bias, relu), spec, images.shape[0], squeeze)
