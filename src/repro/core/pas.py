"""The PASM identity: accumulate-into-bins first, multiply once per bin after.

This module is the *paper-faithful algorithmic core*.  A weight-shared MAC
computes ``result = Σ_k x[k] · codebook[idx[k]]`` directly (one multiply per
element).  PASM (paper §2.2) re-orders it into two phases:

  PAS phase   ``S[b] = Σ_{k : idx[k] = b} x[k]``      (adds only — the
              "weighted histogram of the dictionary weight indices")
  post-pass   ``result = Σ_b S[b] · codebook[b]``      (B multiplies total)

The results are *identical* (bit-exact in integer arithmetic, equal up to
float reassociation otherwise) — paper §5.3; property-tested in
``tests/test_pas.py``.

On TPU the PAS phase maps onto a one-hot contraction; for B bins it costs B×
the MACs of the direct product, so it is a *compute pessimization* on a fixed
MXU (see DESIGN.md §2 — the gate-level win does not transfer; the bandwidth
win of carrying only indices does).  Both formulations are provided so the
trade-off is measured rather than assumed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pasm as _pasm

__all__ = [
    "pas_accumulate",
    "pas_postpass",
    "pasm_dot",
    "weight_shared_dot",
    "pasm_matmul",
    "weight_shared_matmul",
    "pasm_cycles",
    "mac_cycles",
]


# ---------------------------------------------------------------------------
# 1-D (single output) — the paper's Fig 4 / Fig 6 setting
# ---------------------------------------------------------------------------


def pas_accumulate(x: jax.Array, idx: jax.Array, bins: int) -> jax.Array:
    """PAS phase: bin-accumulate ``x`` keyed by weight index (paper Fig 6a).

    Returns ``S`` with ``S[b] = Σ_{k : idx[k]=b} x[k]``.  Pure adds — this is
    the circuit the paper replaces the multiplier array with.
    """
    return jax.ops.segment_sum(x, idx.astype(jnp.int32), num_segments=bins)


def pas_postpass(bins_acc: jax.Array, codebook: jax.Array) -> jax.Array:
    """Post-pass multiply phase (paper Fig 6b): ``Σ_b S[b]·codebook[b]``."""
    return jnp.dot(bins_acc, codebook)


def pasm_dot(x: jax.Array, idx: jax.Array, codebook: jax.Array) -> jax.Array:
    """Full PASM: PAS accumulate then shared post-pass MAC."""
    return pas_postpass(pas_accumulate(x, idx, codebook.shape[-1]), codebook)


def weight_shared_dot(x: jax.Array, idx: jax.Array, codebook: jax.Array) -> jax.Array:
    """Baseline weight-shared MAC (paper Fig 3/4): dereference then MAC."""
    return jnp.dot(x, codebook[idx.astype(jnp.int32)])


# ---------------------------------------------------------------------------
# 2-D (matmul) — PASM generalized to a GEMM with per-(k,n) indices
# ---------------------------------------------------------------------------


def pasm_matmul(x: jax.Array, t: _pasm.PASMTensor, dtype=jnp.float32) -> jax.Array:
    """``x (M,K) @ shared-weight (K,N)`` via the PASM two-phase formulation.

    ``S[m,b,n] = Σ_k x[m,k]·[idx[k,n]=b]`` then ``y[m,n] = Σ_b S[m,b,n]·cb[b]``.
    Grouped codebooks bin-accumulate within each group independently.
    """
    idx = _pasm.logical_idx(t)
    K, N = t.shape
    G, B = t.codebook.shape
    xg = x.astype(dtype).reshape(*x.shape[:-1], G, K // G)
    idxg = idx.reshape(G, K // G, N)
    # one-hot (G, Kg, N, B) contracted with x over Kg: the PAS phase.
    onehot = jax.nn.one_hot(idxg, B, dtype=dtype)  # (G, Kg, N, B)
    s = jnp.einsum("...gk,gknb->...gnb", xg, onehot)  # PAS bins per group
    y = jnp.einsum("...gnb,gb->...n", s, t.codebook.astype(dtype))  # post-pass
    return y


def weight_shared_matmul(x: jax.Array, t: _pasm.PASMTensor, dtype=jnp.float32) -> jax.Array:
    """Baseline: dequantize (dictionary lookup) then ordinary GEMM."""
    w = _pasm.dequantize(t, dtype=dtype)
    return jnp.dot(x.astype(dtype), w)


# ---------------------------------------------------------------------------
# cycle model (paper §2.2 / §4): N vs N + P·B
# ---------------------------------------------------------------------------


def mac_cycles(n_inputs: int) -> int:
    """Fully-pipelined MAC latency: one pair per cycle → ≈ N cycles."""
    return n_inputs


def pasm_cycles(n_inputs: int, bins: int, pas_per_mac: int = 1) -> int:
    """PASM latency: N-cycle PAS phase + post-pass of B per PAS sharing a MAC.

    Paper example (§2.2): N=1024, B=16, 4 PAS / shared MAC → 1024 + 4·16 = 1088.
    """
    return n_inputs + pas_per_mac * bins
