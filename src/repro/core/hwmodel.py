"""Analytical hardware cost model reproducing the paper's evaluation.

The paper evaluates PASM by synthesizing Verilog/SystemC to a 45 nm ASIC
(Cadence Genus) and a Zynq FPGA (Vivado), reporting NAND2-normalized gate
counts, power, and latency.  No synthesis toolchain exists in this container,
so the *faithful reproduction vehicle* for those claims is this analytical
model (DESIGN.md §2):

1. **Structural unit model** — paper Table 1's complexity model with explicit
   NAND2-equivalent constants: adder O(W), array multiplier O(W²), register
   O(W), register-file port O(W·B).  Two constants the paper does not report
   (mux cost per bit·bin, HLS pipeline-register depth) are solved in closed
   form against the paper's §2.4 anchor point (W=32, B=16 standalone:
   sequential −35 %, logic −68 %) — everything else is textbook.
2. **Accelerator-level calibrated model** — the in-CNN accelerator results
   (Figs 15–22) depend on synthesis timing pressure at 1 GHz that a structural
   model cannot see; the paper's own explanation is that the unrolled B-bin
   register network blows up with B.  We fit the paper's observed log-linear
   law ``ratio(B) = a + b·log2(B)`` per metric from two quoted anchors and
   check it *predicts* the third (the B=16 crossover where "PASM no longer
   offers a good return").
3. **Cycle/latency model** — §2.2/§4: MAC ≈ N cycles, PASM ≈ N + P·B.

All paper-quoted numbers live in :data:`PAPER_CLAIMS` so tests/benchmarks can
diff model output against every figure quoted in the text.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

__all__ = [
    "GateConstants",
    "UnitGates",
    "mac_unit",
    "weight_shared_mac_unit",
    "pas_unit",
    "accel_16mac",
    "accel_16pas4mac",
    "gate_ratio",
    "power_model",
    "accel_ratio_asic",
    "accel_ratio_fpga",
    "conv_latency_cycles",
    "conv_latency_ratio",
    "conv_hbm_traffic",
    "dense_hbm_traffic",
    "dense_weight_stream_bytes",
    "im2col_inflation",
    "fpga_resources",
    "PAPER_CLAIMS",
]

# ---------------------------------------------------------------------------
# paper-quoted numbers (anchor + validation data)
# ---------------------------------------------------------------------------

PAPER_CLAIMS: Dict[str, float] = {
    # §2.4 standalone 16-MAC vs 16-PAS-4-MAC, W=32, B=16 (fractions REMAINING)
    "standalone.seq_ratio": 1 - 0.35,
    "standalone.inv_ratio": 1 - 0.78,
    "standalone.buf_ratio": 1 - 0.61,
    "standalone.logic_ratio": 1 - 0.68,
    "standalone.total_ratio": 1 - 0.66,
    "standalone.leak_power_ratio": 1 - 0.60,
    "standalone.dyn_power_ratio": 1 - 0.70,
    "standalone.total_power_ratio": 1 - 0.70,
    # §5.1 ASIC accelerator, 32-bit kernels (PASM vs weight-shared)
    "asic.gates_ratio.b4": 1 - 0.478,
    "asic.power_ratio.b4": 1 - 0.532,
    "asic.gates_ratio.b8": 1 - 0.081,
    "asic.power_ratio.b8": 1 - 0.152,
    # 8-bit kernels, 4 bins
    "asic.gates_ratio.w8b4": 1 - 0.198,
    "asic.power_ratio.w8b4": 1 - 0.313,
    # §5.2 FPGA accelerator, 32-bit kernels
    "fpga.dsp_ratio": 1 - 0.99,
    "fpga.bram_ratio": 1 - 0.28,
    "fpga.power_ratio.b4": 1 - 0.64,
    "fpga.power_ratio.b8": 1 - 0.416,
    "fpga.power_ratio.b16": 1 - 0.18,
    # §5.1 latency (PASM vs weight-shared accelerator, fraction INCREASE)
    "latency.increase.b4": 0.085,
    "latency.increase.b16": 0.1275,
    # §2.2 worked cycle example
    "cycles.example": 1088,
}

# paper's accelerator conv dimensions (§4): 5×5 image, 15 ch, 3×3 kernel, M=2
PAPER_CONV = dict(IH=5, IW=5, C=15, KY=3, KX=3, M=2, stride=1)


# ---------------------------------------------------------------------------
# 1. structural unit model (Table 1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GateConstants:
    """NAND2-equivalent gate constants.  Textbook values unless noted."""

    c_add: float = 6.0      # full adder ≈ 6 NAND2 per bit (ripple)
    c_mul: float = 30.0     # timing-driven multiplier, NAND2 per bit²  [calibrated]
    c_reg: float = 6.0      # DFF ≈ 6 NAND2 per bit
    c_port: float = 2.0     # regfile port mux per bit·bin  [calibrated]
    pipe_stages: float = 13.5  # HLS-inserted pipeline regs  [calibrated]
    # Calibration (closed-form against the paper's §2.4 W=32/B=16 anchor —
    # see tests/test_hwmodel.py): c_mul=30 reflects the Wallace/Booth
    # multiplier the synthesizer instantiates under a timing constraint (a
    # plain array multiplier is ~6/bit²); pipe_stages=13.5 absorbs the HLS
    # pipeline registers the paper itself reports as a 97 % flip-flop
    # increase (§4); c_port=2.0 is a B:1 mux tree per bit (~2 NAND2/bit·bin).


@dataclasses.dataclass(frozen=True)
class UnitGates:
    """Gate counts by category (NAND2-normalized), mirroring Genus categories."""

    mult: float
    logic_rest: float  # adders, muxes, ports — combinational minus multiplier
    seq: float         # registers / flip-flops

    @property
    def logic(self) -> float:
        return self.mult + self.logic_rest

    # Inverters sit overwhelmingly in the multiplier reduction tree; buffers
    # drive the clock tree (∝ seq) and long combinational nets (∝ logic).
    # The seq/logic split for buffers is solved from the paper anchor
    # (see calibrate_buffers()).
    def inverters(self) -> float:
        return 0.30 * self.mult + 0.02 * self.logic_rest

    def buffers(self, seq_frac: float = 0.5) -> float:
        return 0.15 * (seq_frac * self.seq + (1 - seq_frac) * self.logic)

    def total(self) -> float:
        return self.logic + self.seq + self.inverters() + self.buffers()

    def __add__(self, o: "UnitGates") -> "UnitGates":
        return UnitGates(self.mult + o.mult, self.logic_rest + o.logic_rest, self.seq + o.seq)

    def __mul__(self, k: float) -> "UnitGates":
        return UnitGates(self.mult * k, self.logic_rest * k, self.seq * k)

    __rmul__ = __mul__


def mac_unit(W: int, c: GateConstants = GateConstants()) -> UnitGates:
    """Simple MAC (paper Fig 2): multiplier + adder + 2W-bit accumulator."""
    return UnitGates(
        mult=c.c_mul * W * W,
        logic_rest=c.c_add * W,
        seq=c.c_reg * 2 * W * (1 + c.pipe_stages),  # acc + pipeline regs
    )


def weight_shared_mac_unit(W: int, B: int, c: GateConstants = GateConstants()) -> UnitGates:
    """Weight-shared MAC (Fig 3): MAC + B-entry weight regfile + 1 read port."""
    base = mac_unit(W, c)
    return UnitGates(
        mult=base.mult,
        logic_rest=base.logic_rest + c.c_port * W * B,
        seq=base.seq + c.c_reg * W * B,
    )


def pas_unit(W: int, B: int, c: GateConstants = GateConstants()) -> UnitGates:
    """PAS (Fig 5/Table 1): adder + B accumulators + read AND write ports."""
    return UnitGates(
        mult=0.0,
        logic_rest=c.c_add * W + 2 * c.c_port * W * B,
        seq=c.c_reg * W * B + c.c_reg * 2 * W,  # bins + input pipe reg
    )


def accel_16mac(W: int, B: int, c: GateConstants = GateConstants()) -> UnitGates:
    """The paper's standalone baseline: 16 weight-shared MACs."""
    return 16 * weight_shared_mac_unit(W, B, c)


def accel_16pas4mac(W: int, B: int, c: GateConstants = GateConstants()) -> UnitGates:
    """The paper's PASM unit: 16 PAS + 4 shared post-pass (weight-shared) MACs."""
    return 16 * pas_unit(W, B, c) + 4 * weight_shared_mac_unit(W, B, c)


def gate_ratio(W: int, B: int, c: GateConstants = GateConstants()) -> Dict[str, float]:
    """PASM/MAC gate-count ratios by category (paper Figs 7 & 9)."""
    m = accel_16mac(W, B, c)
    p = accel_16pas4mac(W, B, c)
    return {
        "seq": p.seq / m.seq,
        "logic": p.logic / m.logic,
        "inv": p.inverters() / m.inverters(),
        "buf": p.buffers() / m.buffers(),
        "total": p.total() / m.total(),
    }


# power: dynamic ∝ Σ activity·gates (multiplier toggles hardest); leakage ∝
# gates with sequential cells weighted (larger cells).  Activities are
# standard CMOS estimates; they land within a few % of the paper's anchors
# (checked in tests/test_hwmodel.py).
_ACT = dict(mult=0.40, logic_rest=0.15, seq=0.20, inv=0.35, buf=0.30)
_LEAK = dict(mult=1.0, logic_rest=1.0, seq=1.6, inv=0.6, buf=0.8)


def _power_terms(u: UnitGates) -> Dict[str, float]:
    parts = dict(
        mult=u.mult, logic_rest=u.logic_rest, seq=u.seq, inv=u.inverters(), buf=u.buffers()
    )
    dyn = sum(_ACT[k] * v for k, v in parts.items())
    leak = sum(_LEAK[k] * v for k, v in parts.items())
    return {"dynamic": dyn, "leakage": leak, "total": dyn + leak * 0.12}


def power_model(W: int, B: int, c: GateConstants = GateConstants()) -> Dict[str, float]:
    """PASM/MAC power ratios (paper Figs 8 & 10)."""
    pm = _power_terms(accel_16mac(W, B, c))
    pp = _power_terms(accel_16pas4mac(W, B, c))
    return {k: pp[k] / pm[k] for k in pm}


# ---------------------------------------------------------------------------
# 2. accelerator-level calibrated model (Figs 15-22)
# ---------------------------------------------------------------------------


def _loglin(b4: float, b8: float, B: int) -> float:
    """Fit ratio(B) = a + s·log2(B) through the two paper anchors, evaluate."""
    s = b8 - b4  # per-doubling slope (anchors at log2 = 2 and 3)
    a = b4 - 2 * s
    return a + s * math.log2(B)


def accel_ratio_asic(B: int, W: int = 32) -> Dict[str, float]:
    """PASM/weight-shared in-accelerator ratios, 45 nm ASIC @ 1 GHz.

    Calibrated from the paper's B=4 and B=8 anchors (32-bit kernels); the
    model's B=16 prediction > 1 reproduces the paper's reported crossover.
    For W=8 only the B=4 anchor exists; the same slope is reused (the paper's
    own qualitative statement is that the crossover comes *earlier* at W=8).
    """
    if W == 32:
        g = _loglin(PAPER_CLAIMS["asic.gates_ratio.b4"], PAPER_CLAIMS["asic.gates_ratio.b8"], B)
        p = _loglin(PAPER_CLAIMS["asic.power_ratio.b4"], PAPER_CLAIMS["asic.power_ratio.b8"], B)
    elif W == 8:
        slope_g = PAPER_CLAIMS["asic.gates_ratio.b8"] - PAPER_CLAIMS["asic.gates_ratio.b4"]
        slope_p = PAPER_CLAIMS["asic.power_ratio.b8"] - PAPER_CLAIMS["asic.power_ratio.b4"]
        g = PAPER_CLAIMS["asic.gates_ratio.w8b4"] + slope_g * (math.log2(B) - 2)
        p = PAPER_CLAIMS["asic.power_ratio.w8b4"] + slope_p * (math.log2(B) - 2)
    else:
        raise ValueError(f"calibration only for W in (8, 32), got {W}")
    return {"gates": g, "power": p}


def accel_ratio_fpga(B: int) -> Dict[str, float]:
    """PASM/weight-shared in-accelerator ratios, Zynq XC7Z045 @ 200 MHz."""
    p4, p8 = PAPER_CLAIMS["fpga.power_ratio.b4"], PAPER_CLAIMS["fpga.power_ratio.b8"]
    return {
        "dsp": PAPER_CLAIMS["fpga.dsp_ratio"],
        "bram": PAPER_CLAIMS["fpga.bram_ratio"],
        "power": _loglin(p4, p8, B),
    }


def fpga_resources(B: int, W: int = 32, pasm: bool = True) -> Dict[str, int]:
    """Absolute FPGA resource model (§5.2): WS accel = 405 DSPs, PASM = 3."""
    if pasm:
        return {"dsp": 3, "bram_rel": 72}  # 28 % fewer BRAMs (normalized 100)
    return {"dsp": 405, "bram_rel": 100}


# ---------------------------------------------------------------------------
# 3. cycle / latency model
# ---------------------------------------------------------------------------


def conv_latency_cycles(
    *, IH: int, IW: int, C: int, KY: int, KX: int, M: int, stride: int = 1,
    bins: int = 0, postpass_mults: int = 1,
) -> int:
    """Pipelined conv-layer latency in cycles (paper Fig 13 structure).

    ``bins=0`` → weight-shared/simple MAC accelerator: each output pixel×M
    costs N = C·KY·KX pipelined MACs.  ``bins=B`` → PASM: adds the post-pass
    multiply of B bins through ``postpass_mults`` multipliers (ALLOCATION
    limit=1 in the paper) plus fixed drain/control overhead per output.
    """
    OH = (IH - 2 * (KY // 2) + stride - 1) // stride
    OW = (IW - 2 * (KX // 2) + stride - 1) // stride
    n = C * KY * KX
    per_out = n
    if bins:
        # calibrated post-pass overhead: fixed control/drain (≈10 cycles) +
        # B multiplies through the shared multiplier (see EXPERIMENTS.md).
        per_out = n + int(round(9.6 + 0.475 * bins / postpass_mults))
    return OH * OW * M * per_out


def conv_latency_ratio(bins: int, conv: dict = PAPER_CONV) -> float:
    """PASM/weight-shared conv latency ratio (paper Fig 14: +8.5 %…+12.75 %)."""
    base = conv_latency_cycles(**conv, bins=0)
    pasm = conv_latency_cycles(**conv, bins=bins)
    return pasm / base


# ---------------------------------------------------------------------------
# 4. conv HBM traffic model (im2col dataflow: explicit vs implicit)
# ---------------------------------------------------------------------------


def im2col_inflation(KY: int, KX: int, stride: int = 1) -> float:
    """Activation-byte inflation of a materialized patch matrix vs the image.

    Each input pixel lands in up to ``KY·KX/stride²`` patches (≈7.6× for
    AlexNet conv1: 11·11/4² = 7.5625) — the factor implicit-GEMM removes.
    """
    return KY * KX / stride ** 2


def conv_hbm_traffic(
    *, IH: int, IW: int, C: int, KY: int, KX: int, M: int, stride: int = 1,
    batch: int = 1, bins: int = 16, pad: tuple = (0, 0, 0, 0),
    act_bytes: int = 4, packed: bool = True, implicit: bool = True,
    pool: int = 1, dense: bool = False, vmem_budget: Optional[int] = None,
) -> int:
    """Logical-shape HBM bytes of one conv layer on the PASM GEMM.

    The PASM memory argument (DESIGN.md §2) extended to the conv dataflow:
    weights stream as ``log2(B)``-bit indices (int4-``packed`` halves them)
    plus a tiny codebook on either path, so the paths differ *only* in the
    activation term —

    * ``implicit=False`` (explicit im2col): the ``(B·P, K)`` patch matrix is
      written by the front-end and read back by the kernel — ``2·B·P·K``
      activation elements, an :func:`im2col_inflation` blow-up of the image.
    * ``implicit=True``: the padded image streams once per reuse window —
      ``B·C·Hp·Wp`` elements when its double-buffered residency fits
      ``vmem_budget`` (``None`` → the 6 MiB module default).  Past the
      budget the kernel streams row-band slabs and the only extra traffic
      is the re-fetched seam halo: ``(n_slabs−1)·max(KY−stride, 0)`` rows,
      with ``n_slabs = ceil(2·C·Hp·Wp·act_bytes / budget)`` — the
      logical-shape mirror of the kernels' slab plan.

    ``pool > 1`` models the **fused conv/ReLU/max-pool stage** (DESIGN.md
    §3.2): the store shrinks to the pooled ``(OH//pool)·(OW//pool)`` map and
    the explicit patch stream drops the floor-remainder pixels — the
    pre-pool map's separate store + re-read simply vanishes.  ``dense=True``
    models the einsum reference instead: a dense f32 weight stream
    (``K·M·4`` B, no indices, no codebook), so BENCH_conv.json einsum rows
    carry comparable bytes.

    Plan-free counterpart of the tile-aware
    :func:`repro.kernels.ops.conv_hbm_bytes` (which additionally rounds to
    the kernels' padded operands).
    """
    plh, phh, plw, phw = pad
    hp, wp = IH + plh + phh, IW + plw + phw
    OH = (hp - KY) // stride + 1
    OW = (wp - KX) // stride + 1
    K = C * KY * KX
    OHp, OWp = OH // pool, OW // pool
    P = OHp * OWp * pool * pool  # GEMM rows; == OH·OW when pool == 1
    if dense:
        idx_bytes, cb_bytes = K * M * 4, 0  # dense f32 weights, no dictionary
    else:
        idx_bytes = K * M // 2 if packed else K * M
        cb_bytes = bins * 4
    out_bytes = batch * OHp * OWp * M * 4  # f32 store (pooled when pool > 1)
    if implicit:
        budget = 6 * 1024 * 1024 if vmem_budget is None else vmem_budget
        img_resident = 2 * C * hp * wp * act_bytes  # double-buffered image
        rows = hp
        if img_resident > budget:
            n_slabs = -(-img_resident // budget)
            rows = hp + (n_slabs - 1) * max(KY - stride, 0)  # seam halos
        x_bytes = batch * C * rows * wp * act_bytes
    else:
        x_bytes = 2 * batch * P * K * act_bytes  # im2col store + kernel stream
    return x_bytes + idx_bytes + cb_bytes + out_bytes


# ---------------------------------------------------------------------------
# 5. dense-layer HBM traffic model (the weight-stream argument beyond conv)
# ---------------------------------------------------------------------------


def dense_weight_stream_bytes(
    K: int, N: int, *, bins: int = 16, groups: int = 1,
    packed: bool = True, dense: bool = False, dense_dtype_bytes: int = 2,
) -> int:
    """HBM bytes a ``(K, N)`` weight matrix streams per GEMM pass.

    The paper's memory argument applied to a transformer linear layer
    (``PasmParams`` dense kind vs shared/packed): a dense bf16 stream costs
    ``K·N·2`` B; the PASM stream is ``log2(B)``-bit indices (int4-``packed``
    halves uint8) plus the ``(G, B)`` f32 dictionary — the same accounting
    :attr:`repro.core.params.PasmParams.nbytes_weights` reports for the
    stored tree, as a closed-form model for the roofline benches.
    """
    if dense:
        return K * N * dense_dtype_bytes
    return (K * N // 2 if packed else K * N) + groups * bins * 4


def dense_hbm_traffic(
    *, T: int, K: int, N: int, bins: int = 16, groups: int = 1,
    act_bytes: int = 2, packed: bool = True, dense: bool = False,
) -> int:
    """Logical-shape HBM bytes of one dense (linear) layer on the PASM GEMM.

    ``T`` tokens of ``(T, K)`` activations stream in, the weight matrix
    streams per :func:`dense_weight_stream_bytes`, and the ``(T, N)`` result
    stores back — the decode-time regime where the weight stream dominates
    and weight sharing pays (DESIGN.md §2, extended from conv to the
    transformer FFN/attention projections).
    """
    w = dense_weight_stream_bytes(
        K, N, bins=bins, groups=groups, packed=packed, dense=dense,
        dense_dtype_bytes=2,
    )
    return T * K * act_bytes + w + T * N * act_bytes
