"""Quantization-aware training with weight-sharing (beyond-paper feature).

The paper quantizes a *trained* network post-hoc (Han et al. k-means) and
runs inference.  For training with PASM weights in the loop we provide a
straight-through estimator: forward uses the codebook-snapped weight, the
gradient flows to the dense master weight unchanged.  Codebooks can also be
learned: gradients w.r.t. codebook entries are the sums of gradients of the
weights assigned to each bin (the same bin-accumulate structure as PAS —
the PASM identity applied to the backward pass).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pasm as _pasm

__all__ = ["assign_bins", "ste_quantize", "codebook_grads"]


def assign_bins(w: jax.Array, codebook: jax.Array) -> jax.Array:
    """Nearest-entry bin assignment, any weight shape, ``(B,)`` codebook.

    THE single-dictionary assignment rule: :func:`ste_quantize`'s forward,
    the conv stack's ``qat_requantize`` freeze, and (per group)
    :func:`repro.core.pasm.quantize_like` all apply exactly this argmin, so
    a trained master re-assigns identically everywhere.
    """
    return jnp.argmin(jnp.abs(w[..., None] - codebook), axis=-1)


@jax.custom_vjp
def ste_quantize(w: jax.Array, codebook: jax.Array) -> jax.Array:
    """Snap each weight to its nearest codebook entry; identity gradient."""
    return codebook[assign_bins(w, codebook)]


def _ste_fwd(w, codebook):
    idx = assign_bins(w, codebook)
    return codebook[idx], (idx, codebook.shape[0])


def _ste_bwd(res, g):
    idx, bins = res
    # dL/dw: straight through.  dL/dcodebook[b]: Σ of g where idx == b —
    # a PAS bin-accumulate over the gradient tensor.
    gcb = jax.ops.segment_sum(g.reshape(-1), idx.reshape(-1), num_segments=bins)
    return g, gcb


ste_quantize.defvjp(_ste_fwd, _ste_bwd)


def codebook_grads(w: jax.Array, codebook: jax.Array, g: jax.Array) -> jax.Array:
    """Explicit codebook gradient (for tests): Σ_b-binned upstream grads."""
    idx = assign_bins(w, codebook)
    return jax.ops.segment_sum(
        g.reshape(-1), idx.reshape(-1), num_segments=codebook.shape[0]
    )
