"""Sharded, atomic, resumable checkpoints with integrity (no external deps).

Layout:  <dir>/step_<N>/shard_<i>.npz + manifest.json
* **atomic**: shards + manifest land in a tmp dir, **fsync'd before the
  rename** (file contents, then the tmp dir, then the parent dir after the
  rename) so a crash — or a power cut — mid-write never corrupts the latest
  checkpoint (restore scans for the newest *complete* manifest).
* **integrity**: the manifest records a CRC32 per array; restore re-hashes
  every array it loads (``verify=True``) and raises
  :class:`CheckpointCorruptError` on any mismatch, unreadable shard, or
  truncated npz.  ``restore(..., fallback=True)`` (what
  :meth:`CheckpointManager.restore_latest` uses) then scans *backwards* to
  the newest checkpoint that verifies — a byte-flipped or torn latest
  checkpoint costs ``ckpt_every`` steps of recompute, never the run
  (DESIGN.md §4).
* **elastic**: arrays are saved logically (de-sharded per host in this
  single-process container; on a fleet each host saves its addressable
  shards and the manifest records the mesh) and restored onto any mesh —
  N→M host restarts just re-shard at load (DESIGN.md §4).
* **async**: ``save(..., background=True)`` hands the host copy to a worker
  thread so the train loop keeps stepping during I/O.  The writer CAPTURES
  any exception instead of letting it vanish in the daemon thread; it is
  re-raised from :meth:`CheckpointManager.wait` (and therefore from the
  next ``save()``, which waits first) — a failed background write is a
  loud failure, never a silently missing checkpoint.  The manager's GC
  never touches the directory an in-flight background write is about to
  rename into place (``_pending_step``).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import warnings
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = [
    "save",
    "restore",
    "latest_step",
    "complete_steps",
    "CheckpointCorruptError",
    "CheckpointManager",
    "BackgroundWriter",
]

_SEP = "||"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint directory exists and looks complete but fails integrity:
    CRC mismatch, unreadable/truncated shard, or a key the manifest promised
    is missing.  Distinct from ``FileNotFoundError`` (nothing to restore)
    and ``ValueError`` (template/shape disagreement)."""


class BackgroundWriter(threading.Thread):
    """Daemon writer thread that captures its exception for join-time
    re-raise — a background checkpoint failure must surface, not vanish."""

    def __init__(self, fn):
        super().__init__(daemon=True)
        self._fn = fn
        self.exc: Optional[BaseException] = None

    def run(self):
        try:
            self._fn()
        except BaseException as e:  # noqa: BLE001 — captured, re-raised at wait()
            self.exc = e

    def check(self) -> None:
        """Re-raise the captured write failure, if any (idempotent)."""
        if self.exc is not None:
            exc, self.exc = self.exc, None
            raise RuntimeError("background checkpoint write failed") from exc


def _to_numpy(leaf) -> np.ndarray:
    a = np.asarray(leaf)
    if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
        # npz can't store ml_dtypes — upcast losslessly; restore re-casts
        a = a.astype(np.float32)
    return a


def _flatten(tree: Any) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = _to_numpy(leaf)
    return out


def _crc(a: np.ndarray) -> int:
    """CRC32 over the array's raw bytes (C-order) — the manifest integrity
    record; cheap (~GB/s) next to the npz deflate that follows it."""
    return int(zlib.crc32(np.ascontiguousarray(a).tobytes()))


def _fsync_file(path: Path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: Path) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # platforms without O_RDONLY dir opens — best effort
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(
    directory: str | Path,
    step: int,
    tree: Any,
    *,
    extra: Optional[dict] = None,
    background: bool = False,
) -> Optional["BackgroundWriter"]:
    """Write ``tree`` at ``step``.  Returns the writer thread if background
    (join it AND call ``check()`` — or use :class:`CheckpointManager`, whose
    ``wait()`` does both)."""
    directory = Path(directory)
    arrays = _flatten(tree)  # host copy happens here, synchronously

    def _write():
        tmp = directory / f".tmp_step_{step}_{time.monotonic_ns()}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / "shard_0.npz", **arrays)
        manifest = {
            "step": step,
            "n_shards": 1,
            "keys": sorted(arrays.keys()),
            "crc32": {k: _crc(a) for k, a in arrays.items()},
            "time": time.time(),
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        # durability before visibility: flush shard + manifest + the tmp dir
        # entries to stable storage, THEN rename, THEN flush the parent dir —
        # a crash at any point leaves either no step_<N> or a complete one
        _fsync_file(tmp / "shard_0.npz")
        _fsync_file(tmp / "manifest.json")
        _fsync_dir(tmp)
        final = directory / f"step_{step}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        _fsync_dir(directory)

    if background:
        t = BackgroundWriter(_write)
        t.start()
        return t
    _write()
    return None


def complete_steps(directory: str | Path) -> list:
    """All steps with a *complete* manifest, ascending (crash-safe restore
    candidates; validity is checked at restore time — see ``fallback``)."""
    directory = Path(directory)
    if not directory.exists():
        return []
    steps = []
    for p in directory.glob("step_*"):
        if (p / "manifest.json").exists():
            try:
                steps.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(directory: str | Path) -> Optional[int]:
    """Newest step with a *complete* manifest (crash-safe restore point)."""
    steps = complete_steps(directory)
    return steps[-1] if steps else None


def _load_arrays(d: Path, manifest: dict, *, verify: bool) -> dict:
    arrays = {}
    for i in range(manifest["n_shards"]):
        shard = d / f"shard_{i}.npz"
        try:
            with np.load(shard) as z:
                arrays.update({k: z[k] for k in z.files})
        except FileNotFoundError as e:
            raise CheckpointCorruptError(f"{d.name}: missing {shard.name}") from e
        except Exception as e:  # zipfile.BadZipFile, truncated deflate, ...
            raise CheckpointCorruptError(
                f"{d.name}: unreadable {shard.name} ({type(e).__name__}: {e})"
            ) from e
    crcs = manifest.get("crc32")
    if verify and crcs is not None:
        for key, want in crcs.items():
            if key not in arrays:
                raise CheckpointCorruptError(f"{d.name}: manifest key {key} not in shards")
            got = _crc(arrays[key])
            if got != int(want):
                raise CheckpointCorruptError(
                    f"{d.name}: CRC mismatch on {key} "
                    f"(manifest {int(want)}, shard {got})"
                )
    return arrays


def _restore_one(directory: Path, template: Any, step: int, *, verify: bool):
    d = directory / f"step_{step}"
    try:
        manifest = json.loads((d / "manifest.json").read_text())
    except FileNotFoundError:
        raise FileNotFoundError(f"no checkpoint at {d}")
    except (json.JSONDecodeError, OSError) as e:
        raise CheckpointCorruptError(f"{d.name}: unreadable manifest ({e})") from e
    arrays = _load_arrays(d, manifest, verify=verify)

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        a = arrays[key]
        if tuple(a.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint {a.shape} vs template {leaf.shape}")
        out.append(a.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def restore(
    directory: str | Path,
    template: Any,
    step: Optional[int] = None,
    *,
    verify: bool = True,
    fallback: bool = False,
) -> tuple[Any, dict]:
    """Restore into the structure of ``template`` (shapes/dtypes validated).

    ``verify=True`` re-hashes every array against the manifest CRC32s and
    raises :class:`CheckpointCorruptError` on mismatch or unreadable shards
    (manifests predating the CRC field skip verification).  With
    ``fallback=True`` and no explicit ``step``, a corrupt newest checkpoint
    is *warned about and skipped*: the scan walks backwards to the newest
    step that verifies, raising only when none does.

    Elastic: the on-disk arrays are logical (unsharded); putting them back
    on a different mesh/host count is the caller's in_shardings' job.
    """
    directory = Path(directory)
    if step is not None:
        return _restore_one(directory, template, step, verify=verify)
    steps = complete_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no complete checkpoint under {directory}")
    last_err: Optional[CheckpointCorruptError] = None
    for s in reversed(steps):
        try:
            return _restore_one(directory, template, s, verify=verify)
        except CheckpointCorruptError as e:
            if not fallback:
                raise
            warnings.warn(
                f"checkpoint step_{s} failed integrity, falling back to the "
                f"previous checkpoint: {e}",
                RuntimeWarning,
                stacklevel=2,
            )
            last_err = e
    raise CheckpointCorruptError(
        f"no checkpoint under {directory} passes integrity "
        f"(tried steps {list(reversed(steps))})"
    ) from last_err


class CheckpointManager:
    """Keep-last-k rotation + background writes + auto-resume with fallback."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.keep = keep
        self._pending: Optional[BackgroundWriter] = None
        self._pending_step: Optional[int] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()  # surfaces the PREVIOUS write's failure before starting
        self._pending_step = step
        self._pending = save(self.dir, step, tree, extra=extra, background=True)
        self._gc()

    def wait(self):
        """Join the in-flight write and RE-RAISE its failure, if any — a
        background checkpoint loss is never silent."""
        if self._pending is not None:
            t, self._pending = self._pending, None
            t.join()
            self._pending_step = None
            t.check()

    def _gc(self):
        """Delete all but the newest ``keep`` complete checkpoints — but
        NEVER the directory the in-flight background write is about to
        rename into place (after a fallback-restore the loop re-saves an
        *older* step than stale on-disk ones, which the keep-last-k sort
        would otherwise select for deletion mid-write — a silently lost
        checkpoint; regression in tests/test_infra.py)."""
        steps = complete_steps(self.dir)
        for s in steps[: -self.keep] if self.keep > 0 else steps:
            if s == self._pending_step:
                continue
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    def restore_latest(self, template: Any, *, fallback: bool = True):
        self.wait()
        return restore(self.dir, template, fallback=fallback)
