"""Sharded, atomic, resumable checkpoints (no external deps).

Layout:  <dir>/step_<N>/shard_<i>.npz + manifest.json
* **atomic**: shards + manifest land in a tmp dir, renamed into place last —
  a crash mid-write never corrupts the latest checkpoint (restore scans for
  the newest *complete* manifest).
* **elastic**: arrays are saved logically (de-sharded per host in this
  single-process container; on a fleet each host saves its addressable
  shards and the manifest records the mesh) and restored onto any mesh —
  N→M host restarts just re-shard at load (DESIGN.md §4).
* **async**: ``save(..., background=True)`` hands the host copy to a worker
  thread so the train loop keeps stepping during I/O.  The writer CAPTURES
  any exception instead of letting it vanish in the daemon thread; it is
  re-raised from :meth:`CheckpointManager.wait` (and therefore from the
  next ``save()``, which waits first) — a failed background write is a
  loud failure, never a silently missing checkpoint.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager", "BackgroundWriter"]

_SEP = "||"


class BackgroundWriter(threading.Thread):
    """Daemon writer thread that captures its exception for join-time
    re-raise — a background checkpoint failure must surface, not vanish."""

    def __init__(self, fn):
        super().__init__(daemon=True)
        self._fn = fn
        self.exc: Optional[BaseException] = None

    def run(self):
        try:
            self._fn()
        except BaseException as e:  # noqa: BLE001 — captured, re-raised at wait()
            self.exc = e

    def check(self) -> None:
        """Re-raise the captured write failure, if any (idempotent)."""
        if self.exc is not None:
            exc, self.exc = self.exc, None
            raise RuntimeError("background checkpoint write failed") from exc


def _to_numpy(leaf) -> np.ndarray:
    a = np.asarray(leaf)
    if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
        # npz can't store ml_dtypes — upcast losslessly; restore re-casts
        a = a.astype(np.float32)
    return a


def _flatten(tree: Any) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = _to_numpy(leaf)
    return out


def save(
    directory: str | Path,
    step: int,
    tree: Any,
    *,
    extra: Optional[dict] = None,
    background: bool = False,
) -> Optional["BackgroundWriter"]:
    """Write ``tree`` at ``step``.  Returns the writer thread if background
    (join it AND call ``check()`` — or use :class:`CheckpointManager`, whose
    ``wait()`` does both)."""
    directory = Path(directory)
    arrays = _flatten(tree)  # host copy happens here, synchronously

    def _write():
        tmp = directory / f".tmp_step_{step}_{time.monotonic_ns()}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / "shard_0.npz", **arrays)
        manifest = {
            "step": step,
            "n_shards": 1,
            "keys": sorted(arrays.keys()),
            "time": time.time(),
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = directory / f"step_{step}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)

    if background:
        t = BackgroundWriter(_write)
        t.start()
        return t
    _write()
    return None


def latest_step(directory: str | Path) -> Optional[int]:
    """Newest step with a *complete* manifest (crash-safe restore point)."""
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.glob("step_*"):
        if (p / "manifest.json").exists():
            try:
                steps.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(directory: str | Path, template: Any, step: Optional[int] = None) -> tuple[Any, dict]:
    """Restore into the structure of ``template`` (shapes/dtypes validated).

    Elastic: the on-disk arrays are logical (unsharded); putting them back
    on a different mesh/host count is the caller's in_shardings' job.
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {directory}")
    d = directory / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    arrays = {}
    for i in range(manifest["n_shards"]):
        with np.load(d / f"shard_{i}.npz") as z:
            arrays.update({k: z[k] for k in z.files})

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        a = arrays[key]
        if tuple(a.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint {a.shape} vs template {leaf.shape}")
        out.append(a.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


class CheckpointManager:
    """Keep-last-k rotation + background writes + auto-resume."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.keep = keep
        self._pending: Optional[BackgroundWriter] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()  # surfaces the PREVIOUS write's failure before starting
        self._pending = save(self.dir, step, tree, extra=extra, background=True)
        self._gc()

    def wait(self):
        """Join the in-flight write and RE-RAISE its failure, if any — a
        background checkpoint loss is never silent."""
        if self._pending is not None:
            t, self._pending = self._pending, None
            t.join()
            t.check()

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if (p / "manifest.json").exists()
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    def restore_latest(self, template: Any):
        self.wait()
        return restore(self.dir, template)
