"""Model API: family dispatch, input specs, loss — one surface for all archs."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec

__all__ = ["get_model", "input_specs", "lm_loss", "frontend_spec"]


def get_model(cfg: ArchConfig):
    """Returns the module implementing init_params/forward/init_caches/prefill/decode_step.

    Family ``cnn`` (CNNConfig) exposes init_params/quantize/forward only — a
    feed-forward vision stack has no KV-cache/prefill/decode surface.
    """
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import transformer as m
    elif cfg.family == "ssm":
        from repro.models import ssm_lm as m
    elif cfg.family == "hybrid":
        from repro.models import hybrid as m
    elif cfg.family == "audio":
        from repro.models import encdec as m
    elif cfg.family == "cnn":
        from repro.models import cnn as m
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return m


def cache_len(cfg: ArchConfig, shape: ShapeSpec) -> int:
    """KV-cache length for a serve cell (VLM prefill also stores the patch prefix)."""
    extra = cfg.frontend_tokens if cfg.frontend == "vit" else 0
    return shape.seq_len + extra


def frontend_spec(cfg: ArchConfig, batch: int) -> Optional[jax.ShapeDtypeStruct]:
    """Modality-frontend input: vit patch embeddings (stub) or log-mel frames.

    Audio is REAL input now: ``(B, n_mels, 2·frontend_tokens)`` log-mel
    frames into the stride-2 conv stem (encdec halves the time axis onto the
    ``frontend_tokens``-long encoder sequence).
    """
    if cfg.frontend == "vit":
        return jax.ShapeDtypeStruct((batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    if cfg.frontend == "audio":
        return jax.ShapeDtypeStruct(
            (batch, cfg.n_mels, 2 * cfg.frontend_tokens), jnp.bfloat16
        )
    return None


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: full-length token batch (+ frontend embeds).
    decode: one new token; the KV/state cache specs come from
    ``jax.eval_shape`` over ``init_caches`` (launch/dryrun.py).
    """
    B = shape.global_batch
    if shape.kind == "train":
        d = {
            "tokens": jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32),
        }
    elif shape.kind == "prefill":
        d = {"tokens": jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)}
    else:  # decode: one token against a seq_len cache
        d = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    fe = frontend_spec(cfg, B)
    if fe is not None and shape.kind != "decode":
        d["frontend_embeds"] = fe
    return d


def lm_loss(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None):
    """Mean next-token cross-entropy (labels already shifted by the pipeline)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    if mask is None:
        mask = jnp.ones_like(ll)
    mask = mask.astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
