"""Whisper-style encoder–decoder (whisper-tiny backbone).

The conv/mel frontend is REAL: log-mel frames ``(B, n_mels, T_mel)`` run
through the two Whisper stem convs (kernel 3 along time; the second at
stride 2) via the PASM :func:`repro.core.conv.conv2d` path — the same
fused-epilogue Pallas engines the CNN stack uses, which is how the paper's
technique is proven on voice (abstract: image, voice and video).
:func:`quantize_frontend` weight-shares the stem kernels into
:class:`~repro.core.conv.ConvParams` dictionaries (``quantize_params`` keeps
conv leaves dense by name, so the frontend opts in explicitly).

Encoder is non-causal self-attention; decoder is causal self-attention +
cross-attention onto the fixed-length encoder output.  LayerNorm-with-bias
and GELU match the Whisper family; token embeddings are tied to the LM head
(paper-faithful to Radford et al. 2022).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import params as _params
from repro.core.conv import Conv2D, ConvParams, conv2d
from repro.models.common import Initializer, ShardCtx, maybe_scan
from repro.nn import attention as A
from repro.nn import layers as L

__all__ = [
    "init_params",
    "forward",
    "init_caches",
    "prefill",
    "decode_step",
    "quantize_frontend",
]


def _sinusoid(length: int, channels: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(channels // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * jnp.log(10_000.0) / (channels // 2 - 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def _init_attn(cfg, ini, kv_from_d=None):
    D, hd = cfg.d_model, cfg.hd
    dk = kv_from_d or D
    return {
        "wq": ini.dense((D, cfg.n_heads * hd)),
        "wk": ini.dense((dk, cfg.n_kv_heads * hd)),
        "wv": ini.dense((dk, cfg.n_kv_heads * hd)),
        "wo": ini.dense((cfg.n_heads * hd, D)),
    }


def _init_mlp(cfg, ini):
    return {
        "w1": ini.dense((cfg.d_model, cfg.d_ff)),
        "bias1": jnp.zeros((cfg.d_ff,)),
        "w2": ini.dense((cfg.d_ff, cfg.d_model), fan_in=cfg.d_ff),
        "bias2": jnp.zeros((cfg.d_model,)),
    }


def _ln(d):
    return {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))}


def _init_enc_layer(cfg, ini):
    return {
        "ln1": _ln(cfg.d_model),
        "attn": _init_attn(cfg, ini),
        "ln2": _ln(cfg.d_model),
        "mlp": _init_mlp(cfg, ini),
    }


def _init_dec_layer(cfg, ini):
    return {
        "ln1": _ln(cfg.d_model),
        "attn": _init_attn(cfg, ini),
        "ln_cross": _ln(cfg.d_model),
        "cross": _init_attn(cfg, ini),
        "ln2": _ln(cfg.d_model),
        "mlp": _init_mlp(cfg, ini),
    }


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    ini = Initializer(key)
    ekeys = jax.random.split(ini.key(), cfg.encoder_layers)
    dkeys = jax.random.split(ini.key(), cfg.n_layers)
    D = cfg.d_model
    params = {
        "embed": jax.random.normal(ini.key(), (cfg.vocab, D)) * 0.02,
        "pos_embed": jax.random.normal(ini.key(), (cfg.max_seq, D)) * 0.01,
        # Whisper stem: two kernel-3 time convs, the second at stride 2.
        # The "conv" in the names keeps quantize_params' _EXCLUDE away —
        # weight-sharing the stem is an explicit quantize_frontend() opt-in.
        "frontend": {
            "conv1": {
                "kernel": ini.dense((D, cfg.n_mels, 1, 3), fan_in=cfg.n_mels * 3),
                "bias": jnp.zeros((D,)),
            },
            "conv2": {
                "kernel": ini.dense((D, D, 1, 3), fan_in=D * 3),
                "bias": jnp.zeros((D,)),
            },
        },
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(cfg, Initializer(k)))(ekeys),
        "enc_ln": _ln(cfg.d_model),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(cfg, Initializer(k)))(dkeys),
        "dec_ln": _ln(cfg.d_model),
    }
    if dtype != jnp.float32:
        params = jax.tree.map(lambda x: x.astype(dtype), params)
    return params


def _mha(xq, xkv, p, cfg, impl, *, causal):
    B, Sq, D = xq.shape
    hd = cfg.hd
    q = L.linear(xq, p["wq"], impl).reshape(B, Sq, cfg.n_heads, hd)
    k = L.linear(xkv, p["wk"], impl).reshape(B, -1, cfg.n_kv_heads, hd)
    v = L.linear(xkv, p["wv"], impl).reshape(B, -1, cfg.n_kv_heads, hd)
    o = A.gqa_attention(q, k, v, causal=causal, chunk=min(1024, k.shape[1]))
    return L.linear(o.reshape(B, Sq, -1), p["wo"], impl), (k, v)


def _mlp_fwd(x, p, impl):
    h = L.gelu_ffn_act(L.linear(x, p["w1"], impl) + p["bias1"].astype(x.dtype))
    return L.linear(h, p["w2"], impl) + p["bias2"].astype(x.dtype)


def _lnorm(x, p, eps=1e-5):
    return L.layer_norm(x, p["scale"], p["bias"], eps)


def _stem_convs(cfg: ArchConfig) -> tuple:
    """The two Whisper stem conv specs (kernel 3 on time; second at stride 2)."""
    return (
        Conv2D(k=(1, 3), c_in=cfg.n_mels, c_out=cfg.d_model, stride=1,
               padding="same"),
        Conv2D(k=(1, 3), c_in=cfg.d_model, c_out=cfg.d_model, stride=2,
               padding="same"),
    )


def _frontend_conv(x, p, conv: Conv2D, impl: str) -> jax.Array:
    """One stem conv through :func:`conv2d`, honoring the PASM impl choice.

    ``p`` is the init dict (``kernel``/``bias`` → dense) or a
    :class:`ConvParams` installed by :func:`quantize_frontend`.  Quantized
    stems route ``impl`` onto the matching conv engine, so the mel frontend
    runs the same fused-epilogue Pallas kernels as the CNN stack.
    """
    if isinstance(p, dict):
        p = ConvParams.dense(p["kernel"], bias=p["bias"])
        return conv2d(x, p, conv, engine="einsum")
    engine = {"dequant": "einsum", "kernel": "kernel",
              "pas_kernel": "pas_kernel"}.get(impl, "auto")
    return conv2d(x, p, conv, engine=engine)


def quantize_frontend(params: dict, bins: int = 16, *, iters: int = 16) -> dict:
    """Weight-share the mel-stem convs into :class:`ConvParams` dictionaries.

    ``quantize_params`` skips conv leaves by name (``_EXCLUDE``), so voice
    opts in here: each stem kernel gets its own per-layer codebook (paper
    §4), and :func:`encode` then dispatches them through the PASM engines.
    """
    fe = {
        name: ConvParams.quantize(p["kernel"], bins, bias=p["bias"], iters=iters)
        for name, p in params["frontend"].items()
    }
    return {**params, "frontend": fe}


def encode(params, mel, cfg: ArchConfig, sctx: ShardCtx = ShardCtx()):
    """mel: (B, n_mels, T_mel) log-mel frames → (B, T_mel//2, d_model).

    The stem halves the time axis (stride-2 second conv, SAME padding), so
    ``T_mel = 2·cfg.frontend_tokens`` lands exactly on the
    ``frontend_tokens``-long encoder sequence the cross-KV caches size for.
    """
    impl = cfg.quant.impl if cfg.quant.enabled else "dense"
    c1, c2 = _stem_convs(cfg)
    x4 = mel.astype(jnp.float32)[:, :, None, :]  # NCHW: (B, n_mels, 1, T_mel)
    x4 = L.gelu_ffn_act(_frontend_conv(x4, params["frontend"]["conv1"], c1, impl))
    x4 = L.gelu_ffn_act(_frontend_conv(x4, params["frontend"]["conv2"], c2, impl))
    x = jnp.transpose(x4[:, :, 0, :], (0, 2, 1))  # (B, T_mel//2, d_model)
    x = (x + _sinusoid(x.shape[1], cfg.d_model)).astype(jnp.bfloat16)
    x = sctx.act_btd(x)

    def body(h, lp):
        a, _ = _mha(_lnorm(h, lp["ln1"]), _lnorm(h, lp["ln1"]), lp["attn"], cfg,
                    impl, causal=False)
        h = h + a
        h = h + _mlp_fwd(_lnorm(h, lp["ln2"]), lp["mlp"], impl)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = maybe_scan(body_fn, x, params["enc_layers"], cfg.scan_layers)
    return _lnorm(x, params["enc_ln"])


def forward(
    params,
    tokens,
    cfg: ArchConfig,
    sctx: ShardCtx = ShardCtx(),
    *,
    frontend_embeds: Optional[jax.Array] = None,
):
    """Teacher-forced decode over ``tokens`` given log-mel ``frontend_embeds``."""
    impl = cfg.quant.impl if cfg.quant.enabled else "dense"
    if frontend_embeds is None:  # smoke path: silence
        frontend_embeds = jnp.zeros(
            (tokens.shape[0], cfg.n_mels, 2 * cfg.frontend_tokens), jnp.bfloat16
        )
    enc = encode(params, frontend_embeds, cfg, sctx)

    B, S = tokens.shape
    x = _params.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = x + params["pos_embed"][:S].astype(jnp.bfloat16)[None]
    x = sctx.act_btd(x)

    def body(h, lp):
        a, _ = _mha(_lnorm(h, lp["ln1"]), _lnorm(h, lp["ln1"]), lp["attn"], cfg,
                    impl, causal=True)
        h = h + a
        c, _ = _mha(_lnorm(h, lp["ln_cross"]), enc, lp["cross"], cfg, impl, causal=False)
        h = h + c
        h = h + _mlp_fwd(_lnorm(h, lp["ln2"]), lp["mlp"], impl)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = maybe_scan(body_fn, x, params["dec_layers"], cfg.scan_layers)
    x = _lnorm(x, params["dec_ln"])
    head = _params.dense_weight(params["embed"]).T  # tied head
    logits = jnp.dot(x, head.astype(x.dtype))
    return sctx.cs(logits, sctx.batch, None, sctx.model), {}


# ---------------------------------------------------------------------------
# serving: self-attn KV cache + precomputed cross KV
# ---------------------------------------------------------------------------


def init_caches(cfg: ArchConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    Lc = cfg.n_layers
    selfc = A.init_kv_cache(batch, seq, cfg.n_kv_heads, cfg.hd, dtype)
    cross = {
        "k": jnp.zeros((batch, cfg.frontend_tokens, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, cfg.frontend_tokens, cfg.n_kv_heads, cfg.hd), dtype),
    }
    one = {"self": selfc, "cross": cross}
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (Lc,) + x.shape), one)


def prefill(
    params, tokens, caches, cfg: ArchConfig, sctx: ShardCtx = ShardCtx(),
    *, lengths: Optional[jax.Array] = None,
    frontend_embeds: Optional[jax.Array] = None,
):
    """Encode audio, precompute cross KV, run the prompt through the decoder.

    ``lengths`` (B,) — per-slot real prompt lengths for right-padded batches
    (same contract as ``transformer.prefill``): self-KV counters advance per
    slot, logits come from each slot's last real position.
    """
    impl = cfg.quant.impl if cfg.quant.enabled else "dense"
    if frontend_embeds is None:
        frontend_embeds = jnp.zeros(
            (tokens.shape[0], cfg.n_mels, 2 * cfg.frontend_tokens), jnp.bfloat16
        )
    enc = encode(params, frontend_embeds, cfg, sctx)
    B, S = tokens.shape
    x = _params.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = x + params["pos_embed"][:S].astype(jnp.bfloat16)[None]

    def body(h, inp):
        lp, cache = inp
        hd = cfg.hd
        xn = _lnorm(h, lp["ln1"])
        q = L.linear(xn, lp["attn"]["wq"], impl).reshape(B, S, cfg.n_heads, hd)
        k = L.linear(xn, lp["attn"]["wk"], impl).reshape(B, S, cfg.n_kv_heads, hd)
        v = L.linear(xn, lp["attn"]["wv"], impl).reshape(B, S, cfg.n_kv_heads, hd)
        o = A.gqa_attention(q, k, v, causal=True, chunk=min(1024, S))
        h = h + L.linear(o.reshape(B, S, -1), lp["attn"]["wo"], impl)
        new_self = A.update_cache(cache["self"], k, v, lengths=lengths)
        ck = L.linear(enc, lp["cross"]["wk"], impl).reshape(B, -1, cfg.n_kv_heads, hd)
        cv = L.linear(enc, lp["cross"]["wv"], impl).reshape(B, -1, cfg.n_kv_heads, hd)
        xn = _lnorm(h, lp["ln_cross"])
        qc = L.linear(xn, lp["cross"]["wq"], impl).reshape(B, S, cfg.n_heads, hd)
        oc = A.gqa_attention(qc, ck, cv, causal=False, chunk=min(1024, ck.shape[1]))
        h = h + L.linear(oc.reshape(B, S, -1), lp["cross"]["wo"], impl)
        h = h + _mlp_fwd(_lnorm(h, lp["ln2"]), lp["mlp"], impl)
        new_cache = {
            "self": new_self,
            "cross": {"k": ck.astype(cache["cross"]["k"].dtype),
                      "v": cv.astype(cache["cross"]["v"].dtype)},
        }
        return h, new_cache

    x, new_caches = maybe_scan(body, x, (params["dec_layers"], caches), cfg.scan_layers)
    x = _lnorm(x, params["dec_ln"])
    head = _params.dense_weight(params["embed"]).T
    if lengths is None:
        x_last = x[:, -1:]
    else:  # per-slot last real position in a right-padded batch
        x_last = x[jnp.arange(B), jnp.clip(lengths - 1, 0, S - 1)][:, None]
    logits = jnp.dot(x_last, head.astype(x.dtype))
    return logits, new_caches


def decode_step(params, tokens, caches, cfg: ArchConfig, sctx: ShardCtx = ShardCtx()):
    impl = cfg.quant.impl if cfg.quant.enabled else "dense"
    B = tokens.shape[0]
    hd = cfg.hd
    pos = caches["self"].pos[0]  # (B,) per-slot decode positions (layer 0)
    x = _params.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = x + params["pos_embed"][jnp.clip(pos, 0, cfg.max_seq - 1)].astype(
        jnp.bfloat16
    )[:, None]

    def body(h, inp):
        lp, cache = inp
        xn = _lnorm(h, lp["ln1"])
        q = L.linear(xn, lp["attn"]["wq"], impl).reshape(B, 1, cfg.n_heads, hd)
        k = L.linear(xn, lp["attn"]["wk"], impl).reshape(B, 1, cfg.n_kv_heads, hd)
        v = L.linear(xn, lp["attn"]["wv"], impl).reshape(B, 1, cfg.n_kv_heads, hd)
        new_self = A.update_cache(cache["self"], k, v)
        o = A.decode_attention(q, new_self)
        h = h + L.linear(o.reshape(B, 1, -1), lp["attn"]["wo"], impl)
        xn = _lnorm(h, lp["ln_cross"])
        qc = L.linear(xn, lp["cross"]["wq"], impl).reshape(B, 1, cfg.n_heads, hd)
        crossc = A.KVCache(
            k=cache["cross"]["k"], v=cache["cross"]["v"],
            pos=jnp.full((B,), cache["cross"]["k"].shape[1], jnp.int32),
        )
        oc = A.decode_attention(qc, crossc)
        h = h + L.linear(oc.reshape(B, 1, -1), lp["cross"]["wo"], impl)
        h = h + _mlp_fwd(_lnorm(h, lp["ln2"]), lp["mlp"], impl)
        return h, {"self": new_self, "cross": cache["cross"]}

    x, new_caches = maybe_scan(body, x, (params["dec_layers"], caches), cfg.scan_layers)
    x = _lnorm(x, params["dec_ln"])
    head = _params.dense_weight(params["embed"]).T
    logits = jnp.dot(x, head.astype(x.dtype))
    return logits, new_caches
