"""Model-layer plumbing: init helpers, sharding context, PASM param surgery."""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import params as _params
from repro.core import pasm as _pasm

# tree-surgery treats either weight-shared container as one leaf (PASMTensor
# only appears in legacy trees; quantize_params emits PasmParams)
_CONTAINERS = (_params.PasmParams, _pasm.PASMTensor)

__all__ = [
    "ShardCtx",
    "trunc_normal",
    "quantize_params",
    "param_count",
    "Initializer",
    "maybe_scan",
]


def maybe_scan(body, carry, stacked, use_scan: bool):
    """``lax.scan`` or an unrolled python loop (same signature/results).

    The unrolled form exists for the dry-run's cost-analysis correction:
    XLA's cost model counts a while-loop body ONCE, so launch/dryrun.py
    lowers a small unrolled variant to solve for per-layer cost (A + L·B).
    """
    if use_scan:
        return jax.lax.scan(body, carry, stacked)
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    ys = []
    for i in range(n):
        sl = jax.tree.map(lambda x: x[i], stacked)
        carry, y = body(carry, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh-axis naming threaded through model code for sharding constraints.

    ``batch``: axes the batch dim shards over (("pod","data") multi-pod).
    ``model``: tensor-parallel axis name.  ``active``: False → all
    constraints are no-ops (single-device tests / examples).
    """

    batch: tuple = ("data",)
    model: str = "model"
    active: bool = False
    dp: int = 1  # DP degree = local MoE-dispatch groups (keeps sorts shard-local)

    def cs(self, x: jax.Array, *spec) -> jax.Array:
        if not self.active:
            return x
        return jax.lax.with_sharding_constraint(x, P(*spec))

    # common activation layouts
    def act_btd(self, x):  # (batch, seq, d_model)
        return self.cs(x, self.batch, None, None)

    def act_bthd(self, x):  # (batch, seq, heads, hd) — heads TP-sharded
        return self.cs(x, self.batch, None, self.model, None)

    def act_btf(self, x):  # (batch, seq, ff) — ff TP-sharded
        return self.cs(x, self.batch, None, self.model)


def trunc_normal(key, shape, std, dtype=jnp.float32):
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(
        dtype
    ) * jnp.asarray(std, dtype)


class Initializer:
    """Sequential PRNG splitter so init code reads linearly."""

    def __init__(self, key):
        self._key = key

    def key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def dense(self, shape, fan_in=None, dtype=jnp.float32):
        fan_in = fan_in or shape[0]
        return trunc_normal(self.key(), shape, fan_in ** -0.5, dtype)


def param_count(params: Any) -> int:
    """Logical parameter count (PASM leaves count their dense size)."""
    n = 0
    for leaf in jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, _CONTAINERS)
    ):
        if isinstance(leaf, _CONTAINERS):
            p = _params.as_params(leaf)
            n += int(np.prod(p._lead, dtype=np.int64) * np.prod(p.shape))
        else:
            n += leaf.size
    return n


# ---------------------------------------------------------------------------
# PASM parameter surgery: replace selected dense leaves with PasmParams
# ---------------------------------------------------------------------------

_EXCLUDE = re.compile(
    r"(norm|scale|bias|router|lam|A_log|ssm_D|dt_bias|conv|pos_embed)", re.IGNORECASE
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        for attr in ("key", "name", "idx"):
            v = getattr(p, attr, None)
            if v is not None:
                parts.append(str(v))
                break
        else:
            parts.append(str(p))
    return "/".join(parts)


def quantize_params(params: Any, cfg: ArchConfig, *, iters: int = 8) -> Any:
    """Apply the paper's weight-sharing to a model's parameter tree.

    Quantizes every ≥2-D dense leaf whose trailing-2-dim weight matrix is
    large enough (the paper's ``B ≪ N`` efficiency rule) and which isn't an
    excluded parameter class (norms/bias/router/... stay dense, paper §4).
    Stacked (scan-over-layers) leaves are quantized per layer via vmap.
    Emits :class:`~repro.core.params.PasmParams`; int4-eligible bins are
    packed, with the §3 reserved-zero-bin K-pad making odd reductions (odd
    ``d_model``) pack cleanly — the old direct-``pack_int4`` path errored on
    them.
    """
    q = cfg.quant
    if not q.enabled:
        return params

    def maybe_quantize(path, leaf):
        name = _path_str(path)
        if not isinstance(leaf, jax.Array) and not isinstance(leaf, jnp.ndarray):
            return leaf
        if leaf.ndim < 2 or _EXCLUDE.search(name):
            return leaf
        if "embed" in name.lower() and not q.quantize_embed:
            return leaf
        K, N = leaf.shape[-2], leaf.shape[-1]
        if K * N < q.min_weight_elems:
            return leaf
        p = _params.PasmParams.quantize(leaf, q.bins, groups=q.groups, iters=iters)
        if _pasm.bits_for_bins(q.bins) == 4:
            p = p.pack()
        return p

    return jax.tree_util.tree_map_with_path(maybe_quantize, params)


def weight_bytes(params: Any, dense_dtype_bytes: int = 2) -> dict:
    """HBM weight bytes: dense vs PASM-stored (for the memory roofline)."""
    dense = 0
    stored = 0
    for leaf in jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, _CONTAINERS)
    ):
        if isinstance(leaf, _CONTAINERS):
            p = _params.as_params(leaf)
            lead = int(np.prod(p._lead, dtype=np.int64))
            dense += lead * int(np.prod(p.shape)) * dense_dtype_bytes
            stored += p.nbytes_weights
        else:
            dense += leaf.size * dense_dtype_bytes
            stored += leaf.size * dense_dtype_bytes
    return {"dense": dense, "stored": stored, "ratio": dense / max(stored, 1)}
