"""RecurrentGemma-style hybrid: RG-LRU recurrent blocks + local attention.

Layer pattern (recurrent, recurrent, attention) tiled over depth
(recurrentgemma-2b: 26 layers = 8 scanned groups of 3 + a 2-layer recurrent
tail).  Local attention uses a ring-buffer KV cache of ``local_window`` slots
so the ``long_500k`` decode cell holds O(window) state, not O(S) —
sub-quadratic end to end (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Initializer, ShardCtx, maybe_scan
from repro.nn import attention as A
from repro.nn import layers as L
from repro.nn import rglru as RG

__all__ = ["init_params", "forward", "init_caches", "prefill", "decode_step"]


def _pattern(cfg: ArchConfig):
    pat = tuple(cfg.hybrid.pattern)
    n_groups = cfg.n_layers // len(pat)
    tail = cfg.n_layers - n_groups * len(pat)
    return pat, n_groups, tail


def _init_recurrent(cfg: ArchConfig, ini: Initializer) -> dict:
    D = cfg.d_model
    W = cfg.hybrid.lru_width or D
    return {
        "rec_norm": jnp.zeros((D,)),
        "rec_in": ini.dense((D, 2 * W)),  # [lru branch, gate branch]
        "conv_w": jax.random.normal(ini.key(), (cfg.hybrid.conv_width, W)) * 0.1,
        "conv_b": jnp.zeros((W,)),
        "w_a": ini.dense((W, W)),
        "b_a": jnp.zeros((W,)),
        "w_x": ini.dense((W, W)),
        "b_x": jnp.zeros((W,)),
        "lam": jnp.linspace(0.5, 4.0, W),  # Λ init → decay ∈ (~0.6, ~0.999)
        "rec_out": ini.dense((W, D), fan_in=W),
        "ffn_norm": jnp.zeros((D,)),
        "mlp": {
            "w1": ini.dense((D, cfg.d_ff)),
            "w3": ini.dense((D, cfg.d_ff)),
            "w2": ini.dense((cfg.d_ff, D), fan_in=cfg.d_ff),
        },
    }


def _init_attention(cfg: ArchConfig, ini: Initializer) -> dict:
    D, hd = cfg.d_model, cfg.hd
    return {
        "attn_norm": jnp.zeros((D,)),
        "attn": {
            "wq": ini.dense((D, cfg.n_heads * hd)),
            "wk": ini.dense((D, cfg.n_kv_heads * hd)),
            "wv": ini.dense((D, cfg.n_kv_heads * hd)),
            "wo": ini.dense((cfg.n_heads * hd, D)),
        },
        "ffn_norm": jnp.zeros((D,)),
        "mlp": {
            "w1": ini.dense((D, cfg.d_ff)),
            "w3": ini.dense((D, cfg.d_ff)),
            "w2": ini.dense((cfg.d_ff, D), fan_in=cfg.d_ff),
        },
    }


def _init_group(cfg: ArchConfig, ini: Initializer) -> dict:
    pat, _, _ = _pattern(cfg)
    g = {}
    for i, kind in enumerate(pat):
        g[f"l{i}"] = (
            _init_recurrent(cfg, ini) if kind == "recurrent" else _init_attention(cfg, ini)
        )
    return g


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    ini = Initializer(key)
    pat, n_groups, tail = _pattern(cfg)
    keys = jax.random.split(ini.key(), n_groups)
    params = {
        "embed": jax.random.normal(ini.key(), (cfg.vocab, cfg.d_model)) * 0.02,
        "groups": jax.vmap(lambda k: _init_group(cfg, Initializer(k)))(keys),
        "tail": [_init_recurrent(cfg, ini) for _ in range(tail)],
        "final_norm": jnp.zeros((cfg.d_model,)),
        "lm_head": ini.dense((cfg.d_model, cfg.vocab)),
    }
    if dtype != jnp.float32:
        params = jax.tree.map(lambda x: x.astype(dtype), params)
    return params


# ---------------------------------------------------------------------------
# block forwards (full sequence)
# ---------------------------------------------------------------------------


def _mlp(x, p, impl):
    return L.linear(L.swiglu(L.linear(x, p["w1"], impl), L.linear(x, p["w3"], impl)), p["w2"], impl)


def _recurrent_fwd(x, p, cfg, sctx, impl, h0=None):
    B, S, D = x.shape
    W = cfg.hybrid.lru_width or D
    xn = L.rms_norm(x, p["rec_norm"], cfg.norm_eps)
    branches = L.linear(xn, p["rec_in"], impl)
    lru_in, gate = branches[..., :W], branches[..., W:]
    lru_in = sctx.act_btf(lru_in)
    lru_in = RG.causal_conv1d(lru_in, p["conv_w"], p["conv_b"])
    y, h_last = RG.rg_lru_scan(lru_in, p, init_h=h0)
    y = y * jax.nn.gelu(gate)
    x = x + L.linear(y, p["rec_out"], impl)
    x = x + _mlp(L.rms_norm(x, p["ffn_norm"], cfg.norm_eps), p["mlp"], impl)
    return sctx.act_btd(x), h_last


def _attention_fwd(x, p, cfg, sctx, impl, cos, sin):
    B, S, D = x.shape
    hd = cfg.hd
    xn = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    ap = p["attn"]
    q = L.linear(xn, ap["wq"], impl).reshape(B, S, cfg.n_heads, hd)
    k = L.linear(xn, ap["wk"], impl).reshape(B, S, cfg.n_kv_heads, hd)
    v = L.linear(xn, ap["wv"], impl).reshape(B, S, cfg.n_kv_heads, hd)
    q, k = L.apply_rope(q, cos, sin), L.apply_rope(k, cos, sin)
    q = sctx.act_bthd(q)
    o = A.gqa_attention(
        q, k, v, causal=True, window=cfg.hybrid.local_window, chunk=min(1024, S)
    )
    x = x + L.linear(o.reshape(B, S, -1), ap["wo"], impl)
    x = x + _mlp(L.rms_norm(x, p["ffn_norm"], cfg.norm_eps), p["mlp"], impl)
    return sctx.act_btd(x), None


def _group_fwd(x, gp, cfg, sctx, impl, cos, sin):
    pat, _, _ = _pattern(cfg)
    for i, kind in enumerate(pat):
        if kind == "recurrent":
            x, _ = _recurrent_fwd(x, gp[f"l{i}"], cfg, sctx, impl)
        else:
            x, _ = _attention_fwd(x, gp[f"l{i}"], cfg, sctx, impl, cos, sin)
    return x


def forward(params, tokens, cfg: ArchConfig, sctx: ShardCtx = ShardCtx(), *, frontend_embeds=None):
    from repro.models.transformer import _embed_lookup

    x = _embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = sctx.act_btd(x)
    S = x.shape[1]
    cos, sin = L.rope(jnp.arange(S), cfg.hd, cfg.rope_theta)
    cos, sin = cos[None], sin[None]
    impl = cfg.quant.impl if cfg.quant.enabled else "dense"

    def body(h, gp):
        return _group_fwd(h, gp, cfg, sctx, impl, cos, sin), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = maybe_scan(body_fn, x, params["groups"], cfg.scan_layers)
    for p in params["tail"]:
        x, _ = _recurrent_fwd(x, p, cfg, sctx, impl)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.linear(x, params["lm_head"], impl)
    return sctx.cs(logits, sctx.batch, None, sctx.model), {}


# ---------------------------------------------------------------------------
# decode: ring-buffer local-attention cache + LRU/conv states
# ---------------------------------------------------------------------------


def init_caches(cfg: ArchConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    pat, n_groups, tail = _pattern(cfg)
    W = cfg.hybrid.lru_width or cfg.d_model
    win = min(cfg.hybrid.local_window, seq)
    rec = {
        "h": jnp.zeros((batch, W), jnp.float32),
        "conv": jnp.zeros((batch, cfg.hybrid.conv_width - 1, W), dtype),
    }
    attn = {
        "k": jnp.zeros((batch, win, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, win, cfg.n_kv_heads, cfg.hd), dtype),
        # absolute position per ring slot, per batch row (-1 = empty)
        "slot_pos": jnp.full((batch, win), -1, jnp.int32),
    }
    group = {}
    for i, kind in enumerate(pat):
        group[f"l{i}"] = dict(rec) if kind == "recurrent" else dict(attn)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape), group
    )
    return {
        "groups": stacked,
        "tail": [jax.tree.map(jnp.array, rec) for _ in range(tail)],
        "pos": jnp.zeros((batch,), jnp.int32),  # per-slot decode position
    }


def _recurrent_step(x, p, cfg, impl, cache):
    W = cfg.hybrid.lru_width or cfg.d_model
    xn = L.rms_norm(x, p["rec_norm"], cfg.norm_eps)
    branches = L.linear(xn, p["rec_in"], impl)
    lru_in, gate = branches[..., :W], branches[..., W:]
    c_out, new_win = RG.conv1d_decode_step(lru_in, p["conv_w"], p["conv_b"], cache["conv"])
    y, h_new = RG.rg_lru_decode_step(c_out, p, cache["h"])
    y = y * jax.nn.gelu(gate)
    x = x + L.linear(y, p["rec_out"], impl)
    x = x + _mlp(L.rms_norm(x, p["ffn_norm"], cfg.norm_eps), p["mlp"], impl)
    return x, {"h": h_new, "conv": new_win}


def _attention_step(x, p, cfg, impl, cache, pos, cos, sin):
    """x: (B, D) one token.  Ring-buffer local-window attention."""
    B = x.shape[0]
    hd = cfg.hd
    win = cache["k"].shape[1]
    xn = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    ap = p["attn"]
    q = L.linear(xn, ap["wq"], impl).reshape(B, 1, cfg.n_heads, hd)
    k = L.linear(xn, ap["wk"], impl).reshape(B, 1, cfg.n_kv_heads, hd)
    v = L.linear(xn, ap["wv"], impl).reshape(B, 1, cfg.n_kv_heads, hd)
    q, k = L.apply_rope(q, cos, sin), L.apply_rope(k, cos, sin)
    slot = pos % win  # (B,) — each batch row writes its own ring slot
    ck = jax.vmap(
        lambda b, n, si: jax.lax.dynamic_update_slice(b, n, (si, 0, 0))
    )(cache["k"], k.astype(cache["k"].dtype), slot)
    cv = jax.vmap(
        lambda b, n, si: jax.lax.dynamic_update_slice(b, n, (si, 0, 0))
    )(cache["v"], v.astype(cache["v"].dtype), slot)
    spos = jax.vmap(
        lambda b, p, si: jax.lax.dynamic_update_slice(b, p[None], (si,))
    )(cache["slot_pos"], pos, slot)
    # masked attention over the ring buffer (mask invalid / out-of-window)
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, cfg.n_kv_heads, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, ck, preferred_element_type=jnp.float32) * hd ** -0.5
    valid = (spos >= 0) & (spos >= pos[:, None] - win + 1) & (spos <= pos[:, None])
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    pweights = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", pweights.astype(cv.dtype), cv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, cfg.n_heads * hd).astype(x.dtype)
    x = x + L.linear(o, ap["wo"], impl)
    x = x + _mlp(L.rms_norm(x, p["ffn_norm"], cfg.norm_eps), p["mlp"], impl)
    return x, {"k": ck, "v": cv, "slot_pos": spos}


def decode_step(params, tokens, caches, cfg: ArchConfig, sctx: ShardCtx = ShardCtx()):
    from repro.models.transformer import _embed_lookup

    pat, n_groups, tail = _pattern(cfg)
    pos = caches["pos"]  # (B,) per-slot positions
    x = _embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)[:, 0]
    cos, sin = L.rope(pos, cfg.hd, cfg.rope_theta)
    cos, sin = cos[:, None], sin[:, None]  # (B, 1, hd/2): per-slot rope
    impl = cfg.quant.impl if cfg.quant.enabled else "dense"

    def body(h, inp):
        gp, gc = inp
        new_gc = {}
        for i, kind in enumerate(pat):
            if kind == "recurrent":
                h, new_gc[f"l{i}"] = _recurrent_step(h, gp[f"l{i}"], cfg, impl, gc[f"l{i}"])
            else:
                h, new_gc[f"l{i}"] = _attention_step(
                    h, gp[f"l{i}"], cfg, impl, gc[f"l{i}"], pos, cos, sin
                )
        return h, new_gc

    x, new_groups = maybe_scan(body, x, (params["groups"], caches["groups"]), cfg.scan_layers)
    new_tail = []
    for p, c in zip(params["tail"], caches["tail"]):
        x, nc = _recurrent_step(x, p, cfg, impl, c)
        new_tail.append(nc)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.linear(x, params["lm_head"], impl)[:, None, :]
    return logits, {"groups": new_groups, "tail": new_tail, "pos": pos + 1}


def prefill(params, tokens, caches, cfg: ArchConfig, sctx: ShardCtx = ShardCtx(), **kw):
    """Prompt pass: full-sequence forward while extracting decode states.

    Right-padded prompts (``lengths=``) are NOT supported: the RG-LRU scan
    folds every input token into recurrent state, so pad tokens would corrupt
    it.  Serve hybrid slots with exact-length prompts (bucket granularity 1).
    """
    from repro.models.transformer import _embed_lookup

    if kw.get("lengths") is not None:
        raise ValueError("hybrid.prefill: padded prompts (lengths=) unsupported — "
                         "the RG-LRU scan would absorb pad tokens into state")
    pat, n_groups, tail = _pattern(cfg)
    x = _embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = sctx.act_btd(x)
    B, S, D = x.shape
    cos, sin = L.rope(jnp.arange(S), cfg.hd, cfg.rope_theta)
    cos, sin = cos[None], sin[None]
    impl = cfg.quant.impl if cfg.quant.enabled else "dense"

    def fill_attn_cache(k, v, cache):
        """Write the last `win` positions into the ring buffer."""
        winl = cache["k"].shape[1]
        kw_ = k[:, -winl:]
        vw = v[:, -winl:]
        n = kw_.shape[1]
        pos0 = S - n
        slots = (pos0 + jnp.arange(n)) % winl
        ck = cache["k"].at[:, slots].set(kw_.astype(cache["k"].dtype))
        cv = cache["v"].at[:, slots].set(vw.astype(cache["v"].dtype))
        spos = cache["slot_pos"].at[:, slots].set(pos0 + jnp.arange(n))
        return {"k": ck, "v": cv, "slot_pos": spos}

    def body(h, inp):
        gp, gc = inp
        new_gc = {}
        for i, kind in enumerate(pat):
            p = gp[f"l{i}"]
            if kind == "recurrent":
                W = cfg.hybrid.lru_width or cfg.d_model
                xn = L.rms_norm(h, p["rec_norm"], cfg.norm_eps)
                branches = L.linear(xn, p["rec_in"], impl)
                lru_in, gate = branches[..., :W], branches[..., W:]
                conv_tail = lru_in[:, -(cfg.hybrid.conv_width - 1):, :]
                lru_conv = RG.causal_conv1d(lru_in, p["conv_w"], p["conv_b"])
                y, h_last = RG.rg_lru_scan(lru_conv, p)
                y = y * jax.nn.gelu(gate)
                h = h + L.linear(y, p["rec_out"], impl)
                h = h + _mlp(L.rms_norm(h, p["ffn_norm"], cfg.norm_eps), p["mlp"], impl)
                new_gc[f"l{i}"] = {"h": h_last, "conv": conv_tail.astype(gc[f"l{i}"]["conv"].dtype)}
            else:
                hd = cfg.hd
                xn = L.rms_norm(h, p["attn_norm"], cfg.norm_eps)
                ap = p["attn"]
                q = L.linear(xn, ap["wq"], impl).reshape(B, S, cfg.n_heads, hd)
                k = L.linear(xn, ap["wk"], impl).reshape(B, S, cfg.n_kv_heads, hd)
                v = L.linear(xn, ap["wv"], impl).reshape(B, S, cfg.n_kv_heads, hd)
                q, k = L.apply_rope(q, cos, sin), L.apply_rope(k, cos, sin)
                o = A.gqa_attention(q, k, v, causal=True,
                                    window=cfg.hybrid.local_window, chunk=min(1024, S))
                h = h + L.linear(o.reshape(B, S, -1), ap["wo"], impl)
                h = h + _mlp(L.rms_norm(h, p["ffn_norm"], cfg.norm_eps), p["mlp"], impl)
                new_gc[f"l{i}"] = fill_attn_cache(k, v, gc[f"l{i}"])
        return h, new_gc

    x, new_groups = maybe_scan(body, x, (params["groups"], caches["groups"]), cfg.scan_layers)
    new_tail = []
    for p, c in zip(params["tail"], caches["tail"]):
        W = cfg.hybrid.lru_width or cfg.d_model
        xn = L.rms_norm(x, p["rec_norm"], cfg.norm_eps)
        branches = L.linear(xn, p["rec_in"], impl)
        lru_in, gate = branches[..., :W], branches[..., W:]
        conv_tail = lru_in[:, -(cfg.hybrid.conv_width - 1):, :]
        lru_conv = RG.causal_conv1d(lru_in, p["conv_w"], p["conv_b"])
        y, h_last = RG.rg_lru_scan(lru_conv, p)
        y = y * jax.nn.gelu(gate)
        x = x + L.linear(y, p["rec_out"], impl)
        x = x + _mlp(L.rms_norm(x, p["ffn_norm"], cfg.norm_eps), p["mlp"], impl)
        new_tail.append({"h": h_last, "conv": conv_tail.astype(c["conv"].dtype)})
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.linear(x[:, -1:], params["lm_head"], impl)
    return logits, {"groups": new_groups, "tail": new_tail, "pos": caches["pos"] + S}
