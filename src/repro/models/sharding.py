"""Sharding rules: parameter/optimizer/cache PartitionSpecs by path.

Megatron-style TP on the ``model`` axis (column→row pairs per block), EP for
MoE experts, DP over ``data`` (and ``pod``), ZeRO-1 for optimizer states.
Rules are path-regex driven so the same table covers dense params and the
idx/codebook leaves PASM quantization swaps in (DESIGN.md §4).

The CNN conv stack has its own rule set (:func:`conv_param_pspecs` /
:func:`conv_input_pspecs` / :func:`conv_batch_pad`): output channels over
``model``, image batches over ``data``, codebooks replicated — matching the
``conv2d(mesh=)`` sharded dispatch axis-for-axis (DESIGN.md §4.1).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

__all__ = [
    "param_pspecs",
    "cache_pspecs",
    "batch_axes",
    "input_pspecs",
    "opt_state_pspecs",
    "conv_param_pspecs",
    "conv_input_pspecs",
    "conv_batch_pad",
]

MODEL = "model"
DATA = "data"


def batch_axes(multi_pod: bool, global_batch: int, n_data: int = 16, n_pod: int = 2):
    """Axes the batch dim shards over; () when the batch is too small (long_500k)."""
    total = n_data * (n_pod if multi_pod else 1)
    if global_batch % total == 0:
        return ("pod", "data") if multi_pod else ("data",)
    if global_batch % n_data == 0:
        return ("data",)
    return ()


# rules: regex over the flattened path → spec for the TRAILING dims.
# Earlier rules win.  Leading (scan/expert-stack) dims are padded with None.
_RULES: list[tuple[str, tuple]] = [
    # PASM leaves inherit their parent weight's layout (idx) / replicate (codebook)
    (r"codebook$", ("__REPL__",)),
    # MoE experts: 2-D sharding — E over model (EP), FFN hidden over data
    # (w1/w3 (E, D, Fe): Fe sharded; w2 (E, Fe, D): Fe sharded)
    (r"moe/w[13](/idx)?$", (MODEL, None, "data")),
    (r"moe/w2(/idx)?$", (MODEL, "data", None)),
    # column-parallel (output dim sharded)
    (r"(wq|wk|wv|w1|w3|shared_w1|shared_w3|rec_in|in_proj|w_a|w_x)(/idx)?$", (None, MODEL)),
    # row-parallel (input dim sharded)
    (r"(wo|w2|shared_w2|rec_out|out_proj)(/idx)?$", (MODEL, None)),
    # embeddings: vocab-sharded; lm_head column-parallel
    (r"embed(/idx)?$", (MODEL, None)),
    (r"lm_head(/idx)?$", (None, MODEL)),
    (r"vproj(/idx)?$", (None, None)),
    (r"pos_embed$", (None, None)),
    # depthwise conv / gates / per-channel vectors: channel dim sharded
    (r"conv_w$", (None, MODEL)),
    (r"(conv_b|lam|b_a|b_x|ssm_norm)$", (MODEL,)),
    (r"router$", (None, None)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        for attr in ("key", "name", "idx"):
            v = getattr(p, attr, None)
            if v is not None:
                parts.append(str(v))
                break
        else:
            parts.append(str(p))
    return "/".join(parts)


def _spec_for(path_s: str, ndim: int) -> P:
    for pat, tail in _RULES:
        if re.search(pat, path_s):
            if tail == ("__REPL__",):
                return P(*([None] * ndim))
            pad = ndim - len(tail)
            if pad < 0:  # leaf smaller than rule (e.g. smoke dims) — replicate
                return P(*([None] * ndim))
            return P(*([None] * pad + list(tail)))
    return P(*([None] * ndim))  # norms, biases, scalars → replicated


def _divisible(shape, spec: P, axis_sizes: dict) -> bool:
    for dim, ax in zip(shape, spec):
        if ax is None:
            continue
        size = np.prod([axis_sizes[a] for a in (ax if isinstance(ax, tuple) else (ax,))])
        if dim % size:
            return False
    return True


def param_pspecs(params: Any, axis_sizes: dict) -> Any:
    """PartitionSpec tree matching ``params`` (PASMTensor descends into leaves).

    Falls back to replication when a dim doesn't divide the mesh axis (small
    smoke shapes) — full configs shard cleanly by construction.
    """

    def one(path, leaf):
        s = _spec_for(_path_str(path), leaf.ndim)
        if not _divisible(leaf.shape, s, axis_sizes):
            return P(*([None] * leaf.ndim))
        return s

    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_pspecs(params: Any, pspecs: Any, axis_sizes: dict) -> Any:
    """ZeRO-1: Adam moments additionally shard their largest replicated dim
    over ``data``.  Falls back to the param spec when nothing divides."""

    n_data = axis_sizes.get("data", 1)

    def used_axes(spec):
        out = set()
        for d in spec:
            if d is None:
                continue
            out.update(d if isinstance(d, tuple) else (d,))
        return out

    def one(leaf, spec):
        if leaf.ndim == 0:
            return P()
        if "data" in used_axes(spec):
            return spec  # already data-sharded (2-D expert sharding / FSDP)
        dims = list(spec) + [None] * (leaf.ndim - len(spec))
        # find the largest dim not already sharded that divides n_data
        cands = [
            (leaf.shape[i], i)
            for i in range(leaf.ndim)
            if dims[i] is None and leaf.shape[i] % n_data == 0 and leaf.shape[i] >= n_data
        ]
        if not cands:
            return P(*dims)
        _, i = max(cands)
        dims[i] = "data"
        return P(*dims)

    return jax.tree.map(one, params, pspecs)


def cache_pspecs(cfg: ArchConfig, caches: Any, axis_sizes: dict, batch: tuple) -> Any:
    """KV/state cache specs.  KV heads shard over ``model`` when divisible,
    else the sequence dim takes ``model`` (DESIGN.md §4)."""
    tp = axis_sizes.get(MODEL, 1)
    kv_on_model = cfg.n_kv_heads and cfg.n_kv_heads % tp == 0

    def one(path, leaf):
        name = _path_str(path)
        nd = leaf.ndim
        if nd >= 4 and re.search(r"(^|/)(k|v)(_q)?$", name):
            # (L?, B, S, KV, hd)
            dims = [None] * nd
            dims[-4] = batch if batch else None
            if kv_on_model:
                dims[-2] = MODEL
            elif leaf.shape[-3] % tp == 0:
                dims[-3] = MODEL
            return P(*dims)
        if nd >= 3 and re.search(r"(^|/)(k|v)_scale$", name):
            # (L?, B, S, KV) — mirror the cache layout on S/KV
            dims = [None] * nd
            dims[-3] = batch if batch else None
            if kv_on_model:
                dims[-1] = MODEL
            elif leaf.shape[-2] % tp == 0:
                dims[-2] = MODEL
            return P(*dims)
        if re.search(r"ssm$", name) and nd >= 4:
            # (L, B, H, P, N): shard P (head_dim) when divisible
            dims = [None] * nd
            dims[-4] = batch if batch else None
            if leaf.shape[-2] % tp == 0:
                dims[-2] = MODEL
            return P(*dims)
        if re.search(r"(conv$|^h$|/h$)", name) and nd >= 2:
            # recurrent states: (.., B, .., channels) — shard channels on model
            dims = [None] * nd
            if leaf.shape[-1] % tp == 0 and leaf.shape[-1] >= tp:
                dims[-1] = MODEL
            return P(*dims)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, caches)


def input_pspecs(specs: dict, batch: tuple) -> dict:
    """Token/label/frontend inputs: batch-sharded on dim 0, replicated elsewhere."""
    out = {}
    for k, v in specs.items():
        dims = [batch if batch else None] + [None] * (len(v.shape) - 1)
        out[k] = P(*dims)
    return out


# ---------------------------------------------------------------------------
# CNN conv stack (models/cnn.py): ConvParams dictionaries + head
# ---------------------------------------------------------------------------


def conv_param_pspecs(params: Any, axis_sizes: dict) -> Any:
    """PartitionSpecs for the CNN param dict (``{"conv": [ConvParams...],
    "head": {...}}``) — the sharded conv dispatch's weight placement.

    The axis mapping mirrors ``conv2d(mesh=)`` (DESIGN.md §4.1): the GEMM N
    dimension (``c_out``) shards over ``model`` — that is dim 0 of a 4-D
    ``kernel``/``idx`` leaf ``(c_out, c_in, ky, kx)`` but dim 1 of a packed
    2-D ``idx (Kp//2, c_out)`` (the K-major int4 pairing stays intact) —
    bias and the head follow it, and codebooks replicate (≤ 1 KiB, resident
    per device; the paper's per-layer dictionary is mesh-wide state).  A
    ``c_out`` that does not divide ``model`` falls back to replicating that
    leaf, exactly the sharded dispatch's N-replicated rule, so placement
    never disagrees with compute.

    Activations are NOT in this table: each sharded conv all-gathers its
    ``model``-sharded output channels inside the kernel's shard_map body
    (``gather_output=True``, the epilogue-fused collective), so conv
    activations leave every layer model-replicated and ``data``-sharded on
    the batch — the next layer's image operand needs no resharding.
    """

    def one(path, leaf):
        name = _path_str(path)
        nd = leaf.ndim
        dims = [None] * nd
        if re.search(r"codebook$", name):
            pass  # per-layer dictionary: replicated everywhere
        elif re.search(r"(kernel|idx)$", name) and nd == 4:
            dims[0] = MODEL  # (c_out, c_in, ky, kx): output channels
        elif re.search(r"idx$", name) and nd == 2:
            dims[1] = MODEL  # packed (Kp//2, c_out): output channels minor
        elif re.search(r"(bias|head/b)$", name) and nd == 1:
            dims[0] = MODEL  # per-output-channel vectors ride the N sharding
        elif re.search(r"head/w$", name) and nd == 2:
            dims[1] = MODEL  # classifier column-parallel
        s = P(*dims)
        if not _divisible(leaf.shape, s, axis_sizes):
            return P(*([None] * nd))
        return s

    return jax.tree_util.tree_map_with_path(one, params)


def conv_input_pspecs(ndim: int = 4) -> P:
    """Image batches shard over ``data`` on the leading batch dim (both
    NCHW and NHWC keep batch leading)."""
    return P(DATA, *([None] * (ndim - 1)))


def conv_batch_pad(batch: int, n_data: int) -> int:
    """Zero-image rows to append so an uneven batch shards over ``data``.

    ``conv2d(mesh=)`` applies this remainder padding internally (and slices
    the pad rows back off); callers placing inputs ahead of time use it to
    build the padded global batch.
    """
    return -batch % n_data
