"""Decoder-only transformer LM: dense & MoE, GQA(+qk-norm), scan-over-layers.

Covers assigned archs: qwen3-32b, nemotron-4-340b, phi3-medium-14b,
stablelm-3b, deepseek-moe-16b, kimi-k2-1t-a32b, and the LM backbone of
internvl2-26b (``frontend="vit"``).  Per-layer parameters are stacked on a
leading L axis and executed with ``lax.scan`` so the HLO stays O(1 layer)
regardless of depth (DESIGN.md §7); PASM quantization swaps any large dense
leaf for a :class:`~repro.core.params.PasmParams` and every matmul
dispatches through ``nn.layers.linear`` — this module holds zero container
``isinstance`` of its own.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import params as _params
from repro.models.common import Initializer, ShardCtx, maybe_scan
from repro.nn import attention as A
from repro.nn import layers as L
from repro.nn import moe as M

__all__ = [
    "init_params",
    "forward",
    "init_caches",
    "prefill",
    "decode_step",
]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_attn(cfg: ArchConfig, ini: Initializer) -> dict:
    D, hd = cfg.d_model, cfg.hd
    p = {
        "wq": ini.dense((D, cfg.n_heads * hd)),
        "wk": ini.dense((D, cfg.n_kv_heads * hd)),
        "wv": ini.dense((D, cfg.n_kv_heads * hd)),
        "wo": ini.dense((cfg.n_heads * hd, D)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,))
        p["k_norm"] = jnp.zeros((hd,))
    return p


def _init_dense_ffn(cfg: ArchConfig, ini: Initializer, d_ff: Optional[int] = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    p = {"w1": ini.dense((D, F)), "w2": ini.dense((F, D), fan_in=F)}
    if cfg.act == "swiglu":
        p["w3"] = ini.dense((D, F))
    return p


def _init_moe(cfg: ArchConfig, ini: Initializer) -> dict:
    m = cfg.moe
    D = cfg.d_model
    E, Fe = m.n_experts, m.d_expert
    p = {
        "router": ini.dense((D, E)),
        "w1": ini.dense((E, D, Fe), fan_in=D),
        "w3": ini.dense((E, D, Fe), fan_in=D),
        "w2": ini.dense((E, Fe, D), fan_in=Fe),
    }
    if m.n_shared:
        Fs = m.d_shared * m.n_shared
        p["shared_w1"] = ini.dense((D, Fs))
        p["shared_w3"] = ini.dense((D, Fs))
        p["shared_w2"] = ini.dense((Fs, D), fan_in=Fs)
    return p


def _init_layer(cfg: ArchConfig, ini: Initializer, moe: bool) -> dict:
    D = cfg.d_model
    p = {
        "attn_norm": jnp.zeros((D,)),
        "ffn_norm": jnp.zeros((D,)),
        "attn": _init_attn(cfg, ini),
    }
    if moe:
        p["moe"] = _init_moe(cfg, ini)
    else:
        p["mlp"] = _init_dense_ffn(cfg, ini)
    return p


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    ini = Initializer(key)
    D, V = cfg.d_model, cfg.vocab
    params: dict = {"embed": trunc_embed(ini, V, D)}

    moe_on = bool(cfg.moe and cfg.moe.n_experts)
    n_dense = min(cfg.moe.first_dense_layers, cfg.n_layers) if moe_on else 0
    n_scan = cfg.n_layers - n_dense

    if n_dense:
        params["dense_layers"] = [
            _init_layer(cfg, ini, moe=False) for _ in range(n_dense)
        ]

    # stacked layers: vmap the per-layer init over a key batch
    keys = jax.random.split(ini.key(), n_scan)

    def one(k):
        return _init_layer(cfg, Initializer(k), moe=moe_on)

    params["layers"] = jax.vmap(one)(keys)
    params["final_norm"] = jnp.zeros((D,))
    if not cfg.tie_embeddings:
        params["lm_head"] = ini.dense((D, V))
    if cfg.frontend == "vit":
        params["vproj"] = ini.dense((cfg.frontend_dim, D))
    if dtype != jnp.float32:
        params = jax.tree.map(lambda x: x.astype(dtype), params)
    return params


def trunc_embed(ini: Initializer, V: int, D: int):
    return jax.random.normal(ini.key(), (V, D), jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# forward pieces
# ---------------------------------------------------------------------------


def _embed_lookup(w, tokens: jax.Array) -> jax.Array:
    # quantized tables gather uint8 index rows + one dictionary dereference
    return _params.embed_lookup(w, tokens)


def _lm_head(params: dict, cfg: ArchConfig):
    """The ``(D, V)`` head matrix: tied heads dequantize the embedding once.

    Kernels compute ``x @ W``, not ``x @ Wᵀ``, so the tied head takes the
    logical dense matrix (a no-op view for dense tables) and transposes it
    at the call site; untied heads pass their leaf straight to ``linear``.
    """
    if cfg.tie_embeddings:
        return _params.dense_weight(params["embed"]).T
    return params["lm_head"]


def _attention_block(
    x, p, cfg: ArchConfig, sctx: ShardCtx, cos, sin, *, cache=None, impl: str,
    lengths=None,
):
    B, S, D = x.shape
    hd = cfg.hd
    q = L.linear(x, p["wq"], impl).reshape(B, S, cfg.n_heads, hd)
    k = L.linear(x, p["wk"], impl).reshape(B, S, cfg.n_kv_heads, hd)
    v = L.linear(x, p["wv"], impl).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    q, k, v = sctx.act_bthd(q), sctx.cs(k, sctx.batch, None, None, None), sctx.cs(
        v, sctx.batch, None, None, None
    )
    new_cache = None
    if cache is not None:
        quant_cache = isinstance(cache, A.QuantKVCache)
        new_cache = (
            A.update_quant_cache(cache, k, v, lengths=lengths)
            if quant_cache
            else A.update_cache(cache, k, v, lengths=lengths)
        )
        if S == 1:
            o = (
                A.decode_attention_quant(q, new_cache)
                if quant_cache
                else A.decode_attention(q, new_cache)
            )
        else:
            # prefill: attend within the freshly written prefix
            o = A.gqa_attention(q, k, v, causal=True, chunk=min(cfg.attn_chunk, S))
    else:
        o = A.gqa_attention(q, k, v, causal=True, chunk=min(cfg.attn_chunk, S))
    o = sctx.act_bthd(o)
    y = L.linear(o.reshape(B, S, cfg.n_heads * hd), p["wo"], impl)
    return sctx.act_btd(y), new_cache


def _ffn_block(x, p, cfg: ArchConfig, sctx: ShardCtx, impl: str, dropless: bool = False):
    aux = {}
    B, S, D = x.shape
    if "moe" in p:
        y, aux = M.moe_ffn(
            x.reshape(B * S, D),
            p["moe"],
            cfg.moe,
            act=cfg.act,
            impl=impl,
            constrain=(lambda a, s: sctx.cs(a, *s)) if sctx.active else (lambda a, s: a),
            ep_spec=(sctx.model, None, None),
            dropless=dropless,
            n_groups=sctx.dp,
            group_spec=(sctx.batch if sctx.batch else (None,),),
        )
        y = y.reshape(B, S, D)
    else:
        mp = p["mlp"]
        if cfg.act == "swiglu":
            h = L.swiglu(L.linear(x, mp["w1"], impl), L.linear(x, mp["w3"], impl))
        elif cfg.act == "sq_relu":
            h = L.sq_relu(L.linear(x, mp["w1"], impl))
        else:
            h = L.gelu_ffn_act(L.linear(x, mp["w1"], impl))
        h = sctx.act_btf(h)
        y = L.linear(h, mp["w2"], impl)
    return sctx.act_btd(y), aux


def _layer_fwd(x, p, cfg, sctx, cos, sin, cache=None, impl="dense", dropless=False,
               lengths=None):
    h, new_cache = _attention_block(
        L.rms_norm(x, p["attn_norm"], cfg.norm_eps), p["attn"], cfg, sctx, cos, sin,
        cache=cache, impl=impl, lengths=lengths,
    )
    x = x + h
    h, aux = _ffn_block(
        L.rms_norm(x, p["ffn_norm"], cfg.norm_eps), p, cfg, sctx, impl, dropless
    )
    return x + h, new_cache, aux


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def _prep_inputs(params, cfg, sctx, tokens, frontend_embeds):
    x = _embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    n_prefix = 0
    if cfg.frontend == "vit" and frontend_embeds is not None:
        pe = L.linear(frontend_embeds.astype(jnp.bfloat16), params["vproj"], "dense")
        x = jnp.concatenate([pe, x], axis=1)
        n_prefix = pe.shape[1]
    return sctx.act_btd(x), n_prefix


def forward(
    params: dict,
    tokens: jax.Array,
    cfg: ArchConfig,
    sctx: ShardCtx = ShardCtx(),
    *,
    frontend_embeds: Optional[jax.Array] = None,
) -> tuple[jax.Array, dict]:
    """Full forward (training / prefill-style).  Returns (logits, aux)."""
    x, n_prefix = _prep_inputs(params, cfg, sctx, tokens, frontend_embeds)
    B, S, D = x.shape
    cos, sin = L.rope(jnp.arange(S), cfg.hd, cfg.rope_theta)
    cos, sin = cos[None], sin[None]

    impl = cfg.quant.impl if cfg.quant.enabled else "dense"
    aux_sum = {"moe_load_balance": jnp.zeros((), jnp.float32),
               "moe_drop_frac": jnp.zeros((), jnp.float32)}

    for p in params.get("dense_layers", []):
        x, _, _ = _layer_fwd(x, p, cfg, sctx, cos, sin, impl=impl)

    def body(carry, lp):
        h, aux = carry
        h, _, a = _layer_fwd(h, lp, cfg, sctx, cos, sin, impl=impl)
        for k in aux:
            aux = dict(aux)
            aux[k] = aux[k] + a.get(k, 0.0)
        return (h, aux), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux_sum), _ = maybe_scan(body_fn, (x, aux_sum), params["layers"], cfg.scan_layers)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.linear(x, _lm_head(params, cfg), "dense" if cfg.tie_embeddings else impl)
    logits = sctx.cs(logits, sctx.batch, None, sctx.model)
    if n_prefix:
        logits = logits[:, n_prefix:]
    return logits, aux_sum


def init_caches(cfg: ArchConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    """Stacked KV caches for the scanned layers (+ list for dense layers)."""
    moe_on = bool(cfg.moe and cfg.moe.n_experts)
    n_dense = min(cfg.moe.first_dense_layers, cfg.n_layers) if moe_on else 0
    n_scan = cfg.n_layers - n_dense
    if cfg.quant.enabled and cfg.quant.kv_bits == 8:
        one = lambda: A.init_quant_kv_cache(batch, seq, cfg.n_kv_heads, cfg.hd)
    else:
        one = lambda: A.init_kv_cache(batch, seq, cfg.n_kv_heads, cfg.hd, dtype)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *[one() for _ in range(n_scan)]) \
        if n_scan > 1 else jax.tree.map(lambda x: x[None], one())
    return {"dense": [one() for _ in range(n_dense)], "scan": stacked}


def decode_step(
    params: dict,
    tokens: jax.Array,  # (B, 1)
    caches,
    cfg: ArchConfig,
    sctx: ShardCtx = ShardCtx(),
):
    """One autoregressive step against the KV caches.  Returns (logits, caches)."""
    x = _embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = sctx.act_btd(x)
    # layer 0 of the scan stack carries the (B,) per-slot counters — every
    # layer advances in lockstep, so one layer's vector positions all slots
    pos = caches["scan"].pos[0]
    cos, sin = L.rope(pos, cfg.hd, cfg.rope_theta)
    cos, sin = cos[:, None], sin[:, None]  # (B, 1, hd/2): per-slot rope
    impl = cfg.quant.impl if cfg.quant.enabled else "dense"

    new_dense = []
    for p, c in zip(params.get("dense_layers", []), caches["dense"]):
        x, nc, _ = _layer_fwd(x, p, cfg, sctx, cos, sin, cache=c, impl=impl, dropless=True)
        new_dense.append(nc)

    # NOTE [§Perf iteration qwen-decode/2]: a cache-in-carry variant
    # (dynamic_update_index on the stacked cache) was measured: it proves
    # in-place aliasing (temp 2.35 → 0.29 GiB/dev) but XLA's cost model
    # charges the full stacked-cache operand per update (bytes 8.8e9 →
    # 4.7e10, an accounting artifact).  The ys-emission form below is kept:
    # XLA aliases scan ys with xs buffers, and the cost model measures it
    # faithfully.
    def body(h, inp):
        lp, cache = inp
        h, nc, _ = _layer_fwd(h, lp, cfg, sctx, cos, sin, cache=cache, impl=impl, dropless=True)
        return h, nc

    x, new_scan = maybe_scan(body, x, (params["layers"], caches["scan"]), cfg.scan_layers)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.linear(x, _lm_head(params, cfg), "dense" if cfg.tie_embeddings else impl)
    return logits, {"dense": new_dense, "scan": new_scan}


def prefill(
    params: dict,
    tokens: jax.Array,
    caches,
    cfg: ArchConfig,
    sctx: ShardCtx = ShardCtx(),
    *,
    lengths: Optional[jax.Array] = None,
    frontend_embeds: Optional[jax.Array] = None,
):
    """Run the prompt through the model, filling caches.  Returns (logits, caches).

    ``lengths`` (B,) marks per-slot REAL prompt lengths for right-padded
    batches: cache counters advance by ``lengths`` (pad rows beyond each
    slot's length are never valid to decode attention), and the returned
    logits are each slot's LAST REAL position, not column S-1.  ``None``
    keeps the full-length semantics (every slot is exactly S tokens).
    """
    x, n_prefix = _prep_inputs(params, cfg, sctx, tokens, frontend_embeds)
    B, S, D = x.shape
    cos, sin = L.rope(jnp.arange(S), cfg.hd, cfg.rope_theta)
    cos, sin = cos[None], sin[None]
    impl = cfg.quant.impl if cfg.quant.enabled else "dense"
    eff_lengths = None if lengths is None else lengths + n_prefix

    new_dense = []
    for p, c in zip(params.get("dense_layers", []), caches["dense"]):
        x, nc, _ = _layer_fwd(x, p, cfg, sctx, cos, sin, cache=c, impl=impl,
                              dropless=True, lengths=eff_lengths)
        new_dense.append(nc)

    def body(h, inp):
        lp, cache = inp
        h, nc, _ = _layer_fwd(h, lp, cfg, sctx, cos, sin, cache=cache, impl=impl,
                              dropless=True, lengths=eff_lengths)
        return h, nc

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, new_scan = maybe_scan(body_fn, x, (params["layers"], caches["scan"]), cfg.scan_layers)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if eff_lengths is None:
        x_last = x[:, -1:]
    else:
        x_last = x[jnp.arange(B), jnp.clip(eff_lengths - 1, 0, S - 1)][:, None]
    logits = L.linear(x_last, _lm_head(params, cfg), "dense" if cfg.tie_embeddings else impl)
    return logits, {"dense": new_dense, "scan": new_scan}
