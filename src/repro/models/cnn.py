"""AlexNet-style CNN on the weight-shared conv accelerator (DESIGN.md §3).

The paper evaluates ONE AlexNet conv layer; this model stacks the same
accelerator into the full network shape it was drawn from: conv/ReLU/pool
stages, each conv carrying its own PASM dictionary (per-layer codebooks, the
paper's one-dictionary-per-layer rule), followed by a dense classifier head
(fully-connected layers are outside the paper's conv accelerator and stay
dense).  Every conv executes through :func:`repro.core.conv` on the batched
im2col → Pallas GEMM path, so the whole forward pass runs the production
kernels end-to-end.

Usage (see also ``examples/paper_conv.py`` and ``benchmarks/conv_bench.py``)::

    cfg = get_cnn_config("alexnet", smoke=True)
    params = cnn.init_params(cfg, key)          # dense master weights
    qparams = cnn.quantize(params, cfg)         # per-layer k-means codebooks
    logits = cnn.forward(qparams, images, cfg)  # (B, classes) via Pallas
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.alexnet_conv import CNNConfig
from repro.core import conv as _conv
from repro.models.common import Initializer

__all__ = ["layer_specs", "feature_shape", "init_params", "quantize", "forward",
           "forward_dense"]


def _geometry(cfg: CNNConfig) -> tuple:
    """Resolve per-stage ``(ConvSpec, pool)`` plus the final (C, H, W)."""
    C, H, W = cfg.in_chw
    stages = []
    for l in cfg.layers:
        spec = _conv.ConvSpec(IH=H, IW=W, C=C, KY=l.k, KX=l.k, M=l.c_out,
                              stride=l.stride)
        H, W = _conv.out_hw(spec)
        if l.pool > 1:
            H, W = H // l.pool, W // l.pool
        C = l.c_out
        stages.append((spec, l.pool))
    return stages, (C, H, W)


def layer_specs(cfg: CNNConfig) -> list:
    """Per-stage ``(ConvSpec, pool)`` resolved from the input geometry."""
    return _geometry(cfg)[0]


def feature_shape(cfg: CNNConfig) -> tuple:
    """(C, H, W) entering the classifier head."""
    return _geometry(cfg)[1]


def init_params(cfg: CNNConfig, key: jax.Array) -> dict:
    """Dense master weights: per-layer conv kernels/biases + head matrix."""
    ini = Initializer(key)
    convs = []
    for spec, _pool in layer_specs(cfg):
        fan_in = spec.C * spec.KY * spec.KX
        convs.append({
            "kernel": ini.dense((spec.M, spec.C, spec.KY, spec.KX), fan_in=fan_in),
            "bias": jnp.zeros((spec.M,), jnp.float32),
        })
    C, H, W = feature_shape(cfg)
    return {
        "conv": convs,
        "head": {"w": ini.dense((C * H * W, cfg.classes)),
                 "b": jnp.zeros((cfg.classes,), jnp.float32)},
    }


def quantize(params: dict, cfg: CNNConfig, *, iters: int = 16) -> dict:
    """K-means weight-share every conv layer: one PASM dictionary per layer.

    Returns params with each conv entry carrying ``idx``/``codebook`` instead
    of the dense kernel (bias stays dense — §4: bias/activation not shared).
    """
    convs = []
    for p in params["conv"]:
        cb, idx = _conv.quantize_conv_weights(p["kernel"], cfg.bins, iters=iters)
        convs.append({"idx": idx, "codebook": cb, "bias": p["bias"]})
    return {"conv": convs, "head": params["head"]}


def _max_pool(x: jax.Array, p: int) -> jax.Array:
    """(B, C, H, W) non-overlapping max pool, VALID (floor) windowing."""
    if p == 1:
        return x
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, p, p), (1, 1, p, p), "VALID"
    )


def _head(x: jax.Array, head: dict) -> jax.Array:
    B = x.shape[0]
    return x.reshape(B, -1) @ head["w"] + head["b"]


def forward(
    params: dict,
    images: jax.Array,
    cfg: CNNConfig,
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Quantized forward: images (B, C, H, W) → logits (B, classes).

    ``cfg.impl`` picks the conv engine per DESIGN.md §2/§3: ``kernel`` runs
    the fused-dequant ``pasm_matmul``, ``pas_kernel`` the paper-faithful
    two-phase ``pas_matmul``, ``einsum`` the pure-XLA reference port.
    """
    if cfg.impl not in ("einsum", "kernel", "pas_kernel"):
        raise ValueError(f"impl must be einsum|kernel|pas_kernel, got {cfg.impl!r}")
    x = images
    for p, (spec, pool) in zip(params["conv"], layer_specs(cfg)):
        if cfg.impl == "pas_kernel":
            x = _conv.conv2d_pasm(x, p["idx"], p["codebook"], p["bias"],
                                  spec=spec, relu=True, engine="kernel",
                                  interpret=interpret)
        else:
            x = _conv.conv2d_weight_shared(x, p["idx"], p["codebook"], p["bias"],
                                           spec=spec, relu=True, engine=cfg.impl,
                                           interpret=interpret)
        x = _max_pool(x, pool)
    return _head(x, params["head"])


def forward_dense(params: dict, images: jax.Array, cfg: CNNConfig) -> jax.Array:
    """Reference forward on the dense master weights (no weight sharing)."""
    x = images
    for p, (spec, pool) in zip(params["conv"], layer_specs(cfg)):
        x = _conv.conv2d_direct(x, p["kernel"], p["bias"], spec=spec, relu=True)
        x = _max_pool(x, pool)
    return _head(x, params["head"])
