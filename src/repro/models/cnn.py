"""AlexNet-style CNN on the weight-shared conv accelerator (DESIGN.md §3).

The paper evaluates ONE AlexNet conv layer; this model stacks the same
accelerator into the full network shape it was drawn from: conv/ReLU/pool
stages, each conv carrying its own PASM dictionary (per-layer codebooks, the
paper's one-dictionary-per-layer rule), followed by a dense classifier head
(fully-connected layers are outside the paper's conv accelerator and stay
dense).  Every stage is one :class:`repro.core.conv.ConvParams` +
:class:`~repro.core.conv.Conv2D` pair dispatched through
:func:`repro.core.conv.conv2d`; on the Pallas engines bias+ReLU — and the
stage's max-pool (``conv2d(pool=)``, DESIGN.md §3.2) — fuse into the kernel,
so each batched conv/ReLU/pool stage is a single ``pallas_call`` whose store
is already the pooled map.

``cfg.padding``/``cfg.layout`` apply stack-wide (``same``+``NHWC`` gives
torchvision-exact geometry on the TPU-native layout); ``cfg.packed``
int4-packs every conv dictionary at quantize time.

Usage (see also ``examples/paper_conv.py`` and ``benchmarks/conv_bench.py``)::

    cfg = get_cnn_config("alexnet", smoke=True)
    params = cnn.init_params(cfg, key)          # dense ConvParams per stage
    qparams = cnn.quantize(params, cfg)         # per-layer k-means codebooks
    logits = cnn.forward(qparams, images, cfg)  # (B, classes) via Pallas

Sharded (``cfg.mesh_shape`` → ``launch.mesh.make_conv_mesh``)::

    mesh = conv_mesh(cfg)                        # ("data", "model")
    qparams = cnn.quantize(params, cfg, mesh=mesh)   # pspec-placed weights
    logits = cnn.forward(qparams, imgs, cfg, mesh=mesh)  # shard_map per layer

QAT (``core/qat.py`` STE through the conv dictionaries)::

    cbs = cnn.qat_codebooks(params, cfg)         # per-layer dictionaries
    logits = cnn.qat_forward(params, cbs, imgs, cfg)  # STE-snapped forward
    qparams = cnn.qat_requantize(params, cbs, cfg)    # freeze for serving
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.alexnet_conv import CNNConfig
from repro.core import conv as _conv
from repro.core import pasm as _pasm
from repro.core import qat as _qat
from repro.models.common import Initializer

__all__ = ["stages", "feature_shape", "init_params", "quantize", "forward",
           "forward_dense", "conv_mesh", "qat_codebooks", "qat_apply",
           "qat_forward", "qat_requantize"]

#  CNNConfig.impl == conv2d engine (kernel_implicit = implicit-GEMM Pallas;
#  auto lets conv2d pick per layer under cfg.vmem_budget)
_IMPLS = ("auto", "einsum", "kernel", "kernel_implicit", "pas_kernel")


def stages(cfg: CNNConfig) -> list:
    """Per-stage ``(Conv2D, pool)`` with the stack-wide padding/layout applied."""
    return [
        (dataclasses.replace(c, padding=cfg.padding, layout=cfg.layout), p)
        for c, p in zip(cfg.layers, cfg.pools)
    ]


def feature_shape(cfg: CNNConfig) -> tuple:
    """(C, H, W) entering the classifier head."""
    _, H, W = cfg.in_chw
    C = cfg.in_chw[0]
    for conv, pool in stages(cfg):
        H, W = _conv.conv_out_hw(H, W, conv)
        if pool > 1:
            H, W = H // pool, W // pool
        C = conv.c_out
    return C, H, W


def init_params(cfg: CNNConfig, key: jax.Array) -> dict:
    """Dense master weights: per-layer ConvParams + head matrix."""
    ini = Initializer(key)
    convs = []
    for conv, _pool in stages(cfg):
        fan_in = conv.c_in * conv.ky * conv.kx
        kernel = ini.dense((conv.c_out, conv.c_in, conv.ky, conv.kx), fan_in=fan_in)
        convs.append(_conv.ConvParams.dense(
            kernel, bias=jnp.zeros((conv.c_out,), jnp.float32)
        ))
    C, H, W = feature_shape(cfg)
    return {
        "conv": convs,
        "head": {"w": ini.dense((C * H * W, cfg.classes)),
                 "b": jnp.zeros((cfg.classes,), jnp.float32)},
    }


def conv_mesh(cfg: CNNConfig):
    """``cfg.mesh_shape`` → the stack's ``("data", "model")`` mesh."""
    from repro.launch.mesh import make_conv_mesh

    return make_conv_mesh(cfg.mesh_shape)


def _place(params: dict, mesh) -> dict:
    """Put every leaf on ``mesh`` per the models/sharding.py CNN rules."""
    from repro.launch.mesh import axis_sizes
    from repro.models import sharding as _sharding

    specs = _sharding.conv_param_pspecs(params, axis_sizes(mesh))
    return jax.tree.map(
        lambda leaf, s: jax.device_put(leaf, jax.sharding.NamedSharding(mesh, s)),
        params, specs,
    )


def quantize(params: dict, cfg: CNNConfig, *, iters: int = 16, mesh=None) -> dict:
    """K-means weight-share every conv layer: one PASM dictionary per layer.

    Each dense ConvParams becomes a ``shared`` one (bias stays dense — §4:
    bias/activation not shared); ``cfg.groups > 1`` gives every layer that
    many reduction-axis dictionaries (beyond-paper accuracy knob) and
    ``cfg.packed`` additionally int4-packs the dictionary indices into the
    stack layout's GEMM order.  ``mesh=`` places the result per the
    models/sharding.py CNN rules (c_out over ``model``, codebooks
    replicated) so per-device weight HBM shrinks with the mesh.
    """
    convs = []
    for p in params["conv"]:
        q = _conv.ConvParams.quantize(
            p.kernel, cfg.bins, bias=p.bias, iters=iters, groups=cfg.groups,
            layout=cfg.layout,
        )
        if cfg.packed:
            q = q.pack(layout=cfg.layout)
        convs.append(q)
    out = {"conv": convs, "head": params["head"]}
    return _place(out, mesh) if mesh is not None else out


# NOTE: the former ``_max_pool`` helper is gone — conv stages pass ``pool=``
# straight to :func:`repro.core.conv.conv2d` (fused into the kernel epilogue
# where possible), and the standalone fallback is the public
# :func:`repro.core.conv.max_pool2d` (dtype-correct window init: ``iinfo``
# minimum for integer/quantized maps, ``-inf`` — the differentiable max
# identity — for floats).


def _head(x: jax.Array, head: dict, mesh=None) -> jax.Array:
    """Dense classifier.  Under ``mesh=`` the matmul runs in shard_map (rows
    over ``data``, classes over ``model`` when divisible) so the contraction
    keeps the full feature axis per shard — XLA would otherwise split the
    model-sharded channel dim into a psum whose reduction order differs from
    single-device, costing stack-level bit-exactness."""
    B = x.shape[0]
    xf = x.reshape(B, -1)
    if mesh is None:
        return xf @ head["w"] + head["b"]
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import data_model_sizes, n_shard_axis
    from repro.models.sharding import conv_batch_pad

    nd, _ = data_model_sizes(mesh)
    pad = conv_batch_pad(B, nd)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    ns = n_shard_axis(mesh, head["w"].shape[1])
    y = shard_map(
        lambda xl, wl, bl: xl @ wl + bl,
        mesh=mesh, in_specs=(P("data", None), P(None, ns), P(ns)),
        out_specs=P("data", ns), check_rep=False,
    )(xf, head["w"], head["b"])
    return y[:B]


def forward(
    params: dict,
    images: jax.Array,
    cfg: CNNConfig,
    *,
    interpret: Optional[bool] = None,
    mesh=None,
) -> jax.Array:
    """Quantized forward: images (in ``cfg.layout`` order) → logits.

    ``cfg.impl`` picks the conv engine per DESIGN.md §2/§3: ``kernel`` runs
    the fused-dequant ``pasm_matmul`` over an explicit im2col patch matrix,
    ``kernel_implicit`` the implicit-GEMM ``pasm_conv2d`` (patch tiles
    assembled in VMEM, no patch matrix in HBM), ``pas_kernel`` the
    paper-faithful two-phase ``pas_matmul`` (all with the bias/ReLU epilogue
    fused into the pallas_call), ``einsum`` the pure-XLA reference port.

    Each stage's max-pool rides ``conv2d(pool=)``: on the Pallas engines the
    pool fuses into the conv kernel's epilogue (one ``pallas_call`` per
    conv/ReLU/pool stage, pre-pool activations never in HBM — DESIGN.md
    §3.2), with the bit-exact ``reduce_window`` fallback wherever fusion is
    impossible; ``cfg.pool_impl`` pins the policy.

    ``mesh=`` runs every conv layer sharded (``conv2d(mesh=)``: batch over
    ``data``, output channels over ``model``); the fused pool shards with
    the images on every Pallas engine (implicit windows live inside one
    image; explicit window-major patch rows split per image in whole
    windows), and each sharded conv all-gathers its ``model``-sharded
    output channels inside the kernel's shard_map body (the epilogue-fused
    collective) — consecutive conv layers hand over model-replicated
    activations, so XLA inserts no resharding between their pallas_calls.
    ``cfg.vmem_budget`` bounds the implicit engines' per-image VMEM
    footprint: larger images stream through the kernel as row-band slabs,
    bit-exact (DESIGN.md §3.3).
    """
    if cfg.impl not in _IMPLS:
        raise ValueError(
            f"impl must be one of {'|'.join(_IMPLS)}, got {cfg.impl!r}"
        )
    x = images
    for p, (conv, pool) in zip(params["conv"], stages(cfg)):
        x = _conv.conv2d(x, p, conv, engine=cfg.impl, interpret=interpret,
                         mesh=mesh, vmem_budget=cfg.vmem_budget, pool=pool,
                         pool_impl=cfg.pool_impl)
    return _head(x, params["head"], mesh=mesh)


def forward_dense(
    params: dict, images: jax.Array, cfg: CNNConfig, *, mesh=None
) -> jax.Array:
    """Reference forward on the dense master weights (no weight sharing)."""
    x = images
    for p, (conv, pool) in zip(params["conv"], stages(cfg)):
        x = _conv.conv2d(x, p, conv, engine="einsum", mesh=mesh, pool=pool)
        # einsum is pure XLA: conv2d pools via the reduce_window fallback
    return _head(x, params["head"], mesh=mesh)


# ---------------------------------------------------------------------------
# QAT: core/qat.py's STE through the conv stack's per-layer dictionaries
# ---------------------------------------------------------------------------


def _qat_check_groups(cfg: CNNConfig) -> None:
    if cfg.groups > 1:
        raise ValueError(
            "CNN QAT is single-dictionary (the paper's per-layer rule): "
            f"cfg.groups={cfg.groups} would train/freeze a different "
            "quantization scheme than quantize() serves; set groups=1"
        )


def qat_codebooks(params: dict, cfg: CNNConfig, *, iters: int = 16) -> list:
    """Initial per-layer dictionaries: k-means over each dense master kernel
    (the same assignment rule :func:`quantize` bakes into ``shared`` params,
    kept as plain ``(bins,)`` leaves so they can be trained)."""
    _qat_check_groups(cfg)
    cbs = []
    for p in params["conv"]:
        flat = p.kernel.reshape(1, -1).T  # single group = single dictionary
        cb, _ = _pasm.kmeans_codebook(flat, cfg.bins, groups=1, iters=iters)
        cbs.append(cb[0])
    return cbs


def qat_apply(params: dict, codebooks: Sequence[jax.Array]) -> dict:
    """STE-snap every dense master ConvParams onto its layer dictionary.

    The forward value is the codebook-snapped kernel (what the PASM engines
    would serve); the gradient flows straight through to the dense master
    (``qat.ste_quantize``) while each codebook entry accumulates the
    bin-summed grads of its assigned weights.  Bias stays dense (§4).
    """
    convs = [
        _conv.ConvParams.dense(_qat.ste_quantize(p.kernel, cb), bias=p.bias)
        for p, cb in zip(params["conv"], codebooks)
    ]
    return {"conv": convs, "head": params["head"]}


def qat_forward(
    params: dict,
    codebooks: Sequence[jax.Array],
    images: jax.Array,
    cfg: CNNConfig,
    *,
    mesh=None,
) -> jax.Array:
    """QAT training forward: dense masters STE-snapped per step, then the
    dense reference engine (differentiable in masters, codebooks, bias and
    head — the ROADMAP "CNN QAT" wiring)."""
    return forward_dense(qat_apply(params, codebooks), images, cfg, mesh=mesh)


def qat_requantize(
    params: dict, codebooks: Sequence[jax.Array], cfg: CNNConfig, *, mesh=None
) -> dict:
    """Freeze trained masters onto their dictionaries for serving.

    The nearest-entry re-assignment is :func:`repro.core.qat.assign_bins` —
    the STE forward's rule, and per group :func:`repro.core.pasm.
    quantize_like`'s — so the frozen ``shared`` ConvParams' :func:`forward`
    equals :func:`qat_forward` at the same masters/codebooks.
    """
    _qat_check_groups(cfg)
    convs = []
    for p, cb in zip(params["conv"], codebooks):
        idx = _qat.assign_bins(p.kernel, cb).astype(jnp.uint8)
        q = _conv.ConvParams.shared(idx, cb, bias=p.bias)
        if cfg.packed:
            q = q.pack(layout=cfg.layout)
        convs.append(q)
    out = {"conv": convs, "head": params["head"]}
    return _place(out, mesh) if mesh is not None else out
