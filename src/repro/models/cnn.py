"""AlexNet-style CNN on the weight-shared conv accelerator (DESIGN.md §3).

The paper evaluates ONE AlexNet conv layer; this model stacks the same
accelerator into the full network shape it was drawn from: conv/ReLU/pool
stages, each conv carrying its own PASM dictionary (per-layer codebooks, the
paper's one-dictionary-per-layer rule), followed by a dense classifier head
(fully-connected layers are outside the paper's conv accelerator and stay
dense).  Every stage is one :class:`repro.core.conv.ConvParams` +
:class:`~repro.core.conv.Conv2D` pair dispatched through
:func:`repro.core.conv.conv2d`; on the Pallas engines bias+ReLU fuse into the
kernel, so each batched conv layer is a single ``pallas_call``.

``cfg.padding``/``cfg.layout`` apply stack-wide (``same``+``NHWC`` gives
torchvision-exact geometry on the TPU-native layout); ``cfg.packed``
int4-packs every conv dictionary at quantize time.

Usage (see also ``examples/paper_conv.py`` and ``benchmarks/conv_bench.py``)::

    cfg = get_cnn_config("alexnet", smoke=True)
    params = cnn.init_params(cfg, key)          # dense ConvParams per stage
    qparams = cnn.quantize(params, cfg)         # per-layer k-means codebooks
    logits = cnn.forward(qparams, images, cfg)  # (B, classes) via Pallas
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.alexnet_conv import CNNConfig
from repro.core import conv as _conv
from repro.models.common import Initializer

__all__ = ["stages", "feature_shape", "init_params", "quantize", "forward",
           "forward_dense"]

#  CNNConfig.impl == conv2d engine (kernel_implicit = implicit-GEMM Pallas)
_IMPLS = ("einsum", "kernel", "kernel_implicit", "pas_kernel")


def stages(cfg: CNNConfig) -> list:
    """Per-stage ``(Conv2D, pool)`` with the stack-wide padding/layout applied."""
    return [
        (dataclasses.replace(c, padding=cfg.padding, layout=cfg.layout), p)
        for c, p in zip(cfg.layers, cfg.pools)
    ]


def feature_shape(cfg: CNNConfig) -> tuple:
    """(C, H, W) entering the classifier head."""
    _, H, W = cfg.in_chw
    C = cfg.in_chw[0]
    for conv, pool in stages(cfg):
        H, W = _conv.conv_out_hw(H, W, conv)
        if pool > 1:
            H, W = H // pool, W // pool
        C = conv.c_out
    return C, H, W


def init_params(cfg: CNNConfig, key: jax.Array) -> dict:
    """Dense master weights: per-layer ConvParams + head matrix."""
    ini = Initializer(key)
    convs = []
    for conv, _pool in stages(cfg):
        fan_in = conv.c_in * conv.ky * conv.kx
        kernel = ini.dense((conv.c_out, conv.c_in, conv.ky, conv.kx), fan_in=fan_in)
        convs.append(_conv.ConvParams.dense(
            kernel, bias=jnp.zeros((conv.c_out,), jnp.float32)
        ))
    C, H, W = feature_shape(cfg)
    return {
        "conv": convs,
        "head": {"w": ini.dense((C * H * W, cfg.classes)),
                 "b": jnp.zeros((cfg.classes,), jnp.float32)},
    }


def quantize(params: dict, cfg: CNNConfig, *, iters: int = 16) -> dict:
    """K-means weight-share every conv layer: one PASM dictionary per layer.

    Each dense ConvParams becomes a ``shared`` one (bias stays dense — §4:
    bias/activation not shared); ``cfg.groups > 1`` gives every layer that
    many reduction-axis dictionaries (beyond-paper accuracy knob) and
    ``cfg.packed`` additionally int4-packs the dictionary indices into the
    stack layout's GEMM order.
    """
    convs = []
    for p in params["conv"]:
        q = _conv.ConvParams.quantize(
            p.kernel, cfg.bins, bias=p.bias, iters=iters, groups=cfg.groups,
            layout=cfg.layout,
        )
        if cfg.packed:
            q = q.pack(layout=cfg.layout)
        convs.append(q)
    return {"conv": convs, "head": params["head"]}


def _max_pool(x: jax.Array, p: int, layout: str) -> jax.Array:
    """Non-overlapping max pool, VALID (floor) windowing, layout-aware."""
    if p == 1:
        return x
    window = (1, p, p, 1) if layout == "NHWC" else (1, 1, p, p)
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, window, window, "VALID")


def _head(x: jax.Array, head: dict) -> jax.Array:
    B = x.shape[0]
    return x.reshape(B, -1) @ head["w"] + head["b"]


def forward(
    params: dict,
    images: jax.Array,
    cfg: CNNConfig,
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Quantized forward: images (in ``cfg.layout`` order) → logits.

    ``cfg.impl`` picks the conv engine per DESIGN.md §2/§3: ``kernel`` runs
    the fused-dequant ``pasm_matmul`` over an explicit im2col patch matrix,
    ``kernel_implicit`` the implicit-GEMM ``pasm_conv2d`` (patch tiles
    assembled in VMEM, no patch matrix in HBM), ``pas_kernel`` the
    paper-faithful two-phase ``pas_matmul`` (all with the bias/ReLU epilogue
    fused into the pallas_call), ``einsum`` the pure-XLA reference port.
    """
    if cfg.impl not in _IMPLS:
        raise ValueError(
            f"impl must be one of {'|'.join(_IMPLS)}, got {cfg.impl!r}"
        )
    x = images
    for p, (conv, pool) in zip(params["conv"], stages(cfg)):
        x = _conv.conv2d(x, p, conv, engine=cfg.impl, interpret=interpret)
        x = _max_pool(x, pool, cfg.layout)
    return _head(x, params["head"])


def forward_dense(params: dict, images: jax.Array, cfg: CNNConfig) -> jax.Array:
    """Reference forward on the dense master weights (no weight sharing)."""
    x = images
    for p, (conv, pool) in zip(params["conv"], stages(cfg)):
        x = _conv.conv2d(x, p, conv, engine="einsum")
        x = _max_pool(x, pool, cfg.layout)
    return _head(x, params["head"])
