"""Mamba-2 language model (SSD blocks, attention-free) — mamba2-130m."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Initializer, ShardCtx, maybe_scan
from repro.nn import layers as L
from repro.nn import rglru as RG  # causal_conv1d shared
from repro.nn import ssm as S

__all__ = ["init_params", "forward", "init_caches", "prefill", "decode_step"]


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + H
    return d_in, H, conv_dim, proj_out


def _init_layer(cfg: ArchConfig, ini: Initializer) -> dict:
    s = cfg.ssm
    D = cfg.d_model
    d_in, H, conv_dim, proj_out = _dims(cfg)
    return {
        "attn_norm": jnp.zeros((D,)),
        "in_proj": ini.dense((D, proj_out)),
        "conv_w": trunc(ini, (s.d_conv, conv_dim), 0.1),
        "conv_b": jnp.zeros((conv_dim,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "ssm_D": jnp.ones((H,)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))),  # softplus⁻¹
        "ssm_norm": jnp.zeros((d_in,)),
        "out_proj": ini.dense((d_in, D), fan_in=d_in),
    }


def trunc(ini, shape, std):
    return jax.random.normal(ini.key(), shape, jnp.float32) * std


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    ini = Initializer(key)
    keys = jax.random.split(ini.key(), cfg.n_layers)
    params = {
        "embed": jax.random.normal(ini.key(), (cfg.vocab, cfg.d_model)) * 0.02,
        "layers": jax.vmap(lambda k: _init_layer(cfg, Initializer(k)))(keys),
        "final_norm": jnp.zeros((cfg.d_model,)),
        "lm_head": ini.dense((cfg.d_model, cfg.vocab)),
    }
    if dtype != jnp.float32:
        params = jax.tree.map(lambda x: x.astype(dtype), params)
    return params


def _split_proj(proj, cfg):
    s = cfg.ssm
    d_in, H, conv_dim, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z = proj[..., :d_in]
    xbc = proj[..., d_in : d_in + conv_dim]
    dt = proj[..., d_in + conv_dim :]
    return z, xbc, dt


def _layer_fwd(x, p, cfg, sctx, impl, state=None, conv_win=None):
    """Full-sequence SSD layer.  Returns (y, final_ssm_state, last_conv_win)."""
    s = cfg.ssm
    Bsz, Sq, D = x.shape
    d_in, H, conv_dim, _ = _dims(cfg)
    gn = s.n_groups * s.d_state

    xn = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    proj = L.linear(xn, p["in_proj"], impl)
    z, xbc, dt = _split_proj(proj, cfg)
    if conv_win is not None:  # continue from cached inputs (not used in train)
        pass
    xbc_in = xbc
    xbc = jax.nn.silu(RG.causal_conv1d(xbc, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :d_in].reshape(Bsz, Sq, H, s.head_dim)
    Bm = xbc[..., d_in : d_in + gn].reshape(Bsz, Sq, s.n_groups, s.d_state)
    Cm = xbc[..., d_in + gn :].reshape(Bsz, Sq, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xs = sctx.cs(xs, sctx.batch, None, None, sctx.model)
    y, h_final = S.ssd_scan(
        xs, dt, A, Bm, Cm, p["ssm_D"].astype(jnp.float32),
        chunk=min(s.chunk, Sq), init_state=state,
    )
    y = y.reshape(Bsz, Sq, d_in)
    y = L.rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    y = sctx.act_btf(y)
    out = L.linear(y, p["out_proj"], impl)
    last_win = xbc_in[:, -(s.d_conv - 1) :, :] if Sq >= s.d_conv - 1 else None
    return sctx.act_btd(out), h_final, last_win


def forward(
    params, tokens, cfg: ArchConfig, sctx: ShardCtx = ShardCtx(), *, frontend_embeds=None
):
    from repro.models.transformer import _embed_lookup  # PASM-aware lookup

    x = _embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = sctx.act_btd(x)
    impl = cfg.quant.impl if cfg.quant.enabled else "dense"

    def body(h, lp):
        y, _, _ = _layer_fwd(h, lp, cfg, sctx, impl)
        return h + y, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = maybe_scan(body_fn, x, params["layers"], cfg.scan_layers)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.linear(x, params["lm_head"], impl)
    return sctx.cs(logits, sctx.batch, None, sctx.model), {}


def init_caches(cfg: ArchConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    """SSM state + conv window per layer (no KV cache — attention-free)."""
    s = cfg.ssm
    d_in, H, conv_dim, _ = _dims(cfg)
    one = {
        "ssm": jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),  # per-slot decode position
    }
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one
    )


def decode_step(params, tokens, caches, cfg: ArchConfig, sctx: ShardCtx = ShardCtx()):
    from repro.models.transformer import _embed_lookup

    s = cfg.ssm
    d_in, H, conv_dim, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    x = _embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)[:, 0]  # (B, D)
    impl = cfg.quant.impl if cfg.quant.enabled else "dense"

    def body(h, inp):
        lp, cache = inp
        xn = L.rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        proj = L.linear(xn, lp["in_proj"], impl)
        z = proj[..., :d_in]
        xbc = proj[..., d_in : d_in + conv_dim]
        dt = proj[..., d_in + conv_dim :]
        xbc_c, new_win = RG.conv1d_decode_step(xbc, lp["conv_w"], lp["conv_b"], cache["conv"])
        xbc_c = jax.nn.silu(xbc_c)
        xs = xbc_c[..., :d_in].reshape(-1, H, s.head_dim)
        Bm = xbc_c[..., d_in : d_in + gn].reshape(-1, s.n_groups, s.d_state)
        Cm = xbc_c[..., d_in + gn :].reshape(-1, s.n_groups, s.d_state)
        dtv = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
        A = -jnp.exp(lp["A_log"].astype(jnp.float32))
        y, new_state = S.ssd_decode_step(
            xs, dtv, A, Bm, Cm, lp["ssm_D"].astype(jnp.float32), cache["ssm"]
        )
        y = y.reshape(-1, d_in)
        y = L.rms_norm(y * jax.nn.silu(z), lp["ssm_norm"], cfg.norm_eps)
        out = L.linear(y, lp["out_proj"], impl)
        new_cache = {"ssm": new_state, "conv": new_win, "pos": cache["pos"] + 1}
        return h + out, new_cache

    x, new_caches = maybe_scan(body, x, (params["layers"], caches), cfg.scan_layers)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.linear(x, params["lm_head"], impl)[:, None, :]
    return logits, new_caches


def prefill(params, tokens, caches, cfg: ArchConfig, sctx: ShardCtx = ShardCtx(), **kw):
    """Prompt pass producing final states (uses the chunked SSD scan).

    Right-padded prompts (``lengths=``) are NOT supported: the SSD scan folds
    every input token into the recurrent state, so pad tokens would corrupt
    it.  Serve SSM slots with exact-length prompts (bucket granularity 1).
    """
    from repro.models.transformer import _embed_lookup

    if kw.get("lengths") is not None:
        raise ValueError("ssm_lm.prefill: padded prompts (lengths=) unsupported — "
                         "the recurrent scan would absorb pad tokens into state")

    x = _embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = sctx.act_btd(x)
    impl = cfg.quant.impl if cfg.quant.enabled else "dense"
    s = cfg.ssm

    def body(h, inp):
        lp, cache = inp
        y, h_final, last_win = _layer_fwd(h, lp, cfg, sctx, impl)
        new_cache = {
            "ssm": h_final,
            "conv": last_win.astype(cache["conv"].dtype),
            "pos": cache["pos"] + tokens.shape[1],
        }
        return h + y, new_cache

    x, new_caches = maybe_scan(body, x, (params["layers"], caches), cfg.scan_layers)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.linear(x[:, -1:], params["lm_head"], impl)
    return logits, new_caches
