"""RG-LRU recurrence (RecurrentGemma / Griffin, arXiv:2402.19427).

  r_t = σ(x_t W_a + b_a)                        recurrence gate
  i_t = σ(x_t W_x + b_x)                        input gate
  a_t = exp(−c·softplus(Λ)·r_t)                 per-channel decay, c = 8
  h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill uses an associative scan (O(log S) depth, sub-quadratic —
this family runs ``long_500k``); decode is a one-step update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rg_lru_scan", "rg_lru_decode_step", "causal_conv1d", "conv1d_decode_step"]

_C = 8.0


def _gates(x, params):
    from repro.nn import layers as L  # local import (avoid cycle at module load)

    r = jax.nn.sigmoid(L.linear(x, params["w_a"], "dequant") + params["b_a"].astype(x.dtype))
    i = jax.nn.sigmoid(L.linear(x, params["w_x"], "dequant") + params["b_x"].astype(x.dtype))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = (i.astype(jnp.float32) * x.astype(jnp.float32)) * jnp.sqrt(
        jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)
    )
    return a, gated


def rg_lru_scan(
    x: jax.Array, params: dict, init_h: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, W) → (y (B,S,W) , h_final (B,W)).  Associative linear scan."""
    a, b = _gates(x, params)  # (B,S,W) f32 both

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    if init_h is not None:
        b = b.at[:, 0].add(a[:, 0] * init_h.astype(jnp.float32))
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rg_lru_decode_step(
    x: jax.Array, params: dict, h: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """x: (B, W) one token; h: (B, W) carried state."""
    a, b = _gates(x[:, None, :], params)
    h_new = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
    return h_new.astype(x.dtype), h_new


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  x (B,S,W); w (K,W); left-padded, no lookahead."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(
        xp[:, k : k + x.shape[1], :] * w[k][None, None, :].astype(x.dtype)
        for k in range(K)
    )
    return y + b[None, None, :].astype(x.dtype)


def conv1d_decode_step(
    x: jax.Array, w: jax.Array, b: jax.Array, window: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """One-token depthwise conv.  window (B, K-1, W) holds the last K-1 inputs."""
    K = w.shape[0]
    full = jnp.concatenate([window, x[:, None, :]], axis=1)  # (B, K, W)
    y = jnp.einsum("bkw,kw->bw", full.astype(jnp.float32), w.astype(jnp.float32))
    y = (y + b.astype(jnp.float32)).astype(x.dtype)
    return y, full[:, 1:]
