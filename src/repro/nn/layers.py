"""Shared layers: linear (PASM-aware), norms, activations, RoPE, embeddings.

Every weight-bearing op goes through :func:`linear`, which dispatches on the
leaf type: a plain array runs a dense matmul; a :class:`PASMTensor` runs the
weight-shared path selected by ``impl`` — this is how the paper's technique
is integrated as a first-class feature across all architectures.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import pasm as _pasm
from repro.kernels import ops as _kops

Weight = Union[jax.Array, _pasm.PASMTensor]

__all__ = [
    "linear",
    "rms_norm",
    "layer_norm",
    "swiglu",
    "sq_relu",
    "gelu_ffn_act",
    "rope",
    "apply_rope",
]


def linear(x: jax.Array, w: Weight, impl: str = "dense") -> jax.Array:
    """``x @ w`` where ``w`` is dense or weight-shared (PASM).

    impl (for PASM leaves): "dequant" | "kernel" | "pas_kernel".
    "dequant" is the weight-shared-MAC baseline and the only distribution-safe
    path under pjit (pure XLA gather+dot); the kernels are single-device /
    shard_map paths (DESIGN.md §2).
    """
    if isinstance(w, _pasm.PASMTensor):
        if impl == "kernel":
            return _kops.pasm_matmul(x, w).astype(x.dtype)
        if impl == "pas_kernel":
            return _kops.pas_matmul(x, w).astype(x.dtype)
        wd = _pasm.dequantize(w, dtype=x.dtype)  # dictionary lookup (Fig 3)
        return jnp.dot(x, wd, preferred_element_type=jnp.float32).astype(x.dtype)
    return jnp.dot(x, w.astype(x.dtype), preferred_element_type=jnp.float32).astype(
        x.dtype
    )


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(
    x: jax.Array, scale: jax.Array, bias: Optional[jax.Array], eps: float = 1e-5
) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def sq_relu(x: jax.Array) -> jax.Array:
    """Squared ReLU (Nemotron-4)."""
    r = jnp.maximum(x, 0)
    return r * r


def gelu_ffn_act(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


def rope(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for ``positions`` (any shape) → (..., head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over heads axis
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)
