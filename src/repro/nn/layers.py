"""Shared layers: linear (PASM-aware), norms, activations, RoPE, embeddings.

Every weight-bearing op goes through :func:`linear`, a thin alias of
:func:`repro.core.params.matmul` — one dispatch table (dense | shared |
int4-packed | grouped × dequant | kernel | pas_kernel, with the fused
bias/ReLU epilogue and ``mesh=`` shard_map support) shared with the conv
path, zero container ``isinstance`` in model code.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import params as _params

Weight = _params.Weight

__all__ = [
    "linear",
    "rms_norm",
    "layer_norm",
    "swiglu",
    "sq_relu",
    "gelu_ffn_act",
    "rope",
    "apply_rope",
]


def linear(
    x: jax.Array,
    w: Weight,
    impl: str = "dense",
    *,
    bias: Optional[jax.Array] = None,
    relu: bool = False,
    mesh=None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``x @ w`` where ``w`` is dense or weight-shared (a :class:`PasmParams`).

    ``impl`` (for quantized leaves): ``"dequant"`` | ``"kernel"`` |
    ``"pas_kernel"`` — plain arrays and dense params always take the XLA dot
    (post-``quantize_params`` trees mix dense and quantized leaves).  The
    kernel paths carry the fused bias/ReLU epilogue and run under a
    ``("data", "model")`` mesh via the same shard_map dispatch conv uses —
    every impl is distribution-safe (DESIGN.md §2).
    """
    return _params.matmul(
        x, w, impl=impl, bias=bias, relu=relu, mesh=mesh, interpret=interpret
    )


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(
    x: jax.Array, scale: jax.Array, bias: Optional[jax.Array], eps: float = 1e-5
) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def sq_relu(x: jax.Array) -> jax.Array:
    """Squared ReLU (Nemotron-4)."""
    r = jnp.maximum(x, 0)
    return r * r


def gelu_ffn_act(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


def rope(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for ``positions`` (any shape) → (..., head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over heads axis
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)
