"""Layer zoo: pure-JAX, pjit/shard_map-friendly building blocks."""
