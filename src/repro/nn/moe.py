"""Mixture-of-Experts: top-k routing, LOCAL sort-based dispatch, EP combine.

Dispatch is hierarchical, mirroring production MoE systems: tokens are
grouped by data shard (``n_groups`` = DP degree), each group sorts ONLY its
local tokens (no cross-shard sort → no token all-gather), and the grouped
(G, E, C, D) buffer — G sharded over ``data``, E over ``model`` — moves
through the expert einsum as the all-to-all pattern the SPMD partitioner
schedules.  Position-in-expert comes from a searchsorted over run starts, so
no (T, E, C) one-hot is ever built.

Weights follow DeepSeek-MoE structure: ``n_shared`` always-on experts plus
``n_experts`` routed experts with top-k softmax gating.  The router stays
dense under PASM quantization (DESIGN.md §5); expert weights may be
:class:`~repro.core.params.PasmParams` stacked over the expert dim — each
expert dereferencing its OWN codebook set (per-expert grouped dictionaries),
through the same dispatch every other matmul in the zoo uses.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core import params as _params
from repro.nn import layers as L

__all__ = ["moe_ffn", "expert_ffn"]

Constrain = Callable[[jax.Array, tuple], jax.Array]


def _noop_constrain(x, spec):
    return x


def expert_ffn(x: jax.Array, w1, w3, w2, act: str, impl: str) -> jax.Array:
    """SwiGLU / squared-ReLU FFN used for both shared and dense-layer FFNs."""
    if act == "swiglu":
        h = L.swiglu(L.linear(x, w1, impl), L.linear(x, w3, impl))
    elif act == "sq_relu":
        h = L.sq_relu(L.linear(x, w1, impl))
    else:
        h = L.gelu_ffn_act(L.linear(x, w1, impl))
    return L.linear(h, w2, impl)


def _expert_matmul(bufT, w, dt, impl, constrain=_noop_constrain, spec=None):
    """Per-expert batched matmul ``(E, T, K) @ (E, K, N) → (E, T, N)``.

    Quantized experts under a kernel impl run one fused-dequant Pallas GEMM
    per expert (static unroll over E), each slice carrying its own grouped
    codebook — the paper's dictionaries specialized per expert.  Otherwise
    (dense weights, or the dequant baseline) the stack dequantizes through
    :func:`repro.core.params.dense_stack` into one einsum; there ``spec``
    re-lays-out the STORED weight before use (JIT all-gather of the
    2-D-sharded storage), and for quantized weights that gather moves the
    uint8/int4 *indices* — 4–8× fewer bytes than gathering dequantized
    bf16, the paper's compression applied to the collective payload
    [§Perf iteration kimi-prefill/2].
    """
    if _params.is_quantized(w) and impl in ("kernel", "pas_kernel"):
        E = bufT.shape[0]
        return jnp.stack(
            [
                _params.matmul(
                    bufT[e], jax.tree.map(lambda a: a[e], w), impl=impl
                )
                for e in range(E)
            ]
        ).astype(dt)
    return jnp.einsum(
        "etk,ekn->etn", bufT, _params.dense_stack(w, dt, constrain, spec)
    )


def moe_ffn(
    x: jax.Array,
    params: dict,
    cfg: MoEConfig,
    *,
    act: str = "swiglu",
    impl: str = "dense",
    constrain: Constrain = _noop_constrain,
    ep_spec: tuple = ("model", None, None),
    dropless: bool = False,
    n_groups: int = 1,
    group_spec: Optional[tuple] = None,
) -> tuple[jax.Array, dict]:
    """x: (T, D) → (T, D), aux metrics.

    ``n_groups``: local-dispatch groups (set to the DP degree under pjit so
    every sort/scatter stays shard-local).  ``group_spec``: mesh axes of the
    group dim (e.g. ("data",)); ``ep_spec[0]`` is the expert-dim mesh axis.
    """
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    if T % n_groups:
        n_groups = 1
    Tl = T // n_groups
    if dropless:
        # exactly dropless for small local token counts (decode); for large
        # prefill/train batches a cap of Tl inflates the dispatch buffer by
        # E/k× — bound it at 2× the balanced load instead (statistically
        # dropless; measured drop_frac stays 0 for trained routers).
        # [§Perf iteration kimi-prefill/1 — see EXPERIMENTS.md]
        cap = Tl if Tl <= 512 else min(Tl, -(-Tl * k * 5 // (E * 4)))  # 1.25× balanced
    else:
        cap = int(max(1, round(Tl * k / E * cfg.capacity_factor)))
    cap = min(cap, Tl)

    # --- routing (dense f32 for numerics) ---
    logits = jnp.dot(x.astype(jnp.float32), params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    top_w, top_i = jax.lax.top_k(probs, k)  # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    xg = x.reshape(n_groups, Tl, D)
    ig = top_i.reshape(n_groups, Tl, k)
    wg = top_w.reshape(n_groups, Tl, k)

    def dispatch(xl, il, wl):
        """One group: (Tl, D), (Tl, k) → buffer (E, C, D) + combine metadata.

        Inverse-index formulation: the only scatter touches an (E, C) int32
        slot→token map; every D-dimensional movement is a gather, so no
        (Tl·k, D) intermediate is materialized and the SPMD partitioner
        never needs a scatter-combine all-reduce
        [§Perf iteration kimi-prefill/3].
        """
        e_flat = il.reshape(-1)
        order = jnp.argsort(e_flat, stable=True)
        e_sorted = e_flat[order]
        tok_sorted = order // k
        run_starts = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
        pos = jnp.arange(Tl * k) - run_starts[e_sorted]
        keep = pos < cap
        pos_c = jnp.where(keep, pos, 0)
        # slot → token+1 (0 = empty), built from an int scatter — tiny.
        # dropped entries are routed out of bounds (row E) so mode="drop"
        # discards them instead of clobbering slot 0 of their expert.
        slot_tok = jnp.zeros((E, cap), jnp.int32)
        slot_tok = slot_tok.at[jnp.where(keep, e_sorted, E), pos_c].set(
            tok_sorted + 1, mode="drop"
        )
        buf = xl[jnp.maximum(slot_tok - 1, 0)]  # (E, C, D) direct gather
        buf = buf * (slot_tok > 0)[..., None].astype(xl.dtype)
        # per-token (position, kept) in (Tl, k) layout for the combine gathers
        pos_u = jnp.zeros((Tl * k,), jnp.int32).at[order].set(pos_c).reshape(Tl, k)
        keep_u = jnp.zeros((Tl * k,), jnp.bool_).at[order].set(keep).reshape(Tl, k)
        return buf, (il, pos_u, keep_u, wl)

    buf, meta = jax.vmap(dispatch)(xg, ig, wg)  # (G, E, C, D)
    gspec = tuple(group_spec) if group_spec else (None,)
    ep_axis = ep_spec[0]
    ff_axis = gspec[0]  # expert-internal parallelism reuses the freed DP axis
    buf4 = gspec + (ep_axis, None, None)  # (G, E, C, D) token-sharded layout
    buf = constrain(buf, buf4)

    # --- token-parallel expert compute: the (G×E) device grid holds BOTH
    # shardings at once — G (tokens) over data, E (experts) over model — so
    # every (expert, token-group) pair is computed somewhere and NO token
    # ever crosses data shards.  The only communication is a just-in-time
    # all-gather of the 2-D-sharded expert weights (int4 indices under
    # PASM — the paper's compression shrinking the collective payload),
    # orders of magnitude smaller than the activation all-reduce it
    # replaces [§Perf iteration kimi-prefill/2].
    dt = x.dtype
    # regime switch [§Perf iteration kimi-decode/1]: with many tokens
    # (prefill/train) the JIT weight gather (int4 indices) is far cheaper
    # than moving activations; with few tokens (decode) it's the opposite —
    # keep the stored Fe-sharded weights and all-reduce the tiny expert
    # outputs over the data axis instead.
    gather_weights = T > 4096
    tspec = ff_axis if gather_weights else None
    wspec = (ep_axis, None, None) if gather_weights else None
    hspec = (ep_axis, tspec, None) if gather_weights else (ep_axis, None, ff_axis)
    bufT = jnp.transpose(buf, (1, 0, 2, 3)).reshape(E, n_groups * cap, D)
    bufT = constrain(bufT, (ep_axis, tspec, None))
    h = _expert_matmul(bufT, params["w1"], dt, impl, constrain, wspec)
    if act == "swiglu":
        h = jax.nn.silu(h) * _expert_matmul(
            bufT, params["w3"], dt, impl, constrain, wspec
        )
    elif act == "sq_relu":
        r = jnp.maximum(h, 0)
        h = r * r
    else:
        h = jax.nn.gelu(h, approximate=True)
    h = constrain(h, hspec)
    y2 = _expert_matmul(h, params["w2"], dt, impl, constrain, wspec)
    y2 = constrain(y2, (ep_axis, tspec, None))
    yb = y2.reshape(E, n_groups, cap, D).transpose(1, 0, 2, 3)
    yb = constrain(yb, buf4)

    def combine(ybl, m):
        il, pos_u, keep_u, wl = m
        y = jnp.zeros((Tl, D), ybl.dtype)
        for j in range(k):  # k gathers of (Tl, D) — no (Tl·k, D) intermediate
            contrib = ybl[il[:, j], pos_u[:, j]]
            gate = (wl[:, j] * keep_u[:, j]).astype(ybl.dtype)
            y = y + contrib * gate[:, None]
        return y

    y = jax.vmap(combine)(yb, meta).reshape(T, D)

    # --- shared (always-on) experts ---
    if "shared_w1" in params:
        y = y + expert_ffn(
            x, params["shared_w1"], params["shared_w3"], params["shared_w2"], act, impl
        )

    # --- aux: load-balance loss (Switch-style) + drop fraction.  Serving
    # (dropless) skips it: the (T, E) router-prob reduction otherwise costs
    # an all-gather of the full prob matrix [§Perf iteration kimi-prefill/4].
    if dropless:
        aux = {}
    else:
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (T * k)
        keep_frac = meta[2].astype(jnp.float32).mean()
        aux = {
            "moe_load_balance": E * jnp.sum(me * ce),
            "moe_drop_frac": 1.0 - keep_frac,
        }
    return y.astype(x.dtype), aux
