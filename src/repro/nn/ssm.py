"""Mamba-2 SSD (state-space duality) — chunked scan, training + decode.

Implements the SSD form of Mamba-2 (Dao & Gu 2024, arXiv:2405.21060): the
selective SSM  ``h_t = exp(dt_t·A) h_{t-1} + dt_t·B_t ⊗ x_t``,
``y_t = C_t·h_t + D·x_t``  computed chunk-parallel: quadratic
attention-like compute inside chunks of length Q, a linear state recurrence
across chunks.  Sub-quadratic in sequence length → this arch family runs the
``long_500k`` cell (DESIGN.md §5).

Shapes: x (B, S, H, P) heads × head_dim; B/C (B, S, G, N) groups × state;
dt (B, S, H); A (H,) negative reals.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

__all__ = ["ssd_scan", "ssd_decode_step", "SSMState"]


@functools.partial(
    jax.tree_util.register_dataclass, data_fields=["h"], meta_fields=[]
)
@dataclasses.dataclass
class SSMState:
    h: jax.Array  # (B, H, P, N)


def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    D: jax.Array,
    *,
    chunk: int = 128,
    init_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD.  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    S_orig = S
    if S % chunk:
        # pad with dt=0 steps: decay exp(0)=1 keeps state, x=0 adds nothing
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // chunk
    rep = H // G  # heads per B/C group

    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, chunk, G, N)
    Cc = Cm.reshape(Bsz, nc, chunk, G, N)

    dA = dtc * A[None, None, None, :]  # (B,nc,Q,H) ≤ 0
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative log-decay
    seg_end = cum[:, :, -1, :]  # (B,nc,H)

    # --- intra-chunk (quadratic within chunk) ---
    # L[t,s] = exp(cum_t - cum_s) for s ≤ t  (log-space for stability)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,t,s,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    # scores[t,s,h] = (C_t · B_s) per group, broadcast to heads
    cb = jnp.einsum("bctgn,bcsgn->bctsg", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    cb = jnp.repeat(cb, rep, axis=-1)  # (B,nc,t,s,H)
    w = cb * Lmat * dtc[:, :, None, :, :]  # weight on x_s
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", w, xc.astype(jnp.float32))

    # --- chunk states: S_c = Σ_s exp(seg_end - cum_s)·dt_s·B_s ⊗ x_s ---
    decay_to_end = jnp.exp(seg_end[:, :, None, :] - cum) * dtc  # (B,nc,Q,H)
    BxH = jnp.repeat(Bc, rep, axis=3)  # (B,nc,Q,H,N)
    states = jnp.einsum(
        "bcsh,bcshn,bcshp->bchpn", decay_to_end, BxH.astype(jnp.float32), xc.astype(jnp.float32)
    )  # (B,nc,H,P,N)

    # --- inter-chunk recurrence (linear scan over chunks) ---
    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((Bsz, H, P, N), jnp.float32)
    )

    def step(h, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        h_new = h * jnp.exp(dec)[:, :, None, None] + st
        return h_new, h  # emit state *entering* the chunk

    (h_final, h_enter) = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(seg_end, 1, 0))
    )
    h_enter = jnp.moveaxis(h_enter, 0, 1)  # (B,nc,H,P,N)

    # --- inter-chunk contribution: y_t += C_t · exp(cum_t) · h_enter ---
    CH = jnp.repeat(Cc, rep, axis=3)  # (B,nc,Q,H,N)
    y_inter = jnp.einsum(
        "bcthn,bchpn->bcthp", CH.astype(jnp.float32) * jnp.exp(cum)[..., None], h_enter
    )

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y[:, :S_orig].astype(x.dtype), h_final


def ssd_decode_step(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    D: jax.Array,
    state: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """One-token SSD update.  x (B,H,P); dt (B,H); B/C (B,G,N); state (B,H,P,N)."""
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A[None, :])  # (B,H)
    BH = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    CH = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    new_state = state * dA[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dtf, BH, x.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_state, CH) + x.astype(jnp.float32) * D[None, :, None]
    return y.astype(x.dtype), new_state
