"""Attention: GQA with qk-norm, chunked (flash-style) causal/local, decode.

All shapes are (batch, seq, heads, head_dim).  GQA is expressed by reshaping
query heads into (kv_head, group) so the contraction never materializes
repeated K/V.  The chunked path scans KV blocks with an online softmax so
prefill at 32 k context never materializes an (S, S) score matrix.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["KVCache", "gqa_attention", "decode_attention", "init_kv_cache"]

_NEG_INF = -1e30


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["k", "v", "pos"],
    meta_fields=[],
)
@dataclasses.dataclass
class KVCache:
    k: jax.Array  # (B, S, KV, hd)
    v: jax.Array
    pos: jax.Array  # (B,) int32 — tokens already in cache, PER SLOT


def init_kv_cache(batch: int, seq: int, n_kv: int, hd: int, dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, seq, n_kv, hd), dtype),
        v=jnp.zeros((batch, seq, n_kv, hd), dtype),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def _chunk_scores(q, k, scale):
    """q (B,Cq,KV,G,hd) · k (B,Ck,KV,hd) → (B,KV,G,Cq,Ck) f32."""
    return jnp.einsum("bqkgh,bckh->bkgqc", q, k, preferred_element_type=jnp.float32) * scale


def gqa_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Chunked-KV online-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd); H % KV == 0.
    ``window`` limits attention to the last ``window`` positions (local
    attention, RecurrentGemma).  ``q_offset`` is the absolute position of
    q[0] relative to k[0] (prefill: 0; not used for single-token decode —
    see :func:`decode_attention`).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5
    chunk = min(chunk, Sk)
    Sk_orig = Sk
    if Sk % chunk:  # pad KV to a chunk multiple; pad positions masked below
        pad = chunk - Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Sk = Sk + pad
    n_chunks = Sk // chunk

    qg = q.reshape(B, Sq, KV, G, hd)
    if n_chunks == 1:
        # single-pass: no online-softmax carries to round-trip through HBM
        s = _chunk_scores(qg, k, scale)  # (B,KV,G,Sq,Sk)
        k_pos = jnp.arange(Sk)
        mask = jnp.broadcast_to(k_pos[None, :] < Sk_orig, (Sq, Sk))
        if causal:
            mask &= (q_offset + jnp.arange(Sq))[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_offset + jnp.arange(Sq))[:, None] - k_pos[None, :] < window
        s = jnp.where(mask, s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqc,bckh->bkgqh", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd).astype(q.dtype)
    kc = k.reshape(B, n_chunks, chunk, KV, hd)
    vc = v.reshape(B, n_chunks, chunk, KV, hd)
    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, inputs):
        m, l, o = carry  # (B,KV,G,Sq), (B,KV,G,Sq), (B,KV,G,Sq,hd)
        kb, vb, c_idx = inputs
        s = _chunk_scores(qg, kb, scale)  # (B,KV,G,Sq,chunk)
        k_pos = c_idx * chunk + jnp.arange(chunk)
        mask = jnp.broadcast_to(k_pos[None, :] < Sk_orig, (Sq, chunk))
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bkgqc,bckh->bkgqh", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, KV, G, Sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    o0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    (m, l, o), _ = jax.lax.scan(
        step,
        (m0, l0, o0),
        (
            jnp.moveaxis(kc, 1, 0),
            jnp.moveaxis(vc, 1, 0),
            jnp.arange(n_chunks),
        ),
    )
    o = o / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    cache: KVCache,
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """Single-token attention against a KV cache.

    q: (B, 1, H, hd).  Masks positions ≥ cache.pos PER SLOT (and outside
    ``window``) — slots may sit at different depths under continuous
    batching, so every read is masked by its own position counter.
    This is the op the decode_* shape cells lower — bandwidth-bound: it reads
    the whole (B, S, KV, hd) cache to produce one token.
    """
    B, one, H, hd = q.shape
    _, S, KV, _ = cache.k.shape
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum(
        "bkgh,bskh->bkgs", qg, cache.k, preferred_element_type=jnp.float32
    ) * scale
    k_pos = jnp.arange(S)
    valid = k_pos[None, :] < cache.pos[:, None]  # (B, S)
    if window is not None:
        valid &= k_pos[None, :] >= cache.pos[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bkgs,bskh->bkgh", p.astype(cache.v.dtype), cache.v,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def _slot_insert(buf: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """vmapped-over-batch insert of (T, ...) at each slot's own position."""
    return jax.vmap(
        lambda b, n, p: jax.lax.dynamic_update_slice(b, n, (p,) + (0,) * (b.ndim - 1))
    )(buf, new, pos)


def update_cache(
    cache: KVCache,
    k_new: jax.Array,
    v_new: jax.Array,
    *,
    lengths: Optional[jax.Array] = None,
) -> KVCache:
    """Insert (B, T, KV, hd) at each slot's cache.pos (T=1 decode, T=S prefill).

    ``lengths`` (B,) advances each slot's counter by its REAL prompt length
    instead of T: right-padded prefill writes all T rows, but pad rows land
    at positions ≥ ``lengths[b]`` which :func:`decode_attention` never marks
    valid — pad tokens are structurally unattendable (the left-pad
    zeros-are-attended bug is dead).
    """
    adv = jnp.full_like(cache.pos, k_new.shape[1]) if lengths is None else lengths
    return KVCache(
        k=_slot_insert(cache.k, k_new.astype(cache.k.dtype), cache.pos),
        v=_slot_insert(cache.v, v_new.astype(cache.v.dtype), cache.pos),
        pos=cache.pos + adv,
    )


# ---------------------------------------------------------------------------
# PASM-quantized KV cache (beyond paper): int8 storage + scale folded into
# the score/output contractions — cache HBM traffic halves vs bf16, the
# paper's dictionary-compression idea applied to the *activation* cache
# [§Perf iteration qwen-decode/1].
# ---------------------------------------------------------------------------


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["k_q", "v_q", "k_scale", "v_scale", "pos"],
    meta_fields=[],
)
@dataclasses.dataclass
class QuantKVCache:
    k_q: jax.Array  # (B, S, KV, hd) int8
    v_q: jax.Array
    k_scale: jax.Array  # (B, S, KV) f32 — per token·head amax/127
    v_scale: jax.Array
    pos: jax.Array  # (B,) int32 — per slot


def init_quant_kv_cache(batch: int, seq: int, n_kv: int, hd: int) -> QuantKVCache:
    return QuantKVCache(
        k_q=jnp.zeros((batch, seq, n_kv, hd), jnp.int8),
        v_q=jnp.zeros((batch, seq, n_kv, hd), jnp.int8),
        k_scale=jnp.zeros((batch, seq, n_kv), jnp.float32),
        v_scale=jnp.zeros((batch, seq, n_kv), jnp.float32),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def _quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(B, T, KV, hd) → int8 values + (B, T, KV) scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def update_quant_cache(
    cache: QuantKVCache, k_new, v_new, *, lengths: Optional[jax.Array] = None
) -> QuantKVCache:
    kq, ks = _quantize_kv(k_new)
    vq, vs = _quantize_kv(v_new)
    adv = jnp.full_like(cache.pos, k_new.shape[1]) if lengths is None else lengths
    return QuantKVCache(
        k_q=_slot_insert(cache.k_q, kq, cache.pos),
        v_q=_slot_insert(cache.v_q, vq, cache.pos),
        k_scale=_slot_insert(cache.k_scale, ks, cache.pos),
        v_scale=_slot_insert(cache.v_scale, vs, cache.pos),
        pos=cache.pos + adv,
    )


def decode_attention_quant(
    q: jax.Array, cache: QuantKVCache, *, window: Optional[int] = None
) -> jax.Array:
    """Single-token attention over the int8 cache.

    Scales never materialize a dequantized cache: k_scale folds into the
    scores post-contraction; v_scale folds into the softmax weights.
    """
    B, one, H, hd = q.shape
    _, S, KV, _ = cache.k_q.shape
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum(
        "bkgh,bskh->bkgs", qg.astype(jnp.float32), cache.k_q.astype(q.dtype).astype(jnp.float32)
    )
    s = s * jnp.transpose(cache.k_scale, (0, 2, 1))[:, :, None, :] * scale
    k_pos = jnp.arange(S)
    valid = k_pos[None, :] < cache.pos[:, None]  # (B, S) — per slot
    if window is not None:
        valid &= k_pos[None, :] >= cache.pos[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    pv = p * jnp.transpose(cache.v_scale, (0, 2, 1))[:, :, None, :]  # fold v scale
    o = jnp.einsum("bkgs,bskh->bkgh", pv.astype(jnp.float32), cache.v_q.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)
