#!/usr/bin/env bash
# Tier-1 CI: the full offline test suite, the examples on the unified
# ConvParams/conv2d surface (DeprecationWarnings are errors: the examples must
# not touch the legacy shims), an interpret-mode smoke of the batched conv
# benchmark (exercises the Pallas PASM kernels + fused epilogue end to end,
# and leaves BENCH_conv.json behind so perf is tracked per PR), the
# implicit-vs-explicit im2col gate (the implicit engine's modeled HBM bytes
# must be strictly below the explicit path's on the AlexNet conv1 geometry),
# the fused conv/ReLU/max-pool suite + gate (the fused stage's modeled bytes
# strictly below implicit-unfused plus the separate reduce_window pass on
# conv1, read from the BENCH_conv.json engine/pool-stamped rows), the slab
# gate (the over-budget 3x512x512 bigimg shape must run slab-implicit with
# >= 2 row-band slabs — n_slabs/slab_rows stamped in BENCH_conv.json — and
# model strictly fewer HBM bytes than the explicit patch stream),
# the PasmParams suite (dense | shared | packed | grouped linear dispatch
# through the Pallas kernels + the Whisper-tiny voice smoke), the sharded
# conv + params suites on 8 host-platform fake devices (shard_map
# bit-exactness — both skip their mesh tests on one device, so this run is
# where they actually execute), the dense weight-stream gate (BENCH_dense.json
# from pasm_roofline.py: a packed transformer FFN layer must model strictly
# fewer weight-stream bytes than dense bf16), the continuous-batching serve
# suite on one device AND on 8 fake devices plus the traffic-replay smoke
# (BENCH_serve.json: measured p50/p99/tok_s/img_s rows must exist and the
# PASM-quantized modeled decode tok/s must be >= dense — the weight-stream
# win end to end), the fault-tolerance chaos suite (seeded FaultPlan:
# deadlines, backpressure, numeric quarantine, retry/degradation) on one
# device AND on 8 fake devices, the fault-replay gate (serve_bench --faults:
# the chaos replay must drain with zero stuck requests and >= 95% of
# non-faulted SLO'd requests meeting their SLO), the TRAINING chaos suite
# (seeded TrainFaultPlan: fused non-finite guard, CRC/fsync checkpoint
# integrity, bit-exact crash-resume, supervisor failure classification,
# plus the checkpoint-roundtrip property suite) on one device AND on 8 fake
# devices, the train_bench smoke + gates (BENCH_train.json: the crash-resume
# row must stamp resume_bitexact=true, the corrupt-latest row
# fallback_ok=true, and both the fault-free trajectory and the full
# chaos-drill rows must exist with finite losses), and the sharding gate:
# --devices 8 per-device modeled
# HBM bytes on AlexNet conv1 strictly below the single-device figure for
# the same global batch.
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== examples (new API, deprecation warnings are errors) =="
python -W error::DeprecationWarning examples/quickstart.py
python -W error::DeprecationWarning examples/paper_conv.py

echo "== smoke: batched conv benchmark (interpret mode) =="
python benchmarks/conv_bench.py --smoke --json

test -s BENCH_conv.json && echo "BENCH_conv.json written"

echo "== smoke: implicit vs explicit im2col HBM bytes (AlexNet conv1) =="
# two separate --engine runs by design: each exercises its engine's full
# batched path in isolation before the byte comparison (the modeled numbers
# alone could be read from BENCH_conv.json, but would not prove both
# engines still run)
trap 'rm -f BENCH_conv_explicit.json BENCH_conv_implicit.json' EXIT
python benchmarks/conv_bench.py --smoke --engine kernel --json BENCH_conv_explicit.json
python benchmarks/conv_bench.py --smoke --engine kernel_implicit --json BENCH_conv_implicit.json
python - <<'PY'
import json

def row(path, name):
    rows = {r["name"]: r for r in json.load(open(path))["records"]}
    return rows[name]

e = row("BENCH_conv_explicit.json", "conv.batched.kernel.alexnet_conv1.bs1")
i = row("BENCH_conv_implicit.json", "conv.batched.kernel_implicit.alexnet_conv1.bs1")
assert i["hbm_bytes"] is not None and e["hbm_bytes"] is not None, (i, e)
assert i["hbm_bytes"] < e["hbm_bytes"], (
    f"implicit im2col must model strictly fewer HBM bytes than explicit on "
    f"the AlexNet conv1 geometry: implicit={i['hbm_bytes']} explicit={e['hbm_bytes']}"
)
print(f"implicit {i['hbm_bytes']} B < explicit {e['hbm_bytes']} B "
      f"({e['hbm_bytes'] / i['hbm_bytes']:.2f}x reduction) OK")
PY

echo "== fused conv/ReLU/max-pool: suite + HBM-bytes gate (AlexNet conv1) =="
python -m pytest -q tests/test_conv_pool.py
python - <<'PY'
import json

rows = {r["name"]: r for r in json.load(open("BENCH_conv.json"))["records"]}
fused = rows["conv.batched.kernel_implicit_pool.alexnet_conv1.bs1"]
unfused = rows["conv.batched.kernel_implicit.alexnet_conv1.bs1"]
assert fused["engine"] == "kernel_implicit" and fused["pool"] == 2, fused
assert unfused["pool"] == 1, unfused
assert fused["hbm_bytes"] is not None and unfused["hbm_bytes"] is not None
# the unfused path additionally pays the separate reduce_window pass: read
# the full pre-pool map, store the pooled one (conv1 valid_centred:
# 54x54 -> 27x27 over 96 channels, f32)
pool_pass = 54 * 54 * 96 * 4 + 27 * 27 * 96 * 4
assert fused["hbm_bytes"] < unfused["hbm_bytes"] + pool_pass, (fused, unfused)
print(f"fused conv/ReLU/pool {fused['hbm_bytes']} B < implicit-unfused "
      f"{unfused['hbm_bytes']} B + separate pool pass {pool_pass} B "
      f"({(unfused['hbm_bytes'] + pool_pass) / fused['hbm_bytes']:.2f}x) OK")
PY

echo "== slab pipeline: over-budget bigimg HBM-bytes gate (512x512 conv1) =="
python - <<'PY'
import json

rows = {r["name"]: r for r in json.load(open("BENCH_conv.json"))["records"]}
imp = rows["conv.batched.kernel_implicit.bigimg_conv1.bs1"]
exp = rows["conv.batched.kernel.bigimg_conv1.bs1"]
# the 3x512x512 image blows the 6 MiB whole-image budget: the implicit
# engine must run it as >= 2 row-band slabs (no explicit fallback) and
# still model strictly fewer HBM bytes than the explicit patch stream
assert imp["n_slabs"] >= 2 and imp["slab_rows"] is not None, imp
assert imp["hbm_bytes"] is not None and exp["hbm_bytes"] is not None
assert imp["hbm_bytes"] < exp["hbm_bytes"], (
    f"slab-implicit must model strictly fewer HBM bytes than explicit on "
    f"the over-budget bigimg shape: implicit={imp['hbm_bytes']} "
    f"explicit={exp['hbm_bytes']}"
)
print(f"bigimg slab-implicit {imp['hbm_bytes']} B ({imp['n_slabs']} slabs of "
      f"{imp['slab_rows']} rows) < explicit {exp['hbm_bytes']} B "
      f"({exp['hbm_bytes'] / imp['hbm_bytes']:.2f}x reduction) OK")
PY

echo "== PasmParams: dense-kernel dispatch + Whisper-voice smoke =="
python -m pytest -q tests/test_params.py

echo "== sharded conv + params: shard_map suites on 8 fake devices =="
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m pytest -q tests/test_conv_sharded.py tests/test_params.py

echo "== smoke: dense weight-stream bytes (BENCH_dense.json gate) =="
python benchmarks/pasm_roofline.py --smoke --json
test -s BENCH_dense.json && echo "BENCH_dense.json written"
python - <<'PY'
import json

rows = {r["name"]: r for r in json.load(open("BENCH_dense.json"))["records"]}
dense = rows["dense_bytes.qwen3.ffn.dense_bf16"]
packed = rows["dense_bytes.qwen3.ffn.int4"]
assert packed["bins"] == 16 and packed["bits"] == 4 and packed["groups"] == 1, packed
assert dense["hbm_bytes"] is not None and packed["hbm_bytes"] is not None
assert packed["hbm_bytes"] < dense["hbm_bytes"], (
    f"a packed transformer FFN layer must model strictly fewer weight-stream "
    f"bytes than dense bf16: packed={packed['hbm_bytes']} dense={dense['hbm_bytes']}"
)
print(f"FFN packed {packed['hbm_bytes']} B < dense bf16 {dense['hbm_bytes']} B "
      f"(weight stream {packed['compression_ratio']}x smaller) OK")
PY

echo "== serve: continuous-batching suite (single device) =="
python -m pytest -q tests/test_serve.py tests/test_engine.py

echo "== serve: continuous-batching suite (8 fake devices) =="
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m pytest -q tests/test_serve.py

echo "== serve: fault-tolerance chaos suite (single device) =="
python -m pytest -q tests/test_serve_faults.py

echo "== serve: fault-tolerance chaos suite (8 fake devices) =="
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m pytest -q tests/test_serve_faults.py

echo "== smoke: traffic replay (BENCH_serve.json + PASM decode tok/s gate) =="
python benchmarks/serve_bench.py --smoke --json --faults
test -s BENCH_serve.json && echo "BENCH_serve.json written"
python - <<'PY'
import json, math

rows = {r["name"]: r for r in json.load(open("BENCH_serve.json"))["records"]}
# measured replay rows exist and are finite
for name in ("serve.pasm.lm.p50_latency", "serve.pasm.lm.p99_latency",
             "serve.pasm.lm.tok_s", "serve.pasm.cnn.img_s"):
    assert name in rows and math.isfinite(rows[name]["us_per_call"]), name
assert rows["serve.pasm.lm.tok_s"]["tok_s"] > 0
assert rows["serve.pasm.cnn.img_s"]["img_s"] > 0
# the weight-stream win must show up end to end: PASM-quantized modeled
# decode tok/s (memory roofline over the stored weight stream) >= dense
dense = rows["serve.decode.tok_s_modeled.dense"]
pasm = rows["serve.decode.tok_s_modeled.pasm"]
assert pasm["tok_s_modeled"] >= dense["tok_s_modeled"], (
    f"PASM modeled decode tok/s must be >= dense: "
    f"pasm={pasm['tok_s_modeled']:.0f} dense={dense['tok_s_modeled']:.0f}"
)
print(f"PASM modeled decode {pasm['tok_s_modeled']:.0f} tok/s >= dense "
      f"{dense['tok_s_modeled']:.0f} tok/s "
      f"({pasm['tok_s_modeled'] / dense['tok_s_modeled']:.2f}x, "
      f"weight stream {dense['hbm_bytes']} -> {pasm['hbm_bytes']} B) OK")
# fault-replay gate: the seeded chaos replay must drain every request
# (zero stuck) and >= 95% of the non-faulted SLO'd requests must still
# meet their SLO under injected faults
drained = rows["serve.faults.drained"]
assert drained["n_stuck"] == 0, drained
slo = rows["serve.faults.slo"]
assert slo["slo_met"] + slo["slo_missed"] > 0, slo
assert slo["slo_frac"] >= 0.95, (
    f"under injected faults, >= 95% of non-faulted requests must meet SLO: "
    f"met={slo['slo_met']} missed={slo['slo_missed']} frac={slo['slo_frac']:.2f}"
)
print(f"fault replay drained (0 stuck), SLO {slo['slo_met']}/"
      f"{slo['slo_met'] + slo['slo_missed']} met "
      f"({100 * slo['slo_frac']:.0f}% >= 95%) OK")
PY

echo "== train: fault-tolerance chaos suite (single device) =="
python -m pytest -q tests/test_train_faults.py tests/test_ckpt_prop.py

echo "== train: fault-tolerance chaos suite (8 fake devices) =="
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m pytest -q tests/test_train_faults.py

echo "== smoke: QAT train loop + chaos drill (BENCH_train.json gates) =="
python benchmarks/train_bench.py --smoke --faults --json
test -s BENCH_train.json && echo "BENCH_train.json written"
python - <<'PY'
import json, math

rows = {r["name"]: r for r in json.load(open("BENCH_train.json"))["records"]}
# the fault-free trajectory row exists with a real step time and eval losses
ref = rows["train.qat.alexnet_smoke"]
assert ref["us_per_call"] > 0, ref
assert math.isfinite(ref["loss_first"]) and math.isfinite(ref["loss_last"]), ref
# crash-resume gate: the merged per-step losses and final params of the
# crashed-and-restored run must be BIT-exact vs the uninterrupted reference
res = rows["train.fault.resume_bitexact"]
assert res["resume_bitexact"] is True, res
assert res["restarts"] >= 1 and res["resumed_at"], res
# corrupt-latest gate: a byte-flipped newest checkpoint must fall back to
# the newest older step that passes CRC
fb = rows["train.fault.ckpt_fallback"]
assert fb["fallback_ok"] is True and fb["to_step"] is not None, fb
assert fb["to_step"] < fb["from_step"], fb
# the full chaos drill fired its injections, the guard skipped the poisoned
# steps, and the run still reached the final step with a finite loss
chaos = rows["train.qat.faults"]
assert chaos["n_injections"] >= 4 and chaos["n_skipped"] >= 1, chaos
assert math.isfinite(chaos["loss_last"]), chaos
print(f"train gates OK: eval loss {ref['loss_first']:.3f}->{ref['loss_last']:.3f}, "
      f"resume bit-exact after crash@{res['crash_step']} "
      f"({res['restarts']} restart), ckpt fallback step_{fb['from_step']}"
      f"->step_{fb['to_step']}, chaos drill {chaos['n_injections']} injections/"
      f"{chaos['n_skipped']} guard skips/{chaos['restarts']} restarts")
PY

echo "== smoke: per-device HBM bytes under --devices 8 (AlexNet conv1) =="
trap 'rm -f BENCH_conv_explicit.json BENCH_conv_implicit.json BENCH_conv_dev8.json' EXIT
python benchmarks/conv_bench.py --smoke --devices 8 --json BENCH_conv_dev8.json
python - <<'PY'
import json

rows = {r["name"]: r for r in json.load(open("BENCH_conv_dev8.json"))["records"]}
r = rows["conv.sharded.kernel_implicit.alexnet_conv1.bs8.d8"]
assert r["devices"] == 8 and r["mesh_shape"] == [8, 1], r
per_dev, single = r["hbm_bytes"], r["hbm_bytes_1dev"]
assert per_dev is not None and single is not None, r
assert per_dev < single, (
    f"sharding AlexNet conv1 over 8 devices must model strictly fewer "
    f"per-device HBM bytes than one device doing the whole batch: "
    f"per-device={per_dev} single={single}"
)
print(f"per-device {per_dev} B < single-device {single} B "
      f"({single / per_dev:.2f}x reduction over 8 devices) OK")
PY

echo "CI OK"
