#!/usr/bin/env bash
# Tier-1 CI: the full offline test suite, the examples on the unified
# ConvParams/conv2d surface (DeprecationWarnings are errors: the examples must
# not touch the legacy shims), and an interpret-mode smoke of the batched conv
# benchmark (exercises the Pallas PASM kernels + fused epilogue end to end,
# and leaves BENCH_conv.json behind so perf is tracked per PR).
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== examples (new API, deprecation warnings are errors) =="
python -W error::DeprecationWarning examples/quickstart.py
python -W error::DeprecationWarning examples/paper_conv.py

echo "== smoke: batched conv benchmark (interpret mode) =="
python benchmarks/conv_bench.py --smoke --json

test -s BENCH_conv.json && echo "BENCH_conv.json written"

echo "CI OK"
