#!/usr/bin/env bash
# Tier-1 CI: the full offline test suite plus an interpret-mode smoke of the
# batched conv benchmark (exercises the Pallas PASM kernels end to end).
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: batched conv benchmark (interpret mode) =="
python benchmarks/conv_bench.py --smoke

echo "CI OK"
