"""Traffic-replay serving benchmark: mixed LM + CNN under Poisson arrivals.

Replays a SEEDED trace (Poisson inter-arrival ticks, mixed LM decode and CNN
classification requests) through the continuous-batching service loop
(serve/engine.py + serve/batcher.py) and rolls the per-request timelines
(serve/metrics.py) into ``BENCH_serve.json``:

- measured rows: p50/p99 end-to-end latency and TTFT per traffic class,
  wall tok/s and img/s, mean slot occupancy, queue stats;
- modeled rows: decode tok/s on the v5e memory roofline
  (``HBM_BW / weight-stream bytes per decode step``) for dense-bf16 vs the
  PASM-quantized container — the weight-stream argument (DESIGN.md §2)
  applied to serving, gated by scripts/ci.sh (PASM modeled decode tok/s must
  be ≥ dense; wall-clock on a CPU host measures dequant arithmetic, not the
  HBM stream the accelerator would move, so the roofline rows carry the
  gate while the measured rows track this host's trajectory);
- fault rows (``--faults``): the SAME seeded trace replayed under a seeded
  :class:`~repro.serve.faults.FaultPlan` (NaN poisoning, prefill/decode
  raises, a slow-tick stall) on a deterministic tick clock —
  ``serve.faults.*`` rows carry the failure counters, the non-faulted SLO
  hit fraction, per-failure-kind latency, and the drained/stuck verdict
  that scripts/ci.sh gates (zero stuck, ≥95 % of non-faulted requests meet
  SLO).

    PYTHONPATH=src python benchmarks/serve_bench.py --smoke --json --faults
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))
sys.path.insert(0, str(_ROOT))  # direct-script runs: make `benchmarks` importable

import jax
import numpy as np

from repro.configs import get_cnn_config, get_config
from repro.models import api, cnn
from repro.models.common import quantize_params, weight_bytes
from repro.roofline import HBM_BW
from repro.serve.batcher import CnnBatcher, MixedBatcher
from repro.serve.engine import Engine
from repro.serve.faults import FaultPlan
from repro.serve.metrics import FAILURE_COUNTERS, Metrics

from benchmarks.common import bench_row, emit

_RECORDS: list = []


def record(name, us, derived="", **kw) -> None:
    _RECORDS.append(bench_row(name, us, derived=derived, **kw))
    emit(name, us, derived, kw.get("hbm_bytes"))


def make_trace(rng, *, n_lm, n_cnn, rate, vocab, in_chw, max_prompt, max_new):
    """Seeded Poisson replay trace: [(arrival_tick, kind, payload), ...]."""
    events = []
    t = 0.0
    for kind in ["lm"] * n_lm + ["cnn"] * n_cnn:
        t += rng.exponential(1.0 / rate)  # Poisson arrivals → exp inter-arrival
        events.append((t, kind))
    rng.shuffle(events)  # interleave the classes along the arrival axis
    events.sort(key=lambda e: e[0])
    trace = []
    C, H, W = in_chw
    for t, kind in events:
        if kind == "lm":
            payload = {
                "prompt": rng.integers(0, vocab, size=int(rng.integers(3, max_prompt))),
                "max_new": max_new,
            }
        else:
            h = int(rng.integers(8, H + 1))
            w = int(rng.integers(8, W + 1))
            payload = {"image": rng.standard_normal((C, h, w)).astype(np.float32)}
        trace.append((int(t), kind, payload))
    return trace


def replay(trace, engine: Engine, cnn_b: CnnBatcher, *, slo_s=None,
           clock_box=None) -> int:
    """Drive the mixed service loop: submit due arrivals, tick, repeat.

    With ``clock_box`` (a one-element list the engine's metrics clock and
    injected ``sleep`` read/advance), the replay runs on a deterministic
    tick clock: one tick = one second, slow-tick faults add their stall on
    top — deadlines and the SLO gate are then seed-reproducible.
    """
    mix = MixedBatcher(engine, cnn_b)
    i, tick = 0, 0
    while i < len(trace) or not mix.drained:
        while i < len(trace) and trace[i][0] <= tick:
            _, kind, payload = trace[i]
            if kind == "lm":
                engine.submit(payload["prompt"], payload["max_new"], slo_s=slo_s)
            else:
                cnn_b.submit(payload["image"])
            i += 1
        mix.tick()
        tick += 1
        if clock_box is not None:
            clock_box[0] += 1.0
        if tick > 100_000:
            raise RuntimeError("replay did not drain")
    return tick


def measured_rows(rollup: dict, *, slots: int, tag: str) -> None:
    """Metrics rollup → BENCH rows (latency rows carry µs in us_per_call)."""
    for kind in ("lm", "cnn"):
        for pct in ("p50", "p99"):
            lat = rollup[f"{kind}_{pct}_latency_s"]
            record(f"serve.{tag}.{kind}.{pct}_latency", float(lat * 1e6),
                   derived=f"n={rollup[f'{kind}_n']}", n_requests=rollup[f"{kind}_n"])
            ttft = rollup[f"{kind}_{pct}_ttft_s"]
            record(f"serve.{tag}.{kind}.{pct}_ttft", float(ttft * 1e6),
                   n_requests=rollup[f"{kind}_n"])
    tok_s = rollup["tok_s"]
    record(f"serve.{tag}.lm.tok_s", float(1e6 / tok_s) if tok_s else float("nan"),
           derived=f"{tok_s:.1f} tok/s", tok_s=tok_s)
    img_s = rollup["img_s"]
    record(f"serve.{tag}.cnn.img_s", float(1e6 / img_s) if img_s else float("nan"),
           derived=f"{img_s:.1f} img/s", img_s=img_s)
    record(f"serve.{tag}.occupancy", 0.0,
           derived=f"mean {rollup['mean_occupancy']:.2f} over {slots} slots",
           mean_occupancy=rollup["mean_occupancy"],
           slo_met=rollup["slo_met"], slo_missed=rollup["slo_missed"])


def fault_rows(roll: dict, *, tag: str = "faults") -> None:
    """Fault-replay rollup → BENCH rows: counters, SLO fraction over the
    NON-faulted population, per-failure-kind latency, drained verdict."""
    counters = {k: roll[k] for k in FAILURE_COUNTERS}
    tripped = ", ".join(f"{k[2:]}={v}" for k, v in counters.items() if v)
    record(f"serve.{tag}.counters", 0.0,
           derived=tripped or "no faults tripped",
           n_failed=roll["n_failed"], **counters)
    met, missed = roll["slo_met"], roll["slo_missed"]
    frac = met / max(met + missed, 1)
    record(f"serve.{tag}.slo", 0.0,
           derived=f"{met}/{met + missed} non-faulted requests met SLO",
           slo_met=met, slo_missed=missed, slo_frac=frac)
    for kind in ("deadline", "numeric", "error", "rejected"):
        n = roll.get(f"failed_{kind}_n", 0)
        if n:
            record(f"serve.{tag}.failed.{kind}.p99_latency",
                   float(roll[f"failed_{kind}_p99_latency_s"] * 1e6),
                   derived=f"n={n}", n_requests=n)
    record(f"serve.{tag}.drained", 0.0,
           derived=f"n_stuck={roll['n_stuck']} n_done={roll['n_done']}"
                   f"/{roll['n_requests']}",
           n_stuck=roll["n_stuck"], n_done=roll["n_done"],
           n_requests=roll["n_requests"])


def modeled_decode_rows(dense_params, pasm_params, *, batch: int) -> None:
    """Memory-roofline decode tok/s: the batched step streams the weights
    once, so tok/s = batch · HBM_BW / weight_bytes (decode is weight-bound;
    DESIGN.md §2)."""
    for tag, params in (("dense", dense_params), ("pasm", pasm_params)):
        wb = weight_bytes(params)
        stream = wb["stored"] if tag == "pasm" else wb["dense"]
        tok_s = batch * HBM_BW / max(stream, 1)
        record(f"serve.decode.tok_s_modeled.{tag}", 1e6 / tok_s,
               derived=f"{tok_s:.0f} tok/s @ {stream} weight B",
               hbm_bytes=int(stream), tok_s_modeled=tok_s, batch=batch)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI sizing")
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json", default=None,
                    metavar="PATH", help="write rows to JSON (default BENCH_serve.json)")
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--lm-requests", type=int, default=12)
    ap.add_argument("--cnn-requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.5, help="arrivals per tick")
    ap.add_argument("--bins", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--faults", action="store_true",
                    help="also replay the trace under a seeded FaultPlan")
    ap.add_argument("--policy", default="reject",
                    help="bounded-queue admission policy for the fault replay")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded queue depth for the fault replay")
    ap.add_argument("--max-retries", type=int, default=1)
    ap.add_argument("--slo-ticks", type=float, default=400.0,
                    help="per-request SLO (ticks on the deterministic clock)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.lm_requests = min(args.lm_requests, 6)
        args.cnn_requests = min(args.cnn_requests, 4)
        args.max_new = min(args.max_new, 6)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = api.get_model(cfg)
    dense_params = model.init_params(cfg, jax.random.PRNGKey(args.seed))
    # min_weight_elems=1024 keeps smoke-size layers quantizable (the default
    # B ≪ N rule would leave the tiny smoke matrices dense and the modeled
    # weight stream identical to dense — no win to measure)
    qcfg = cfg.with_quant(enabled=True, bins=args.bins, impl="dequant",
                          min_weight_elems=1024)
    pasm_params = quantize_params(dense_params, qcfg)

    ccfg = get_cnn_config("alexnet", smoke=args.smoke)
    cparams = cnn.quantize(cnn.init_params(ccfg, jax.random.PRNGKey(args.seed)), ccfg)

    rng = np.random.default_rng(args.seed)
    trace = make_trace(
        rng, n_lm=args.lm_requests, n_cnn=args.cnn_requests, rate=args.rate,
        vocab=cfg.vocab, in_chw=ccfg.in_chw,
        max_prompt=max(4, args.max_seq // 4), max_new=args.max_new,
    )

    print("name,us_per_call,hbm_bytes,derived")
    for tag, c, p in (("dense", cfg, dense_params), ("pasm", qcfg, pasm_params)):
        metrics = Metrics()
        engine = Engine(c, p, batch_slots=args.slots, max_seq=args.max_seq,
                        metrics=metrics)
        cnn_b = CnnBatcher(ccfg, cparams, max_batch=args.slots, metrics=metrics)
        ticks = replay(trace, engine, cnn_b)
        roll = metrics.rollup()
        assert roll["n_stuck"] == 0, roll
        measured_rows(roll, slots=args.slots, tag=tag)
        print(f"[serve_bench] {tag}: {roll['n_done']} requests drained "
              f"in {ticks} ticks", file=sys.stderr)

    modeled_decode_rows(dense_params, pasm_params, batch=args.slots)

    if args.faults:
        # same trace, PASM weights, seeded chaos on a deterministic tick
        # clock: the metrics clock reads clock_box[0] (one tick = 1 s), the
        # injected sleep adds slow-fault stalls on top — fully reproducible
        clock_box = [0.0]
        metrics = Metrics(clock=lambda: clock_box[0])
        plan = FaultPlan.sample(
            args.seed, n_ticks=20, n_slots=args.slots,
            n_requests=args.lm_requests, n_nan=2, n_prefill=1, n_decode=1,
            n_slow=1, slow_delay_s=3.0,
        )
        engine = Engine(
            qcfg, pasm_params, batch_slots=args.slots, max_seq=args.max_seq,
            metrics=metrics, faults=plan, max_retries=args.max_retries,
            max_queue=args.max_queue, policy=args.policy,
            sleep=lambda d: clock_box.__setitem__(0, clock_box[0] + d),
        )
        cnn_b = CnnBatcher(ccfg, cparams, max_batch=args.slots, metrics=metrics)
        ticks = replay(trace, engine, cnn_b, slo_s=float(args.slo_ticks),
                       clock_box=clock_box)
        roll = metrics.rollup()
        assert roll["n_stuck"] == 0, roll
        fault_rows(roll, tag="faults")
        print(f"[serve_bench] faults: {len(plan.fired)} injections fired, "
              f"{roll['n_done']}/{roll['n_requests']} done, "
              f"{roll['n_failed']} failed, drained in {ticks} ticks",
              file=sys.stderr)

    if args.json:
        payload = {
            "benchmark": "serve",
            "smoke": bool(args.smoke),
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "devices": 1,
            "seed": args.seed,
            "trace": {"lm": args.lm_requests, "cnn": args.cnn_requests,
                      "rate": args.rate},
            "faults": bool(args.faults),
            "records": _RECORDS,
        }
        Path(args.json).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {len(_RECORDS)} records to {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
