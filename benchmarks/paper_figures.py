"""Reproductions of every paper table/figure via the calibrated HW model.

Each function regenerates one artifact and prints model-vs-paper rows so the
deviation is visible (the model is calibrated at the §2.4 anchor; everything
else is extrapolation — see core/hwmodel.py).
"""
from __future__ import annotations

from repro.core import hwmodel as hw
from repro.core import pas

from benchmarks.common import emit


def fig7_8_standalone_pasm():
    """Figs 7/8: 16-MAC vs 16-PAS-4-MAC over W ∈ {4,8,16,32}, B=16."""
    for W in (4, 8, 16, 32):
        g = hw.gate_ratio(W, 16)
        p = hw.power_model(W, 16)
        emit(
            f"fig7.gates.W{W}",
            0.0,
            f"total_ratio={g['total']:.3f} seq={g['seq']:.3f} logic={g['logic']:.3f}",
        )
        emit(f"fig8.power.W{W}", 0.0, f"total={p['total']:.3f} dyn={p['dynamic']:.3f} leak={p['leakage']:.3f}")
    g = hw.gate_ratio(32, 16)
    emit("fig7.paper_anchor.W32", 0.0, f"model_total={g['total']:.3f} paper_total=0.340")


def fig9_10_bins_sweep():
    """Figs 9/10: B ∈ {4,16,64,256} at W=32 — crossover at large B."""
    for B in (4, 16, 64, 256):
        g = hw.gate_ratio(32, B)
        p = hw.power_model(32, B)
        emit(f"fig9.gates.B{B}", 0.0, f"total_ratio={g['total']:.3f} seq={g['seq']:.3f}")
        emit(f"fig10.power.B{B}", 0.0, f"total={p['total']:.3f}")
    emit("fig9.crossover", 0.0, f"seq_ratio_B256={hw.gate_ratio(32, 256)['seq']:.2f} (>1 per paper)")


def fig14_latency():
    """Fig 14: PASM latency overhead vs weight-shared conv (cycle model)."""
    for B in (4, 8, 16):
        r = hw.conv_latency_ratio(B)
        paper = {4: 1.085, 16: 1.1275}.get(B)
        tag = f" paper={paper}" if paper else ""
        emit(f"fig14.latency.B{B}", 0.0, f"ratio={r:.4f}{tag}")
    emit("sec2.2.cycles", 0.0, f"16-PAS-4-MAC(1024,B=16)={pas.pasm_cycles(1024, 16, 4)} paper=1088")


def fig15_18_asic_accel():
    """Figs 15-18: in-CNN accelerator, 45nm ASIC @ 1 GHz."""
    for B in (4, 8, 16):
        r = hw.accel_ratio_asic(B)
        emit(f"fig15_17.asic.B{B}.32bit", 0.0, f"gates={r['gates']:.3f} power={r['power']:.3f}")
    r8 = hw.accel_ratio_asic(4, W=8)
    emit("fig18.asic.B4.8bit", 0.0, f"gates={r8['gates']:.3f} power={r8['power']:.3f} (paper: .802/.687)")


def fig19_22_fpga_accel():
    """Figs 19-22: Zynq XC7Z045 @ 200 MHz — DSP/BRAM/power."""
    for B in (4, 8, 16):
        r = hw.accel_ratio_fpga(B)
        emit(
            f"fig19_21.fpga.B{B}",
            0.0,
            f"dsp={r['dsp']:.2f} bram={r['bram']:.2f} power={r['power']:.3f}",
        )
    ws = hw.fpga_resources(4, pasm=False)
    pm = hw.fpga_resources(4, pasm=True)
    emit("fig19.fpga.dsp_counts", 0.0, f"weight_shared={ws['dsp']} pasm={pm['dsp']} (405 vs 3)")


def table2_macops():
    """Table 2: MAC operations per output element."""
    for C in (32, 128, 512):
        for k in (1, 3, 5, 7):
            emit(f"table2.C{C}.K{k}x{k}", 0.0, f"macs={C * k * k}")
