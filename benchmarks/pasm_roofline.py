"""Beyond-paper: PASM weight-byte accounting + matmul formulation timings.

The TPU-relevant win of PASM is the HBM weight-traffic reduction in
bandwidth-bound regimes (DESIGN.md §2).  This benchmark reports, per layer
shape, the bytes a decode step must move under dense-bf16 vs PASM-uint8 vs
PASM-int4 storage, the implied v5e memory-roofline time, and measured
wall-times of the dequant (weight-shared) and PAS (paper-faithful)
formulations on this host.

Run directly it also emits ``BENCH_dense.json``: per transformer-layer rows
(modeled weight-stream bytes from :func:`repro.core.hwmodel
.dense_weight_stream_bytes`, with ``bins``/``bits``/``groups`` and the
container's ``compression_ratio`` stamped on every quantized row), plus
measured ``nn.layers.linear`` timings over :class:`~repro.core.params
.PasmParams` on this host — the dense-side counterpart of
``conv_bench.py``/BENCH_conv.json, gated by scripts/ci.sh (packed must model
strictly fewer bytes than dense bf16).

    PYTHONPATH=src python benchmarks/pasm_roofline.py [--smoke] [--json [PATH]]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))
sys.path.insert(0, str(_ROOT))  # direct-script runs: make `benchmarks` importable

import jax
import jax.numpy as jnp

from repro.core import hwmodel, pas, pasm
from repro.core.params import PasmParams
from repro.kernels import ops
from repro.nn import layers as L
from repro.roofline import HBM_BW

from benchmarks.common import bench_row, emit, time_us

SHAPES = [
    ("qwen3.ffn", 5120, 25_600),
    ("kimi.expert", 7168, 2048),
    ("stablelm.attn", 2560, 2560),
]

_RECORDS: list = []


def record(name, us, derived="", **kw) -> None:
    _RECORDS.append(bench_row(name, us, derived=derived, **kw))
    emit(name, us, derived, kw.get("hbm_bytes"))


def weight_bytes_table():
    for name, K, N in SHAPES:
        dense = K * N * 2
        u8 = K * N + 16 * 4
        i4 = K * N // 2 + 16 * 4
        emit(
            f"pasm_bytes.{name}",
            0.0,
            f"dense={dense} uint8={u8} int4={i4} "
            f"roofline_us dense={dense / HBM_BW * 1e6:.1f} int4={i4 / HBM_BW * 1e6:.1f} "
            f"(4.0x memory-term reduction)",
        )


def matmul_formulations():
    """Measured: dense vs dequant(weight-shared) vs PAS-histogram (M=8 decode-ish)."""
    K, N, M = 1024, 1024, 8
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    t16 = pasm.quantize(w, bins=16)
    dense = jax.jit(lambda x: x @ w)
    dequant = jax.jit(lambda x: pas.weight_shared_matmul(x, t16))
    pas_form = jax.jit(lambda x: pas.pasm_matmul(x, t16))
    t_d = time_us(dense, x)
    t_q = time_us(dequant, x)
    t_p = time_us(pas_form, x, iters=5)
    emit("pasm_matmul.dense", t_d)
    emit("pasm_matmul.dequant", t_q, f"vs dense {t_q / t_d:.2f}x")
    emit(
        "pasm_matmul.pas_histogram",
        t_p,
        f"vs dense {t_p / t_d:.2f}x (B x FLOPs — the measured DESIGN.md trade-off)",
    )


def kernel_oracle_check():
    """The fused kernel (interpret) agrees with its oracle at bench shapes."""
    from repro.kernels import ref

    K, N, M = 512, 256, 16
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    t = pasm.quantize(w, bins=16)
    got = ops.pasm_matmul(x, t, interpret=True)
    want = ref.pasm_matmul_ref(x, t.idx, t.codebook, packed=t.packed)
    err = float(jnp.abs(got - want).max())
    emit("pasm_kernel.allclose", 0.0, f"max_err={err:.2e}")


# ---------------------------------------------------------------------------
# BENCH_dense.json: modeled weight-stream bytes + measured linear() timings
# ---------------------------------------------------------------------------


def dense_layer_byte_rows(*, decode_T: int = 1) -> None:
    """Modeled HBM bytes per layer storage kind (hwmodel, no execution).

    One row per (layer shape × storage): dense bf16, PASM uint8 (B=16),
    PASM int4-packed (B=16, G=1) and grouped int4 (G=8) — decode regime
    (``T = decode_T`` tokens), where the weight stream dominates.
    """
    for name, K, N in SHAPES:
        dense = hwmodel.dense_hbm_traffic(T=decode_T, K=K, N=N, dense=True)
        record(f"dense_bytes.{name}.dense_bf16", 0.0, "modeled, decode T=1",
               hbm_bytes=dense, bins=None, bits=None, groups=None)
        for label, bins, groups, packed in (
            ("uint8", 256, 1, False),
            ("int4", 16, 1, True),
            ("int4_g8", 16, 8, True),
        ):
            b = hwmodel.dense_hbm_traffic(
                T=decode_T, K=K, N=N, bins=bins, groups=groups, packed=packed
            )
            w_dense = hwmodel.dense_weight_stream_bytes(K, N, dense=True)
            w_q = hwmodel.dense_weight_stream_bytes(
                K, N, bins=bins, groups=groups, packed=packed
            )
            record(
                f"dense_bytes.{name}.{label}", 0.0,
                f"modeled, decode T=1; weight stream {w_dense / w_q:.2f}x smaller",
                hbm_bytes=b, bins=bins, bits=4 if packed else 8, groups=groups,
                compression_ratio=round(w_dense / w_q, 3),
            )


def linear_formulation_rows(*, smoke: bool = True) -> None:
    """Measured: one transformer FFN-ish linear through every PasmParams path."""
    K, N, T = (512, 1024, 16) if smoke else (2048, 8192, 64)
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N)) * K ** -0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (T, K))
    shared = PasmParams.quantize(w, bins=16)
    packed = shared.pack()
    grouped = PasmParams.quantize(w, bins=16, groups=8)
    iters = 3 if smoke else 20

    t_dense = time_us(jax.jit(lambda x: L.linear(x, w, "dense")), x, iters=iters)
    record(f"dense_linear.dense.K{K}N{N}", t_dense,
           hbm_bytes=hwmodel.dense_hbm_traffic(T=T, K=K, N=N, dense=True),
           bins=None, bits=None, groups=None)
    for label, p, impl in (
        ("dequant", shared, "dequant"),
        ("kernel", shared, "kernel"),
        ("kernel_packed", packed, "kernel"),
        ("kernel_g8", grouped, "kernel"),
        ("pas_kernel", shared, "pas_kernel"),
    ):
        t = time_us(jax.jit(lambda x, p=p, i=impl: L.linear(x, p, i)), x,
                    iters=iters)
        record(
            f"dense_linear.{label}.K{K}N{N}", t,
            f"vs dense {t / t_dense:.2f}x",
            hbm_bytes=hwmodel.dense_hbm_traffic(
                T=T, K=K, N=N, bins=p.bins, groups=p.groups, packed=p.packed
            ),
            bins=p.bins, bits=p.bits, groups=p.groups,
            compression_ratio=round(p.compression_ratio, 3),
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing: small measured shapes, few iterations")
    ap.add_argument("--json", nargs="?", const="BENCH_dense.json", default=None,
                    metavar="PATH", help="also write rows to a JSON file "
                    "(default BENCH_dense.json)")
    args = ap.parse_args()
    print("name,us_per_call,hbm_bytes,derived")
    dense_layer_byte_rows()
    linear_formulation_rows(smoke=args.smoke)
    if args.json:
        payload = {
            "benchmark": "dense",
            "smoke": bool(args.smoke),
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "devices": 1,
            "records": _RECORDS,
        }
        Path(args.json).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {len(_RECORDS)} records to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
