"""Beyond-paper: PASM weight-byte accounting + matmul formulation timings.

The TPU-relevant win of PASM is the HBM weight-traffic reduction in
bandwidth-bound regimes (DESIGN.md §2).  This benchmark reports, per layer
shape, the bytes a decode step must move under dense-bf16 vs PASM-uint8 vs
PASM-int4 storage, the implied v5e memory-roofline time, and measured
wall-times of the dequant (weight-shared) and PAS (paper-faithful)
formulations on this host.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pas, pasm
from repro.kernels import ops
from repro.roofline import HBM_BW

from benchmarks.common import emit, time_us

SHAPES = [
    ("qwen3.ffn", 5120, 25_600),
    ("kimi.expert", 7168, 2048),
    ("stablelm.attn", 2560, 2560),
]


def weight_bytes_table():
    for name, K, N in SHAPES:
        dense = K * N * 2
        u8 = K * N + 16 * 4
        i4 = K * N // 2 + 16 * 4
        emit(
            f"pasm_bytes.{name}",
            0.0,
            f"dense={dense} uint8={u8} int4={i4} "
            f"roofline_us dense={dense / HBM_BW * 1e6:.1f} int4={i4 / HBM_BW * 1e6:.1f} "
            f"(4.0x memory-term reduction)",
        )


def matmul_formulations():
    """Measured: dense vs dequant(weight-shared) vs PAS-histogram (M=8 decode-ish)."""
    K, N, M = 1024, 1024, 8
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    t16 = pasm.quantize(w, bins=16)
    dense = jax.jit(lambda x: x @ w)
    dequant = jax.jit(lambda x: pas.weight_shared_matmul(x, t16))
    pas_form = jax.jit(lambda x: pas.pasm_matmul(x, t16))
    t_d = time_us(dense, x)
    t_q = time_us(dequant, x)
    t_p = time_us(pas_form, x, iters=5)
    emit("pasm_matmul.dense", t_d)
    emit("pasm_matmul.dequant", t_q, f"vs dense {t_q / t_d:.2f}x")
    emit(
        "pasm_matmul.pas_histogram",
        t_p,
        f"vs dense {t_p / t_d:.2f}x (B x FLOPs — the measured DESIGN.md trade-off)",
    )


def kernel_oracle_check():
    """The fused kernel (interpret) agrees with its oracle at bench shapes."""
    from repro.kernels import ref

    K, N, M = 512, 256, 16
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    t = pasm.quantize(w, bins=16)
    got = ops.pasm_matmul(x, t, interpret=True)
    want = ref.pasm_matmul_ref(x, t.idx, t.codebook, packed=t.packed)
    err = float(jnp.abs(got - want).max())
    emit("pasm_kernel.allclose", 0.0, f"max_err={err:.2e}")
