"""Render the 40-cell roofline table from saved dry-run artifacts."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

_FINAL = Path("experiments/dryrun_final")
DRYRUN_DIR = _FINAL if _FINAL.exists() else Path("experiments/dryrun")


def roofline_summary():
    if not DRYRUN_DIR.exists():
        emit("roofline.table", 0.0, "no dry-run artifacts (run repro.launch.dryrun --all)")
        return
    rows = []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        d = json.loads(f.read_text())
        if d.get("status") == "skipped":
            emit(f"roofline.{f.stem}", 0.0, f"SKIP ({d['reason'][:40]})")
            continue
        if d.get("status") == "error" or "compute_s" not in d:
            emit(f"roofline.{f.stem}", 0.0, "ERROR")
            continue
        terms = {
            "compute": d["compute_s"],
            "memory": d["memory_s"],
            "collective": d["collective_s"],
        }
        bound = max(terms, key=terms.get)
        step = max(terms.values())
        ideal = d["model_flops"] / d["n_devices"] / 197e12
        frac = ideal / max(step, 1e-30)
        emit(
            f"roofline.{f.stem}",
            step * 1e6,
            f"bound={bound} frac={frac:.3f} c={d['compute_s']*1e3:.1f}ms "
            f"m={d['memory_s']*1e3:.1f}ms x={d['collective_s']*1e3:.1f}ms",
        )
