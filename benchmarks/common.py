"""Benchmark harness helpers: wall-clock timing of jitted callables."""
from __future__ import annotations

import time

import jax


def time_us(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Best (min) wall time per call in µs (blocks on device results).

    Warmup runs absorb compilation and cache fill; the timed repeats then
    take the *minimum*, the standard low-noise latency estimator — scheduler
    preemption and allocator hiccups only ever ADD time, so min-of-N
    converges on the true cost where a median can still rank configurations
    by noise (the seed's ``conv.weight_shared.B8`` (30µs) < ``B4`` (69µs)
    inversion in BENCH_conv.json).
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def emit(name: str, us_per_call: float, derived: str = "", hbm_bytes=None) -> None:
    hbm = "" if hbm_bytes is None else str(hbm_bytes)
    print(f"{name},{us_per_call:.2f},{hbm},{derived}")
