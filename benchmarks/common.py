"""Benchmark harness helpers: wall-clock timing of jitted callables."""
from __future__ import annotations

import time

import jax


def time_us(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall time per call in µs (blocks on device results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
