"""Benchmark harness helpers: wall-clock timing of jitted callables, plus the
one row schema every BENCH_*.json record uses — each row carries the device
count and mesh shape it ran under, so cross-run trajectories stay comparable
when a later run changes the device configuration."""
from __future__ import annotations

import time

import jax


def time_us(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Best (min) wall time per call in µs (blocks on device results).

    Warmup runs absorb compilation and cache fill; the timed repeats then
    take the *minimum*, the standard low-noise latency estimator — scheduler
    preemption and allocator hiccups only ever ADD time, so min-of-N
    converges on the true cost where a median can still rank configurations
    by noise (the seed's ``conv.weight_shared.B8`` (30µs) < ``B4`` (69µs)
    inversion in BENCH_conv.json).
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def emit(name: str, us_per_call: float, derived: str = "", hbm_bytes=None) -> None:
    hbm = "" if hbm_bytes is None else str(hbm_bytes)
    print(f"{name},{us_per_call:.2f},{hbm},{derived}")


def bench_row(
    name: str,
    us_per_call: float,
    *,
    hbm_bytes=None,
    derived: str = "",
    mesh_shape=None,
    engine=None,
    pool=None,
    **extra,
) -> dict:
    """One BENCH_*.json record.  ``devices``/``mesh_shape`` are always
    present: single-device rows record ``devices=1, mesh_shape=None``,
    sharded rows the mesh they ran on — without them a ``--devices 8`` run
    would be indistinguishable from a single-device regression in the
    cross-run trajectory.  ``engine``/``pool`` are likewise always present
    (``None`` when not applicable): the fused-pool rows are only comparable
    to their unfused counterparts when both record which conv2d engine ran
    and whether the max-pool was folded into the kernel (``pool > 1``)."""
    n_dev = 1
    if mesh_shape is not None:
        for s in mesh_shape:
            n_dev *= int(s)
    row = {
        "name": name,
        "us_per_call": us_per_call,
        "hbm_bytes": hbm_bytes,
        "derived": derived,
        "devices": n_dev,
        "mesh_shape": list(mesh_shape) if mesh_shape is not None else None,
        "engine": engine,
        "pool": pool,
    }
    row.update(extra)
    return row
