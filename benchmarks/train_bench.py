"""Measured QAT training trajectory + chaos drill (BENCH_train.json).

The training-side counterpart of serve_bench's fault rows (DESIGN.md §4):
the AlexNet-smoke QAT loop (STE through per-layer conv dictionaries,
train/step.py::make_cnn_train_step) runs on the step-addressed synthetic
image stream and emits:

- ``train.qat.alexnet_smoke`` — the fault-free reference: median step wall
  time plus the held-out eval loss before/after training (``loss_drop`` —
  scored on one fixed batch, since per-step training losses are too noisy
  to compare), the row CI tracks across PRs;
- ``train.fault.resume_bitexact`` — an injected ``crash`` (post-update,
  pre-checkpoint — the worst kill point) under ``ft.Supervisor`` with the
  CRC-verified checkpoint manager: the merged per-step losses and the final
  params of the crashed-and-resumed run are compared **bit-exactly**
  (``np.array_equal``) against the uninterrupted reference — the row stamps
  ``resume_bitexact`` and ci.sh gates on it;
- ``train.fault.ckpt_fallback`` — the newest checkpoint's shard is
  byte-flipped on disk; ``restore_latest`` must *fall back* to the previous
  step that passes CRC (stamps ``fallback_ok``/``from_step``/``to_step`` —
  the second ci.sh gate);
- ``train.qat.faults`` (``--faults``) — the full seeded
  ``TrainFaultPlan.sample`` chaos drill (nan/spike/ckpt-io/data-io/crash/
  slow) under the supervisor: counts guard skips, checkpoint-save failures,
  absorbed data retries and restarts, asserting the run still completes.

``--devices N`` reruns everything on N host-platform fake devices with the
conv stack sharded over a ``("data", "model")`` mesh (``(N//2, 2)``) — the
flag is peeked off ``sys.argv`` before jax initializes.  All faults are
virtual (seeded, step-keyed, zero wall clock), so rows are reproducible.

    PYTHONPATH=src python benchmarks/train_bench.py [--smoke] [--json [PATH]]
                                                    [--faults] [--devices N]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))
sys.path.insert(0, str(_ROOT))


def _peek_devices(argv):
    """--devices N / --devices=N, read before argparse (and before jax)."""
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith("--devices="):
            return a.split("=", 1)[1]
    return None


_dev_arg = _peek_devices(sys.argv)
if _dev_arg is not None:
    try:
        _n = int(_dev_arg)
    except ValueError:
        _n = 0
    if _n > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={_n}"
        )

import jax  # noqa: E402  (after the XLA_FLAGS pin)
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import bench_row  # noqa: E402
from repro import ft  # noqa: E402
from repro.ckpt import checkpoint as ckpt  # noqa: E402
from repro.configs.alexnet_conv import smoke_config  # noqa: E402
from repro.data.pipeline import DataConfig, synthetic_image_batch  # noqa: E402
from repro.launch.mesh import make_conv_mesh  # noqa: E402
from repro.models import cnn  # noqa: E402
from repro.train import optimizer as opt  # noqa: E402
from repro.train import step as step_mod  # noqa: E402
from repro.train.faults import TrainFaultPlan, TrainFaultSpec  # noqa: E402
from repro.train.loop import run_loop  # noqa: E402

_RECORDS: list = []


def record(row: dict) -> None:
    _RECORDS.append(row)
    extras = {k: v for k, v in row.items()
              if k not in ("name", "us_per_call", "hbm_bytes", "derived",
                           "devices", "mesh_shape", "engine", "pool")}
    print(f"{row['name']},{row['us_per_call']:.2f},,{extras}")


def _init(cfg, ocfg, seed: int, mesh):
    params = cnn.init_params(cfg, jax.random.PRNGKey(seed))
    tree = {"params": params, "codebooks": cnn.qat_codebooks(params, cfg)}
    opt_state = opt.init_opt_state(tree)
    train_step = jax.jit(
        step_mod.make_cnn_train_step(cfg, ocfg, mesh=mesh)
    )
    return tree, opt_state, train_step


def _batch_fn(dcfg, cfg):
    return lambda s: synthetic_image_batch(
        dcfg, s, chw=cfg.in_chw, classes=cfg.classes, noise=0.1
    )


def _median_us(step_times: dict) -> float:
    ts = sorted(step_times.values())
    return ts[len(ts) // 2] * 1e6 if ts else 0.0


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fewer steps (CI)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-every", type=int, default=None)
    ap.add_argument("--faults", action="store_true",
                    help="also run the full sampled chaos drill")
    ap.add_argument("--devices", type=int, default=1,
                    help="fake host devices; >1 shards the conv stack")
    ap.add_argument("--json", nargs="?", const="BENCH_train.json", default=None,
                    metavar="PATH", help="write rows (default BENCH_train.json)")
    args = ap.parse_args(argv)

    steps = args.steps or (12 if args.smoke else 24)
    ckpt_every = args.ckpt_every or max(steps // 4, 1)
    cfg = smoke_config()
    # lr/noise picked so the held-out eval loss FALLS within a smoke run
    # (weight decay off: this tiny stack is under- not over-parameterised)
    ocfg = opt.AdamWConfig(lr=3e-4, weight_decay=0.0, total_steps=steps,
                           warmup_steps=2)
    dcfg = DataConfig(seed=args.seed, vocab=2, seq_len=1, global_batch=args.batch)
    mesh = None
    mesh_shape = None
    if args.devices > 1:
        if args.devices != len(jax.devices()):
            raise SystemExit(
                f"--devices {args.devices} but {len(jax.devices())} visible "
                f"(the flag must be first on the command line? it is peeked "
                f"pre-import — check XLA_FLAGS)"
            )
        mesh_shape = (args.devices // 2, 2) if args.devices % 2 == 0 else (args.devices, 1)
        mesh = make_conv_mesh(mesh_shape)
    batch_fn = _batch_fn(dcfg, cfg)
    tag_mesh = dict(mesh_shape=mesh_shape)

    # ---- fault-free reference trajectory --------------------------------
    # progress is scored on a FIXED held-out batch (per-step training losses
    # are one-noisy-batch-each — too high-variance to compare across runs)
    eval_batch = batch_fn(10**6)
    eval_loss = jax.jit(
        lambda t: step_mod.cnn_qat_loss(t, eval_batch, cfg, mesh=mesh)
    )
    tree, opt_state, train_step = _init(cfg, ocfg, args.seed, mesh)
    loss_first = float(eval_loss(tree))
    ref = run_loop(train_step, (tree, opt_state), batch_fn, steps=steps)
    loss_last = float(eval_loss(ref.state[0]))
    record(bench_row(
        "train.qat.alexnet_smoke", _median_us(ref.step_times), **tag_mesh,
        steps=steps, batch=args.batch, loss_first=loss_first,
        loss_last=loss_last, loss_drop=loss_first - loss_last,
    ))
    print(f"[train_bench] fault-free: eval loss {loss_first:.4f} -> "
          f"{loss_last:.4f} over {steps} steps", file=sys.stderr)

    with tempfile.TemporaryDirectory() as tmp:
        # ---- crash + restore: bit-exact resume --------------------------
        crash_step = steps - max(steps // 3, 2)  # past the first checkpoint
        plan = TrainFaultPlan([TrainFaultSpec("crash", step=crash_step)])
        mgr = ckpt.CheckpointManager(Path(tmp) / "resume", keep=3)
        tree, opt_state, train_step = _init(cfg, ocfg, args.seed, mesh)
        losses: dict = {}
        times: dict = {}
        state_box = {"state": (tree, opt_state), "restarts_resumed_at": []}
        sup = ft.Supervisor(ft.RestartPolicy(max_restarts=2, backoff_s=0.0),
                            sleep=lambda _d: None)

        def loop(resume_step):
            t, o = state_box["state"]
            start = 0
            if ckpt.latest_step(mgr.dir) is not None:
                (t, o), man = mgr.restore_latest((t, o))
                start = man["step"]
                state_box["restarts_resumed_at"].append(start)
            res = run_loop(
                train_step, (t, o), batch_fn, steps=steps, start_step=start,
                mgr=mgr, ckpt_every=ckpt_every, faults=plan,
                losses=losses, step_times=times,
            )
            state_box["state"] = res.state
            return res.last_step

        sup.run(loop)
        bitexact = (
            set(losses) == set(ref.losses)
            and all(losses[s] == ref.losses[s] for s in ref.losses)
            and _trees_equal(state_box["state"][0], ref.state[0])
        )
        record(bench_row(
            "train.fault.resume_bitexact", _median_us(times), **tag_mesh,
            steps=steps, crash_step=crash_step, restarts=sup.restarts,
            resumed_at=state_box["restarts_resumed_at"],
            resume_bitexact=bool(bitexact),
        ))
        print(f"[train_bench] crash@{crash_step}: restarts={sup.restarts} "
              f"resumed_at={state_box['restarts_resumed_at']} "
              f"bitexact={bitexact}", file=sys.stderr)

        # ---- corrupt-latest checkpoint: CRC fallback --------------------
        fb_steps = ckpt.complete_steps(mgr.dir)
        from_step = fb_steps[-1]
        shard = Path(mgr.dir) / f"step_{from_step}" / "shard_0.npz"
        raw = bytearray(shard.read_bytes())
        raw[len(raw) // 2] ^= 0xFF
        shard.write_bytes(bytes(raw))
        import warnings as _w
        with _w.catch_warnings():
            _w.simplefilter("ignore")
            try:
                (_t, _o), man = mgr.restore_latest((tree, opt_state))
                to_step = man["step"]
                fallback_ok = to_step == fb_steps[-2] if len(fb_steps) > 1 else False
            except ckpt.CheckpointCorruptError:
                to_step, fallback_ok = None, False
        record(bench_row(
            "train.fault.ckpt_fallback", 0.0, **tag_mesh,
            from_step=from_step, to_step=to_step, fallback_ok=bool(fallback_ok),
            on_disk_steps=fb_steps,
        ))
        print(f"[train_bench] corrupt step_{from_step}: fell back to "
              f"step_{to_step} ok={fallback_ok}", file=sys.stderr)

        # ---- full sampled chaos drill -----------------------------------
        if args.faults:
            plan = TrainFaultPlan.sample(
                args.seed, n_steps=steps, n_slow=1, slow_delay_s=0.05,
            )
            mgr = ckpt.CheckpointManager(Path(tmp) / "chaos", keep=3)
            tree, opt_state, train_step = _init(cfg, ocfg, args.seed, mesh)
            losses, times = {}, {}
            state_box = {"state": (tree, opt_state)}
            counters = {"skipped": 0, "ckpt_failures": 0}
            sup = ft.Supervisor(ft.RestartPolicy(max_restarts=3, backoff_s=0.0),
                                sleep=lambda _d: None)

            def chaos_loop(resume_step):
                t, o = state_box["state"]
                start = 0
                if ckpt.latest_step(mgr.dir) is not None:
                    (t, o), man = mgr.restore_latest((t, o))
                    start = man["step"]
                res = run_loop(
                    train_step, (t, o), batch_fn, steps=steps,
                    start_step=start, mgr=mgr, ckpt_every=ckpt_every,
                    faults=plan, losses=losses, step_times=times,
                )
                state_box["state"] = res.state
                counters["skipped"] += res.n_skipped
                counters["ckpt_failures"] += res.n_ckpt_failures
                return res.last_step

            import warnings as _w2
            with _w2.catch_warnings():
                _w2.simplefilter("ignore")
                last = sup.run(chaos_loop)
            assert last == steps, (last, steps)
            record(bench_row(
                "train.qat.faults", _median_us(times), **tag_mesh,
                steps=steps, n_injections=len(plan.fired),
                fired=[f[0] for f in plan.fired],
                n_skipped=counters["skipped"],
                n_ckpt_failures=counters["ckpt_failures"],
                restarts=sup.restarts,
                loss_last=losses[steps - 1],
            ))
            print(f"[train_bench] chaos: {len(plan.fired)} injections "
                  f"({[f[0] for f in plan.fired]}), {counters['skipped']} "
                  f"guard skips, {counters['ckpt_failures']} ckpt failures, "
                  f"{sup.restarts} restarts — completed", file=sys.stderr)

    if args.json:
        payload = {
            "benchmark": "train",
            "smoke": bool(args.smoke),
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "devices": len(jax.devices()) if mesh is not None else 1,
            "seed": args.seed,
            "steps": steps,
            "faults": bool(args.faults),
            "records": _RECORDS,
        }
        Path(args.json).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {len(_RECORDS)} records to {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
