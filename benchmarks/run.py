"""Benchmark runner: one function per paper table/figure + beyond-paper.

Prints ``name,us_per_call,hbm_bytes,derived`` CSV rows (0.0 µs = analytical artifact).

    PYTHONPATH=src python -m benchmarks.run [--only substring]
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from benchmarks import conv_bench, paper_figures, pasm_roofline, roofline_table  # noqa: E402

BENCHES = [
    ("fig7_8", paper_figures.fig7_8_standalone_pasm),
    ("fig9_10", paper_figures.fig9_10_bins_sweep),
    ("fig14", paper_figures.fig14_latency),
    ("fig15_18", paper_figures.fig15_18_asic_accel),
    ("fig19_22", paper_figures.fig19_22_fpga_accel),
    ("table2", paper_figures.table2_macops),
    ("conv_latency", conv_bench.conv_variants_latency),
    # interpret-mode on CPU: smoke sizing; run conv_bench.py directly on TPU
    ("conv_batched", lambda: conv_bench.batched_conv_latency(smoke=True)),
    ("cnn_forward", lambda: conv_bench.cnn_forward_latency(smoke=True)),
    ("pasm_bytes", pasm_roofline.weight_bytes_table),
    ("pasm_matmul", pasm_roofline.matmul_formulations),
    ("pasm_kernel", pasm_roofline.kernel_oracle_check),
    ("roofline", roofline_table.roofline_summary),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    print("name,us_per_call,hbm_bytes,derived")
    for name, fn in BENCHES:
        if args.only and args.only not in name:
            continue
        fn()


if __name__ == "__main__":
    main()
