"""Measured latency of the conv-accelerator variants (paper §5 analog).

Two tiers:

* ``conv_variants_latency`` — the paper's own §4 single-image configuration,
  all three einsum ports.  On TPU hardware the PASM variant's +N→N+B latency
  shows up per §4; on this CPU container we confirm (a) numerical agreement
  and (b) the relative cost ordering — the PAS-histogram formulation costs
  ≈B× the MACs of the direct product, exactly the DESIGN.md §2 trade-off.

* ``batched_conv_latency`` / ``cnn_forward_latency`` — the production shape
  of the same workload (DESIGN.md §3): batched convs on the Pallas GEMMs at
  realistic AlexNet layer sizes (224×224×3→96, 27×27×96→256) with the
  bias/ReLU epilogue fused into the kernels, comparing the einsum port
  against ``pasm_matmul`` (explicit im2col), ``pasm_conv2d``
  (``kernel_implicit`` — implicit im2col, no patch matrix in HBM),
  ``pas_matmul`` (paper-faithful two-phase), and the **fused
  conv/ReLU/max-pool stage** (``conv.batched.kernel_implicit_pool.*`` —
  ``conv2d(pool=2)``, one pallas_call storing only the pooled map).  Every
  row carries a modeled ``hbm_bytes`` column — tile-plan aware
  (``ops.conv_hbm_bytes``) for the Pallas engines, the analytic
  ``hwmodel.conv_hbm_traffic`` (dense f32 weight stream) for the einsum
  rows — plus the ``engine``/``pool`` stamps, so fused and unfused rows
  stay comparable.  Every row also stamps ``slab_rows``/``n_slabs`` — the
  row-band slab plan the implicit engine uses at that layer shape
  (``n_slabs == 1`` → whole image VMEM-resident); the over-budget
  ``bigimg_conv1`` layer (3×512×512, double-buffered residency ≈ 6.3 MB >
  the 6 MiB budget) records ``n_slabs >= 2`` and strictly fewer implicit
  than explicit modeled bytes — the ci.sh slab gate.  On CPU the kernels
  run in interpret mode, so the *bytes* column is the hardware-meaningful
  trajectory signal and µs only compares formulations on equal footing
  (``--smoke`` shrinks batch/iters for CI).

``--json [PATH]`` additionally writes every row to ``BENCH_conv.json`` so CI
tracks the engine trajectory from this PR onward; ``--engine e1,e2`` runs
*only* the batched suite restricted to those engines (the CI comparison mode
that gates implicit-vs-explicit modeled HBM bytes).

``--devices N`` runs the *sharded* suite instead, on N host-platform fake
devices (the flag must be seen before jax initializes, so it is peeked off
``sys.argv`` below): every conv layer dispatches through ``conv2d(mesh=)``
over a ``(N, 1)`` data mesh, and each row reports per-device throughput
(``img/s/dev``) plus the modeled **per-device** HBM bytes
(``ops.conv_hbm_bytes(shards=)``) next to the single-device figure
(``hbm_bytes_1dev``) — the CI gate asserts per-device < single-device on
AlexNet conv1.

    PYTHONPATH=src python benchmarks/conv_bench.py [--smoke] [--json [PATH]]
                                                   [--engine e1,e2]
                                                   [--devices N]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))
sys.path.insert(0, str(_ROOT))  # direct-script runs: make `benchmarks` importable

def _peek_devices(argv):
    """--devices N / --devices=N, read before argparse (and before jax)."""
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith("--devices="):
            return a.split("=", 1)[1]
    return None


_dev_arg = _peek_devices(sys.argv)
if _dev_arg is not None:
    # the fake-device count must be pinned BEFORE the first jax import;
    # invalid values (non-int, < 1) are left for the argparse check below
    # rather than crashing deep inside CPU-backend init
    try:
        if int(_dev_arg) >= 1:
            os.environ["XLA_FLAGS"] = (
                f"--xla_force_host_platform_device_count={int(_dev_arg)} "
                + os.environ.get("XLA_FLAGS", "")
            )
            os.environ.setdefault("JAX_PLATFORMS", "cpu")
    except ValueError:
        pass

import jax
import jax.numpy as jnp

from repro.configs.alexnet_conv import PAPER_SPEC
from repro.core import conv as cv
from repro.core import hwmodel as hw
from repro.kernels import ops

from benchmarks.common import bench_row, emit, time_us

# the ISSUE's realistic layer sizes: AlexNet conv1 and conv2 (geometry-free
# specs; the image dims ride with the inputs), plus a conv1-style layer on a
# 512×512 image whose double-buffered residency (2·3·512·512·4 ≈ 6.3 MB)
# overflows the 6 MiB VMEM budget — the implicit engine streams it as
# row-band slabs (n_slabs ≥ 2 in the row stamps; the ci.sh slab gate)
REALISTIC_LAYERS = (
    ("alexnet_conv1", cv.Conv2D(k=11, c_in=3, c_out=96, stride=4, relu=True),
     (224, 224)),
    ("alexnet_conv2", cv.Conv2D(k=5, c_in=96, c_out=256, stride=1, relu=True),
     (27, 27)),
    ("bigimg_conv1", cv.Conv2D(k=11, c_in=3, c_out=96, stride=4, relu=True),
     (512, 512)),
)

PAPER_CONV = cv.Conv2D(k=(PAPER_SPEC.KY, PAPER_SPEC.KX), c_in=PAPER_SPEC.C,
                       c_out=PAPER_SPEC.M, stride=PAPER_SPEC.stride)

BATCH_ENGINES = ("einsum", "kernel", "kernel_implicit", "pas_kernel")

_RECORDS: list = []


def record(name: str, us_per_call: float, derived: str = "", hbm_bytes=None,
           mesh_shape=None, **extra) -> None:
    emit(name, us_per_call, derived, hbm_bytes=hbm_bytes)
    _RECORDS.append(bench_row(name, us_per_call, hbm_bytes=hbm_bytes,
                              derived=derived, mesh_shape=mesh_shape, **extra))


def _slab_info(t_gemm, geom, ih, iw) -> dict:
    """``slab_rows``/``n_slabs`` row stamps: the row-band slab plan the
    implicit engine uses at this layer shape under the default VMEM budget
    (``n_slabs == 1`` → whole-image resident; the ci.sh slab gate asserts
    the over-budget bigimg rows stream with ``n_slabs >= 2``)."""
    (plh, phh), (plw, phw) = geom.pad
    hp, wp = ih + plh + phh, iw + plw + phw
    K, N = t_gemm.shape
    G, B = t_gemm.codebook.shape
    bm, bn, bk, _ = ops._pick_blocks(geom.P_rows, K, N, K // G, t_gemm.packed)
    bm = ops._pool_bm(bm, geom.pool)
    plan = ops.conv_slab_plan(geom, hp, wp, bm=bm, bn=bn, bk=bk, bins=B,
                              packed=t_gemm.packed)
    return {"slab_rows": plan.band_rows, "n_slabs": plan.n_slabs}


def _analytic_hbm(conv, ih, iw, batch, *, bins=16, implicit=False,
                  dense=False, pool=1):
    """`hwmodel.conv_hbm_traffic` on a Conv2D spec — the plan-free model that
    fills rows the tile-aware `ops.conv_hbm_bytes` cannot describe (einsum
    streams dense f32 weights, not indexed operands)."""
    geom = cv.conv_geom(conv, ih, iw)
    (plh, phh), (plw, phw) = geom.pad
    return hw.conv_hbm_traffic(
        IH=ih, IW=iw, C=conv.c_in, KY=conv.ky, KX=conv.kx, M=conv.c_out,
        stride=conv.stride, batch=batch, bins=bins, pad=(plh, phh, plw, phw),
        act_bytes=4, packed=False, implicit=implicit, pool=pool, dense=dense,
    )


def conv_variants_latency():
    key = jax.random.PRNGKey(0)
    img = jax.random.normal(key, (PAPER_SPEC.C, PAPER_SPEC.IH, PAPER_SPEC.IW))
    kern = jax.random.normal(
        jax.random.PRNGKey(1),
        (PAPER_SPEC.M, PAPER_SPEC.C, PAPER_SPEC.KY, PAPER_SPEC.KX),
    )
    hbm_dense = _analytic_hbm(PAPER_CONV, PAPER_SPEC.IH, PAPER_SPEC.IW, 1,
                              dense=True)
    for bins in (4, 8, 16):
        p = cv.ConvParams.quantize(kern, bins)
        dense = cv.ConvParams.dense(p.codebook[p.idx.astype(jnp.int32)])
        f_direct = jax.jit(lambda i, d=dense: cv.conv2d(i, d, PAPER_CONV))
        f_ws = jax.jit(lambda i, p=p: cv.conv2d(i, p, PAPER_CONV, engine="einsum"))
        f_pasm = jax.jit(lambda i, p=p: cv.conv2d(i, p, PAPER_CONV, engine="pas_einsum"))
        t_d = time_us(f_direct, img)
        t_w = time_us(f_ws, img)
        t_p = time_us(f_pasm, img)
        hbm_ws = _analytic_hbm(PAPER_CONV, PAPER_SPEC.IH, PAPER_SPEC.IW, 1,
                               bins=bins)
        slab = _slab_info(p.gemm_tensor(PAPER_CONV.layout),
                          cv.conv_geom(PAPER_CONV, PAPER_SPEC.IH, PAPER_SPEC.IW),
                          PAPER_SPEC.IH, PAPER_SPEC.IW)
        record(f"conv.direct.B{bins}", t_d, hbm_bytes=hbm_dense,
               engine="einsum", pool=1, **slab)
        record(f"conv.weight_shared.B{bins}", t_w, hbm_bytes=hbm_ws,
               engine="einsum", pool=1, **slab)
        record(f"conv.pasm.B{bins}", t_p, f"pasm/ws={t_p / max(t_w, 1e-9):.2f}",
               hbm_bytes=hbm_ws, engine="pas_einsum", pool=1, **slab)


def batched_conv_latency(smoke: bool = False, engines=BATCH_ENGINES):
    """Realistic layers, batched: einsum vs kernel vs kernel_implicit vs pas.

    Each row carries the tile-plan-aware modeled HBM bytes of its dataflow —
    explicit engines pay the materialized-patch-matrix write+read, implicit
    streams the padded image once per reuse window.
    """
    batch = 1 if smoke else 8
    iters = 1 if smoke else 5
    warmup = 1 if smoke else 2
    for name, conv, (ih, iw) in REALISTIC_LAYERS:
        imgs = jax.random.normal(jax.random.PRNGKey(2), (batch, conv.c_in, ih, iw))
        kern = jax.random.normal(
            jax.random.PRNGKey(3), (conv.c_out, conv.c_in, conv.ky, conv.kx)
        ) * conv.K ** -0.5
        params = cv.ConvParams.quantize(
            kern, 16, bias=jnp.linspace(-0.1, 0.1, conv.c_out)
        )
        t_gemm = params.gemm_tensor(conv.layout)
        geom = cv.conv_geom(conv, ih, iw)
        oh, ow = cv.conv_out_hw(ih, iw, conv)
        derived = f"P={batch * oh * ow} K={conv.K} M={conv.c_out}"
        slab = _slab_info(t_gemm, geom, ih, iw)

        for engine in engines:
            if engine == "pas_kernel" and smoke and (conv.K > 1000
                                                     or geom.P > 8000):
                # no silent caps: the one-hot PAS formulation costs B× the
                # MACs — conv2's K=2400 (or bigimg's P=15876 rows) is
                # minutes in interpret mode
                print(f"# skipped conv.batched.pas_kernel.{name}: K={conv.K} "
                      f"P={geom.P} too large for CI smoke (interpret mode)",
                      file=sys.stderr)
                continue
            # the tile-aware model describes the Pallas-kernel dataflows; the
            # XLA einsum port streams dense f32 weights over an explicit
            # patch matrix, which the analytic hwmodel covers (dense=True)
            if engine == "einsum":
                hbm = _analytic_hbm(conv, ih, iw, batch, dense=True)
            else:
                hbm = ops.conv_hbm_bytes(
                    t_gemm, geom, batch, ih, iw,
                    implicit=engine == "kernel_implicit", act_bytes=4,
                )
            f = jax.jit(lambda i, p=params, c=conv, e=engine:
                        cv.conv2d(i, p, c, engine=e))
            t = time_us(f, imgs, iters=iters, warmup=warmup)
            record(f"conv.batched.{engine}.{name}.bs{batch}", t, derived,
                   hbm_bytes=hbm, engine=engine, pool=1, **slab)

        if "kernel_implicit" in engines:
            # the fused conv/ReLU/max-pool stage (PR 5): ONE pallas_call,
            # pooled in-kernel — the AlexNet pool=2 window of both layers
            pool = 2
            geom_p = cv.conv_geom(conv, ih, iw, pool=pool)
            hbm_p = ops.conv_hbm_bytes(t_gemm, geom_p, batch, ih, iw,
                                       implicit=True, act_bytes=4)
            f = jax.jit(lambda i, p=params, c=conv, q=pool:
                        cv.conv2d(i, p, c, engine="kernel_implicit", pool=q,
                                  pool_impl="fused"))
            t = time_us(f, imgs, iters=iters, warmup=warmup)
            record(f"conv.batched.kernel_implicit_pool.{name}.bs{batch}", t,
                   f"{derived} pool={pool}", hbm_bytes=hbm_p,
                   engine="kernel_implicit", pool=pool,
                   **_slab_info(t_gemm, geom_p, ih, iw))


def sharded_conv_latency(
    n_devices: int, smoke: bool = False, engines=("kernel_implicit",)
):
    """Realistic layers through ``conv2d(mesh=)`` on an ``(N, 1)`` data mesh.

    One image per device at smoke scale (4 per device otherwise), so the
    per-device work matches the single-device smoke row.  Each row carries
    per-device throughput (``img/s/dev`` — wall time covers all shards, so
    device-seconds are ``t·N``) and the modeled per-device HBM bytes
    alongside the single-device figure for the same global batch.
    """
    from repro.launch.mesh import make_conv_mesh

    mesh = make_conv_mesh((n_devices, 1))
    batch = n_devices * (1 if smoke else 4)
    iters = 1 if smoke else 5
    warmup = 1 if smoke else 2
    for name, conv, (ih, iw) in REALISTIC_LAYERS:
        imgs = jax.random.normal(jax.random.PRNGKey(2), (batch, conv.c_in, ih, iw))
        kern = jax.random.normal(
            jax.random.PRNGKey(3), (conv.c_out, conv.c_in, conv.ky, conv.kx)
        ) * conv.K ** -0.5
        params = cv.ConvParams.quantize(
            kern, 16, bias=jnp.linspace(-0.1, 0.1, conv.c_out)
        )
        t_gemm = params.gemm_tensor(conv.layout)
        geom = cv.conv_geom(conv, ih, iw)
        slab = _slab_info(t_gemm, geom, ih, iw)
        for engine in engines:
            if engine in ("einsum", "pas_kernel") and smoke and conv.K > 1000:
                print(f"# skipped conv.sharded.{engine}.{name}: K={conv.K} "
                      "too large for CI smoke (interpret mode)", file=sys.stderr)
                continue
            hbm_dev = hbm_1dev = None
            if engine != "einsum":
                kw = dict(implicit=engine == "kernel_implicit", act_bytes=4)
                hbm_dev = ops.conv_hbm_bytes(
                    t_gemm, geom, batch, ih, iw, shards=(n_devices, 1), **kw
                )
                hbm_1dev = ops.conv_hbm_bytes(t_gemm, geom, batch, ih, iw, **kw)
            f = jax.jit(lambda i, p=params, c=conv, e=engine:
                        cv.conv2d(i, p, c, engine=e, mesh=mesh))
            t = time_us(f, imgs, iters=iters, warmup=warmup)
            img_s_dev = batch / n_devices / (t * 1e-6)
            record(
                f"conv.sharded.{engine}.{name}.bs{batch}.d{n_devices}", t,
                f"P={batch * geom.P} K={conv.K} M={conv.c_out} "
                f"img/s/dev={img_s_dev:.1f}",
                hbm_bytes=hbm_dev, mesh_shape=(n_devices, 1),
                hbm_bytes_1dev=hbm_1dev, engine=engine, pool=1, **slab,
            )


def cnn_forward_latency(smoke: bool = True):
    """Full AlexNet-style stack forward on the fused-dequant kernel path."""
    from repro.configs import get_cnn_config
    from repro.models import cnn

    cfg = get_cnn_config("alexnet", smoke=smoke)
    params = cnn.quantize(cnn.init_params(cfg, jax.random.PRNGKey(0)), cfg)
    batch = 2 if smoke else 8
    imgs = jax.random.normal(jax.random.PRNGKey(1), (batch, *cfg.in_chw))
    iters = 1 if smoke else 5
    t = time_us(lambda i: cnn.forward(params, i, cfg), imgs, iters=iters, warmup=1)
    # stack-level modeled bytes: resolve each stage's engine and pool
    # dispatch through cv.conv_plan — the same rule conv2d routes through —
    # so the row never claims a fused (or implicit) dataflow the measured
    # run didn't take
    hbm = 0
    n_slabs = 1  # stack stamp: the worst (max) per-stage slab count
    _, H, W = cfg.in_chw
    for p, (conv, pool) in zip(params["conv"], cnn.stages(cfg)):
        eng, fused = cv.conv_plan(p, conv, H, W, engine=cfg.impl, pool=pool,
                                  pool_impl=cfg.pool_impl,
                                  vmem_budget=cfg.vmem_budget)
        geom = cv.conv_geom(conv, H, W, pool=pool if fused else 1)
        t_gemm = p.gemm_tensor(cfg.layout)
        hbm += ops.conv_hbm_bytes(t_gemm, geom, batch, H, W,
                                  implicit="implicit" in eng, act_bytes=4)
        if "implicit" in eng:
            n_slabs = max(n_slabs, _slab_info(t_gemm, geom, H, W)["n_slabs"])
        if not fused and pool > 1:
            # the separate reduce_window pass: read pre-pool, store pooled
            hbm += batch * conv.c_out * 4 * (
                geom.oh * geom.ow + (geom.oh // pool) * (geom.ow // pool))
        H, W = geom.oh // pool, geom.ow // pool
    record(f"cnn.forward.{cfg.name}.bs{batch}", t, f"layers={len(cfg.layers)}",
           hbm_bytes=hbm, engine=cfg.impl, pool=None,  # per-stage pools vary
           slab_rows=None, n_slabs=n_slabs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing: batch 1-2, single timed iteration")
    ap.add_argument("--json", nargs="?", const="BENCH_conv.json", default=None,
                    metavar="PATH", help="also write rows to a JSON file "
                    "(default BENCH_conv.json)")
    ap.add_argument("--engine", default=None, metavar="E1,E2",
                    help="run ONLY the batched suite, restricted to these "
                    f"conv2d engines (choices: {','.join(BATCH_ENGINES)}) — "
                    "the CI implicit-vs-explicit comparison mode")
    ap.add_argument("--devices", type=int, default=None, metavar="N",
                    help="run ONLY the sharded suite on N host-platform fake "
                    "devices (conv2d(mesh=) over an (N, 1) data mesh); rows "
                    "report per-device throughput and modeled per-device "
                    "HBM bytes")
    args = ap.parse_args()
    engines = None
    if args.engine:
        engines = tuple(e.strip() for e in args.engine.split(",") if e.strip())
        bad = [e for e in engines if e not in BATCH_ENGINES]
        if bad:
            ap.error(f"unknown engine(s) {bad}; choices: {BATCH_ENGINES}")
    print("name,us_per_call,hbm_bytes,derived")
    if args.devices is not None:
        if args.devices < 1:
            ap.error(f"--devices must be >= 1, got {args.devices}")
        if jax.device_count() < args.devices:
            ap.error(f"--devices {args.devices}: only {jax.device_count()} "
                     "devices came up (the XLA_FLAGS peek runs before jax "
                     "init; is another backend pinned?)")
        sharded_conv_latency(args.devices, smoke=args.smoke,
                             engines=engines or ("kernel_implicit",))
    elif engines:
        batched_conv_latency(smoke=args.smoke, engines=engines)
    else:
        conv_variants_latency()
        batched_conv_latency(smoke=args.smoke)
        cnn_forward_latency(smoke=args.smoke)
    if args.json:
        payload = {
            "benchmark": "conv",
            "smoke": bool(args.smoke),
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "devices": args.devices or 1,
            "records": _RECORDS,
        }
        Path(args.json).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {len(_RECORDS)} records to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
