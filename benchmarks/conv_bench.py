"""Measured latency of the three conv-accelerator variants (paper §5 analog).

On TPU hardware the PASM variant's +N→N+B latency shows up per §4; on this
CPU container we measure the jitted JAX ports to confirm (a) all three agree
numerically and (b) the relative cost ordering of the formulations — the
PAS-histogram formulation costs ≈B× the MACs of the direct product, which is
exactly the DESIGN.md §2 trade-off statement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.alexnet_conv import PAPER_SPEC
from repro.core import conv as cv

from benchmarks.common import emit, time_us


def conv_variants_latency():
    spec = PAPER_SPEC
    key = jax.random.PRNGKey(0)
    img = jax.random.normal(key, (spec.C, spec.IH, spec.IW))
    kern = jax.random.normal(jax.random.PRNGKey(1), (spec.M, spec.C, spec.KY, spec.KX))
    for bins in (4, 8, 16):
        cb, idx = cv.quantize_conv_weights(kern, bins)
        direct = jax.jit(lambda i: cv.conv2d_direct(i, cb[idx.astype(jnp.int32)], spec=spec))
        ws = jax.jit(lambda i: cv.conv2d_weight_shared(i, idx, cb, spec=spec))
        pasm = jax.jit(lambda i: cv.conv2d_pasm(i, idx, cb, spec=spec))
        t_d = time_us(direct, img)
        t_w = time_us(ws, img)
        t_p = time_us(pasm, img)
        emit(f"conv.direct.B{bins}", t_d)
        emit(f"conv.weight_shared.B{bins}", t_w)
        emit(f"conv.pasm.B{bins}", t_p, f"pasm/ws={t_p / max(t_w, 1e-9):.2f}")
