"""Measured latency of the conv-accelerator variants (paper §5 analog).

Two tiers:

* ``conv_variants_latency`` — the paper's own §4 single-image configuration,
  all three einsum ports.  On TPU hardware the PASM variant's +N→N+B latency
  shows up per §4; on this CPU container we confirm (a) numerical agreement
  and (b) the relative cost ordering — the PAS-histogram formulation costs
  ≈B× the MACs of the direct product, exactly the DESIGN.md §2 trade-off.

* ``batched_conv_latency`` / ``cnn_forward_latency`` — the production shape
  of the same workload (DESIGN.md §3): batched im2col lowered onto the Pallas
  GEMMs at realistic AlexNet layer sizes (224×224×3→96, 27×27×96→256) and
  the full CNN stack.  On CPU the kernels run in interpret mode, so absolute
  µs are not hardware numbers — the rows exist to exercise the fast path at
  scale and to compare formulations on equal footing (``--smoke`` shrinks
  batch/iters for CI).

    PYTHONPATH=src python benchmarks/conv_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))
sys.path.insert(0, str(_ROOT))  # direct-script runs: make `benchmarks` importable

import jax
import jax.numpy as jnp

from repro.configs.alexnet_conv import PAPER_SPEC
from repro.core import conv as cv

from benchmarks.common import emit, time_us

# the ISSUE's realistic layer sizes: AlexNet conv1 and conv2 under the
# paper's kernel-centred VALID windowing
REALISTIC_LAYERS = (
    ("alexnet_conv1", cv.ConvSpec(IH=224, IW=224, C=3, KY=11, KX=11, M=96, stride=4)),
    ("alexnet_conv2", cv.ConvSpec(IH=27, IW=27, C=96, KY=5, KX=5, M=256, stride=1)),
)


def conv_variants_latency():
    spec = PAPER_SPEC
    key = jax.random.PRNGKey(0)
    img = jax.random.normal(key, (spec.C, spec.IH, spec.IW))
    kern = jax.random.normal(jax.random.PRNGKey(1), (spec.M, spec.C, spec.KY, spec.KX))
    for bins in (4, 8, 16):
        cb, idx = cv.quantize_conv_weights(kern, bins)
        direct = jax.jit(lambda i: cv.conv2d_direct(i, cb[idx.astype(jnp.int32)], spec=spec))
        ws = jax.jit(lambda i: cv.conv2d_weight_shared(i, idx, cb, spec=spec))
        pasm = jax.jit(lambda i: cv.conv2d_pasm(i, idx, cb, spec=spec))
        t_d = time_us(direct, img)
        t_w = time_us(ws, img)
        t_p = time_us(pasm, img)
        emit(f"conv.direct.B{bins}", t_d)
        emit(f"conv.weight_shared.B{bins}", t_w)
        emit(f"conv.pasm.B{bins}", t_p, f"pasm/ws={t_p / max(t_w, 1e-9):.2f}")


def batched_conv_latency(smoke: bool = False):
    """Realistic layers, batched, Pallas kernel path vs the einsum port."""
    batch = 1 if smoke else 8
    iters = 1 if smoke else 5
    warmup = 1 if smoke else 2
    for name, spec in REALISTIC_LAYERS:
        imgs = jax.random.normal(jax.random.PRNGKey(2), (batch, spec.C, spec.IH, spec.IW))
        kern = jax.random.normal(
            jax.random.PRNGKey(3), (spec.M, spec.C, spec.KY, spec.KX)
        ) * (spec.C * spec.KY * spec.KX) ** -0.5
        cb, idx = cv.quantize_conv_weights(kern, 16)
        oh, ow = cv.out_hw(spec)
        derived = f"P={batch * oh * ow} K={spec.C * spec.KY * spec.KX} M={spec.M}"

        def f_kernel(i, idx=idx, cb=cb, spec=spec):
            return cv.conv2d_weight_shared(i, idx, cb, spec=spec, engine="kernel")

        def f_einsum(i, idx=idx, cb=cb, spec=spec):
            return cv.conv2d_weight_shared(i, idx, cb, spec=spec, engine="einsum")

        t_k = time_us(jax.jit(f_kernel), imgs, iters=iters, warmup=warmup)
        t_e = time_us(jax.jit(f_einsum), imgs, iters=iters, warmup=warmup)
        emit(f"conv.batched.pasm_kernel.{name}.bs{batch}", t_k, derived)
        emit(f"conv.batched.einsum.{name}.bs{batch}", t_e, derived)


def cnn_forward_latency(smoke: bool = True):
    """Full AlexNet-style stack forward on the fused-dequant kernel path."""
    from repro.configs import get_cnn_config
    from repro.models import cnn

    cfg = get_cnn_config("alexnet", smoke=smoke)
    params = cnn.quantize(cnn.init_params(cfg, jax.random.PRNGKey(0)), cfg)
    batch = 2 if smoke else 8
    imgs = jax.random.normal(jax.random.PRNGKey(1), (batch, *cfg.in_chw))
    iters = 1 if smoke else 5
    t = time_us(lambda i: cnn.forward(params, i, cfg), imgs, iters=iters, warmup=1)
    emit(f"cnn.forward.{cfg.name}.bs{batch}", t, f"layers={len(cfg.layers)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing: batch 1-2, single timed iteration")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    conv_variants_latency()
    batched_conv_latency(smoke=args.smoke)
    cnn_forward_latency(smoke=args.smoke)


if __name__ == "__main__":
    main()
